"""End-to-end behaviour tests for the xDGP adaptive partitioning system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveConfig, AdaptivePartitioner, initial_partition,
                        make_state, migrate_step, occupancy)
from repro.graph import apply_delta, cut_ratio, generators


@pytest.fixture(scope="module")
def fem():
    return generators.fem_cube(12)            # 1728 nodes


@pytest.fixture(scope="module")
def plc():
    return generators.power_law(1500, seed=2)


def test_adaptive_improves_fem_cut(fem):
    k = 9
    lab = initial_partition(fem, k, "hsh")
    initial = float(cut_ratio(fem, lab))
    part = AdaptivePartitioner(AdaptiveConfig(k=k, max_iters=150, patience=25))
    state, hist = part.run_to_convergence(fem, part.init_state(fem, lab))
    final = float(cut_ratio(fem, state.assignment))
    # paper Fig.5: >0.6 improvement on FEM graphs from hash partitioning
    assert initial > 0.85
    assert initial - final > 0.5, (initial, final)


def test_adaptive_improves_powerlaw_cut(plc):
    k = 9
    lab = initial_partition(plc, k, "hsh")
    initial = float(cut_ratio(plc, lab))
    part = AdaptivePartitioner(AdaptiveConfig(k=k, max_iters=100, patience=20))
    state, _ = part.run_to_convergence(plc, part.init_state(plc, lab))
    final = float(cut_ratio(plc, state.assignment))
    assert final < initial - 0.15                     # improves
    # paper: power-law graphs are harder — final cut stays above FEM levels
    assert final > 0.2


def test_balance_maintained(fem):
    k = 9
    part = AdaptivePartitioner(AdaptiveConfig(k=k, slack=0.1, max_iters=120,
                                              patience=120))
    state = part.init_state(fem, initial_partition(fem, k, "hsh"))
    n = int(fem.num_nodes)
    for _ in range(3):
        state, hist = part.adapt(fem, state, 40)
        occ = np.asarray(occupancy(state, fem.node_mask))
        assert occ.max() <= int(np.ceil(n / k) * 1.1) + 1, occ


def test_capacity_never_exceeded_each_iteration(fem):
    k = 6
    cfg = AdaptiveConfig(k=k, slack=0.15)
    part = AdaptivePartitioner(cfg)
    state = part.init_state(fem, initial_partition(fem, k, "rnd"))
    cap = int(np.asarray(state.capacity)[0])
    for _ in range(30):
        state, _ = part.step(state, fem)
        occ = np.asarray(occupancy(state, fem.node_mask))
        assert occ.max() <= cap, (occ.max(), cap)


def test_deferred_migration_semantics(fem):
    """Decisions at t commit at t+1 (paper §4.2): after one step, assignment
    is unchanged but pending holds the admitted moves."""
    k = 9
    state = make_state(fem, initial_partition(fem, k, "hsh"), k)
    a0 = np.asarray(state.assignment).copy()
    state, stats = migrate_step(state, fem, s=0.5)
    assert int(stats.committed) == 0                 # nothing commits at t=0
    assert np.array_equal(np.asarray(state.assignment), a0)
    assert int(stats.admitted) > 0
    state2, stats2 = migrate_step(state, fem, s=0.5)
    assert int(stats2.committed) == int(stats.admitted)


def test_dynamic_adaptation_recovers(fem):
    """After a forest-fire burst, adaptation returns cut near pre-burst level
    (paper Fig. 7)."""
    k = 9
    g = generators.fem_cube(10, n_cap=1300, e_cap=3600)
    part = AdaptivePartitioner(AdaptiveConfig(k=k, slack=0.35, max_iters=200,
                                              patience=200))
    state = part.init_state(g, initial_partition(g, k, "hsh"))
    state, _ = part.adapt(g, state, 80)
    settled = float(cut_ratio(g, state.assignment))
    delta = generators.forest_fire_delta(g, 0.10, seed=3)
    assert int(jnp.sum(delta.add_mask)) > 0
    g2 = apply_delta(g, delta)
    after_burst = float(cut_ratio(g2, state.assignment))
    state, _ = part.adapt(g2, state, 60)
    recovered = float(cut_ratio(g2, state.assignment))
    assert after_burst > settled               # burst degrades the cut
    assert recovered < after_burst             # adaptation recovers most of it
    assert recovered - settled < 0.35


def test_paper_convergence_criterion_stay_rule(fem):
    """With the paper's literal stay-on-tie rule, migrations reach zero and
    stay zero (the paper's 30-quiet-iteration criterion terminates)."""
    k = 9
    part = AdaptivePartitioner(AdaptiveConfig(k=k, tie_break="stay",
                                              max_iters=300, patience=30))
    state, hist = part.run_to_convergence(
        fem, part.init_state(fem, initial_partition(fem, k, "hsh")))
    assert hist.iterations < 300               # converged before the cap
    assert all(m == 0 for m in hist.migrations[-10:])


def test_seed_determinism(fem):
    k = 9
    outs = []
    for _ in range(2):
        part = AdaptivePartitioner(AdaptiveConfig(k=k, seed=7, max_iters=40,
                                                  patience=40))
        state = part.init_state(fem, initial_partition(fem, k, "hsh"))
        state, _ = part.adapt(fem, state, 40)
        outs.append(np.asarray(state.assignment))
    assert np.array_equal(outs[0], outs[1])
