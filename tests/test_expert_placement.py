"""Beyond-paper expert-placement (xDGP over the co-routing graph)."""
import numpy as np

from repro.core.expert_placement import place_experts


def test_expert_placement_reduces_cross_traffic_and_balances():
    rng = np.random.default_rng(0)
    E, D, T = 32, 4, 20_000
    per = E // D
    # D cliques of experts that co-fire for the same tokens, but scattered
    # across the default block layout by a fixed permutation
    perm = rng.permutation(E)
    clique = rng.integers(0, D, size=T)
    a = perm[clique * per + rng.integers(0, per, T)]
    b = perm[clique * per + rng.integers(0, per, T)]
    choices = np.stack([a, b], axis=1)
    placement, report = place_experts(choices, E, D, adapt_iters=80)
    counts = np.bincount(placement, minlength=D)
    assert (counts == per).all(), counts            # hard balance
    assert report["cross_traffic_after"] < report["cross_traffic_before"], report
    assert report["reduction_pct"] > 30, report
