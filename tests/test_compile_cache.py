"""Compile-cache behavior of the sharded backend (DESIGN.md §10).

The contract under test: a streaming run on the sharded backend compiles
the cluster step at most once per *shape bucket* — zero recompiles after
warmup. ``repro.core.distributed.TRACE_COUNTS["cluster_step"]`` is bumped
inside the jitted step *body*, so it moves only when jit traces (and hence
compiles), never on a cache-hit dispatch; the tests assert directly on it.

Three groups, mirroring tests/test_cluster.py:
  * device-free — the growth policy, the bucket floors, the content
    fingerprint, the new ClusterSection knobs;
  * in-process sharded (skipped below 8 devices) — cache keying across
    ``s``/``tie_break``/shape-bucket changes, the in-place-mutation
    rebuild regression, the probe-rollback invariant;
  * subprocess under 8 fake devices — the end-to-end no-recompile
    property over a streamed run, with local parity re-pinned.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.api import (ClusterSection, DynamicGraphSystem, PartitionSection,
                       StreamSection, SystemConfig, empty_graph)
from repro.api.backend import _graph_fingerprint
from repro.graph import generators
from repro.graph.structure import Graph

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (tier-1-sharded CI runs with fake devices)")


def _run(snippet: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _numpy_graph(g: Graph) -> Graph:
    """Host-array copy of a Graph — the mutable-in-place hazard case."""
    return Graph(src=np.asarray(g.src).copy(), dst=np.asarray(g.dst).copy(),
                 node_mask=np.asarray(g.node_mask).copy(),
                 edge_mask=np.asarray(g.edge_mask).copy())


# ---------------------------------------------------------------------------
# Device-free: growth policy, floors, fingerprint, config knobs
# ---------------------------------------------------------------------------

def test_cluster_section_validates_growth_pads():
    with pytest.raises(ValueError, match="block_pad"):
        ClusterSection(block_pad=-0.1)
    with pytest.raises(ValueError, match="edge_pad"):
        ClusterSection(edge_pad=-1.0)
    cfg = SystemConfig(cluster=ClusterSection(block_pad=0.5, edge_pad=0.0))
    assert SystemConfig.from_dict(cfg.to_dict()) == cfg


def test_grow_policy_is_shape_stable_until_genuine_growth():
    from repro.core.distributed import _grow
    assert _grow(10, 16, 0.25) == 16       # fits the floor: shape unchanged
    assert _grow(16, 16, 0.25) == 16       # boundary case: still the floor
    assert _grow(17, 16, 0.25) == 22       # genuine growth: padded jump
    assert _grow(17, 0, 0.0) == 17         # legacy exact fit (no floor/pad)
    assert _grow(3, 0, 0.5) == 5           # pad applies from a cold start too


def test_bucket_floors_keep_shapes_across_rebuilds():
    """A rebuild handed the previous shapes as floors reproduces them even
    when the graph shrank — the compiled step stays valid."""
    from repro.core.distributed import build_cluster_graph
    g = generators.fem_grid2d(8)
    rng = np.random.default_rng(0)
    assignment = rng.integers(0, 4, size=np.asarray(g.node_mask).shape[0])
    dg1, l1 = build_cluster_graph(g, assignment, 4)
    em = np.asarray(g.edge_mask).copy()
    em[np.flatnonzero(em)[::3]] = False            # drop a third of the edges
    g2 = dataclasses.replace(g, edge_mask=em)
    dg2, l2 = build_cluster_graph(
        g2, assignment, 4, min_block=dg1.block_size,
        min_edges=int(dg1.src_owner.shape[1]), min_halo=dg1.halo_size)
    assert dg2.block_size == dg1.block_size
    assert dg2.src_owner.shape == dg1.src_owner.shape
    assert dg2.halo_size == dg1.halo_size
    # without floors the shrunken graph gets smaller buckets
    dg3, _ = build_cluster_graph(g2, assignment, 4)
    assert int(dg3.src_owner.shape[1]) < int(dg1.src_owner.shape[1])


def test_block_pad_grows_geometrically():
    from repro.core.distributed import build_cluster_graph
    g = generators.fem_grid2d(8)
    n = np.asarray(g.node_mask).shape[0]
    skew = np.zeros(n, dtype=np.int64)             # everything in partition 0
    dg, _ = build_cluster_graph(g, skew, 4, block_pad=0.5)
    live = int(np.asarray(g.node_mask).sum())
    assert dg.block_size == int(np.ceil(live * 1.5))


def test_graph_fingerprint_detects_in_place_mutation():
    g = _numpy_graph(generators.fem_grid2d(6))
    fp0 = _graph_fingerprint(g)
    assert _graph_fingerprint(g) == fp0            # deterministic
    e0 = int(np.flatnonzero(g.edge_mask)[0])
    g.edge_mask[e0] = False                        # in-place edge kill
    assert _graph_fingerprint(g) != fp0
    g.edge_mask[e0] = True
    assert _graph_fingerprint(g) == fp0            # content, not identity
    n0 = int(np.flatnonzero(g.node_mask)[-1])
    g.node_mask[n0] = False                        # in-place node expiry
    assert _graph_fingerprint(g) != fp0


# ---------------------------------------------------------------------------
# In-process sharded: cache keying, mutation rebuild, probe rollback
# ---------------------------------------------------------------------------

def _sharded_system(g, k: int = 8, **cluster_kw):
    cfg = SystemConfig(
        partition=PartitionSection(strategy="xdgp", k=k, adapt_iters=2),
        cluster=ClusterSection(backend="sharded", **cluster_kw))
    return DynamicGraphSystem(g, cfg)


@needs_devices
def test_cache_keying_across_s_tie_break_and_shape():
    """``s`` is a traced scalar (no retrace); ``tie_break`` and the shape
    bucket are part of the signature (one compile each); ``invalidate()``
    drops the cache."""
    from repro.core.distributed import TRACE_COUNTS
    g = generators.fem_grid2d(10)
    system = _sharded_system(g)
    backend = system.backend
    system.adapt(1)
    assert len(backend._migrators) == 1
    traces = TRACE_COUNTS["cluster_step"]

    # a different damping s dispatches into the SAME executable
    ctx = dataclasses.replace(system._ctx(), s=0.9)
    backend.adapt(system.strategy, system.graph, system.state, ctx)
    assert len(backend._migrators) == 1
    assert TRACE_COUNTS["cluster_step"] == traces

    # a different tie_break is a different signature: exactly one compile
    ctx = dataclasses.replace(system._ctx(), tie_break="stay")
    backend.adapt(system.strategy, system.graph, system.state, ctx)
    assert len(backend._migrators) == 2
    assert TRACE_COUNTS["cluster_step"] == traces + 1

    # invalidate() drops the executables (k-change / restore semantics)
    backend.invalidate()
    assert backend._migrators == {}
    system.adapt(1)
    assert len(backend._migrators) == 1
    assert TRACE_COUNTS["cluster_step"] == traces + 2


@needs_devices
def test_shape_bucket_growth_compiles_once():
    """Outgrowing a padded bucket costs exactly one new compile; a rebuild
    inside the padded shapes costs none."""
    from repro.core.distributed import TRACE_COUNTS
    base = _numpy_graph(generators.fem_grid2d(10))
    pad = 2000                                     # dead edge slots to grow into
    g = Graph(src=np.concatenate([base.src, np.zeros(pad, base.src.dtype)]),
              dst=np.concatenate([base.dst, np.zeros(pad, base.dst.dtype)]),
              node_mask=base.node_mask,
              edge_mask=np.concatenate([base.edge_mask,
                                        np.zeros(pad, bool)]))
    system = _sharded_system(g)
    backend = system.backend
    system.adapt(1)
    sig0 = backend._sig(system._ctx())
    traces = TRACE_COUNTS["cluster_step"]

    # shrink the live graph in place: rebuild, same padded shapes, no compile
    em_live = np.flatnonzero(g.edge_mask)
    g.edge_mask[em_live[::5]] = False
    system.adapt(1)
    assert backend._sig(system._ctx()) == sig0
    assert TRACE_COUNTS["cluster_step"] == traces
    assert len(backend._migrators) == 1

    # grow far past the padded bucket: exactly one new signature + compile
    g.edge_mask[em_live] = True
    dead = np.flatnonzero(~g.edge_mask)
    live_nodes = np.flatnonzero(g.node_mask)
    rng = np.random.default_rng(7)
    a = rng.choice(live_nodes, size=dead.size)
    b = rng.choice(live_nodes, size=dead.size)
    keep = a != b
    g.src[dead[keep]] = a[keep]
    g.dst[dead[keep]] = b[keep]
    g.edge_mask[dead[keep]] = True
    system.adapt(1)
    assert backend._sig(system._ctx()) != sig0
    assert TRACE_COUNTS["cluster_step"] == traces + 1
    assert len(backend._migrators) == 2


@needs_devices
def test_in_place_mutation_triggers_rebuild():
    """Regression for the stale-bucketing hazard: object identity alone
    used to skip the rebuild when a Graph was mutated in place."""
    g = _numpy_graph(generators.fem_grid2d(10))
    system = _sharded_system(g)
    backend = system.backend
    system.adapt(1)
    fp0 = backend._graph_fp
    comm0 = dict(backend._comm)
    # same object, unchanged content: no rebuild (dg object survives)
    dg0 = backend._dg
    system.adapt(1)
    assert backend._dg is dg0
    # in-place topology change on the SAME object: must rebuild
    g.edge_mask[np.flatnonzero(g.edge_mask)[::2]] = False
    system.adapt(1)
    assert backend._graph_fp != fp0
    assert backend._dg is not dg0
    assert backend._comm["halo_live_bytes_per_device"] <= \
        comm0["halo_live_bytes_per_device"]


@needs_devices
def test_probe_rollback_is_exact():
    """The comm probe's own iterations must not leak into the session's
    comm counters: a traced+probed superstep charges exactly
    adapt_iters iterations."""
    from repro.obs.trace import Tracer
    g = generators.fem_grid2d(10)
    cfg = SystemConfig(
        partition=PartitionSection(strategy="xdgp", k=8, adapt_iters=3))
    system = DynamicGraphSystem(g, cfg)
    backend = system.backend

    from repro.api.backend import ShardedBackend
    sharded = ShardedBackend(ClusterSection(backend="sharded"))
    sharded.tracer = Tracer()
    sharded.comm_probe = True
    ctx = system._ctx()
    state = sharded.adapt(system.strategy, system.graph, system.state, ctx)
    c = sharded._comm
    P = c["devices"]
    expected = ctx.adapt_iters * P
    assert sharded._total_iterations == ctx.adapt_iters
    assert sharded._total_comm["halo_bytes"] == \
        expected * c["halo_bytes_per_device"]
    assert sharded._total_comm["halo_live_bytes"] == \
        expected * c["halo_live_bytes_per_device"]
    assert sharded._total_comm["collective_bytes"] == \
        expected * c["collective_bytes_per_device"]
    # the probe really ran (it emits synthetic spans) and the trace saw a
    # genuine compile exactly once
    phases = sharded.tracer.phase_totals()
    assert "obs/comm_probe" in phases
    assert "cluster/recompile" in phases
    assert np.asarray(state.assignment).shape == \
        np.asarray(system.state.assignment).shape


# ---------------------------------------------------------------------------
# Subprocess: the end-to-end no-recompile property over a streamed run
# ---------------------------------------------------------------------------

def test_streaming_no_recompiles_after_warmup():
    """N streaming supersteps on the sharded backend: once the stream
    reaches steady state the trace counter must not move — every rebuild
    keeps the padded shapes and every dispatch hits a cached executable —
    and the trajectory stays bit-identical to local.

    The stream is a rotating-band churn: nodes cycle through three bands
    and the window holds ~1.5 bands, so the live topology changes every
    superstep (real rebuilds — the fingerprint fast-path never fires)
    while its SIZE oscillates around a steady state the padded buckets
    absorb."""
    _run("""
import numpy as np
from repro.api import DynamicGraphSystem, PartitionSection, StreamSection, \\
    SystemConfig, empty_graph
from repro.stream.ingest import stream_batches
from repro.core.distributed import TRACE_COUNTS

n, span, phases, per_phase = 300, 60, 12, 400
rng = np.random.default_rng(11)
ts, us, vs = [], [], []
for p in range(phases):                     # band p%3 is active in phase p
    lo = 100 * (p % 3)
    a = rng.integers(lo, lo + 100, size=per_phase)
    b = rng.integers(lo, lo + 100, size=per_phase)
    keep = a != b
    ts.append(np.sort(rng.integers(p * span, (p + 1) * span,
                                   size=int(keep.sum()))))
    us.append(a[keep]); vs.append(b[keep])
times, u, v = np.concatenate(ts), np.concatenate(us), np.concatenate(vs)

cfg = SystemConfig(
    stream=StreamSection(window=90, batch_span=30),
    partition=PartitionSection(strategy="xdgp", k=8, adapt_iters=3))
local = DynamicGraphSystem(empty_graph(n, 6000), cfg)
shard = DynamicGraphSystem(empty_graph(n, 6000),
                           cfg.with_cluster(backend="sharded",
                                            halo_pad=0.25))
batches = list(stream_batches(times, u, v, 30))
warmup = len(batches) // 2                  # two full band cycles
rebuilds = 0
for i, (now, ev) in enumerate(batches):
    if i == warmup:
        traces_after_warmup = TRACE_COUNTS["cluster_step"]
        sigs_after_warmup = len(shard.backend._migrators)
    local.step(ev, now)
    fp = shard.backend._graph_fp
    shard.step(ev, now)
    rebuilds += int(shard.backend._graph_fp != fp)

# the stream really churns: (nearly) every superstep rebuilt the buckets…
assert rebuilds >= len(batches) - 2, rebuilds
# …yet ZERO recompiles after warmup: every padded bucket shape held
assert TRACE_COUNTS["cluster_step"] == traces_after_warmup, (
    TRACE_COUNTS["cluster_step"], traces_after_warmup)
assert len(shard.backend._migrators) == sigs_after_warmup
# one executable per shape bucket, and only a handful of buckets total
assert TRACE_COUNTS["cluster_step"] == len(shard.backend._migrators)
assert len(shard.backend._migrators) <= 5, len(shard.backend._migrators)
# parity is untouched by the cache (bit-identical to local)
assert np.array_equal(np.asarray(local.labels), np.asarray(shard.labels))
print("OK", TRACE_COUNTS["cluster_step"], len(batches))
""")
