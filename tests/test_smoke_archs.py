"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs (assignment requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import (forward, init_params, lm_loss, decode_step,
                          init_cache)
from repro.models.gnn import (GraphBatch, gatedgcn_forward, gatedgcn_init,
                              gin_forward, gin_init, pna_forward, pna_init,
                              node_classification_loss)
from repro.models.dimenet import (TripletBatch, build_triplets, dimenet_init,
                                  dimenet_forward)
from repro.models import recsys as rs
from repro.train import TrainConfig, make_train_state, make_train_step
from repro.optim import AdamWConfig

KEY = jax.random.PRNGKey(0)


def _rand_graph(n=40, e=120, f=8, n_classes=4, seed=0):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, n, e)
    d = rng.integers(0, n, e)
    keep = s != d
    src = np.concatenate([s[keep], d[keep]]).astype(np.int32)
    dst = np.concatenate([d[keep], s[keep]]).astype(np.int32)
    return GraphBatch(
        node_feat=jnp.asarray(rng.normal(size=(n, f)).astype(np.float32)),
        src=jnp.asarray(src), dst=jnp.asarray(dst),
        node_mask=jnp.ones((n,), bool),
        edge_mask=jnp.ones((len(src),), bool),
        graph_ids=jnp.zeros((n,), jnp.int32), n_graphs=1,
        labels=jnp.asarray(rng.integers(0, n_classes, n).astype(np.int32)))


LM_ARCHS = ["granite-34b", "gemma2-9b", "phi4-mini-3.8b", "arctic-480b",
            "deepseek-v2-lite-16b"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    mod = registry.get(arch)
    cfg = mod.smoke()
    params = init_params(KEY, cfg)
    tok = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3), warmup_steps=1,
                       total_steps=10)
    state = make_train_state(params, tcfg)
    step = jax.jit(make_train_step(lambda p, b: lm_loss(p, b, cfg), tcfg))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    logits, _ = forward(state.params, tok, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode(arch):
    mod = registry.get(arch)
    cfg = mod.smoke()
    params = init_params(KEY, cfg)
    cache = init_cache(cfg, 2, 24)
    tok = jax.random.randint(KEY, (2, 1), 0, cfg.vocab)
    for i in range(3):
        logits, cache = decode_step(params, tok, cache, jnp.int32(i), cfg)
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_pna_smoke():
    cfg = registry.get("pna").smoke()
    batch = _rand_graph(f=cfg.d_in, n_classes=cfg.n_out)
    p = pna_init(KEY, cfg)
    out = jax.jit(lambda p, b: pna_forward(p, b, cfg))(p, batch)
    assert out.shape == (40, cfg.n_out) and np.isfinite(np.asarray(out)).all()
    loss, grads = jax.value_and_grad(
        lambda p: node_classification_loss(pna_forward(p, batch, cfg), batch))(p)
    assert np.isfinite(float(loss))


def test_gatedgcn_smoke():
    cfg = registry.get("gatedgcn").smoke()
    batch = _rand_graph(f=cfg.d_in, n_classes=cfg.n_out)
    p = gatedgcn_init(KEY, cfg)
    out = jax.jit(lambda p, b: gatedgcn_forward(p, b, cfg))(p, batch)
    assert out.shape == (40, cfg.n_out) and np.isfinite(np.asarray(out)).all()


def test_gin_smoke():
    cfg = registry.get("gin-tu").smoke()
    batch = _rand_graph(f=cfg.d_in, n_classes=cfg.n_out)
    p = gin_init(KEY, cfg)
    out = jax.jit(lambda p, b: gin_forward(p, b, cfg))(p, batch)
    # smoke() uses readout="sum" default? config sets readout per call
    assert np.isfinite(np.asarray(out)).all()


def test_dimenet_smoke():
    cfg = registry.get("dimenet").smoke()
    batch = _rand_graph(f=cfg.d_in)
    rng = np.random.default_rng(0)
    pos = jnp.asarray(rng.normal(size=(40, 3)).astype(np.float32))
    t_in, t_out, t_ok = build_triplets(np.asarray(batch.src),
                                       np.asarray(batch.dst),
                                       np.asarray(batch.edge_mask), 2048)
    trip = TripletBatch(edge_src=batch.src, edge_dst=batch.dst,
                        edge_mask=batch.edge_mask,
                        trip_in=jnp.asarray(t_in), trip_out=jnp.asarray(t_out),
                        trip_mask=jnp.asarray(t_ok))
    p = dimenet_init(KEY, cfg)
    out = jax.jit(lambda p: dimenet_forward(
        p, batch.node_feat, pos, trip, batch.node_mask, batch.graph_ids, 1,
        cfg))(p)
    assert out.shape == (1, cfg.n_out) and np.isfinite(np.asarray(out)).all()


def test_two_tower_smoke():
    cfg = registry.get("two-tower-retrieval").smoke()
    rng = np.random.default_rng(0)
    B = 8
    batch = {}
    for f in cfg.user_features:
        shape = (B,) if f.n_hot == 1 else (B, f.n_hot)
        batch[f.name] = jnp.asarray(rng.integers(0, f.vocab, shape).astype(np.int32))
    for f in cfg.item_features:
        batch[f.name] = jnp.asarray(rng.integers(0, f.vocab, B).astype(np.int32))
    batch["user_dense"] = jnp.asarray(rng.normal(size=(B, cfg.n_dense_user)).astype(np.float32))
    batch["item_dense"] = jnp.asarray(rng.normal(size=(B, cfg.n_dense_item)).astype(np.float32))
    batch["item_logq"] = jnp.zeros((B,), jnp.float32)
    p = rs.init_params(KEY, cfg)
    loss = jax.jit(lambda p, b: rs.sampled_softmax_loss(p, b, cfg))(p, batch)
    assert np.isfinite(float(loss))
    scores = rs.score_pairs(p, batch, cfg)
    assert scores.shape == (B,) and np.isfinite(np.asarray(scores)).all()


def test_registry_covers_40_cells():
    cells = registry.all_cells()
    assert len(cells) == 40
    skipped = [c for c in cells if c.skip]
    assert len(skipped) == 4          # long_500k × 4 full-attention archs
    assert all(c.shape_name == "long_500k" for c in skipped)
