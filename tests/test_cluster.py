"""Cluster-session tests: the ExecutionBackend layer (DESIGN.md §10).

Three groups:
  * in-process — config/registry surface, rescale, save/restore (1 device
    is enough: they exercise the lifecycle, not the sharded engine);
  * subprocess under 8 fake devices — the local-vs-sharded parity property
    (assignments bit-identical, capacity invariant, distribute()/gather()
    round-trips) on random dynamic graphs;
  * in-process sharded — skipped unless the host already exposes ≥8
    devices (the tier-1-sharded CI job runs with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.api import (ClusterSection, DynamicGraphSystem, LocalBackend,
                       PartitionSection, ShardedBackend, StreamSection,
                       SystemConfig, empty_graph, execution_backend_names,
                       resolve_execution_backend)
from repro.graph import generators

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Surface: registry, config section, protocol
# ---------------------------------------------------------------------------

def test_backend_registry():
    assert execution_backend_names() == ("local", "sharded")
    assert resolve_execution_backend("local").name == "local"
    b = resolve_execution_backend(
        "sharded", cluster=ClusterSection(backend="sharded", devices=4))
    assert isinstance(b, ShardedBackend) and b.cluster.devices == 4
    inst = LocalBackend()
    assert resolve_execution_backend(inst) is inst
    with pytest.raises(ValueError, match="execution backends"):
        resolve_execution_backend("shardedd")


def test_cluster_section_round_trips():
    cfg = SystemConfig(cluster=ClusterSection(backend="sharded", axis="vtx",
                                              devices=8, halo_pad=0.25))
    assert SystemConfig.from_dict(cfg.to_dict()) == cfg
    assert cfg.with_cluster(backend="local").cluster.backend == "local"
    assert cfg.with_cluster(backend="local").cluster.devices == 8
    with pytest.raises(ValueError, match="unknown keys.*cluster"):
        SystemConfig.from_dict({"cluster": {"backed": "sharded"}})


def test_cluster_section_validates_knobs():
    with pytest.raises(ValueError, match="halo_pad"):
        ClusterSection(halo_pad=-0.5)
    with pytest.raises(ValueError, match="devices"):
        ClusterSection(devices=-1)


def test_session_default_backend_is_local():
    g = generators.fem_grid2d(6)
    system = DynamicGraphSystem(g, SystemConfig(
        partition=PartitionSection(strategy="xdgp", k=4)))
    assert system.backend.name == "local"
    snap = system.snapshot()
    assert snap["backend"] == "local" and snap["cluster"] is None
    # local records carry zeroed comm counters (same telemetry keys)
    system.adapt(3)
    assert system.backend.pop_superstep_comm() == {"halo_bytes": 0,
                                                   "halo_live_bytes": 0,
                                                   "collective_bytes": 0}


def test_distribute_rejects_missing_devices_atomically():
    g = generators.fem_grid2d(6)
    k_too_many = len(jax.devices()) + 1
    system = DynamicGraphSystem(g, SystemConfig(
        partition=PartitionSection(strategy="xdgp", k=k_too_many)))
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        system.distribute()
    # the failed move left the session untouched and fully usable
    assert system.backend.name == "local"
    assert system.config.cluster.backend == "local"
    system.adapt(2)


def test_sharded_requires_partition_per_device():
    with pytest.raises(ValueError, match="partition-per-device"):
        ShardedBackend(ClusterSection(backend="sharded",
                                      devices=4)).required_devices(k=8)


# ---------------------------------------------------------------------------
# Elastic rescale as a session operation
# ---------------------------------------------------------------------------

def test_rescale_down_rehomes_and_readapts():
    g = generators.fem_cube(10)
    system = DynamicGraphSystem(g, SystemConfig(
        partition=PartitionSection(strategy="xdgp", k=8, slack=0.15)))
    system.adapt(50)
    report = system.rescale(6, lost=(2, 5), adapt_iters=40)
    assert report["old_k"] == 8 and report["new_k"] == 6
    assert report["cut_after_adapt"] < report["cut_after_rehash"]
    assert report["migrations"] > 0
    assert system.config.partition.k == 6
    lab = np.asarray(system.labels)[np.asarray(system.graph.node_mask)]
    assert lab.min() >= 0 and lab.max() < 6
    occ = np.asarray(system.tracker.occupancy)
    assert (occ <= np.asarray(system.state.capacity)).all()
    snap = system.snapshot()
    assert snap["k"] == 6 and len(snap["occupancy"]) == 6


def test_rescale_up_keeps_labels_and_reprovisions():
    """Scale-up keeps existing labels (new partitions start empty, filled
    only as the heuristic's quotas route movers there); the session
    re-provisions capacity and telemetry for the new k."""
    g = generators.fem_cube(10)
    system = DynamicGraphSystem(g, SystemConfig(
        partition=PartitionSection(strategy="xdgp", k=4)))
    system.adapt(40)
    cut_before = system.cut_ratio
    report = system.rescale(6, adapt_iters=60)
    assert report["new_k"] == 6 and system.config.partition.k == 6
    occ = np.asarray(system.tracker.occupancy)
    assert occ.shape == (6,) and occ.sum() == int(g.num_nodes)
    assert system.cut_ratio <= cut_before + 1e-6   # adaptation never regresses


# ---------------------------------------------------------------------------
# Checkpoint / restore as session operations
# ---------------------------------------------------------------------------

def _stream_cfg(n, window):
    return SystemConfig(
        stream=StreamSection(window=window, batch_span=window // 2),
        partition=PartitionSection(strategy="xdgp", k=4, adapt_iters=3),
    )


def test_save_restore_resumes_bit_identical(tmp_path):
    """A mid-run snapshot + restore continues exactly the uninterrupted
    trajectory: partition state, RNG, window liveness and backlog all
    survive the round trip."""
    from repro.stream.ingest import stream_batches

    n, window = 250, 120
    times, u, v = generators.sliding_window_stream(n, 3000, window, seed=3)
    cfg = _stream_cfg(n, window)

    ref = DynamicGraphSystem(empty_graph(n, 5000), cfg)
    ref.run((times, u, v))

    system = DynamicGraphSystem(empty_graph(n, 5000), cfg)
    batches = list(stream_batches(times, u, v, window // 2))
    half = len(batches) // 2
    for now, ev in batches[:half]:
        system.step(ev, now)
    step = system.save(str(tmp_path / "ckpt"))
    resumed = DynamicGraphSystem.restore(str(tmp_path / "ckpt"), step=step)
    assert resumed.config == cfg
    for now, ev in batches[half:]:
        resumed.step(ev, now)

    assert np.array_equal(np.asarray(ref.labels), np.asarray(resumed.labels))
    assert ([r.cut_ratio for r in ref.telemetry]
            == [r.cut_ratio for r in resumed.telemetry])
    assert ([r.migrations for r in ref.telemetry]
            == [r.migrations for r in resumed.telemetry])
    # the restored tracker is still exact (drift check passes in score path)
    assert all(r.drift == 0.0 for r in resumed.telemetry
               if r.drift is not None)


def test_save_restore_preserves_int64_window_state(tmp_path):
    """Epoch-millisecond timestamps and the int64 NEVER sentinel must
    survive the round trip — jax canonicalises int64 to int32 when x64 is
    off, which would wrap both (regression: checkpointer keeps 64-bit
    leaves on host)."""
    from repro.stream.ingest import WindowTracker

    n, window = 100, 60_000
    t0 = 1_700_000_000_000                       # epoch ms
    cfg = SystemConfig(
        stream=StreamSection(window=window, batch_span=10_000),
        partition=PartitionSection(strategy="xdgp", k=4, adapt_iters=2))
    system = DynamicGraphSystem(empty_graph(n, 2000), cfg)
    ev = np.array([[t0, 1, 2], [t0 + 5, 3, 4]], np.int64)
    system.step(ev, t0 + 5)
    before = system.ingestor.tracker.last_seen.copy()
    tracked_before = system.ingestor.tracker.tracked
    system.save(str(tmp_path / "ckpt"))
    resumed = DynamicGraphSystem.restore(str(tmp_path / "ckpt"))
    after = resumed.ingestor.tracker.last_seen
    assert after.dtype == np.int64
    assert np.array_equal(before, after)
    assert resumed.ingestor.tracker.tracked == tracked_before
    assert (after[after != WindowTracker.NEVER] >= t0).all()
    # and the next superstep does not hallucinate expiries
    rec = resumed.step(np.array([[t0 + 10, 5, 6]], np.int64), t0 + 10)
    assert rec.dels == 0 and rec.invalid_events == 0


def test_restore_refuses_dropped_constructor_overrides(tmp_path):
    """A checkpoint records override names only; restoring without handing
    the same overrides back must fail loudly, not silently diverge."""
    from repro.api import XdgpAdaptive

    g = generators.fem_grid2d(6)
    cfg = SystemConfig(partition=PartitionSection(strategy="xdgp", k=4))
    inherit = XdgpAdaptive(placement="inherit")   # same name as "xdgp"!
    system = DynamicGraphSystem(g, cfg, strategy=inherit)
    system.adapt(2)
    system.save(str(tmp_path / "ckpt"))
    with pytest.raises(ValueError, match="strategy"):
        DynamicGraphSystem.restore(str(tmp_path / "ckpt"))
    resumed = DynamicGraphSystem.restore(str(tmp_path / "ckpt"),
                                         strategy=inherit)
    assert resumed.strategy is inherit


def test_restore_refuses_dropped_program_override(tmp_path):
    """A same-config session with a *program* constructor override must be
    handed the same program back on restore — the config would silently
    rebuild a different vertex program otherwise."""
    from repro.core.vertex_program import make_program

    n, window = 120, 60
    times, u, v = generators.sliding_window_stream(n, 800, window, seed=1)
    cfg = SystemConfig(
        stream=StreamSection(window=window, batch_span=30),
        partition=PartitionSection(strategy="xdgp", k=4, adapt_iters=2))
    prog = make_program("degree")
    system = DynamicGraphSystem(empty_graph(n, 2000), cfg, program=prog)
    system.run((times, u, v), max_supersteps=3)
    system.save(str(tmp_path / "ckpt"))
    with pytest.raises(ValueError, match="program override"):
        DynamicGraphSystem.restore(str(tmp_path / "ckpt"))
    resumed = DynamicGraphSystem.restore(str(tmp_path / "ckpt"),
                                         program=prog)
    assert resumed.program is prog
    assert np.array_equal(np.asarray(resumed.program_state),
                          np.asarray(system.program_state))


def test_restore_rejects_non_session_checkpoints(tmp_path):
    from repro.checkpoint import Checkpointer

    ckpt = Checkpointer(str(tmp_path / "raw"), use_async=False)
    ckpt.save(0, {"weights": np.zeros((3,))})
    with pytest.raises(ValueError, match="session checkpoint"):
        DynamicGraphSystem.restore(str(tmp_path / "raw"))


# ---------------------------------------------------------------------------
# The parity property: local == sharded, bit for bit (8 fake devices)
# ---------------------------------------------------------------------------

def test_local_vs_sharded_parity_property():
    """Random dynamic graphs: the sharded backend's assignments are
    bit-identical to local across full streamed runs, converge, and
    distribute()/gather() round-trips; the capacity invariant holds
    throughout. (The ISSUE's parity acceptance criterion.)"""
    _run("""
import numpy as np
from repro.api import DynamicGraphSystem, PartitionSection, StreamSection, \
    SystemConfig, empty_graph
from repro.graph import generators
from repro.stream.ingest import stream_batches

for seed in (0, 1, 2):
    n, window = 220 + 40 * seed, 100 + 20 * seed
    times, u, v = generators.sliding_window_stream(n, 2600, window, seed=seed)
    cfg = SystemConfig(
        stream=StreamSection(window=window, batch_span=window // 2),
        partition=PartitionSection(strategy="xdgp", k=8,
                                   adapt_iters=3 + seed % 3),
        seed=seed)

    local = DynamicGraphSystem(empty_graph(n, 5000), cfg)
    recs_l = local.run((times, u, v))
    shard = DynamicGraphSystem(empty_graph(n, 5000),
                               cfg.with_cluster(backend="sharded"))
    recs_s = shard.run((times, u, v))

    assert np.array_equal(np.asarray(local.labels), np.asarray(shard.labels)), seed
    assert [r.cut_ratio for r in recs_l] == [r.cut_ratio for r in recs_s], seed
    assert [r.migrations for r in recs_l] == [r.migrations for r in recs_s], seed
    # sharded telemetry gains comm counters; local stays at zero
    assert sum(r.halo_bytes for r in recs_s) > 0 and \
        all(r.halo_bytes == 0 for r in recs_l), seed
    occ = np.asarray(shard.tracker.occupancy)
    assert (occ <= np.asarray(shard.state.capacity)).all(), seed

    # mid-run distribute()/gather() round-trip changes nothing
    rt = DynamicGraphSystem(empty_graph(n, 5000), cfg)
    batches = list(stream_batches(times, u, v, window // 2))
    third = max(1, len(batches) // 3)
    for now, ev in batches[:third]:
        rt.step(ev, now)
    rt.distribute()
    assert rt.backend.name == "sharded"
    for now, ev in batches[third:2 * third]:
        rt.step(ev, now)
    rt.gather()
    for now, ev in batches[2 * third:]:
        rt.step(ev, now)
    assert np.array_equal(np.asarray(local.labels), np.asarray(rt.labels)), seed

# batch mode: converge() parity including the recorded History
g = generators.fem_cube(10)
cfg = SystemConfig(partition=PartitionSection(strategy="xdgp", k=8,
                                              max_iters=60, patience=10))
a = DynamicGraphSystem(g, cfg)
h1 = a.converge()
b = DynamicGraphSystem(g, cfg.with_cluster(backend="sharded"))
h2 = b.converge()
assert np.array_equal(np.asarray(a.labels), np.asarray(b.labels))
assert h1.as_dict() == h2.as_dict()
stats = b.snapshot()["cluster"]
assert stats["devices"] == 8 and stats["halo_bytes_total"] > 0
print("OK")
""")


def test_sharded_save_restore_round_trip():
    """A sharded session snapshots its canonical state and resumes sharded,
    continuing the exact local-reference trajectory (ISSUE acceptance:
    rescale/save/restore round-trip a mid-run session)."""
    _run("""
import numpy as np, tempfile
from repro.api import DynamicGraphSystem, PartitionSection, StreamSection, \
    SystemConfig, empty_graph
from repro.graph import generators
from repro.stream.ingest import stream_batches

n, window = 260, 120
times, u, v = generators.sliding_window_stream(n, 3000, window, seed=5)
cfg = SystemConfig(
    stream=StreamSection(window=window, batch_span=window // 2),
    partition=PartitionSection(strategy="xdgp", k=8, adapt_iters=4))

ref = DynamicGraphSystem(empty_graph(n, 5000), cfg)
ref.run((times, u, v))

shard = DynamicGraphSystem(empty_graph(n, 5000),
                           cfg.with_cluster(backend="sharded"))
batches = list(stream_batches(times, u, v, window // 2))
half = len(batches) // 2
for now, ev in batches[:half]:
    shard.step(ev, now)
with tempfile.TemporaryDirectory() as d:
    shard.save(d)
    resumed = DynamicGraphSystem.restore(d)
assert resumed.backend.name == "sharded"     # cluster section survived
for now, ev in batches[half:]:
    resumed.step(ev, now)
assert np.array_equal(np.asarray(ref.labels), np.asarray(resumed.labels))

# elastic rescale on the sharded backend: k 8 -> 6 re-meshes to 6 devices
report = resumed.rescale(6, lost=(1, 4), adapt_iters=30)
assert report["cut_after_adapt"] < report["cut_after_rehash"]
assert resumed.backend.name == "sharded"
occ = np.asarray(resumed.tracker.occupancy)
assert occ.shape == (6,) and (occ <= np.asarray(resumed.state.capacity)).all()

# a rescale the cluster cannot serve fails BEFORE mutating the session
try:
    resumed.rescale(12, adapt_iters=5)
    raise SystemExit("rescale(12) should have raised on an 8-device host")
except RuntimeError as e:
    assert "12 devices" in str(e), e
assert resumed.config.partition.k == 6           # untouched
assert np.asarray(resumed.tracker.occupancy).shape == (6,)
resumed.adapt(2)                                 # still fully usable
print("OK")
""")


# ---------------------------------------------------------------------------
# In-process sharded checks (run under the tier-1-sharded CI job)
# ---------------------------------------------------------------------------

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@needs_devices
def test_sharded_converge_parity_in_process():
    g = generators.fem_cube(8)
    cfg = SystemConfig(partition=PartitionSection(strategy="xdgp", k=8,
                                                  max_iters=40, patience=8))
    a = DynamicGraphSystem(g, cfg)
    a.converge(record_history=False)
    b = DynamicGraphSystem(g, cfg.with_cluster(backend="sharded"))
    b.converge(record_history=False)
    assert np.array_equal(np.asarray(a.labels), np.asarray(b.labels))


@needs_devices
def test_sharded_static_baseline_is_free():
    """Non-adapting strategies fall through to their local no-op hooks —
    a sharded static baseline exchanges nothing."""
    n, window = 200, 100
    times, u, v = generators.sliding_window_stream(n, 1500, window, seed=2)
    cfg = SystemConfig(
        stream=StreamSection(window=window, batch_span=50),
        partition=PartitionSection(strategy="static", k=8),
        cluster=ClusterSection(backend="sharded"))
    system = DynamicGraphSystem(empty_graph(n, 4000), cfg)
    recs = system.run((times, u, v), max_supersteps=5)
    assert all(r.halo_bytes == 0 and r.collective_bytes == 0 for r in recs)
    assert sum(r.migrations for r in recs) == 0
