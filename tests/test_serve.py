"""Serving-layer tests (DESIGN.md §12): the multi-tenant GraphServer front
door (admission, backpressure, autoscale, checkpoint/recover), the open-loop
load generator, and the continuous-batching ServeEngine's per-slot position
handling."""
import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.serve import (AdmissionPolicy, AutoscalePolicy, CheckpointPolicy,
                         GraphServer, TrafficShape, arrival_offsets,
                         synthetic_stream, telemetry_digest, tick_schedule)
from repro.serve import drill

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _drill_cfg(workdir, **over):
    cfg = dict(drill.DEFAULT_CONFIG)
    cfg.update(tenants=2, ticks=10, kill_tick=7, checkpoint_every=3,
               n_events=200, n_nodes=64, workdir=str(workdir))
    cfg.update(over)
    return cfg


# ---------------------------------------------------------------------------
# load generation
# ---------------------------------------------------------------------------

def test_arrival_offsets_deterministic_and_bursty():
    shape = TrafficShape(rate=100.0, burst_rate=1000.0,
                         burst_every=1.0, burst_len=0.2)
    a = arrival_offsets(500, shape, seed=3)
    b = arrival_offsets(500, shape, seed=3)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) > 0)
    # arrivals inside burst windows are denser than the base rate by far:
    # count events landing in [n, n+0.2) vs [n+0.2, n+1.0) windows
    frac = np.mod(a, 1.0)
    in_burst = int(np.sum(frac < 0.2))
    outside = a.size - in_burst
    # burst windows are 20% of time at 10x rate → ~71% of events
    assert in_burst > outside


def test_tick_schedule_is_pure_and_complete():
    t, u, v = synthetic_stream(50, 300, seed=5)
    shape = TrafficShape(rate=200.0)
    s1 = tick_schedule(t, u, v, shape, ticks=16, seed=5)
    s2 = tick_schedule(t, u, v, shape, ticks=16, seed=5)
    assert len(s1) == 16
    for c1, c2 in zip(s1, s2):
        if c1 is None:
            assert c2 is None
        else:
            np.testing.assert_array_equal(c1, c2)
    total = sum(c.shape[0] for c in s1 if c is not None)
    assert total == 300          # every event lands in exactly one tick


# ---------------------------------------------------------------------------
# ServeEngine per-slot positions (the shared-clock bug regression)
# ---------------------------------------------------------------------------

def _solo_tokens(params, cfg, req):
    from repro.serve import ServeEngine
    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=64)
    eng.submit(req)
    (out,) = eng.run_until_drained()
    return out.tokens


def test_engine_staggered_requests_match_solo():
    """Two requests joining the batch at different times must decode exactly
    what they decode alone — per-slot cache positions, not a shared clock."""
    from repro.models import TransformerConfig, init_params
    from repro.serve import Request, ServeEngine
    cfg = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=2,
                            n_kv_heads=1, head_dim=16, d_ff=64, vocab=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    req_a = Request(uid=0, prompt=np.array([5, 9, 12, 3, 7]),
                    max_new_tokens=8)
    req_b = Request(uid=1, prompt=np.array([11, 4, 6]), max_new_tokens=8)
    solo_a = _solo_tokens(params, cfg, req_a)
    solo_b = _solo_tokens(params, cfg, req_b)

    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=64)
    eng.submit(req_a)
    outs = []
    for _ in range(3):                 # A decodes alone for three steps,
        outs.extend(eng.step())        # then B joins mid-flight
    eng.submit(req_b)
    outs.extend(eng.run_until_drained())
    got = {c.uid: c.tokens for c in outs}
    assert got[0] == solo_a
    assert got[1] == solo_b


# ---------------------------------------------------------------------------
# GraphServer: tenant isolation
# ---------------------------------------------------------------------------

def test_tenant_isolation_interleaved_matches_solo(tmp_path):
    """Interleaving tenants through one server must leave each tenant's
    telemetry bit-identical to serving it alone."""
    cfg = _drill_cfg(tmp_path)
    sched = drill.schedules(cfg)

    both = drill.build_server(cfg, checkpoints=False)
    drill.replay(both, cfg, 0)
    interleaved = drill.digests(both)

    for i, name in enumerate(sched):
        solo = GraphServer(admission=AdmissionPolicy(
            queue_cap=cfg["queue_cap"]))
        solo.add_tenant(name, config=drill._system_config(cfg, i))
        for chunk in sched[name]:
            if chunk is not None:
                solo.submit(name, chunk)
            solo.tick()
        solo.drain()
        assert telemetry_digest(solo.tenants[name].system.telemetry) \
            == interleaved[name], f"tenant {name} diverged under interleaving"


# ---------------------------------------------------------------------------
# GraphServer: backpressure policies
# ---------------------------------------------------------------------------

def _tiny_server(on_full, queue_cap=300, a_cap=64):
    from repro.api import SystemConfig
    server = GraphServer(admission=AdmissionPolicy(
        queue_cap=queue_cap, on_full=on_full))
    server.add_tenant("t", config=SystemConfig.from_dict({
        "graph": {"n_cap": 256, "e_cap": 4096},
        "stream": {"window": 10_000, "a_cap": a_cap, "d_cap": 32},
        "partition": {"k": 2},
    }))
    return server


def _events(n, seed=0):
    t, u, v = synthetic_stream(200, n, seed=seed)
    return np.stack([t, u, v], axis=1)


def test_backpressure_reject_counts_stream_backlog(tmp_path):
    server = _tiny_server("reject")
    r = server.submit("t", _events(200))
    assert (r.accepted, r.rejected) == (200, 0)
    server.tick()                       # one step drains a_cap=64 events;
    t = server.tenants["t"]             # the rest defers inside the buffer
    assert t.queued == 0
    assert t.stream_backlog == 136
    assert 0 < t.pressure < 1
    r = server.submit("t", _events(200, seed=1))
    assert r.accepted == 300 - 136      # room is cap minus deferred backlog
    assert r.rejected == 200 - r.accepted
    assert server.metrics.counter("events_rejected_total").values[
        (("tenant", "t"),)] == r.rejected
    server.drain()
    assert t.stream_backlog == 0 and t.pressure == 0.0


def test_backpressure_shed_drops_oldest():
    server = _tiny_server("shed")
    first = _events(250, seed=0)
    server.submit("t", first)
    r = server.submit("t", _events(100, seed=1))
    t = server.tenants["t"]
    assert r.shed == 50                 # 350 offered, cap 300 → oldest 50 go
    assert t.queued == 300
    batch, _ = t.take_batch(10_000)
    np.testing.assert_array_equal(batch[:200], first[50:])  # head was shed


def test_backpressure_queue_accepts_over_cap():
    server = _tiny_server("queue")
    r = server.submit("t", _events(400))
    assert (r.accepted, r.rejected, r.shed) == (400, 0, 0)
    assert r.pressure > 1.0             # the gauge still tells the truth


def test_admission_policy_validates():
    with pytest.raises(ValueError):
        AdmissionPolicy(on_full="explode")
    server = _tiny_server("reject")
    with pytest.raises(ValueError):
        server.submit("t", np.zeros((4, 2), np.int64))
    with pytest.raises(KeyError):
        server.submit("nobody", _events(1))


# ---------------------------------------------------------------------------
# GraphServer: autoscale
# ---------------------------------------------------------------------------

def test_autoscale_scales_up_on_occupancy():
    from repro.api import SystemConfig
    server = GraphServer(autoscale=AutoscalePolicy(
        enabled=True, min_k=2, max_k=8, occupancy_high=0.2,
        latency_high=1e9, latency_low=-1.0, cooldown=0, adapt_iters=2))
    server.add_tenant("t", config=SystemConfig.from_dict({
        "graph": {"n_cap": 64, "e_cap": 1024},
        "stream": {"window": 10_000, "a_cap": 512, "d_cap": 64},
        "partition": {"k": 2},
    }))
    server.submit("t", _events(120, seed=2))
    server.drain()
    t = server.tenants["t"]
    assert t.system.config.partition.k > 2
    assert t.rescales >= 1
    assert server.metrics.counter("rescales_total").values[
        (("direction", "up"), ("tenant", "t"))] >= 1


# ---------------------------------------------------------------------------
# kill-and-recover drill (real SIGKILL, separate processes)
# ---------------------------------------------------------------------------

def _drill_proc(command, cfg_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.serve.drill", command,
         "--config", str(cfg_path)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)


def test_kill_recover_drill_is_bit_exact(tmp_path):
    """The operator's drill: SIGKILL a checkpointed serving process, recover
    in a fresh process, replay — every tenant must match an uninterrupted
    reference run bit for bit (wall-clock fields excluded)."""
    cfg = _drill_cfg(tmp_path)
    cfg_path = tmp_path / "cfg.json"
    cfg_path.write_text(json.dumps(cfg))

    run = _drill_proc("run", cfg_path)
    assert run.returncode == -signal.SIGKILL, \
        f"drill run should die by SIGKILL, got {run.returncode}: {run.stderr}"
    assert os.path.exists(tmp_path / "ckpt" / "MANIFEST.json")

    rec = _drill_proc("recover", cfg_path)
    assert rec.returncode == 0, rec.stderr
    with open(tmp_path / "recovered.json") as f:
        recovered = json.load(f)
    # the checkpoint cadence means the manifest tick trails the kill tick
    assert 0 < recovered["recovery"]["tick"] < cfg["kill_tick"]
    assert recovered["recovery"]["seconds"] >= 0

    drill.cmd_reference(cfg)             # reference is in-process (no kill)
    with open(tmp_path / "reference.json") as f:
        reference = json.load(f)
    assert recovered["digests"] == reference["digests"]
    for name, t in reference["stats"]["tenants"].items():
        assert recovered["stats"]["tenants"][name]["supersteps"] \
            == t["supersteps"]


def test_server_checkpoint_requires_directory():
    server = _tiny_server("reject")
    with pytest.raises(ValueError):
        server.save_checkpoint()


# ---------------------------------------------------------------------------
# metrics surface: quantiles + the serve bench schema
# ---------------------------------------------------------------------------

def test_histogram_quantile_interpolates():
    from repro.obs.metrics import Histogram
    h = Histogram("lat", buckets=(0.1, 0.2, 0.4, 0.8))
    assert h.quantile(0.5) is None
    for v in (0.05, 0.15, 0.15, 0.3):
        h.observe(v)
    q50 = h.quantile(0.5)
    assert 0.1 <= q50 <= 0.2
    assert h.quantile(1.0) == pytest.approx(0.4)
    h.observe(5.0)                       # beyond the last bucket
    assert h.quantile(1.0) == 0.8
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_serve_bench_schema_validates():
    from repro.obs.schema import SchemaError, validate_serve_bench
    good = {
        "tenants": 2, "ticks": 10, "events_total": 100,
        "supersteps_total": 20, "wall_seconds": 1.0,
        "events_per_sec": 100.0, "ingest_p50_s": 0.01, "ingest_p99_s": 0.05,
        "per_tenant": {
            "a": {"events": 50, "supersteps": 10, "rejected": 0, "shed": 0},
            "b": {"events": 50, "supersteps": 10, "rejected": 0, "shed": 0},
        },
        "recovery": {"seconds": 0.5, "bit_exact": True, "tenants": 2},
    }
    validate_serve_bench(good)
    for mutate in (
        lambda d: d.update(tenants=0),
        lambda d: d.update(ingest_p99_s=0.001),          # p99 < p50
        lambda d: d.pop("per_tenant"),
        lambda d: d["recovery"].update(bit_exact=False),
        lambda d: d["recovery"].update(tenants=1),
    ):
        bad = json.loads(json.dumps(good))
        mutate(bad)
        with pytest.raises(SchemaError):
            validate_serve_bench(bad)


def test_committed_serve_bench_results_validate():
    from repro.obs.schema import validate_serve_bench_file
    path = os.path.join(REPO, "results", "bench_serve_sessions.json")
    if not os.path.exists(path):
        pytest.skip("no committed serve bench results")
    payload = validate_serve_bench_file(path)
    assert payload["tenants"] >= 8
