"""Paper-scenario suite tests: driver validity, fixed-seed adaptive-vs-hash
regressions (paper §5.3 / Fig. 5–6), the engine's vertex-program hook, and
the capacity invariant under random event sequences."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                     # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.core.partition_state import occupancy
from repro.core.vertex_program import make_program
from repro.graph import generators
from repro.scenarios import SCENARIOS, compare_scenario, empty_graph
from repro.stream import StreamConfig, StreamEngine, stream_batches


@pytest.fixture(scope="module")
def smoke_comparisons():
    """One adaptive-vs-static comparison per scenario, shared by the
    regression assertions below (each run is seconds, so run once)."""
    return {name: compare_scenario(build("smoke", seed=0))
            for name, build in SCENARIOS.items()}


def test_drivers_emit_valid_streams():
    for name, build in SCENARIOS.items():
        scn = build("smoke", seed=0)
        t = np.asarray(scn.times)
        u = np.asarray(scn.src)
        v = np.asarray(scn.dst)
        n_cap = scn.graph.n_cap
        assert t.shape == u.shape == v.shape and t.size > 1000, name
        assert (np.diff(t) >= 0).all(), f"{name}: stream not time-ordered"
        assert ((u >= 0) & (u < n_cap)).all(), f"{name}: src out of range"
        assert ((v >= 0) & (v < n_cap)).all(), f"{name}: dst out of range"
        assert (u != v).all(), f"{name}: self-loop events"
        # deterministic under the seed
        scn2 = build("smoke", seed=0)
        assert np.array_equal(t, np.asarray(scn2.times)), name
        assert np.array_equal(u, np.asarray(scn2.src)), name


def test_cell_grid_generator_shape():
    g = generators.cell_grid(4, 5)
    assert int(g.num_nodes) == 20
    # 4-neighbourhood: 4*4 + 3*5 = 31; diagonals add 2*3*4 = 24
    assert int(g.num_edges) == 31 + 24
    g2 = generators.cell_grid(4, 5, diagonals=False)
    assert int(g2.num_edges) == 31


@pytest.mark.parametrize("name", list(SCENARIOS))
def test_adaptive_beats_static_hash(name, smoke_comparisons):
    """Fixed-seed regression: adaptive partitioning must beat static hash on
    cut ratio and on cross-partition message volume, every scenario."""
    row = smoke_comparisons[name]
    a, s = row["adaptive"], row["static"]
    assert a["cut_final"] < s["cut_final"], row
    assert a["remote_bytes"] < s["remote_bytes"], row
    assert a["exec_cost_total"] < s["exec_cost_total"], row
    # partition-relabelled BSR must tile no worse than the hash baseline
    assert a["bsr"]["nnzb"] <= s["bsr"]["nnzb"], row


def test_fem_cut_improvement_matches_paper(smoke_comparisons):
    """Paper Fig. 5/6: ≥0.6 cut improvement on the FEM workload."""
    row = smoke_comparisons["fem"]
    assert row["cut_improvement"] >= 0.6, row["cut_improvement"]


def test_exec_cost_reduction_regression(smoke_comparisons):
    """Pinned floors well under the measured smoke values (85/68/47%), so a
    regression that erodes adaptation quality fails loudly."""
    floors = {"twitter": 0.60, "fem": 0.50, "cellular": 0.30}
    for name, floor in floors.items():
        red = smoke_comparisons[name]["exec_cost_reduction_pct"] / 100.0
        assert red >= floor, f"{name}: {red:.2f} < {floor}"


def test_engine_vertex_program_hook_accounting():
    """The interleaved program must run every superstep and its message
    accounting must satisfy local + remote == 2 · live_edges · unit."""
    scn = SCENARIOS["cellular"]("smoke", seed=1)
    prog = make_program(scn.program)
    eng = StreamEngine(scn.graph, scn.stream_config(adaptive=True),
                       program=prog)
    recs = eng.run_stream(scn.times, scn.src, scn.dst, scn.batch_span,
                          max_supersteps=6)
    unit = prog.state_dim * 4
    assert eng.program_state is not None
    for r in recs:
        assert r.compute_seconds > 0.0
        assert r.local_bytes + r.remote_bytes == 2 * r.live_edges * unit, r
    # program state is finite over live vertices
    state = np.asarray(eng.program_state)
    live = np.asarray(eng.graph.node_mask)
    assert np.isfinite(state[live]).all()


# fixed shapes across examples so the jit cache is shared by the sweep
_N_CAP, _E_CAP, _K = 300, 4000, 5


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.integers(200, 900), st.integers(20, 80))
def test_migrate_never_overfills_capacity_over_random_streams(seed, n_events,
                                                              window):
    """Capacity invariant (paper §3.3): across random event sequences the
    interleaved migrate_step + online placement never push any partition
    past its hard capacity."""
    rng = np.random.default_rng(seed)
    times = np.sort(rng.integers(0, 6 * window, n_events))
    src = rng.integers(0, _N_CAP, n_events)
    dst = rng.integers(0, _N_CAP, n_events)
    keep = src != dst
    cfg = StreamConfig(k=_K, window=window, adapt_iters=3, a_cap=512,
                       d_cap=512, slack=0.2, recompute_every=0, seed=seed)
    eng = StreamEngine(empty_graph(_N_CAP, _E_CAP), cfg)
    cap = np.asarray(eng.state.capacity)
    for now, events in stream_batches(times[keep], src[keep], dst[keep],
                                      window // 2):
        eng.superstep(events, now)
        occ = np.asarray(occupancy(eng.state, eng.graph.node_mask))
        assert (occ <= cap).all(), (occ, cap)
