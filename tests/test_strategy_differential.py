"""Differential oracles for the rival partitioners (DESIGN.md §13).

Each new strategy is pinned against an independent brute-force reference on
tiny (≤12-vertex) graphs, bit for bit:

  * spinner — a numpy replay of the balanced-LPA step (same float32 op
    order, same stable admission ranking, same RNG draws) must reproduce
    every iterate exactly, and with damping off / capacity unconstrained /
    penalty weight 0 the converged state must equal an exhaustively
    computed synchronous-LPA fixpoint;
  * sdp — a numpy replay of the boundary-only strict-improvement sweep;
  * restream — an adjacency-dict streaming replay of the restreaming pass
    (an independent reimplementation, not the CSR scan under test);

plus the capacity property: spinner's balance penalty + admission never
violate capacity on graphs where *plain* LPA provably would.

The oracles recompute every decision in numpy; only the Bernoulli gate is
drawn through the identical ``jax.random`` calls, because the contract
under test is the decision logic given the draws, not the PRNG itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.api import resolve_strategy
from repro.api.strategy import StrategyContext
from repro.core.partition_state import (PartitionState, make_state,
                                        occupancy)
from repro.core.restream import restream_pass
from repro.core.sdp import sdp_refine_step
from repro.core.spinner import spinner_step
from repro.graph.structure import from_edges


def tiny_graph(seed: int, n: int = 10, e: int = 24):
    assert n <= 12, "differential oracles are exhaustive on <=12 vertices"
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    m = src != dst
    return from_edges(src[m], dst[m], num_nodes=n, n_cap=n + 2, e_cap=2 * e)


def np_counts(graph, lab: np.ndarray, k: int) -> np.ndarray:
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    em = np.asarray(graph.edge_mask)
    s2 = np.concatenate([src[em], dst[em]])
    d2 = np.concatenate([dst[em], src[em]])
    counts = np.zeros((graph.n_cap, k), np.int64)
    np.add.at(counts, (d2, np.clip(lab[s2], 0, k - 1)), 1)
    return counts


def np_occupancy(lab: np.ndarray, nm: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(np.clip(lab[nm], 0, k - 1), minlength=k)


def np_rank_within_group(group: np.ndarray, active: np.ndarray) -> np.ndarray:
    """Stable id-order rank within group — the numpy mirror of
    ``core.migration._rank_within_group``."""
    rank = np.zeros(group.shape[0], np.int64)
    for j in np.unique(group[active]):
        idx = np.flatnonzero(active & (group == j))
        rank[idx] = np.arange(idx.size)
    return rank


def np_spinner_step(graph, lab, cap, rng, *, k, w, s):
    """Numpy replay of one ``spinner_step`` — float32 ops in the identical
    order, decisions and admission recomputed from scratch."""
    nm = np.asarray(graph.node_mask)
    counts = np_counts(graph, lab, k)
    occ = np_occupancy(lab, nm, k)
    deg = counts.sum(1)
    degf = np.maximum(deg, 1).astype(np.float32)
    norm = counts.astype(np.float32) / degf[:, None]
    capf = np.maximum(cap, 1).astype(np.float32)
    penalty = np.maximum(cap - occ, 0).astype(np.float32) / capf
    score = norm + np.float32(w) * penalty[None, :]

    cur = np.clip(lab, 0, k - 1)
    cur_score = score[np.arange(lab.size), cur]
    best = score.max(1)
    isolated = (deg == 0) | ~nm
    stay = (cur_score >= best) | isolated
    target = np.where(stay, cur, score.argmax(1))

    rng, sub = jax.random.split(rng)
    gate = np.asarray(jax.random.bernoulli(sub, p=s, shape=(lab.size,)))
    willing = (target != cur) & nm & gate

    free = np.maximum(cap - occ, 0)
    rank = np_rank_within_group(target, willing)
    admitted = willing & (rank < free[np.clip(target, 0, k - 1)])
    new_lab = np.where(admitted, target, lab).astype(np.int32)
    return new_lab, rng, int(admitted.sum()), int(willing.sum())


def np_sdp_step(graph, lab, cap, rng, *, k, s):
    """Numpy replay of one ``sdp_refine_step``."""
    nm = np.asarray(graph.node_mask)
    counts = np_counts(graph, lab, k)
    occ = np_occupancy(lab, nm, k)
    capf = np.maximum(cap, 1).astype(np.float32)
    balance = np.float32(1.0) - occ.astype(np.float32) / capf
    score = counts.astype(np.float32) * balance[None, :]

    cur = np.clip(lab, 0, k - 1)
    idx = np.arange(lab.size)
    cur_count = counts[idx, cur]
    cur_score = score[idx, cur]
    deg = counts.sum(1)
    boundary = (deg - cur_count) > 0
    best = score.max(1)
    target = score.argmax(1)
    wants = boundary & (best > cur_score) & (target != cur) & nm

    rng, sub = jax.random.split(rng)
    gate = np.asarray(jax.random.bernoulli(sub, p=s, shape=(lab.size,)))
    willing = wants & gate

    free = np.maximum(cap - occ, 0)
    rank = np_rank_within_group(target, willing)
    admitted = willing & (rank < free[np.clip(target, 0, k - 1)])
    new_lab = np.where(admitted, target, lab).astype(np.int32)
    return new_lab, rng, int(admitted.sum()), int(willing.sum())


def np_lpa_fixpoint(graph, lab: np.ndarray, k: int, max_iters: int = 60):
    """Exhaustive synchronous LPA (argmax neighbour count, stay on ties,
    no damping, no capacity). Returns (labels, converged)."""
    nm = np.asarray(graph.node_mask)
    lab = lab.copy()
    for _ in range(max_iters):
        counts = np_counts(graph, lab, k)
        cur = np.clip(lab, 0, k - 1)
        idx = np.arange(lab.size)
        best = counts.max(1)
        stay = (counts[idx, cur] >= best) | (counts.sum(1) == 0) | ~nm
        new = np.where(stay, cur, counts.argmax(1)).astype(lab.dtype)
        if np.array_equal(new, lab):
            return lab, True
        lab = new
    return lab, False


# ---------------------------------------------------------------------------
# spinner
# ---------------------------------------------------------------------------

def test_spinner_step_matches_numpy_oracle_bitwise():
    for seed in range(6):
        graph = tiny_graph(seed)
        k = 3
        strat = resolve_strategy("spinner")
        state = make_state(graph, strat.init(graph, k), k, seed=seed)
        lab = np.asarray(state.assignment)
        cap = np.asarray(state.capacity)
        rng = state.rng
        for it in range(6):
            state, stats = spinner_step(state, graph, None,
                                        balance_weight=0.5, s=0.5,
                                        backend="ref")
            lab, rng, committed, willing = np_spinner_step(
                graph, lab, cap, rng, k=k, w=0.5, s=0.5)
            assert np.array_equal(np.asarray(state.assignment), lab), \
                (seed, it)
            assert int(stats.committed) == committed, (seed, it)
            assert int(stats.willing) == willing, (seed, it)


def test_spinner_unconstrained_reaches_exhaustive_lpa_fixpoint():
    # damping off (s=1), penalty off (w=0), capacity unconstrained: spinner
    # degenerates to synchronous LPA and must land on the exhaustively
    # computed fixpoint (argmax of counts/deg == argmax of counts per row)
    converged_cases = 0
    for seed in range(8):
        graph = tiny_graph(seed, n=9, e=20)
        k = 3
        lab0 = np.asarray(resolve_strategy("spinner").init(graph, k))
        oracle, converged = np_lpa_fixpoint(graph, lab0, k)
        if not converged:
            continue                       # sync LPA can 2-cycle; skip those
        converged_cases += 1
        huge = jnp.full((k,), 10_000, jnp.int32)
        state = make_state(graph, jnp.asarray(lab0), k, seed=seed,
                           capacity=huge)
        for _ in range(70):
            state, stats = spinner_step(state, graph, None,
                                        balance_weight=0.0, s=1.0,
                                        backend="ref")
            if int(stats.committed) == 0:
                break
        assert np.array_equal(np.asarray(state.assignment), oracle), seed
    assert converged_cases >= 4, "oracle never converged - graphs too hostile"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 20))
def test_spinner_capacity_never_violated_where_plain_lpa_would(seed):
    graph = tiny_graph(seed % 1000, n=10, e=26)
    k = 3
    nm = np.asarray(graph.node_mask)
    n_live = int(nm.sum())
    tight = jnp.full((k,), -(-n_live // k) + 1, jnp.int32)    # ceil + 1
    lab0 = np.asarray(resolve_strategy("spinner").init(graph, k))
    state = make_state(graph, jnp.asarray(lab0), k, seed=seed, capacity=tight)
    occ0 = np.asarray(occupancy(state, graph.node_mask))
    for _ in range(10):
        state, _ = spinner_step(state, graph, None, balance_weight=0.5,
                                s=0.5, backend="ref")
    occ = np.asarray(occupancy(state, graph.node_mask))
    assert np.all(occ <= np.maximum(occ0, np.asarray(tight)))


def test_plain_lpa_violates_capacity_on_core_graph_but_spinner_does_not():
    # a triangle core labelled 0 with two pendant leaves per core vertex
    # labelled 1: plain LPA collapses every leaf onto the core's label in
    # one sweep (the core itself stays on its 2-vs-2 tie), blowing any
    # balanced capacity; spinner's admission forbids it
    src = np.array([0, 1, 2, 0, 0, 1, 1, 2, 2], np.int64)
    dst = np.array([1, 2, 0, 3, 4, 5, 6, 7, 8], np.int64)
    n = 9
    graph = from_edges(src, dst, num_nodes=n, n_cap=n, e_cap=2 * n)
    k = 2
    lab0 = np.asarray([0, 0, 0] + [1] * 6, np.int32)          # core in 0
    cap = np.asarray([n // 2 + 1, n // 2 + 1], np.int64)

    oracle, converged = np_lpa_fixpoint(graph, lab0, k)
    assert converged
    occ_plain = np_occupancy(oracle, np.asarray(graph.node_mask), k)
    assert occ_plain[0] > cap[0], "witness broken: plain LPA must overflow"

    state = make_state(graph, jnp.asarray(lab0), k, seed=0,
                       capacity=jnp.asarray(cap, jnp.int32))
    for _ in range(12):
        state, _ = spinner_step(state, graph, None, balance_weight=0.5,
                                s=1.0, backend="ref")
    occ = np.asarray(occupancy(state, graph.node_mask))
    assert np.all(occ <= np.asarray(cap)), occ


# ---------------------------------------------------------------------------
# sdp
# ---------------------------------------------------------------------------

def test_sdp_step_matches_numpy_oracle_bitwise():
    for seed in range(6):
        graph = tiny_graph(seed)
        k = 3
        strat = resolve_strategy("sdp")
        state = make_state(graph, strat.init(graph, k), k, seed=seed)
        lab = np.asarray(state.assignment)
        cap = np.asarray(state.capacity)
        rng = state.rng
        for it in range(6):
            state, stats = sdp_refine_step(state, graph, None, s=0.5,
                                           backend="ref")
            lab, rng, committed, willing = np_sdp_step(
                graph, lab, cap, rng, k=k, s=0.5)
            assert np.array_equal(np.asarray(state.assignment), lab), \
                (seed, it)
            assert int(stats.committed) == committed, (seed, it)
            assert int(stats.willing) == willing, (seed, it)


def test_sdp_only_moves_boundary_vertices():
    # two disjoint triangles, each uniformly labelled: no vertex has an
    # external neighbour, so a refinement sweep must move nothing
    src = np.array([0, 1, 2, 3, 4, 5], np.int64)
    dst = np.array([1, 2, 0, 4, 5, 3], np.int64)
    graph = from_edges(src, dst, num_nodes=6, n_cap=6, e_cap=16)
    lab0 = np.asarray([0, 0, 0, 1, 1, 1], np.int32)
    state = make_state(graph, jnp.asarray(lab0), 2, seed=0)
    state, stats = sdp_refine_step(state, graph, None, s=1.0, backend="ref")
    assert int(stats.willing) == 0
    assert np.array_equal(np.asarray(state.assignment)[:6], lab0)


# ---------------------------------------------------------------------------
# restream
# ---------------------------------------------------------------------------

def np_restream_replay(graph, lab: np.ndarray, cap: np.ndarray, k: int):
    """Streaming replay with a plain adjacency dict — independent of the
    CSR scan in ``core.restream``."""
    nm = np.asarray(graph.node_mask)
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    em = np.asarray(graph.edge_mask)
    adj: dict = {int(v): [] for v in np.flatnonzero(nm)}
    for u, v in zip(src[em], dst[em]):
        adj[int(u)].append(int(v))
        adj[int(v)].append(int(u))
    lab = lab.astype(np.int64).copy()
    occ = [0] * k
    for v in np.flatnonzero(nm):
        occ[int(np.clip(lab[v], 0, k - 1))] += 1
    moved = 0
    for v in np.flatnonzero(nm):
        cur = int(np.clip(lab[v], 0, k - 1))
        occ[cur] -= 1
        hist = [0.0] * k
        for u in adj[int(v)]:
            if nm[u]:
                hist[int(np.clip(lab[u], 0, k - 1))] += 1.0
        scores = [hist[j] * (1.0 - occ[j] / max(cap[j], 1))
                  if occ[j] < cap[j] else -np.inf for j in range(k)]
        if all(s == -np.inf for s in scores):
            best = cur
        elif occ[cur] < cap[cur] and scores[cur] >= max(scores):
            best = cur
        else:
            best = int(np.argmax(scores))
        lab[v] = best
        occ[best] += 1
        moved += int(best != cur)
    return lab.astype(np.int32), moved


def test_restream_pass_matches_streaming_replay_bitwise():
    for seed in range(8):
        graph = tiny_graph(seed)
        k = 3
        strat = resolve_strategy("restream")
        lab0 = np.asarray(strat.init(graph, k))
        cap = np.asarray(make_state(graph, jnp.asarray(lab0), k).capacity)
        got, moved = restream_pass(graph, lab0, cap, k)
        want, moved_want = np_restream_replay(graph, lab0, cap, k)
        nm = np.asarray(graph.node_mask)
        assert np.array_equal(got[nm], want[nm]), seed
        assert moved == moved_want, seed


def test_restream_pass_is_idempotent_at_fixpoint():
    graph = tiny_graph(4)
    k = 3
    lab = np.asarray(resolve_strategy("restream").init(graph, k))
    cap = np.asarray(make_state(graph, jnp.asarray(lab), k).capacity)
    for _ in range(20):
        lab, moved = restream_pass(graph, lab, cap, k)
        if moved == 0:
            break
    lab2, moved2 = restream_pass(graph, lab, cap, k)
    assert moved2 == 0
    assert np.array_equal(lab, lab2)


def test_restream_strategy_adapt_equals_one_pass():
    graph = tiny_graph(5)
    k = 3
    strat = resolve_strategy("restream")
    state = make_state(graph, strat.init(graph, k), k, seed=3)
    ctx = StrategyContext(k=k, backend="ref")
    out = strat.adapt(graph, state, ctx)
    want, _ = restream_pass(graph, np.asarray(state.assignment),
                            np.asarray(state.capacity), k)
    assert np.array_equal(np.asarray(out.assignment), want)
