"""Minimal, deterministic stand-in for the subset of hypothesis the property
suite uses, so the invariants still *run* (as a fixed-seed sampled sweep)
when hypothesis is not installed instead of silently skipping.

Real hypothesis is preferred whenever importable (CI installs it via the
``dev`` extras) — it shrinks failures and explores adversarially. This
fallback only replays ``max_examples`` pseudo-random samples per test,
seeded from the test name so runs are reproducible.

Supported: ``@settings(max_examples=..., deadline=...)``, ``@given(...)``
with positional strategies, and the strategies ``integers``, ``booleans``,
``floats`` (finite), ``sampled_from``, ``tuples``, ``lists``.
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(seq) -> _Strategy:
    pool = list(seq)
    return _Strategy(lambda rng: pool[rng.randrange(len(pool))])


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.sample(rng) for s in strategies))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def sample(rng):
        n = rng.randint(min_size, max_size)
        return [elements.sample(rng) for _ in range(n)]
    return _Strategy(sample)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        def wrapper():
            # @settings may sit above @given (stamps the wrapper) or below
            # it (stamps the original fn) — honour both orders
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", 20))
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                args = tuple(s.sample(rng) for s in strategies)
                try:
                    fn(*args)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example (fallback sampler): "
                        f"{fn.__name__}{args}") from e
        # copy identity by hand: functools.wraps would expose the original
        # parametrised signature via __wrapped__ and pytest would demand
        # fixtures for the strategy arguments
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


class _StrategiesNamespace:
    integers = staticmethod(integers)
    booleans = staticmethod(booleans)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
    tuples = staticmethod(tuples)
    lists = staticmethod(lists)


st = _StrategiesNamespace()
