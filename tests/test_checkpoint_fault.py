"""Checkpoint atomicity / restore + trainer fault tolerance."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import TokenStream
from repro.models import TransformerConfig, init_params, lm_loss
from repro.optim import AdamWConfig
from repro.train import (FailureInjector, TrainConfig, Trainer, TrainerConfig,
                         make_train_state, make_train_step)

CFG = TransformerConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                        n_kv_heads=1, head_dim=16, d_ff=64, vocab=64)


def _state(quant=False):
    params = init_params(jax.random.PRNGKey(0), CFG)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, quantize_moments=quant),
                       warmup_steps=2, total_steps=30)
    return make_train_state(params, tcfg), tcfg


def test_checkpoint_roundtrip_plain():
    state, _ = _state()
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, use_async=False)
        ck.save(7, state)
        restored, step = ck.restore(state)
        assert step == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(d)


def test_checkpoint_roundtrip_quantized_and_bf16():
    cfg = TransformerConfig(name="bf", n_layers=1, d_model=32, n_heads=2,
                            n_kv_heads=1, head_dim=16, d_ff=64, vocab=64,
                            param_dtype=jnp.bfloat16)
    params = init_params(jax.random.PRNGKey(1), cfg)
    tcfg = TrainConfig(optimizer=AdamWConfig(quantize_moments=True))
    state = make_train_state(params, tcfg)
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, use_async=False)
        ck.save(3, state)
        restored, _ = ck.restore(state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
    finally:
        shutil.rmtree(d)


def test_partial_checkpoint_never_restored():
    state, _ = _state()
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, use_async=False)
        ck.save(5, state)
        # simulate a crash mid-write of step 9: tmp dir without manifest
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        # and a committed-looking dir without manifest (torn rename)
        os.makedirs(os.path.join(d, "step_00000011"))
        assert ck.latest_step() == 5
        _, step = ck.restore(state)
        assert step == 5
    finally:
        shutil.rmtree(d)


def test_trainer_recovers_from_injected_failures():
    state, tcfg = _state()
    ts = TokenStream(vocab=64, seq_len=16, batch=4, seed=0)
    step_fn = make_train_step(lambda p, b: lm_loss(p, b, CFG), tcfg)
    d = tempfile.mkdtemp()
    try:
        trainer = Trainer(
            TrainerConfig(total_steps=30, checkpoint_every=10,
                          checkpoint_dir=d, log_every=10),
            step_fn, ts.batch_at,
            injector=FailureInjector(fail_at=(15, 25)))
        out = trainer.run(state)
        assert trainer.restarts == 2
        assert trainer.ckpt.latest_step() == 30
        losses = [m["loss"] for m in trainer.metrics_log]
        assert losses[-1] < losses[0]
    finally:
        shutil.rmtree(d)


def test_trainer_resumes_from_existing_checkpoint():
    state, tcfg = _state()
    ts = TokenStream(vocab=64, seq_len=16, batch=4, seed=0)
    step_fn = make_train_step(lambda p, b: lm_loss(p, b, CFG), tcfg)
    d = tempfile.mkdtemp()
    try:
        t1 = Trainer(TrainerConfig(total_steps=20, checkpoint_every=10,
                                   checkpoint_dir=d, log_every=10),
                     step_fn, ts.batch_at)
        s1 = t1.run(state)
        # new trainer continues to 30 from the stored step-20 checkpoint
        t2 = Trainer(TrainerConfig(total_steps=30, checkpoint_every=10,
                                   checkpoint_dir=d, log_every=10),
                     step_fn, ts.batch_at)
        t2.run(state)
        assert t2.ckpt.latest_step() == 30
    finally:
        shutil.rmtree(d)
