"""Observability-layer tests (DESIGN.md §11).

Four groups:
  * schema snapshots — ``SuperstepRecord.as_dict()`` keys and the
    trace/metrics JSONL formats are contracts; exporters fail loudly here
    instead of drifting silently;
  * tracer/metrics mechanics — nesting, exports, the null-object path;
  * the overhead budget — enabled tracing costs <3% of superstep wall
    time, the disabled path touches no clock and allocates nothing;
  * traced smoke — a traced session on the local backend in-process, and
    the sharded backend (with the comm probe) in a subprocess under 8 fake
    devices, both validated against the schema and the named-span list the
    bench deliverable relies on.
"""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import DynamicGraphSystem, PartitionSection, SystemConfig
from repro.api.config import GraphSection, TelemetrySection
from repro.api.telemetry import SuperstepRecord
from repro.graph import generators
from repro.obs import (MetricsRegistry, NULL_TRACER, Tracer, config_hash,
                       kernel_profile, plan_cost, record_cluster,
                       record_superstep, run_manifest)
from repro.obs.report import main as report_main
from repro.obs.schema import (SchemaError, validate_metrics_file,
                              validate_trace_file, validate_trace_line)
from repro.obs.trace import _NULL_SPAN

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _events(n: int, n_nodes: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.stack([np.arange(n) // 4,
                     rng.integers(0, n_nodes, n),
                     rng.integers(0, n_nodes, n)], axis=1).astype(np.int64)


def _session(trace: bool, **tele) -> DynamicGraphSystem:
    cfg = SystemConfig(
        graph=GraphSection(n_cap=256, e_cap=2048),
        partition=PartitionSection(strategy="xdgp", k=4, adapt_iters=2),
        telemetry=TelemetrySection(trace=trace, **tele))
    return DynamicGraphSystem(None, cfg)


# ---------------------------------------------------------------------------
# Telemetry schema snapshots
# ---------------------------------------------------------------------------

# the exporter contract: SuperstepRecord.as_dict() keys, frozen.  A field
# added to the record must be added HERE and to the metrics mapping
# (repro.obs.metrics) in the same change.
RECORD_KEYS = (
    "superstep", "now", "events", "adds", "dels", "backlog_adds",
    "backlog_dels", "invalid_events", "stale_dropped", "new_placed",
    "migrations", "cut_edges", "live_edges", "cut_ratio", "imbalance",
    "ingest_seconds", "step_seconds", "drift", "dup_dropped",
    "local_bytes", "remote_bytes", "compute_seconds", "halo_bytes",
    "halo_live_bytes", "collective_bytes", "events_per_second",
)


def test_superstep_record_as_dict_keys_frozen():
    rec = SuperstepRecord(superstep=1, now=0, events=0, adds=0, dels=0,
                          backlog_adds=0, backlog_dels=0, invalid_events=0,
                          stale_dropped=0, new_placed=0, migrations=0,
                          cut_edges=0, live_edges=0, cut_ratio=0.0,
                          imbalance=1.0, ingest_seconds=0.0,
                          step_seconds=0.0, drift=None)
    assert tuple(rec.as_dict()) == RECORD_KEYS


def test_record_metrics_mapping_covers_every_numeric_field():
    # every record field lands in exactly one metric family
    from repro.obs.metrics import (_RECORD_COUNTERS, _RECORD_GAUGES,
                                   _RECORD_HISTOGRAMS)
    mapped = set(_RECORD_COUNTERS) | set(_RECORD_GAUGES) | \
        set(_RECORD_HISTOGRAMS)
    fields = set(RECORD_KEYS) - {"drift", "events_per_second"}
    assert mapped == fields
    assert not (set(_RECORD_COUNTERS) & set(_RECORD_GAUGES))


# ---------------------------------------------------------------------------
# Tracer mechanics + trace schema
# ---------------------------------------------------------------------------

def test_tracer_spans_nest_and_export(tmp_path):
    tr = Tracer(meta={"label": "t"})
    with tr.span("superstep", superstep=1):
        with tr.span("ingest"):
            pass
        with tr.span("migrate") as sp:
            sp.set(moved=3)
            sp.fence(jnp.ones(4))
    tr.add_span("comm/halo_exchange", 0.002, probed=True)
    tr.counter("migrations", 3)
    names = [e["name"] for e in tr.events if e["type"] == "span"]
    # children emit at exit, before their parent
    assert names == ["ingest", "migrate", "superstep",
                     "comm/halo_exchange"]
    by = {e["name"]: e for e in tr.events if e["type"] == "span"}
    assert by["superstep"]["depth"] == 0 and by["ingest"]["depth"] == 1
    assert by["migrate"]["attrs"]["moved"] == 3
    # children are contained in the parent interval (Perfetto nesting)
    for child in ("ingest", "migrate"):
        assert by[child]["ts_us"] >= by["superstep"]["ts_us"]
        assert (by[child]["ts_us"] + by[child]["dur_us"]
                <= by["superstep"]["ts_us"] + by["superstep"]["dur_us"] + 1)

    p = tr.write_jsonl(str(tmp_path / "t.jsonl"))
    events = validate_trace_file(p)
    assert len(events) == len(tr.events)
    header = json.loads(open(p).read().splitlines()[0])
    assert header["type"] == "meta" and header["label"] == "t"

    chrome = tr.write_chrome(str(tmp_path / "t.trace.json"))
    doc = json.load(open(chrome))
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"M", "X", "C"} <= phases

    totals = tr.phase_totals()
    assert totals["superstep"]["count"] == 1
    assert totals["comm/halo_exchange"]["total_s"] == pytest.approx(0.002)


def test_trace_schema_rejects_bad_lines(tmp_path):
    with pytest.raises(SchemaError, match="negative dur_us"):
        validate_trace_line({"type": "span", "name": "x", "ts_us": 0,
                             "dur_us": -1, "depth": 0})
    with pytest.raises(SchemaError, match="unknown event type"):
        validate_trace_line({"type": "spam", "name": "x"})
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"type": "meta", "schema": 999, "clock": '
                   '"perf_counter_ns", "unit": "us"}\n')
    with pytest.raises(SchemaError, match="schema"):
        validate_trace_file(str(bad))


# ---------------------------------------------------------------------------
# Metrics registry + metrics schema
# ---------------------------------------------------------------------------

def test_metrics_registry_exports(tmp_path):
    reg = MetricsRegistry(namespace="t")
    reg.counter("events_total", "events seen").inc(5)
    reg.counter("events_total").inc(2, backend="sharded")
    reg.gauge("cut_ratio").set(0.25)
    reg.histogram("step_seconds").observe(0.004)
    reg.histogram("step_seconds").observe(9.0)   # beyond last bucket
    with pytest.raises(ValueError, match="cannot decrease"):
        reg.counter("events_total").inc(-1)
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("events_total")

    p = reg.write_jsonl(str(tmp_path / "m.jsonl"))
    samples = validate_metrics_file(p)
    by = {(s["name"], tuple(sorted(s["labels"].items()))): s["value"]
          for s in samples}
    assert by[("t_events_total", ())] == 5
    assert by[("t_events_total", (("backend", "sharded"),))] == 2
    # +Inf bucket counts every observation; the 9.0 one only lands there
    assert by[("t_step_seconds_bucket", (("le", "+Inf"),))] == 2
    assert by[("t_step_seconds_count", ())] == 2

    text = reg.to_prometheus()
    assert "# TYPE t_events_total counter" in text
    assert 't_events_total{backend="sharded"} 2.0' in text
    assert '# HELP t_events_total events seen' in text
    assert 't_step_seconds_bucket{le="+Inf"} 2.0' in text


def test_record_superstep_and_cluster_feed():
    reg = MetricsRegistry()
    rec = SuperstepRecord(superstep=1, now=10, events=20, adds=5, dels=1,
                          backlog_adds=0, backlog_dels=0, invalid_events=0,
                          stale_dropped=0, new_placed=3, migrations=7,
                          cut_edges=4, live_edges=16, cut_ratio=0.25,
                          imbalance=1.1, ingest_seconds=0.001,
                          step_seconds=0.02, drift=None, halo_bytes=64)
    record_superstep(reg, rec, backend="local")
    assert reg.counter("migrations_total").values[
        (("backend", "local"),)] == 7
    assert reg.gauge("cut_ratio").values[(("backend", "local"),)] == 0.25
    record_cluster(reg, None)                     # local backend: no-op
    record_cluster(reg, {
        "devices": 2, "halo_slots": 4, "boundary_live_per_device": [3, 2],
        "halo_bytes_per_iter_per_device": 32,
        "halo_live_bytes_per_iter_per_device": 24,
        "collective_bytes_per_iter_per_device": 16,
        "halo_bytes_total": 640, "halo_live_bytes_total": 480,
        "collective_bytes_total": 320,
        "iterations_total": 10, "compiled_steps": 1})
    assert reg.gauge("cluster_devices").values[()] == 2
    assert reg.gauge("cluster_boundary_live").values[
        (("device", "1"),)] == 2


# ---------------------------------------------------------------------------
# Manifest / profiling / common.timed
# ---------------------------------------------------------------------------

def test_run_manifest_and_config_hash():
    cfg = SystemConfig()
    m = run_manifest(cfg, label="test")
    for key in ("manifest_version", "git_sha", "python", "timestamp_utc",
                "jax_version", "backend", "device_count", "config_hash"):
        assert key in m, key
    assert m["label"] == "test"
    assert m["config_hash"] == config_hash(cfg)
    assert config_hash(cfg) != config_hash(cfg.with_seed(1))


def test_save_attaches_manifest(tmp_path, monkeypatch):
    import benchmarks.common as common
    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    path = common.save("x", {"rows": [1, 2]})
    doc = json.load(open(path))
    assert doc["rows"] == [1, 2]
    assert doc["manifest"]["manifest_version"] == 1
    assert "jax_version" in doc["manifest"]


def test_timed_fences_and_warms_up():
    import benchmarks.common as common
    calls = []

    def fn(x):
        calls.append(1)
        return x * 2
    out, dt = common.timed(fn, jnp.ones(4), repeats=2, warmup=1)
    assert len(calls) == 3 and dt >= 0
    assert float(out[0]) == 2.0


def test_plan_cost_all_kinds():
    from repro.kernels.migration_kernels import build_plan
    g = generators.fem_grid2d(8)
    for executor, kinds in (("native", ("bsr",)), ("jax", ("ell", "flat"))):
        plan = build_plan(g, executor=executor)
        assert plan.kind in kinds + ("flat",)
        c = plan_cost(plan, g, k=4)
        assert c["kind"] == plan.kind
        assert c["flops"] > 0 and c["hbm_bytes"] > 0
        assert c["t_bound_s"] == max(c["t_compute_s"], c["t_memory_s"])
        assert c["dominant"] in ("compute", "memory")
    c = plan_cost(None, g, k=4)                   # no plan → flat estimate
    assert c["kind"] == "flat" and c["live_edges2"] == c["edges2"]


def test_kernel_profile_disabled_is_noop():
    with kernel_profile(None) as status:
        pass
    assert status["enabled"] is False and status["error"] is None
    with kernel_profile("/tmp/x", enabled=False) as status:
        pass
    assert status["enabled"] is False


# ---------------------------------------------------------------------------
# Traced sessions: local smoke, disabled null path, overhead budget
# ---------------------------------------------------------------------------

LOCAL_PHASES = {"superstep", "ingest", "place", "migrate",
                "kernel/score_select", "commit"}
SHARDED_PHASES = {"superstep", "ingest", "place", "migrate", "commit",
                  "cluster/bucket", "cluster/recompile", "cluster/dispatch",
                  "cluster/host_sync", "cluster/flush", "obs/comm_probe",
                  "comm/halo_exchange", "comm/quota_collective",
                  "kernel/score"}


def test_traced_local_session(tmp_path):
    system = _session(trace=True, metrics=True)
    ev = _events(300, 200)
    for i in range(3):
        system.step(ev[i * 100:(i + 1) * 100])
    assert set(system.tracer.phase_totals()) == LOCAL_PHASES
    assert system.tracer.phase_totals()["superstep"]["count"] == 3
    p = system.tracer.write_jsonl(str(tmp_path / "local.jsonl"))
    validate_trace_file(p)
    # the metrics feed saw every superstep
    assert system.metrics.counter("events_total").values[
        (("backend", "local"),)] == 300


def test_disabled_session_is_null_path():
    system = _session(trace=False)
    system.step(_events(100, 200))
    assert system.tracer is NULL_TRACER
    assert system.metrics is None
    assert system.tracer.events == ()
    # the null tracer hands out ONE shared span object: no allocation,
    # no clock reads on the disabled hot path
    assert NULL_TRACER.span("x") is _NULL_SPAN
    assert NULL_TRACER.span("y", a=1) is _NULL_SPAN
    _NULL_SPAN.fence(jnp.ones(2))                 # no-op, takes anything


def test_tracing_overhead_under_3pct():
    """The §11 budget: enabled tracing costs <3% of superstep wall time.

    Two identical sessions consume the same stream; batches are timed
    interleaved and the min over rounds taken on both sides (min-of-N is
    robust to scheduler noise in a way means are not).  A small absolute
    epsilon guards the comparison on very fast hosts.
    """
    ev = _events(4000, 200, seed=3)
    plain = _session(trace=False)
    traced = _session(trace=True)
    # warmup: absorb jit compilation on both sides
    for i in range(2):
        plain.step(ev[i * 100:(i + 1) * 100])
        traced.step(ev[i * 100:(i + 1) * 100])
    best = {"plain": float("inf"), "traced": float("inf")}
    for j, r in enumerate(range(2, 18, 2)):
        batches = [ev[i * 100:(i + 1) * 100] for i in range(r, r + 2)]
        sides = [("plain", plain), ("traced", traced)]
        if j % 2:                       # alternate order: a load trend during
            sides.reverse()             # the test biases both sides equally
        for tag, system in sides:
            t0 = time.perf_counter()
            for b in batches:
                system.step(b)
            best[tag] = min(best[tag], time.perf_counter() - t0)
    best_plain, best_traced = best["plain"], best["traced"]
    assert best_traced <= best_plain * 1.03 + 1e-3, \
        f"tracing overhead {best_traced / best_plain - 1:.1%} " \
        f"(plain {best_plain * 1e3:.2f}ms, traced {best_traced * 1e3:.2f}ms)"


# ---------------------------------------------------------------------------
# Report CLI
# ---------------------------------------------------------------------------

def _write_trace(path, scale=1.0):
    tr = Tracer(meta={"label": "x"})
    with tr.span("superstep"):
        time.sleep(0.001)
        # synthetic span: exact duration, so the a-vs-b comparison below is
        # deterministic under suite load (a real sleep can overshoot 3x)
        tr.add_span("migrate", 0.002 * scale)
    tr.write_jsonl(str(path))


def test_report_cli_single_and_compare(tmp_path, capsys):
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write_trace(a)
    _write_trace(b, scale=3.0)
    assert report_main([str(a)]) == 0
    out = capsys.readouterr().out
    assert "superstep" in out and "migrate" in out and "share" in out
    assert report_main([str(a), str(b)]) == 0
    out = capsys.readouterr().out
    assert "ratio" in out and "vs" in out
    assert report_main([str(a), str(b), "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["b"]["phases"]["migrate"]["total_s"] > \
        doc["a"]["phases"]["migrate"]["total_s"]


# ---------------------------------------------------------------------------
# Sharded traced smoke (subprocess under 8 fake devices)
# ---------------------------------------------------------------------------

def test_traced_sharded_session_names_comm_phases(tmp_path):
    out = _run(f"""
import numpy as np
from repro.api import DynamicGraphSystem, PartitionSection, SystemConfig
from repro.api.config import GraphSection, TelemetrySection
from repro.obs.schema import validate_trace_file

cfg = SystemConfig(graph=GraphSection(n_cap=256, e_cap=2048),
                   partition=PartitionSection(strategy="xdgp", k=8,
                                              adapt_iters=2),
                   telemetry=TelemetrySection(trace=True,
                                              trace_comm_probe=True))
rng = np.random.default_rng(0)
ev = np.stack([np.arange(300) // 4, rng.integers(0, 200, 300),
               rng.integers(0, 200, 300)], 1).astype(np.int64)
local = DynamicGraphSystem(None, cfg)
sharded = DynamicGraphSystem(None, cfg).distribute()
for i in range(3):
    local.step(ev[i * 100:(i + 1) * 100])
    sharded.step(ev[i * 100:(i + 1) * 100])
assert bool((local.labels == sharded.labels).all()), "parity broke"
path = sharded.tracer.write_jsonl({str(tmp_path / 'sh.jsonl')!r})
validate_trace_file(path)
print(sorted(sharded.tracer.phase_totals()))
""")
    phases = set(eval(out.strip().splitlines()[-1]))
    assert phases == SHARDED_PHASES
    # the committed deliverable's named spans, explicitly:
    for must in ("comm/halo_exchange", "comm/quota_collective",
                 "kernel/score", "cluster/host_sync"):
        assert must in phases, must


def test_telemetry_section_round_trips_new_knobs():
    cfg = SystemConfig(telemetry=TelemetrySection(
        trace=True, trace_comm_probe=True, metrics=True))
    assert SystemConfig.from_dict(cfg.to_dict()) == cfg
    with pytest.raises(ValueError, match="unknown keys.*telemetry"):
        SystemConfig.from_dict({"telemetry": {"tracing": True}})
