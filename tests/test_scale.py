"""Scale-tier suite (DESIGN.md §14): streaming generators, chunked BSR,
overflow guards, and the SystemConfig wiring.

The load-bearing pins:

* chunked ``graph_to_bsr_chunked`` is **bit-identical** to the monolithic
  ``graph_to_bsr`` (property test over blk / normalize / chunk size);
* generators replay deterministically per chunk and show a power-law tail;
* every int32 container on the scale path fails loudly at its boundary
  instead of wrapping (BSR indices, quota rank keys);
* a generator-named ``GraphSection`` builds a working session unchanged
  through both execution backends.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                     # pragma: no cover
    from _hypothesis_fallback import given, settings, st

import jax

from repro.graph.bsr import check_int32_index, graph_to_bsr
from repro.graph.structure import from_edges
from repro.scale import (ChungLuStream, MemoryBudgetError, RmatStream,
                         chunk_rng, graph_to_bsr_chunked, make_edge_stream,
                         session_graph, stream_events, stream_to_graph)

needs_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


# ---------------------------------------------------------------------------
# generators: deterministic replay + power-law shape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["rmat", "chung_lu"])
def test_chunk_replay_is_deterministic(name):
    st1 = make_edge_stream(name, 4000, avg_degree=6.0, chunk_edges=2048,
                           seed=11)
    st2 = make_edge_stream(name, 4000, avg_degree=6.0, chunk_edges=2048,
                           seed=11)
    assert st1.num_chunks > 1
    for i in range(st1.num_chunks):
        for a, b in zip(st1.chunk(i), st2.chunk(i)):
            assert np.array_equal(a, b)
    # chunks are independently regenerable: out-of-order == in-order
    last = st1.num_chunks - 1
    tail_first = st1.chunk(last)
    for a, b in zip(tail_first, st2.chunk(last)):
        assert np.array_equal(a, b)


@pytest.mark.parametrize("name", ["rmat", "chung_lu"])
def test_different_seeds_diverge(name):
    a = make_edge_stream(name, 4000, avg_degree=4.0, seed=1).chunk(0)
    b = make_edge_stream(name, 4000, avg_degree=4.0, seed=2).chunk(0)
    assert not (a[0].shape == b[0].shape and np.array_equal(a[0], b[0]))


def test_chunks_are_entropy_separated():
    # chunk i and chunk j draw from disjoint SeedSequence pools
    r0 = chunk_rng(5, 0).random(8)
    r1 = chunk_rng(5, 1).random(8)
    assert not np.array_equal(r0, r1)


@pytest.mark.parametrize("name", ["rmat", "chung_lu"])
def test_degree_distribution_has_power_law_tail(name):
    n = 20000
    g = stream_to_graph(make_edge_stream(name, n, avg_degree=8.0, seed=3))
    deg = np.asarray(g.degrees())
    deg = deg[deg > 0]
    mean = deg.mean()
    # a heavy tail: the max degree is far above the mean (an Erdős–Rényi
    # graph at this size would have max/mean ≈ 3), and the top percentile
    # holds a disproportionate share of the edge endpoints
    assert deg.max() > 10 * mean
    top = np.sort(deg)[-len(deg) // 100:]
    assert top.sum() > 0.05 * deg.sum()
    # log-log tail slope: P(D >= d) for a power law with exponent gamma
    # decays ~ d^(1-gamma); fit over the upper decade and sanity-bound it
    ds = np.sort(deg)
    ccdf = 1.0 - np.arange(len(ds)) / len(ds)
    lo_d = max(int(mean), 2)
    sel = (ds >= lo_d) & (ccdf > 1e-4)
    slope = np.polyfit(np.log(ds[sel]), np.log(ccdf[sel]), 1)[0]
    assert -4.0 < slope < -0.5, f"tail slope {slope} not power-law-like"


def test_stream_to_graph_matches_from_edges():
    stream = make_edge_stream("rmat", 3000, avg_degree=5.0, chunk_edges=1024,
                              seed=9)
    g = stream_to_graph(stream)
    src = np.concatenate([s for s, _ in stream])
    dst = np.concatenate([d for _, d in stream])
    ref = from_edges(src, dst, stream.n)
    for field in ("src", "dst", "node_mask", "edge_mask"):
        assert np.array_equal(np.asarray(getattr(g, field)),
                              np.asarray(getattr(ref, field))), field


def test_stream_events_timestamps_advance():
    stream = make_edge_stream("chung_lu", 1000, avg_degree=4.0,
                              chunk_edges=512, seed=4)
    batches = list(stream_events(stream, t0=10, span_per_chunk=5))
    assert len(batches) == stream.num_chunks
    t = np.concatenate([b[:, 0] for b in batches])
    assert np.all(np.diff(t) >= 0) and t[0] == 10


# ---------------------------------------------------------------------------
# chunked BSR: bit-identity, budget, guards
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.sampled_from([8, 16, 32]),
       st.sampled_from([None, "sym", "row"]),
       st.sampled_from([64, 257, 1000]))
def test_chunked_bsr_bit_identical(seed, blk, normalize, chunk_edges):
    stream = make_edge_stream("rmat", 700, avg_degree=5.0, seed=seed % 1000)
    g = stream_to_graph(stream)
    ref = graph_to_bsr(g, blk=blk, normalize=normalize)
    out = graph_to_bsr_chunked(g, blk=blk, normalize=normalize,
                               chunk_edges=chunk_edges)
    assert np.array_equal(np.asarray(ref.blocks), np.asarray(out.blocks))
    assert np.array_equal(np.asarray(ref.row_ptr), np.asarray(out.row_ptr))
    assert np.array_equal(np.asarray(ref.block_cols),
                          np.asarray(out.block_cols))
    assert int(ref.nnzb) == int(out.nnzb)


def test_chunked_bsr_empty_graph():
    from repro.api.system import empty_graph
    g = empty_graph(64, 32)
    ref = graph_to_bsr(g, blk=8)
    out = graph_to_bsr_chunked(g, blk=8, chunk_edges=4)
    assert np.array_equal(np.asarray(ref.blocks), np.asarray(out.blocks))
    assert int(out.nnzb) == 0


def test_chunked_bsr_respects_nnzb_cap():
    g = stream_to_graph(make_edge_stream("rmat", 500, avg_degree=4.0, seed=1))
    ref = graph_to_bsr(g, blk=16, nnzb_cap=5000)
    out = graph_to_bsr_chunked(g, blk=16, nnzb_cap=5000, chunk_edges=100)
    assert ref.blocks.shape == out.blocks.shape
    assert np.array_equal(np.asarray(ref.blocks), np.asarray(out.blocks))
    with pytest.raises(ValueError, match="nnzb_cap"):
        graph_to_bsr_chunked(g, blk=16, nnzb_cap=1)


def test_memory_budget_fails_loudly_before_allocating():
    g = stream_to_graph(make_edge_stream("rmat", 2000, avg_degree=6.0, seed=2))
    with pytest.raises(MemoryBudgetError, match="memory_budget"):
        graph_to_bsr_chunked(g, blk=8, memory_budget=10_000)
    # a generous budget packs fine
    out = graph_to_bsr_chunked(g, blk=8, memory_budget=1 << 30)
    assert int(out.nnzb) > 0


def test_int32_guard_boundary():
    assert check_int32_index(2 ** 31 - 1, "x") == 2 ** 31 - 1
    with pytest.raises(OverflowError, match="overflows int32"):
        check_int32_index(2 ** 31, "nnzb")


def test_monolithic_bsr_guard_trips_on_impossible_tiling():
    # n_blocks for a 10M-vertex graph at blk=128 is fine; fabricate the
    # overflow through the guard (the full graph would not fit in CI)
    with pytest.raises(OverflowError):
        check_int32_index((2 ** 33), "n_blocks (tile rows)")


# ---------------------------------------------------------------------------
# quota rank keys: widening + boundary behaviour
# ---------------------------------------------------------------------------

def test_rank_key_dtype_cascade():
    import jax.numpy as jnp
    from repro.core.distributed import rank_key_dtype
    assert rank_key_dtype(8, 100_000) == jnp.int32
    assert rank_key_dtype(8, 10_000_000) == jnp.int32     # 6.5e8 keys
    assert rank_key_dtype(8, 40_000_000) == jnp.uint32    # 2.6e9 keys
    boundary = (2 ** 31 - 8) // 65                        # k=8: spans 2^31-ish
    assert rank_key_dtype(8, boundary) == jnp.int32
    assert rank_key_dtype(8, boundary + 1) == jnp.uint32
    if jax.dtypes.canonicalize_dtype(jnp.int64) != jnp.int64:
        with pytest.raises(OverflowError, match="uint32"):
            rank_key_dtype(32, 1_000_000_000)


@needs_devices
def test_cluster_step_bit_identical_under_uint32_keys():
    """Forcing the widened key dtype must not change a single admission
    decision: ranks are dtype-invariant by construction."""
    import jax.numpy as jnp
    from repro.api import DynamicGraphSystem, SystemConfig
    from repro.api.config import ClusterSection, PartitionSection

    def run(key_dtype):
        import repro.core.distributed as dist
        cfg = SystemConfig(partition=PartitionSection(k=8, adapt_iters=2),
                           cluster=ClusterSection(backend="sharded"), seed=3)
        g = stream_to_graph(make_edge_stream("rmat", 600, avg_degree=5.0,
                                             seed=5))
        orig = dist.make_cluster_step
        if key_dtype is not None:
            def forced(mesh, **kw):
                kw["key_dtype"] = key_dtype
                return orig(mesh, **kw)
            dist.make_cluster_step = forced
        try:
            system = DynamicGraphSystem(g, cfg)
            system.adapt(3)
            return np.asarray(system.state.assignment)
        finally:
            dist.make_cluster_step = orig

    a32 = run(None)                 # auto (int32 at this size)
    a_u32 = run(jnp.uint32)         # forced wide path
    assert np.array_equal(a32, a_u32)


# ---------------------------------------------------------------------------
# SystemConfig wiring: generator sessions through both backends
# ---------------------------------------------------------------------------

def _gen_cfg(backend="local", n=1500):
    from repro.api import SystemConfig
    from repro.api.config import (ClusterSection, GraphSection,
                                  PartitionSection)
    return SystemConfig(
        graph=GraphSection(generator="rmat", n=n, avg_degree=4.0,
                           chunk_edges=1024),
        partition=PartitionSection(k=4, adapt_iters=2),
        cluster=ClusterSection(backend=backend), seed=7)


def test_generator_config_round_trips():
    from repro.api import SystemConfig
    cfg = _gen_cfg()
    assert SystemConfig.from_dict(cfg.to_dict()) == cfg
    with pytest.raises(ValueError, match="graph.n >= 2"):
        from repro.api.config import GraphSection
        GraphSection(generator="rmat")


def test_generator_session_local():
    from repro.api import DynamicGraphSystem
    from repro.stream.metrics import cut_ratio_of
    system = DynamicGraphSystem(config=_gen_cfg())
    assert int(system.graph.num_nodes) == 1500
    assert int(system.graph.num_edges) > 1000
    assert system.graph.e_cap > int(system.graph.num_edges)  # stream head-room
    before = float(cut_ratio_of(system.tracker))
    # live events stream in through the unchanged step() path
    live = make_edge_stream("rmat", 1500, avg_degree=1.0, seed=8)
    for batch in stream_events(live, t0=1):
        system.step(batch)
    system.adapt(4)
    assert float(cut_ratio_of(system.tracker)) < before


def test_generator_session_deterministic_by_seed():
    from repro.api import DynamicGraphSystem
    g1 = DynamicGraphSystem(config=_gen_cfg()).graph
    g2 = DynamicGraphSystem(config=_gen_cfg()).graph
    assert np.array_equal(np.asarray(g1.src), np.asarray(g2.src))


def test_session_graph_respects_explicit_caps():
    from repro.api.config import GraphSection
    sec = GraphSection(generator="chung_lu", n=800, avg_degree=4.0,
                       chunk_edges=512, n_cap=1000, e_cap=5000)
    g = session_graph(sec, seed=1)
    assert g.n_cap == 1000 and g.e_cap == 5000
    with pytest.raises(ValueError, match="capacity too small"):
        session_graph(GraphSection(generator="chung_lu", n=800,
                                   avg_degree=4.0, e_cap=3), seed=1)


def test_unknown_generator_fails_loudly():
    with pytest.raises(ValueError, match="unknown scale generator"):
        make_edge_stream("barabasi", 100)


@needs_devices
def test_generator_session_sharded_matches_local():
    from repro.api import DynamicGraphSystem
    local = DynamicGraphSystem(config=_gen_cfg("local", n=800))
    shard = DynamicGraphSystem(config=_gen_cfg("sharded", n=800))
    # k=4 <= 8 devices? sharded requires k == devices when devices=0 → k
    local.adapt(3)
    shard.adapt(3)
    assert np.array_equal(np.asarray(local.state.assignment),
                          np.asarray(shard.state.assignment))


# ---------------------------------------------------------------------------
# sweep result schema
# ---------------------------------------------------------------------------

def _scale_payload():
    row = {"vertices": 1000, "backend": "local", "edges": 2000, "events": 500,
           "supersteps": 3, "migrations": 10, "build_seconds": 0.5,
           "ingest_events_per_sec": 1e5, "superstep_seconds": 0.1,
           "adapt_seconds": 0.2, "cut_before": 0.9, "cut_after": 0.4,
           "bsr": {"nnzb": 4, "blocks_bytes": 262144, "build_seconds": 0.01},
           "peak_rss_bytes": 1 << 28}
    return {"bench": "scale_sweep", "generator": "rmat", "k": 8,
            "chunk_edges": 1024, "sizes": [1000], "backends": ["local"],
            "rows": [row]}


def test_scale_bench_schema_accepts_and_rejects():
    from repro.obs.schema import SchemaError, validate_scale_bench
    validate_scale_bench(_scale_payload())
    # a budget refusal is a legal bsr outcome
    p = _scale_payload()
    p["rows"][0]["bsr"] = {"skipped": "memory_budget: needs 3 GiB"}
    validate_scale_bench(p)
    # missing cells, zero RSS, and out-of-range cuts all fail loudly
    p = _scale_payload()
    p["backends"] = ["local", "sharded"]
    with pytest.raises(SchemaError, match="cross product"):
        validate_scale_bench(p)
    p = _scale_payload()
    p["rows"][0]["peak_rss_bytes"] = 0
    with pytest.raises(SchemaError, match="peak_rss_bytes"):
        validate_scale_bench(p)
    p = _scale_payload()
    p["rows"][0]["cut_after"] = 1.5
    with pytest.raises(SchemaError, match="out of"):
        validate_scale_bench(p)


def test_peak_rss_probe_and_superstep_gauge():
    from repro.obs.metrics import MetricsRegistry, record_superstep
    from repro.obs.profiling import memory_probe, peak_rss_bytes
    assert peak_rss_bytes() > 0
    probe = memory_probe()
    assert probe["peak_rss_bytes"] >= (probe["current_rss_bytes"] or 0)
    from repro.api.telemetry import SuperstepRecord
    rec = SuperstepRecord(superstep=1, now=0, events=0, adds=0, dels=0,
                          backlog_adds=0, backlog_dels=0, invalid_events=0,
                          stale_dropped=0, new_placed=0, migrations=0,
                          cut_edges=0, live_edges=0, cut_ratio=0.0,
                          imbalance=1.0, ingest_seconds=0.0,
                          step_seconds=0.0, drift=None)
    reg = MetricsRegistry()
    record_superstep(reg, rec)
    val = reg.gauge("peak_rss_bytes").values[()]
    assert val > 0
    assert "peak_rss_bytes" in reg.to_prometheus()
