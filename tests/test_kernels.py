"""Pallas kernel correctness sweeps (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph import generators
from repro.graph.bsr import graph_to_bsr
from repro.kernels import ref
from repro.kernels.bsr_spmm import bsr_spmm, max_tiles_per_row
from repro.kernels.embedding_bag import embedding_bag_sum
from repro.kernels.flash_attention import flash_attention

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,h,kv,sq,sk,d", [
    (2, 4, 2, 128, 128, 64),
    (1, 8, 1, 256, 256, 64),      # MQA
    (2, 4, 4, 128, 256, 32),      # cross lengths (non-causal only)
    (1, 2, 2, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, h, kv, sq, sk, d, dtype):
    causal = sq == sk
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, sk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64, interpret=True)
    exp = ref.ref_flash_attention(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window,cap", [(0, None), (64, None), (0, 30.0),
                                        (32, 50.0)])
def test_flash_attention_window_softcap(window, cap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    out = flash_attention(q, k, v, causal=True, window=window, softcap=cap,
                          bq=64, bk=64, interpret=True)
    exp = ref.ref_flash_attention(q, k, v, causal=True, window=window,
                                  softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("graph,blk,d", [
    ("fem", 64, 16), ("fem", 128, 8), ("plc", 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bsr_spmm_sweep(graph, blk, d, dtype):
    g = generators.fem_cube(8) if graph == "fem" else generators.power_law(
        500, seed=1)
    bsr = graph_to_bsr(g, blk=blk)
    x = jax.random.normal(KEY, (bsr.n_blocks * blk, d), dtype)
    mpr = max_tiles_per_row(np.asarray(bsr.row_ptr))
    out = bsr_spmm(bsr.blocks.astype(dtype), bsr.block_cols, bsr.row_ptr, x,
                   max_per_row=mpr, interpret=True)
    exp = ref.ref_bsr_spmm(bsr.blocks.astype(dtype), bsr.block_cols,
                           bsr.row_ptr, x)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


def test_bsr_spmm_normalized():
    g = generators.fem_cube(6)
    bsr = graph_to_bsr(g, blk=32, normalize="sym")
    x = jax.random.normal(KEY, (bsr.n_blocks * 32, 4))
    mpr = max_tiles_per_row(np.asarray(bsr.row_ptr))
    out = bsr_spmm(bsr.blocks, bsr.block_cols, bsr.row_ptr, x,
                   max_per_row=mpr, interpret=True)
    exp = ref.ref_bsr_spmm(bsr.blocks, bsr.block_cols, bsr.row_ptr, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


@pytest.mark.parametrize("v,d,b,h", [(100, 16, 4, 3), (500, 64, 8, 6),
                                     (64, 128, 2, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(v, d, b, h, dtype):
    table = jax.random.normal(KEY, (v, d), dtype)
    idx = jax.random.randint(jax.random.PRNGKey(1), (b, h), -1, v).astype(jnp.int32)
    out = embedding_bag_sum(table, idx, interpret=True)
    exp = ref.ref_embedding_bag(table, idx, "sum")
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


def test_partition_counts_kernel_matches_core():
    from repro.core import initial_partition
    from repro.core.migration import neighbour_partition_counts
    from repro.kernels import ops
    g = generators.fem_cube(8)
    bsr = graph_to_bsr(g, blk=64)
    lab = initial_partition(g, 9, "hsh")
    counts_core = neighbour_partition_counts(g, lab, 9)
    counts_kern = ops.partition_counts(bsr, lab, 9)
    n = int(g.num_nodes)
    np.testing.assert_allclose(np.asarray(counts_core[:n], np.float32),
                               np.asarray(counts_kern[:n]), atol=1e-5)
