"""Ref-vs-pallas parity suite for the fused migration kernels (DESIGN.md §9).

The contract under test: every executor of the fused superstep path —
the pure-jax oracle ("jax"), the Pallas kernel under ``interpret=True``
and (on TPU) the native kernel — produces **bit-identical** partition
assignments, pending moves and statistics to the unfused reference
pipeline in ``core/migration.py``, on any graph, because the counts are
exact integers, the RNG draws are shared and argmax tie handling matches.

Runs under hypothesis when installed; otherwise the deterministic
fixed-seed fallback sampler (``tests/_hypothesis_fallback.py``) replays
the same properties.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                     # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.core import initial_partition, make_state, occupancy
from repro.core.migration import (_rank_within_group, _rank_within_group_fast,
                                  migrate_step, neighbour_partition_counts)
from repro.core.repartitioner import adapt_jit, run_to_convergence
from repro.graph import generators
from repro.graph.bsr import graph_to_bsr
from repro.graph.structure import Graph, from_edges
from repro.kernels import ref
from repro.kernels.bsr_spmm import max_tiles_per_row
from repro.kernels.migration_kernels import (MigrationPlan, build_plan,
                                             label_histogram,
                                             pallas_score_select,
                                             score_select)

KEY = jax.random.PRNGKey(0)


def _random_graph(n: int, seed: int, kind: str) -> Graph:
    if kind == "fem":
        side = max(2, round(n ** (1 / 3)))
        return generators.fem_cube(side)
    if kind == "plc":
        return generators.power_law(max(n, 10), seed=seed)
    # sparse random COO with dead padding slots
    rng = np.random.default_rng(seed)
    m = max(1, 2 * n)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    return from_edges(src, dst, n, n_cap=n + 7, e_cap=m + 5)


# ---------------------------------------------------------------------------
# histogram parity: core ref / flat / ELL / BSR oracle / interpret kernel
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(20, 90), st.integers(0, 4), st.integers(2, 11),
       st.sampled_from(["fem", "plc", "coo"]))
def test_histogram_parity_random_graphs(n, seed, k, kind):
    g = _random_graph(n, seed, kind)
    lab = initial_partition(g, k, "hsh")
    want = np.asarray(neighbour_partition_counts(g, lab, k))
    for executor, plan in (("jax", None),
                           ("jax", build_plan(g, executor="jax")),
                           ("interpret", build_plan(g, executor="interpret",
                                                    blk=8))):
        got = np.asarray(label_histogram(g, plan, lab, k, executor=executor))
        kindname = plan.kind if plan is not None else "flat"
        np.testing.assert_array_equal(
            got, want, err_msg=f"executor={executor} plan={kindname}")


def test_histogram_padded_and_empty_tiles():
    """Padding tiles (block_cols == -1, nnzb_cap > nnzb) and empty row
    blocks must contribute nothing, in the kernel and in its oracle."""
    g = generators.fem_grid2d(5, n_cap=40, e_cap=80)   # 25 live of 40 slots
    k = 4
    lab = initial_partition(g, k, "hsh")
    bsr = graph_to_bsr(g, blk=8, nnzb_cap=64)          # heavy tile padding
    plan = MigrationPlan(kind="bsr", blocks=bsr.blocks,
                         block_cols=bsr.block_cols, row_ptr=bsr.row_ptr,
                         max_per_row=max_tiles_per_row(np.asarray(bsr.row_ptr)))
    want = np.asarray(neighbour_partition_counts(g, lab, k))
    got = np.asarray(label_histogram(g, plan, lab, k, executor="interpret"))
    np.testing.assert_array_equal(got, want)
    # an all-padding (edgeless) graph: counts identically zero
    g0 = Graph(src=jnp.full((16,), -1, jnp.int32),
               dst=jnp.full((16,), -1, jnp.int32),
               node_mask=jnp.zeros((24,), bool),
               edge_mask=jnp.zeros((16,), bool))
    got0 = np.asarray(label_histogram(g0, None, jnp.zeros((24,), jnp.int32),
                                      k, executor="jax"))
    assert (got0 == 0).all()


def test_score_select_parity_all_executors():
    """Fused decide+damp epilogue: targets/willing/gain identical across
    the oracle and the interpret-mode kernel, both tie-break rules."""
    g = generators.fem_cube(6)
    n, k = g.n_cap, 5
    lab = initial_partition(g, k, "hsh")
    keys = jax.random.split(KEY, 2)
    noise = jax.random.uniform(keys[0], (n, k))
    gate = jax.random.bernoulli(keys[1], p=0.5, shape=(n,))
    plan_bsr = build_plan(g, executor="interpret", blk=8)
    for tie in ("random", "stay"):
        base = None
        for executor, plan in (("jax", None),
                               ("jax", build_plan(g, executor="jax")),
                               ("interpret", plan_bsr)):
            out = score_select(g, plan, lab, g.node_mask, noise, gate, k,
                               tie_break=tie, executor=executor)
            out = tuple(np.asarray(x) for x in out)
            if base is None:
                base = out
                continue
            for name, a, b in zip(("counts", "target", "willing", "gain"),
                                  base, out):
                np.testing.assert_array_equal(
                    a, b, err_msg=f"{tie}/{executor}/{name}")


def test_bsr_oracle_matches_kernel():
    """kernels/ref.py oracle of the BSR histogram == the interpret kernel
    on the same packed tiles (the per-kernel contract of DESIGN.md §9)."""
    g = generators.power_law(60, seed=2)
    k = 6
    lab = initial_partition(g, k, "hsh")
    bsr = graph_to_bsr(g, blk=8, nnzb_cap=None)
    n_pad = bsr.n_blocks * 8
    lab_pad = jnp.pad(lab, (0, n_pad - g.n_cap), constant_values=-1)
    want = np.asarray(ref.ref_bsr_label_histogram(
        bsr.blocks, bsr.block_cols, bsr.row_ptr, lab_pad, k))
    counts, _, _, _ = pallas_score_select(
        bsr.blocks, bsr.block_cols, bsr.row_ptr, lab_pad,
        jnp.ones((n_pad,), bool), jnp.zeros((n_pad, k), jnp.float32),
        jnp.zeros((n_pad,), bool), k=k,
        max_per_row=max_tiles_per_row(np.asarray(bsr.row_ptr)),
        tie_break="stay", interpret=True)
    np.testing.assert_array_equal(np.asarray(counts), want)


# ---------------------------------------------------------------------------
# full-step parity: the acceptance criterion (identical assignments)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(25, 100), st.integers(0, 4), st.integers(2, 9),
       st.sampled_from(["random", "stay"]), st.sampled_from(["fem", "plc"]))
def test_migrate_step_backend_parity(n, seed, k, tie, kind):
    g = _random_graph(n, seed, kind)
    lab = initial_partition(g, k, "hsh")
    st_ref = st_fused = make_state(g, lab, k, slack=0.2, seed=seed)
    plan = build_plan(g, executor="jax")
    for _ in range(5):
        st_ref, stats_ref = migrate_step(st_ref, g, s=0.5, tie_break=tie,
                                         backend="ref")
        st_fused, stats_fused = migrate_step(st_fused, g, plan, s=0.5,
                                             tie_break=tie, backend="pallas",
                                             executor="jax")
        np.testing.assert_array_equal(np.asarray(st_ref.assignment),
                                      np.asarray(st_fused.assignment))
        np.testing.assert_array_equal(np.asarray(st_ref.pending),
                                      np.asarray(st_fused.pending))
        assert all(int(a) == int(b) for a, b
                   in zip(stats_ref, stats_fused))


def test_migrate_step_interpret_kernel_parity():
    """The actual Pallas kernel (interpret mode) inside migrate_step."""
    g = generators.fem_cube(5)
    k = 4
    lab = initial_partition(g, k, "hsh")
    st_ref = st_k = make_state(g, lab, k, slack=0.2, seed=1)
    plan = build_plan(g, executor="interpret", blk=8)
    for _ in range(3):
        st_ref, _ = migrate_step(st_ref, g, s=0.5, backend="ref")
        st_k, _ = migrate_step(st_k, g, plan, s=0.5, backend="pallas",
                               executor="interpret")
        np.testing.assert_array_equal(np.asarray(st_ref.assignment),
                                      np.asarray(st_k.assignment))


def test_driver_parity_adapt_and_converge():
    """The jit'd superstep (lax.scan) and the convergence driver agree
    across backends end to end."""
    g = generators.fem_cube(7)
    k = 6
    lab = initial_partition(g, k, "hsh")
    state = make_state(g, lab, k, slack=0.2, seed=3)
    plan = build_plan(g, executor="jax")

    a = adapt_jit(g, state, s=0.5, iters=6, backend="ref")
    b = adapt_jit(g, state, s=0.5, iters=6, backend="pallas", plan=plan)
    np.testing.assert_array_equal(np.asarray(a.assignment),
                                  np.asarray(b.assignment))

    sa, ha = run_to_convergence(g, state, max_iters=40, patience=10,
                                backend="ref")
    sb, hb = run_to_convergence(g, state, max_iters=40, patience=10,
                                backend="pallas", plan=plan)
    np.testing.assert_array_equal(np.asarray(sa.assignment),
                                  np.asarray(sb.assignment))
    assert ha.migrations == hb.migrations
    assert ha.cut_ratio == hb.cut_ratio


# ---------------------------------------------------------------------------
# capacity invariant + full partitions under the fused path
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(25, 100), st.integers(0, 4), st.integers(2, 8))
def test_fused_migration_preserves_capacity_invariant(n, seed, k):
    """Quotas under the fused path guarantee occupancy never grows past
    max(initial, capacity) — same invariant the ref path holds."""
    g = generators.power_law(n, seed=seed)
    state = make_state(g, initial_partition(g, k, "hsh"), k, slack=0.2,
                       seed=seed)
    cap = int(np.asarray(state.capacity)[0])
    bound = max(cap, int(np.asarray(occupancy(state, g.node_mask)).max()))
    plan = build_plan(g, executor="jax")
    for _ in range(6):
        state, _ = migrate_step(state, g, plan, s=0.5, backend="pallas",
                                executor="jax")
        a = np.asarray(state.assignment)
        assert ((a >= 0) & (a < k)).all()
        assert int(np.asarray(occupancy(state, g.node_mask)).max()) <= bound


def test_full_partitions_admit_nothing():
    """With zero free capacity everywhere, the quota is zero and the fused
    step must not admit a single move."""
    g = generators.fem_cube(5)
    k = 5
    lab = initial_partition(g, k, "hsh")
    state = make_state(g, lab, k, seed=0)
    occ = occupancy(state, g.node_mask)
    state = state.__class__(assignment=state.assignment, pending=state.pending,
                            capacity=occ.astype(jnp.int32), rng=state.rng,
                            iteration=state.iteration,
                            last_moves=state.last_moves)
    for backend in ("ref", "pallas"):
        st2, stats = migrate_step(state, g, s=1.0, backend=backend)
        assert int(stats.admitted) == 0
        assert (np.asarray(st2.pending) == -1).all()


# ---------------------------------------------------------------------------
# quota ranking: the fast path is bit-identical to the stable sort
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(1, 400), st.integers(1, 100), st.integers(0, 6),
       st.floats(0.0, 1.0))
def test_rank_within_group_fast_matches_stable(n, num_groups, seed, density):
    rng = np.random.default_rng(seed)
    group = jnp.asarray(rng.integers(0, num_groups, n).astype(np.int32))
    active = jnp.asarray(rng.random(n) < density)
    slow = np.asarray(_rank_within_group(group, active))
    fast = np.asarray(_rank_within_group_fast(group, active,
                                              num_groups=num_groups))
    np.testing.assert_array_equal(slow, fast)
