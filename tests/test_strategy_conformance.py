"""Registry-wide strategy conformance suite (DESIGN.md §13).

Every canonical strategy in the ``repro.api`` registry is held to the same
contract, whatever its policy:

  * ``init`` lands every slot label in ``[0, k)``;
  * adaptation keeps live labels in ``[0, k)``, never touches dead slots,
    and never grows a partition past ``max(initial occupancy, capacity)``
    (the capacity invariant — pre-existing overflow may drain, never worsen);
  * a full session is bit-for-bit deterministic under a fixed seed;
  * empty / singleton / full-partition graphs don't crash.

The parameterisation is computed from ``canonical_strategy_names()`` at
import, so registering a new strategy automatically enrols it here — a new
rival partitioner cannot land without inheriting the whole contract.

The random-graph sweep runs under hypothesis when installed, and under the
deterministic ``tests/_hypothesis_fallback.py`` sampler otherwise.
"""
from __future__ import annotations

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.api import (DynamicGraphSystem, GraphSection, PartitionSection,
                       StreamSection, SystemConfig, canonical_strategy_names,
                       empty_graph, resolve_strategy, strategy_names)
from repro.api.strategy import StrategyContext
from repro.core.partition_state import make_state, occupancy
from repro.graph.structure import from_edges

CANONICAL = canonical_strategy_names()
MIGRATING = tuple(n for n in CANONICAL
                  if getattr(resolve_strategy(n), "adapts", False))


def random_graph(seed: int, n: int = 40, extra_cap: int = 16, e: int = 160):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    m = src != dst
    return from_edges(src[m], dst[m], num_nodes=n, n_cap=n + extra_cap,
                      e_cap=2 * e)


def adapt_and_converge(name: str, graph, state, k: int, iters: int = 3):
    strat = resolve_strategy(name)
    ctx = StrategyContext(k=k, adapt_iters=iters, backend="ref",
                          max_iters=25, patience=4, record_history=False)
    state = strat.adapt(graph, state, ctx)
    state, _ = strat.converge(graph, state, ctx)
    state, _ = strat.adapt_rounds(graph, state, 2, ctx)
    return state


def check_invariants(graph, state0, state, k: int):
    nm = np.asarray(graph.node_mask)
    lab = np.asarray(state.assignment)
    assert lab.dtype.kind == "i"
    if nm.any():
        assert lab[nm].min() >= 0 and lab[nm].max() < k
    # dead slots are never relabelled by adaptation
    assert np.array_equal(lab[~nm], np.asarray(state0.assignment)[~nm])
    # capacity invariant: occupancy never grows past max(initial, capacity)
    occ0 = np.asarray(occupancy(state0, graph.node_mask))
    occ = np.asarray(occupancy(state, graph.node_mask))
    cap = np.asarray(state.capacity)
    assert np.all(occ <= np.maximum(occ0, cap)), (occ, occ0, cap)
    assert occ.sum() == nm.sum()


# ---------------------------------------------------------------------------
# registry hygiene (the canonical_strategy_names contract)
# ---------------------------------------------------------------------------

def test_canonical_names_subset_of_all_names():
    assert set(CANONICAL) <= set(strategy_names())


def test_canonical_names_exclude_aliases():
    aliases = {"hsh", "rnd", "mod", "blk", "online", "adaptive", "lpa",
               "lemerrer"}
    assert aliases <= set(strategy_names())
    assert not (aliases & set(CANONICAL))


def test_canonical_names_unique_factories():
    # one entry per strategy: resolving an alias and its canonical name
    # must hit the same factory, and no two canonical names may collide
    assert len(set(CANONICAL)) == len(CANONICAL)
    assert type(resolve_strategy("hsh")) is type(resolve_strategy("hash"))
    assert type(resolve_strategy("adaptive")) is type(resolve_strategy("xdgp"))


def test_unknown_strategy_error_lists_aliases_too():
    with pytest.raises(ValueError) as e:
        resolve_strategy("definitely-not-registered")
    msg = str(e.value)
    assert "registered strategies" in msg
    for name in ("hsh", "adaptive", "xdgp", "spinner"):
        assert name in msg


def test_rivals_resolvable_by_config_name():
    for name in ("spinner", "sdp", "restream"):
        cfg = SystemConfig(graph=GraphSection(n_cap=16, e_cap=16),
                           partition=PartitionSection(strategy=name, k=2))
        assert DynamicGraphSystem(config=cfg).strategy.name == name


def test_rival_migrators_not_cluster_native():
    # the sharded backend's cluster engine implements the xDGP step only;
    # rivals must fall through to their own local hooks
    assert resolve_strategy("xdgp").cluster_native is True
    for name in ("spinner", "sdp", "restream", "static", "fennel"):
        assert resolve_strategy(name).cluster_native is False


# ---------------------------------------------------------------------------
# per-strategy contract (auto-enrols new registrations)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", CANONICAL)
def test_init_labels_in_range(name):
    graph = random_graph(1, n=30)
    k = 4
    lab = np.asarray(resolve_strategy(name).init(graph, k))
    assert lab.shape == (graph.n_cap,)
    assert lab.min() >= 0 and lab.max() < k


@pytest.mark.parametrize("name", CANONICAL)
def test_adaptation_invariants_on_random_graph(name):
    graph = random_graph(2, n=36)
    k = 3
    strat = resolve_strategy(name)
    state0 = make_state(graph, strat.init(graph, k), k, seed=7)
    state = adapt_and_converge(name, graph, state0, k)
    check_invariants(graph, state0, state, k)


@pytest.mark.parametrize("name", CANONICAL)
def test_session_deterministic_under_fixed_seed(name):
    rng = np.random.default_rng(11)
    n, events = 48, 240
    times = np.sort(rng.integers(0, 120, events))
    src = rng.integers(0, n, events)
    dst = (src + 1 + rng.integers(0, n - 1, events)) % n
    stream = (times, src, dst)
    cfg = SystemConfig(
        graph=GraphSection(n_cap=64, e_cap=600),
        stream=StreamSection(window=60, batch_span=20, a_cap=256, d_cap=128),
        partition=PartitionSection(strategy=name, k=3, adapt_iters=2),
        seed=5)

    def final_assignment():
        system = DynamicGraphSystem(config=cfg)
        system.run(stream)
        return np.asarray(system.state.assignment)

    assert np.array_equal(final_assignment(), final_assignment())


@pytest.mark.parametrize("name", CANONICAL)
def test_empty_graph_does_not_crash(name):
    graph = empty_graph(8, 8)
    k = 2
    strat = resolve_strategy(name)
    state0 = make_state(graph, strat.init(graph, k), k, seed=0)
    state = adapt_and_converge(name, graph, state0, k)
    check_invariants(graph, state0, state, k)


@pytest.mark.parametrize("name", CANONICAL)
def test_singleton_graph_does_not_crash(name):
    graph = from_edges(np.array([], np.int64), np.array([], np.int64),
                       num_nodes=1, n_cap=4, e_cap=4)
    k = 2
    strat = resolve_strategy(name)
    state0 = make_state(graph, strat.init(graph, k), k, seed=0)
    state = adapt_and_converge(name, graph, state0, k)
    check_invariants(graph, state0, state, k)


@pytest.mark.parametrize("name", CANONICAL)
def test_full_partition_does_not_overflow(name):
    # everyone starts in partition 0 and partition 0 is exactly full:
    # adaptation may only drain it, and may not overfill the others
    import jax.numpy as jnp
    graph = random_graph(3, n=24)
    k = 3
    n_live = int(np.asarray(graph.node_mask).sum())
    assignment = jnp.zeros((graph.n_cap,), jnp.int32)
    capacity = jnp.asarray([n_live, n_live, n_live], jnp.int32)
    state0 = make_state(graph, assignment, k, seed=1, capacity=capacity)
    state = adapt_and_converge(name, graph, state0, k)
    check_invariants(graph, state0, state, k)


# ---------------------------------------------------------------------------
# random-graph sweep (hypothesis, or the deterministic fallback sampler)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 20), st.sampled_from(MIGRATING),
       st.integers(2, 5))
def test_migrating_strategies_hold_invariants(seed, name, k):
    graph = random_graph(seed, n=20 + seed % 17, e=90)
    strat = resolve_strategy(name)
    state0 = make_state(graph, strat.init(graph, k), k, seed=seed)
    ctx = StrategyContext(k=k, adapt_iters=2, backend="ref",
                          max_iters=10, patience=3, record_history=False)
    state = strat.adapt(graph, state0, ctx)
    state, _ = strat.converge(graph, state, ctx)
    check_invariants(graph, state0, state, k)


# ---------------------------------------------------------------------------
# arena result contract (results/bench_strategy_arena.json)
# ---------------------------------------------------------------------------

def _arena_payload():
    row = lambda scn, strat: {
        "scenario": scn, "strategy": strat, "events": 10, "supersteps": 2,
        "cut_final": 0.3, "cut_mean": 0.35, "imbalance_final": 1.1,
        "migrations_total": 5, "wall_seconds": 0.2, "exec_cost_total": 9.0,
    }
    return {
        "bench": "strategy_arena",
        "scenarios": ["twitter", "adversarial"],
        "strategies": ["xdgp", "spinner"],
        "rows": [row(s, t) for s in ("twitter", "adversarial")
                 for t in ("xdgp", "spinner")],
        "winners": {"twitter": {"cut": "spinner"},
                    "adversarial": {"cut": "xdgp"}},
    }


def test_arena_bench_schema_validates():
    import json as _json
    from repro.obs.schema import SchemaError, validate_arena_bench
    good = _arena_payload()
    validate_arena_bench(good)
    for mutate in (
        lambda d: d.update(bench="other"),
        lambda d: d.update(strategies=["xdgp", "adaptive", "spinner"]),
        lambda d: d["rows"].pop(),                    # missing cell
        lambda d: d["rows"].__setitem__(1, d["rows"][0]),   # duplicate cell
        lambda d: d["rows"][0].update(cut_final=1.5),
        lambda d: d["rows"][0].update(migrations_total=-1),
        lambda d: d["winners"].pop("twitter"),
        lambda d: d["winners"]["twitter"].update(cut="static"),
    ):
        bad = _json.loads(_json.dumps(good))
        mutate(bad)
        with pytest.raises(SchemaError):
            validate_arena_bench(bad)


def test_committed_arena_results_validate():
    import os
    from repro.obs.schema import validate_arena_bench_file
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "results", "bench_strategy_arena.json")
    if not os.path.exists(path):
        pytest.skip("no committed arena results")
    payload = validate_arena_bench_file(path)
    # the acceptance bar: every rival sweeps every paper scenario plus the
    # adversarial stream, against the committed canonical-name roster
    assert {"spinner", "sdp", "restream", "xdgp"} <= set(payload["strategies"])
    assert set(payload["scenarios"]) >= {"twitter", "fem", "cellular",
                                         "adversarial"}
    assert set(payload["strategies"]) <= set(CANONICAL)
