"""Property tests on system invariants.

Runs under hypothesis when installed (adversarial exploration + shrinking;
the CI ``dev`` extras install it). Without hypothesis the same properties
run as a deterministic fixed-seed sampled sweep via the local fallback
(``tests/_hypothesis_fallback.py``) instead of skipping silently.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                     # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.core import initial_partition, make_state, migrate_step, occupancy
from repro.graph import apply_delta, cut_ratio, from_edges, generators
from repro.graph.structure import GraphDelta
from repro.optim.optimizer import _dequantize, _quantize
from repro.stream import WindowIngestor


# ---------------------------------------------------------------------------
# partitioning invariants
# ---------------------------------------------------------------------------

graphs = st.tuples(st.integers(20, 120), st.integers(0, 4))


@settings(max_examples=15, deadline=None)
@given(graphs, st.integers(2, 12), st.sampled_from(["hsh", "rnd", "blk"]))
def test_assignment_stays_in_range_and_balanced(gparams, k, strat):
    """Quotas guarantee occupancy never grows past max(initial, capacity):
    the heuristic cannot *evict* an initial overflow (hash partitioning on
    tiny graphs can start above capacity — found by hypothesis) but must
    never create or worsen one."""
    n, seed = gparams
    g = generators.power_law(n, seed=seed)
    state = make_state(g, initial_partition(g, k, strat), k, slack=0.2)
    cap = int(np.asarray(state.capacity)[0])
    occ0 = int(np.asarray(occupancy(state, g.node_mask)).max())
    bound = max(cap, occ0)
    for _ in range(6):
        state, _ = migrate_step(state, g, s=0.5)
        a = np.asarray(state.assignment)
        assert ((a >= 0) & (a < k)).all()
        occ = np.asarray(occupancy(state, g.node_mask))
        assert occ.max() <= bound


@settings(max_examples=10, deadline=None)
@given(st.integers(3, 8), st.integers(0, 3))
def test_cut_ratio_bounds(side, seed):
    g = generators.fem_cube(side)
    for k in (2, 5):
        lab = initial_partition(g, k, "rnd", seed=seed)
        c = float(cut_ratio(g, lab))
        assert 0.0 <= c <= 1.0


def test_apply_delta_never_clobbers_live_edges():
    """Regression: additions must fill FREE slots only (a rank/slot indexing
    bug once overwrote the first n_add live edges — caught via Fig. 7's
    impossible static-time drop)."""
    g = generators.fem_cube(6, n_cap=250, e_cap=700)
    before = set(zip(np.asarray(g.src)[np.asarray(g.edge_mask)].tolist(),
                     np.asarray(g.dst)[np.asarray(g.edge_mask)].tolist()))
    delta = generators.forest_fire_delta(g, 0.10, seed=1)
    g2 = apply_delta(g, delta)
    after = set(zip(np.asarray(g2.src)[np.asarray(g2.edge_mask)].tolist(),
                    np.asarray(g2.dst)[np.asarray(g2.edge_mask)].tolist()))
    assert before <= after                       # every old edge survives
    assert len(after) > len(before)              # and new ones landed


@settings(max_examples=10, deadline=None)
@given(st.integers(24, 80), st.integers(0, 3), st.integers(1, 10))
def test_apply_delta_preserves_masks(n, seed, n_add):
    g = generators.power_law(n, seed=seed, n_cap=n + 16,
                             e_cap=int(4 * n * np.log(n)))
    rng = np.random.default_rng(seed)
    a_cap = 8
    src = np.full(a_cap, -1, np.int32)
    dst = np.full(a_cap, -1, np.int32)
    mask = np.zeros(a_cap, bool)
    for i in range(min(n_add, a_cap)):
        src[i] = n + rng.integers(0, 8)     # new node
        dst[i] = rng.integers(0, n)
        mask[i] = src[i] != dst[i]
    delta = GraphDelta(add_src=jnp.asarray(src), add_dst=jnp.asarray(dst),
                       add_mask=jnp.asarray(mask),
                       del_nodes=jnp.full((1,), -1, jnp.int32),
                       del_mask=jnp.zeros((1,), bool))
    n0 = int(g.num_nodes)
    e0 = int(g.num_edges)
    g2 = apply_delta(g, delta)
    # masks consistent: every live edge has live endpoints
    src2, dst2 = np.asarray(g2.src), np.asarray(g2.dst)
    em = np.asarray(g2.edge_mask)
    nm = np.asarray(g2.node_mask)
    assert nm[src2[em]].all() and nm[dst2[em]].all()
    assert int(g2.num_edges) >= e0
    assert int(g2.num_nodes) >= n0


# ---------------------------------------------------------------------------
# windowed-ingest invariants (stream front end)
# ---------------------------------------------------------------------------

def _rand_batch(rng, n_ids, now, window, size):
    """Events inside the current window (so none are stale on arrival)."""
    lo = max(0, now - window + 1)
    t = np.sort(rng.integers(lo, now + 1, size))
    u = rng.integers(0, n_ids, size)
    v = rng.integers(0, n_ids, size)
    return np.stack([t, u, v], axis=1)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_dedupe_ingest_never_duplicates_live_edges(seed):
    """dedupe=True: across arbitrary event sequences (repeats, backlog,
    expiry, resurrection) the applied graph never holds the same undirected
    edge twice, and the ingestor's live-edge mirror matches the graph."""
    from repro.graph.structure import Graph
    rng = np.random.default_rng(seed)
    n, window, span = 40, 25, 10
    ing = WindowIngestor(n_cap=n, window=window, a_cap=16, d_cap=64,
                         dedupe=True)
    g = Graph(src=jnp.full((600,), -1, jnp.int32),
              dst=jnp.full((600,), -1, jnp.int32),
              node_mask=jnp.zeros((n,), bool),
              edge_mask=jnp.zeros((600,), bool))
    empty = np.empty((0, 3), np.int64)
    steps = [(j * span, _rand_batch(rng, n, j * span, window,
                                    int(rng.integers(5, 30))))
             for j in range(1, 9)]
    steps += [((9 + j) * span, empty) for j in range(12)]   # drain the backlog
    for now, ev in steps:
        delta, _ = ing.ingest(ev, now)
        g = apply_delta(g, delta)
        em = np.asarray(g.edge_mask)
        s = np.asarray(g.src)[em].astype(np.int64)
        d = np.asarray(g.dst)[em].astype(np.int64)
        key = np.minimum(s, d) * n + np.maximum(s, d)
        assert np.unique(key).size == key.size, "duplicate live edge"
        mirror = ing.live_edge_keys()
        assert np.array_equal(np.sort(key), mirror), "live-set mirror drifted"


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_window_expiry_matches_reference_model(seed):
    """Expiry respects the window: tracked nodes are exactly those seen
    within it, and released deletions are exactly the nodes that fell out."""
    rng = np.random.default_rng(seed)
    n, window = 60, 20
    ing = WindowIngestor(n_cap=n, window=window, a_cap=4096, d_cap=4096)
    model = {}
    now = 0
    for _ in range(12):
        now += int(rng.integers(3, 15))
        ev = _rand_batch(rng, n, now, window, int(rng.integers(0, 25)))
        delta, _ = ing.ingest(ev, now)
        horizon = now - window
        for t, u, v in ev:
            model[u] = max(model.get(u, t), t)
            model[v] = max(model.get(v, t), t)
        expired = {v for v, t in model.items() if t < horizon}
        for v in expired:
            del model[v]
        tracked = set(np.flatnonzero(
            ing.tracker.last_seen != ing.tracker.NEVER).tolist())
        assert tracked == set(model), "window liveness diverged"
        dels = set(np.asarray(delta.del_nodes)[np.asarray(delta.del_mask)]
                   .tolist())
        assert dels == expired, "released deletions != expired set"


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 32), st.sampled_from([False, True]))
def test_add_backlog_conservation_under_backpressure(seed, a_cap, dedupe):
    """Every valid addition is accounted for: released + still-queued +
    dropped-as-duplicate, at every step and after a full drain."""
    rng = np.random.default_rng(seed)
    n = 30
    ing = WindowIngestor(n_cap=n, window=10 ** 9, a_cap=a_cap, d_cap=64,
                         dedupe=dedupe)
    pushed = released = dups = 0
    for j in range(1, 8):
        size = int(rng.integers(0, 40))
        ev = _rand_batch(rng, n, j * 10, 10 ** 9, size)
        ev[rng.random(size) < 0.1, 1] = n + 5        # some invalid endpoints
        _, s = ing.ingest(ev, j * 10)
        pushed += size - s.invalid
        released += s.adds_out
        dups += s.dup_dropped
        assert pushed == released + dups + s.adds_backlog
    empty = np.empty((0, 3), np.int64)
    for _ in range(200):
        if ing.buffer.backlog[0] == 0:
            break
        _, s = ing.ingest(empty, 80)
        released += s.adds_out
        dups += s.dup_dropped
    assert ing.buffer.backlog[0] == 0, "backlog failed to drain"
    assert pushed == released + dups


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 8))
def test_del_backlog_conservation_under_backpressure(seed, d_cap):
    """Expired nodes queued under d_cap backpressure are all accounted for:
    released, still queued, or dropped because the node came back to life."""
    rng = np.random.default_rng(seed)
    n, window = 40, 15
    ing = WindowIngestor(n_cap=n, window=window, a_cap=4096, d_cap=d_cap)
    pushed_dels = 0
    orig_push = ing.buffer.push_node_removals

    def counting_push(nodes):
        nonlocal pushed_dels
        pushed_dels += int(np.asarray(nodes).reshape(-1).shape[0])
        orig_push(nodes)

    ing.buffer.push_node_removals = counting_push
    released = dropped = 0
    now = 0
    for _ in range(14):
        now += int(rng.integers(4, 20))
        ev = _rand_batch(rng, n, now, window, int(rng.integers(0, 20)))
        _, s = ing.ingest(ev, now)
        released += s.dels_out
        dropped += s.stale_dropped        # adds are never stale here (in-window)
        assert pushed_dels == released + dropped + s.dels_backlog
    empty = np.empty((0, 3), np.int64)
    for _ in range(300):
        if ing.buffer.backlog[1] == 0:
            break
        _, s = ing.ingest(empty, now)
        released += s.dels_out
        dropped += s.stale_dropped
    assert ing.buffer.backlog[1] == 0
    assert pushed_dels == released + dropped


# ---------------------------------------------------------------------------
# quantizer invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(2, 300), st.integers(0, 5))
def test_quantize_roundtrip_lin(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32) *
                    rng.uniform(0.01, 100))
    t = _quantize(x, "lin")
    y = _dequantize(t)
    assert y.shape == x.shape
    scale = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert (err <= scale / 127.0 * 1.01 + 1e-9).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(2, 300), st.integers(0, 5))
def test_quantize_roundtrip_log(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=(rows, cols)) ** 2).astype(np.float32))
    t = _quantize(x, "log")
    y = np.asarray(_dequantize(t))
    assert (y >= 0).all()
    # log-space: relative error bounded by the per-row log-range step
    xs = np.asarray(x)
    big = xs > 1e-12
    rel = np.abs(y[big] - xs[big]) / xs[big]
    assert rel.max() < 0.35, rel.max()


# ---------------------------------------------------------------------------
# attention reference invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 4))
def test_attention_probs_rowsum(seed):
    from repro.kernels.ref import ref_flash_attention
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 16))
    k = jax.random.normal(ks[1], (1, 2, 32, 16))
    # v = ones → output rows must be exactly 1 (softmax rows sum to 1)
    v = jnp.ones((1, 2, 32, 16))
    out = ref_flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)
