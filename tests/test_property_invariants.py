"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import initial_partition, make_state, migrate_step, occupancy
from repro.graph import apply_delta, cut_ratio, from_edges, generators
from repro.graph.structure import GraphDelta
from repro.optim.optimizer import _dequantize, _quantize


# ---------------------------------------------------------------------------
# partitioning invariants
# ---------------------------------------------------------------------------

graphs = st.tuples(st.integers(20, 120), st.integers(0, 4))


@settings(max_examples=15, deadline=None)
@given(graphs, st.integers(2, 12), st.sampled_from(["hsh", "rnd", "blk"]))
def test_assignment_stays_in_range_and_balanced(gparams, k, strat):
    """Quotas guarantee occupancy never grows past max(initial, capacity):
    the heuristic cannot *evict* an initial overflow (hash partitioning on
    tiny graphs can start above capacity — found by hypothesis) but must
    never create or worsen one."""
    n, seed = gparams
    g = generators.power_law(n, seed=seed)
    state = make_state(g, initial_partition(g, k, strat), k, slack=0.2)
    cap = int(np.asarray(state.capacity)[0])
    occ0 = int(np.asarray(occupancy(state, g.node_mask)).max())
    bound = max(cap, occ0)
    for _ in range(6):
        state, _ = migrate_step(state, g, s=0.5)
        a = np.asarray(state.assignment)
        assert ((a >= 0) & (a < k)).all()
        occ = np.asarray(occupancy(state, g.node_mask))
        assert occ.max() <= bound


@settings(max_examples=10, deadline=None)
@given(st.integers(3, 8), st.integers(0, 3))
def test_cut_ratio_bounds(side, seed):
    g = generators.fem_cube(side)
    for k in (2, 5):
        lab = initial_partition(g, k, "rnd", seed=seed)
        c = float(cut_ratio(g, lab))
        assert 0.0 <= c <= 1.0


def test_apply_delta_never_clobbers_live_edges():
    """Regression: additions must fill FREE slots only (a rank/slot indexing
    bug once overwrote the first n_add live edges — caught via Fig. 7's
    impossible static-time drop)."""
    g = generators.fem_cube(6, n_cap=250, e_cap=700)
    before = set(zip(np.asarray(g.src)[np.asarray(g.edge_mask)].tolist(),
                     np.asarray(g.dst)[np.asarray(g.edge_mask)].tolist()))
    delta = generators.forest_fire_delta(g, 0.10, seed=1)
    g2 = apply_delta(g, delta)
    after = set(zip(np.asarray(g2.src)[np.asarray(g2.edge_mask)].tolist(),
                    np.asarray(g2.dst)[np.asarray(g2.edge_mask)].tolist()))
    assert before <= after                       # every old edge survives
    assert len(after) > len(before)              # and new ones landed


@settings(max_examples=10, deadline=None)
@given(st.integers(24, 80), st.integers(0, 3), st.integers(1, 10))
def test_apply_delta_preserves_masks(n, seed, n_add):
    g = generators.power_law(n, seed=seed, n_cap=n + 16,
                             e_cap=int(4 * n * np.log(n)))
    rng = np.random.default_rng(seed)
    a_cap = 8
    src = np.full(a_cap, -1, np.int32)
    dst = np.full(a_cap, -1, np.int32)
    mask = np.zeros(a_cap, bool)
    for i in range(min(n_add, a_cap)):
        src[i] = n + rng.integers(0, 8)     # new node
        dst[i] = rng.integers(0, n)
        mask[i] = src[i] != dst[i]
    delta = GraphDelta(add_src=jnp.asarray(src), add_dst=jnp.asarray(dst),
                       add_mask=jnp.asarray(mask),
                       del_nodes=jnp.full((1,), -1, jnp.int32),
                       del_mask=jnp.zeros((1,), bool))
    n0 = int(g.num_nodes)
    e0 = int(g.num_edges)
    g2 = apply_delta(g, delta)
    # masks consistent: every live edge has live endpoints
    src2, dst2 = np.asarray(g2.src), np.asarray(g2.dst)
    em = np.asarray(g2.edge_mask)
    nm = np.asarray(g2.node_mask)
    assert nm[src2[em]].all() and nm[dst2[em]].all()
    assert int(g2.num_edges) >= e0
    assert int(g2.num_nodes) >= n0


# ---------------------------------------------------------------------------
# quantizer invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(2, 300), st.integers(0, 5))
def test_quantize_roundtrip_lin(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32) *
                    rng.uniform(0.01, 100))
    t = _quantize(x, "lin")
    y = _dequantize(t)
    assert y.shape == x.shape
    scale = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert (err <= scale / 127.0 * 1.01 + 1e-9).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(2, 300), st.integers(0, 5))
def test_quantize_roundtrip_log(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=(rows, cols)) ** 2).astype(np.float32))
    t = _quantize(x, "log")
    y = np.asarray(_dequantize(t))
    assert (y >= 0).all()
    # log-space: relative error bounded by the per-row log-range step
    xs = np.asarray(x)
    big = xs > 1e-12
    rel = np.abs(y[big] - xs[big]) / xs[big]
    assert rel.max() < 0.35, rel.max()


# ---------------------------------------------------------------------------
# attention reference invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 4))
def test_attention_probs_rowsum(seed):
    from repro.kernels.ref import ref_flash_attention
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 16))
    k = jax.random.normal(ks[1], (1, 2, 32, 16))
    # v = ones → output rows must be exactly 1 (softmax rows sum to 1)
    v = jnp.ones((1, 2, 32, 16))
    out = ref_flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)
