"""Distributed engine tests — run in a subprocess with fake devices
(XLA locks the device count at first init, so tests that need >1 device
must re-exec). The fake-device count is set ONLY through the subprocess
environment — snippets must not mutate ``os.environ`` themselves, so no
setting can leak between tests or into this process."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_migrator_reduces_cut():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.graph import generators
from repro.core import initial_partition
from repro.core.distributed import build_dist_graph, make_distributed_migrator
P = 8
g = generators.fem_cube(10)
lab = np.asarray(initial_partition(g, P, "hsh"))
dg, _ = build_dist_graph(g, lab, P)
from repro.compat import make_mesh
mesh = make_mesh((P,), ("nodes",))
mig = make_distributed_migrator(mesh, dg, P, s=0.5)
assignment = jnp.repeat(jnp.arange(P, dtype=jnp.int32), dg.block_size)
pending = jnp.full((P*dg.block_size,), -1, jnp.int32)
rng = jax.random.PRNGKey(0)
cap = jnp.full((P,), int(dg.block_size*1.15)+1, jnp.int32)
def cut(a):
    so, ss, sl, dl, eo = (np.asarray(x) for x in (dg.src_owner, dg.src_slot, dg.src_local, dg.dst_local, dg.edge_ok))
    bnd = np.asarray(dg.boundary); a2 = np.asarray(a).reshape(P, dg.block_size)
    c = t = 0
    for p in range(P):
        m = eo[p]
        sd, sslot, loc, dslot = so[p][m], ss[p][m], sl[p][m], dl[p][m]
        sl_ = np.where(loc, a2[p][sslot], a2[sd, bnd[sd, sslot]])
        c += (sl_ != a2[p][dslot]).sum(); t += m.sum()
    return c / t
c0 = cut(assignment)
for _ in range(40):
    assignment, pending, rng = mig(assignment, pending, rng, cap)
c1 = cut(assignment)
assert c0 > 0.8 and c1 < 0.5, (c0, c1)
# balance under capacity (count live slots only — padding keeps its block id)
node_ok = np.asarray(dg.node_ok).reshape(-1)
occ = np.bincount(np.asarray(assignment)[node_ok], minlength=P)
assert occ.max() <= int(dg.block_size*1.15)+1, occ
print("OK", c0, c1)
""")


def test_distributed_aggregate_matches_degrees():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.graph import generators
from repro.core import initial_partition
from repro.core.distributed import build_dist_graph, make_distributed_aggregate
P = 8
g = generators.power_law(300, seed=1)
lab = np.asarray(initial_partition(g, P, "rnd"))
dg, _ = build_dist_graph(g, lab, P)
from repro.compat import make_mesh
mesh = make_mesh((P,), ("nodes",))
agg = make_distributed_aggregate(mesh, dg)
f = jnp.ones((P*dg.block_size, 2))
out = np.asarray(agg(f))
assert abs(out.sum() - 2*2*int(g.num_edges)) < 1e-3
print("OK")
""")


def test_halo_gin_matches_reference():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.graph import generators
from repro.core import initial_partition
from repro.core.distributed import build_dist_graph
from repro.core.halo_gnn import gin_halo_forward
from repro.models.gnn import GINConfig, GraphBatch, gin_init, gin_forward
P = 8
g = generators.chung_lu(300, 6.0, seed=0)
lab = np.asarray(initial_partition(g, P, "hsh"))
dg, _ = build_dist_graph(g, lab, P)
cfg = GINConfig(n_layers=2, d_hidden=8, d_in=4, n_out=3, readout="none")
key = jax.random.PRNGKey(0)
params = gin_init(key, cfg)
feats_orig = jax.random.normal(key, (g.n_cap, 4))
src = np.asarray(g.src); dst = np.asarray(g.dst); em = np.asarray(g.edge_mask)
s2 = np.concatenate([src[em], dst[em]]); d2 = np.concatenate([dst[em], src[em]])
batch = GraphBatch(node_feat=feats_orig, src=jnp.asarray(s2), dst=jnp.asarray(d2),
                   node_mask=g.node_mask, edge_mask=jnp.ones(len(s2), bool),
                   graph_ids=jnp.zeros((g.n_cap,), jnp.int32), n_graphs=1)
ref = np.asarray(gin_forward(params, batch, cfg))
node_mask = np.asarray(g.node_mask)
order = np.lexsort((np.arange(g.n_cap), ~node_mask, lab))
new_global = np.full(g.n_cap, -1, np.int64)
sa = lab[order]; sliv = node_mask[order]
for p in range(P):
    sel = np.flatnonzero((sa == p) & sliv)
    new_global[order[sel]] = p * dg.block_size + np.arange(sel.size)
feats_dist = np.zeros((P*dg.block_size, 4), np.float32)
live = np.flatnonzero(node_mask)
feats_dist[new_global[live]] = np.asarray(feats_orig)[live]
from repro.compat import make_mesh
mesh = make_mesh((P,), ("nodes",))
out = np.asarray(jax.jit(lambda p, f: gin_halo_forward(p, dg, f, cfg, mesh))(params, jnp.asarray(feats_dist)))
err = np.abs(ref[live] - out[new_global[live]]).max()
assert err < 1e-4, err
print("OK", err)
""")


def test_shard_map_moe_matches_einsum():
    _run("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.models.moe import MoEConfig, moe_init, moe_apply
from repro.runtime import sharding as shr
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
cfg_ref = MoEConfig(n_experts=8, top_k=2, d_ff=64, capacity_factor=16.0, dispatch="einsum")
cfg_shd = dataclasses.replace(cfg_ref, dispatch="sharded")
p = moe_init(key, 32, cfg_ref)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)
y_ref, _ = jax.jit(lambda p, x: moe_apply(p, x, cfg_ref))(p, x)
shr.set_activation_mesh(mesh)
with mesh:
    y_shd, _ = jax.jit(lambda p, x: moe_apply(p, x, cfg_shd))(p, x)
shr.set_activation_mesh(None)
err = float(jnp.max(jnp.abs(y_ref - y_shd)))
assert err < 1e-4, err
print("OK", err)
""")


def test_production_mesh_shapes():
    # 512 fake devices come from the subprocess env (the _run fixture), not
    # an in-snippet os.environ mutation that could outlive the test
    _run("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh(multi_pod=False)
assert dict(m1.shape) == {"data": 16, "model": 16}
m2 = make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
print("OK")
""", devices=512)
