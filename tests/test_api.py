"""repro.api front-door tests: config round-trip, strategy-registry
resolution, shim equivalence (old StreamEngine telemetry == new
DynamicGraphSystem telemetry on the same seed/stream), deprecation
warnings on the seed-era entry points, and the frozen public-API snapshot."""
import dataclasses
import warnings

import numpy as np
import pytest

import repro.api as api
from repro.api import (DynamicGraphSystem, PartitionSection, StreamSection,
                       SystemConfig, TelemetrySection, XdgpAdaptive,
                       empty_graph, resolve_strategy, strategy_names)
from repro.graph import cut_ratio, generators


# ---------------------------------------------------------------------------
# Public surface — frozen. Extend deliberately, never accidentally.
# ---------------------------------------------------------------------------

PUBLIC_API = [
    # config
    "SystemConfig", "GraphSection", "StreamSection", "PartitionSection",
    "ComputeSection", "ClusterSection", "TelemetrySection",
    # strategy protocol + registry
    "PartitionStrategy", "StrategyContext",
    "register_strategy", "resolve_strategy", "strategy_names",
    "canonical_strategy_names",
    # shipped strategies
    "Static", "Hash", "Random", "Modulo", "Block", "Dgr", "Mnn",
    "OnlineFennel", "XdgpAdaptive", "Spinner", "Sdp", "Restream",
    # execution backends
    "ExecutionBackend", "LocalBackend", "ShardedBackend",
    "register_execution_backend", "resolve_execution_backend",
    "execution_backend_names",
    # session + measurement
    "DynamicGraphSystem", "SuperstepRecord", "History", "CostModel",
    "empty_graph", "bsr_snapshot", "partition_relabelled",
]


def test_public_api_snapshot():
    assert api.__all__ == PUBLIC_API
    for name in PUBLIC_API:
        assert hasattr(api, name), name


# ---------------------------------------------------------------------------
# SystemConfig
# ---------------------------------------------------------------------------

def test_system_config_round_trip():
    cfg = SystemConfig(
        stream=StreamSection(window=123, batch_span=7, a_cap=11, d_cap=5,
                             dedupe=True, carry_backlog=False),
        partition=PartitionSection(strategy="fennel", k=3, s=0.7,
                                   adapt_iters=2, tie_break="stay",
                                   slack=0.33, placement_passes=4,
                                   patience=9, max_iters=44, rel_tol=1e-2),
        telemetry=TelemetrySection(recompute_every=3, bsr_blk=16),
        seed=42)
    d = cfg.to_dict()
    assert SystemConfig.from_dict(d) == cfg
    # the dict is plain JSON types all the way down
    import json
    assert SystemConfig.from_dict(json.loads(json.dumps(d))) == cfg


def test_system_config_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown SystemConfig sections"):
        SystemConfig.from_dict({"partitoin": {}})
    with pytest.raises(ValueError, match="unknown keys.*partition"):
        SystemConfig.from_dict({"partition": {"strateg": "xdgp"}})


def test_with_strategy_swaps_one_field():
    cfg = SystemConfig()
    swapped = cfg.with_strategy("static")
    assert swapped.partition.strategy == "static"
    assert dataclasses.replace(swapped.partition, strategy="xdgp") == cfg.partition
    assert swapped.stream == cfg.stream and swapped.seed == cfg.seed


# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------

def test_registry_resolution_names_aliases_instances():
    assert resolve_strategy("xdgp").name == "xdgp"
    assert resolve_strategy("adaptive").name == "xdgp"     # alias
    assert resolve_strategy("hsh").name == "hash"          # seed-era alias
    inst = XdgpAdaptive(placement="inherit")
    assert resolve_strategy(inst) is inst
    assert resolve_strategy(api.Static) .name == "static"  # class
    for name in ("static", "hash", "random", "dgr", "mnn", "fennel", "xdgp"):
        assert name in strategy_names()


def test_registry_typo_lists_names():
    with pytest.raises(ValueError) as ei:
        resolve_strategy("xdpg")
    msg = str(ei.value)
    assert "xdpg" in msg and "xdgp" in msg and "static" in msg


def test_initial_partition_goes_through_registry():
    from repro.core import initial_partition
    g = generators.fem_cube(6)
    lab = initial_partition(g, 4, "hsh")
    assert ((np.asarray(lab) >= 0) & (np.asarray(lab) < 4)).all()
    # kwargs forward to the strategy constructor
    r1 = initial_partition(g, 4, "rnd", seed=3)
    r2 = initial_partition(g, 4, "rnd", seed=3)
    assert np.array_equal(np.asarray(r1), np.asarray(r2))
    with pytest.raises(ValueError, match="registered strategies"):
        initial_partition(g, 4, "hshh")


def test_strategy_init_matches_legacy_functions():
    from repro.core.initial import hash_partition, random_partition
    g = generators.fem_cube(6)
    assert np.array_equal(np.asarray(resolve_strategy("hash").init(g, 5)),
                          np.asarray(hash_partition(g, 5)))
    assert np.array_equal(np.asarray(resolve_strategy("random", seed=2).init(g, 5)),
                          np.asarray(random_partition(g, 5, seed=2)))


# ---------------------------------------------------------------------------
# Shim equivalence: old front doors == new front door
# ---------------------------------------------------------------------------

_TIMING_FIELDS = {"ingest_seconds", "step_seconds", "compute_seconds"}


def _structural(records):
    out = []
    for r in records:
        d = dataclasses.asdict(r)
        for f in _TIMING_FIELDS:
            d.pop(f)
        out.append(d)
    return out


@pytest.mark.parametrize("placement,adapt_iters",
                         [("online", 3), ("hash", 0)])
def test_stream_engine_shim_matches_system(placement, adapt_iters):
    """StreamEngine.run_stream telemetry must equal DynamicGraphSystem.run
    on the same seed/stream — the shim mapping is exact, not approximate."""
    from repro.stream import StreamConfig, StreamEngine
    from repro.stream.engine import _system_config

    n, window = 250, 120
    times, u, v = generators.sliding_window_stream(n, 2500, window, seed=4)
    cfg = StreamConfig(k=4, window=window, adapt_iters=adapt_iters,
                       placement=placement, a_cap=2048, d_cap=2048,
                       recompute_every=3, seed=11)
    g = empty_graph(n, 5000)
    with pytest.warns(DeprecationWarning):
        eng = StreamEngine(g, cfg)
    recs_old = eng.run_stream(times, u, v, window // 2)

    sys_cfg, strategy = _system_config(g, cfg)
    system = DynamicGraphSystem(g, sys_cfg, strategy=strategy)
    recs_new = system.run((times, u, v), batch_span=window // 2)

    assert _structural(recs_old) == _structural(recs_new)
    assert np.array_equal(np.asarray(eng.state.assignment),
                          np.asarray(system.state.assignment))


def test_adaptive_partitioner_shim_matches_converge():
    """The deprecated batch driver and DynamicGraphSystem.converge() run the
    identical heuristic under the same seed."""
    from repro.core import AdaptiveConfig, AdaptivePartitioner, initial_partition
    from repro.core.partition_state import default_capacity

    g = generators.fem_cube(7)
    k = 4
    lab = initial_partition(g, k, "hsh")
    with pytest.warns(DeprecationWarning):
        part = AdaptivePartitioner(AdaptiveConfig(k=k, max_iters=30,
                                                  patience=8, slack=0.2))
    # pin the slot-space capacity the session provisions, so both drivers
    # start from the identical PartitionState
    cap = default_capacity(g.n_cap, k, 0.2)
    state = part.init_state(g, lab, capacity=cap)
    state, hist_old = part.run_to_convergence(g, state)

    cfg = SystemConfig(partition=PartitionSection(strategy="xdgp", k=k,
                                                  max_iters=30, patience=8,
                                                  slack=0.2))
    system = DynamicGraphSystem(g, cfg, assignment=lab)
    hist_new = system.converge()
    assert hist_old.as_dict() == hist_new.as_dict()
    assert np.array_equal(np.asarray(state.assignment),
                          np.asarray(system.labels))


def test_deprecation_warnings_on_seed_entry_points():
    from repro.graph.dynamics import ChangeQueue, SlidingWindowGraph
    with pytest.warns(DeprecationWarning, match="repro.api"):
        ChangeQueue(a_cap=4, d_cap=4)
    with pytest.warns(DeprecationWarning, match="repro.api"):
        SlidingWindowGraph(empty_graph(10, 10), window=5)


# ---------------------------------------------------------------------------
# Session behaviour
# ---------------------------------------------------------------------------

def test_strategy_swap_reproduces_adaptive_vs_static():
    """Swapping xdgp → static in the one SystemConfig field is the paper's
    comparison: same stream, adaptive ends with the lower cut."""
    n, window = 250, 120
    times, u, v = generators.sliding_window_stream(n, 3000, window, seed=6)
    cfg = SystemConfig(
        stream=StreamSection(window=window, batch_span=window // 2),
        partition=PartitionSection(strategy="xdgp", k=4, adapt_iters=4),
        telemetry=TelemetrySection(recompute_every=2))
    runs = {}
    for name in ("xdgp", "static"):
        system = DynamicGraphSystem(empty_graph(n, 5000),
                                    cfg.with_strategy(name))
        system.run((times, u, v))
        runs[name] = system
    assert runs["xdgp"].cut_ratio < runs["static"].cut_ratio
    # static == zero migrations, zero online placements beyond inheritance
    assert sum(r.migrations for r in runs["static"].telemetry) == 0


def test_compare_keys_and_direction():
    """compare() keeps the historical harness layout and picks the winner."""
    n, window = 250, 120
    times, u, v = generators.sliding_window_stream(n, 3000, window, seed=8)
    cfg = SystemConfig(
        stream=StreamSection(window=window, batch_span=window // 2),
        partition=PartitionSection(strategy="xdgp", k=4, adapt_iters=4),
        compute=api.ComputeSection(program="degree"),
        telemetry=TelemetrySection(recompute_every=2))
    system = DynamicGraphSystem(empty_graph(n, 5000), cfg)
    # a comparison without a vertex program would score 0 vs 0 and fabricate
    # a 100% reduction — the session refuses instead
    bare = SystemConfig(stream=cfg.stream, partition=cfg.partition,
                        telemetry=cfg.telemetry)
    with pytest.raises(RuntimeError, match="vertex program"):
        DynamicGraphSystem(empty_graph(n, 5000), bare).compare((times, u, v))
    row = system.compare((times, u, v), baseline="static")
    for key in ("adaptive", "static", "exec_cost_reduction_pct",
                "remote_reduction_pct", "cut_improvement",
                "bsr_tile_reduction_pct", "meets_50pct_claim",
                "scenario", "program", "k", "events", "notes"):
        assert key in row, key
    for sub in ("adaptive", "static"):
        for key in ("mode", "supersteps", "events", "cut_final", "cut_mean",
                    "imbalance_final", "migrations_total", "placed_total",
                    "local_bytes", "remote_bytes", "exec_cost_total",
                    "exec_cost_per_superstep", "adaptation_cost",
                    "compute_seconds", "wall_seconds", "bsr",
                    "cut_trajectory"):
            assert key in row[sub], (sub, key)
    assert row["adaptive"]["cut_final"] <= row["static"]["cut_final"]


def test_inject_and_snapshot():
    g = generators.fem_cube(7, n_cap=420, e_cap=1600)   # head-room for growth
    cfg = SystemConfig(partition=PartitionSection(strategy="xdgp", k=4,
                                                  max_iters=40, patience=10,
                                                  slack=0.3))
    system = DynamicGraphSystem(g, cfg)
    before = system.snapshot()
    system.converge()
    after = system.snapshot()
    assert after["cut_ratio"] < before["cut_ratio"]
    delta = generators.forest_fire_delta(system.graph, 0.05, seed=2)
    placed = system.inject(delta)
    assert placed > 0
    snap = system.snapshot()
    # the incremental tracker stays exact through inject()
    assert snap["cut_ratio"] == pytest.approx(
        float(cut_ratio(system.graph, system.labels)), abs=1e-6)
    assert snap["nodes"] == int(np.asarray(system.graph.node_mask).sum())


def test_custom_strategy_plugs_in():
    """Anything satisfying the protocol works — no subclassing required."""
    import jax.numpy as jnp

    class Blocky:
        name = "blocky-custom"

        def init(self, graph, k):
            ids = jnp.arange(graph.n_cap)
            per = -(-graph.n_cap // k)
            return jnp.minimum(ids // per, k - 1).astype(jnp.int32)

        def place(self, delta, ctx):
            return ctx.assignment

        def adapt(self, graph, state, ctx):
            return state

        def converge(self, graph, state, ctx):
            from repro.core.repartitioner import History
            return state, History.empty()

        def adapt_rounds(self, graph, state, iters, ctx):
            from repro.core.repartitioner import History
            return state, History.empty()

    n, window = 150, 100
    times, u, v = generators.sliding_window_stream(n, 1200, window, seed=1)
    cfg = SystemConfig(stream=StreamSection(window=window, batch_span=50),
                       partition=PartitionSection(strategy="static", k=3),
                       telemetry=TelemetrySection(recompute_every=1))
    system = DynamicGraphSystem(empty_graph(n, 3000), cfg, strategy=Blocky())
    recs = system.run((times, u, v), max_supersteps=6)
    assert system.strategy.name == "blocky-custom"
    assert all(r.drift == 0.0 for r in recs if r.drift is not None)


def test_scenario_is_a_valid_stream():
    """A Scenario drops into run()/compare() directly (batch_span honoured)."""
    from repro.scenarios import SCENARIOS
    scn = SCENARIOS["cellular"]("smoke", seed=0)
    system = DynamicGraphSystem(scn.graph, scn.system_config())
    recs = system.run(scn, max_supersteps=4)
    assert len(recs) == 4
    assert recs[0].now == int(np.asarray(scn.times).min()) + scn.batch_span - 1 \
        or recs[0].now >= int(np.asarray(scn.times).min())
