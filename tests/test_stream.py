"""Streaming ingestion engine tests: vectorized ingest equivalence with the
seed per-event path, incremental cut tracking vs. full recompute, online
placement quality, and capacity backpressure accounting."""
import dataclasses
import time
from collections import deque

import jax
import numpy as np
import jax.numpy as jnp

from repro.graph import generators
from repro.graph.dynamics import ChangeQueue, SlidingWindowGraph
from repro.graph.structure import Graph, GraphDelta, apply_delta, cut_edges, cut_ratio
from repro.stream import (StreamConfig, StreamEngine, WindowIngestor,
                          build_delta, place_delta, stream_batches)
from repro.stream.ingest import EdgeStreamBuffer


# --- reference implementation: the seed's per-event Python loops -----------

class _SeedChangeQueue:
    def __init__(self, a_cap=4096, d_cap=1024):
        self.a_cap, self.d_cap = a_cap, d_cap
        self._adds, self._dels = deque(), deque()

    def add_edge(self, u, v):
        self._adds.append((u, v))

    def remove_node(self, v):
        self._dels.append(v)

    def drain(self):
        a = min(len(self._adds), self.a_cap)
        d = min(len(self._dels), self.d_cap)
        add_src = np.full((self.a_cap,), -1, np.int32)
        add_dst = np.full((self.a_cap,), -1, np.int32)
        add_mask = np.zeros((self.a_cap,), bool)
        for i in range(a):
            u, v = self._adds.popleft()
            add_src[i], add_dst[i] = u, v
            add_mask[i] = True
        del_nodes = np.full((self.d_cap,), -1, np.int32)
        del_mask = np.zeros((self.d_cap,), bool)
        for i in range(d):
            del_nodes[i] = self._dels.popleft()
            del_mask[i] = True
        return GraphDelta(add_src=jnp.asarray(add_src), add_dst=jnp.asarray(add_dst),
                          add_mask=jnp.asarray(add_mask),
                          del_nodes=jnp.asarray(del_nodes),
                          del_mask=jnp.asarray(del_mask))


class _SeedSlidingWindow:
    def __init__(self, graph, window, a_cap=8192, d_cap=4096):
        self.graph, self.window = graph, window
        self.a_cap, self.d_cap = a_cap, d_cap
        self.last_seen = {}

    def advance(self, events, now):
        queue = _SeedChangeQueue(self.a_cap, self.d_cap)
        for t, u, v in events:
            queue.add_edge(int(u), int(v))
            self.last_seen[int(u)] = int(t)
            self.last_seen[int(v)] = int(t)
        horizon = now - self.window
        stale = [n for n, t in self.last_seen.items() if t < horizon]
        for n in stale:
            queue.remove_node(n)
            del self.last_seen[n]
        self.graph = apply_delta(self.graph, queue.drain())
        return self.graph


def _empty_graph(n_cap, e_cap):
    return Graph(src=jnp.full((e_cap,), -1, jnp.int32),
                 dst=jnp.full((e_cap,), -1, jnp.int32),
                 node_mask=jnp.zeros((n_cap,), bool),
                 edge_mask=jnp.zeros((e_cap,), bool))


def _graphs_equal(a: Graph, b: Graph) -> bool:
    return (np.array_equal(np.asarray(a.src), np.asarray(b.src))
            and np.array_equal(np.asarray(a.dst), np.asarray(b.dst))
            and np.array_equal(np.asarray(a.node_mask), np.asarray(b.node_mask))
            and np.array_equal(np.asarray(a.edge_mask), np.asarray(b.edge_mask)))


def test_sliding_window_matches_seed_loop():
    """Vectorized windowed ingest reproduces the seed per-event path exactly."""
    n, window = 400, 200
    times, u, v = generators.sliding_window_stream(n, 4000, window, seed=3)
    new = SlidingWindowGraph(_empty_graph(n, 6000), window, a_cap=2048, d_cap=2048)
    old = _SeedSlidingWindow(_empty_graph(n, 6000), window, a_cap=2048, d_cap=2048)
    for i, (now, events) in enumerate(stream_batches(times, u, v, window // 2)):
        g_new = new.advance(events, now)
        g_old = old.advance(events, now)
        assert _graphs_equal(g_new, g_old), f"diverged at batch {i}"
        assert new.last_seen == old.last_seen, f"window state diverged at batch {i}"


def test_change_queue_drain_matches_seed():
    """Vectorized drain: identical padded layout, FIFO order, leftovers kept."""
    rng = np.random.default_rng(0)
    new = ChangeQueue(a_cap=64, d_cap=16)
    old = _SeedChangeQueue(a_cap=64, d_cap=16)
    for _ in range(100):                      # oversubscribe both caps
        a, b = int(rng.integers(0, 500)), int(rng.integers(0, 500))
        new.add_edge(a, b)
        old.add_edge(a, b)
    for _ in range(40):
        d = int(rng.integers(0, 500))
        new.remove_node(d)
        old.remove_node(d)
    while len(new) or len(old._adds) or len(old._dels):
        dn, do = new.drain(), old.drain()
        for f in ("add_src", "add_dst", "add_mask", "del_nodes", "del_mask"):
            assert np.array_equal(np.asarray(getattr(dn, f)),
                                  np.asarray(getattr(do, f))), f
    assert len(new) == 0


def test_incremental_cut_matches_full_recompute_every_batch():
    """QualityTracker drift must be exactly zero at every superstep."""
    n, window = 500, 250
    times, u, v = generators.sliding_window_stream(n, 5000, window, seed=11)
    cfg = StreamConfig(k=5, window=window, adapt_iters=3, recompute_every=1,
                       a_cap=2048, d_cap=2048, seed=1)
    eng = StreamEngine(_empty_graph(n, 8000), cfg)
    recs = eng.run_stream(times, u, v, window // 3)
    assert len(recs) >= 10
    for r in recs:
        assert r.drift == 0.0, f"superstep {r.superstep}: drift {r.drift}"
        assert abs(r.cut_edges - r.cut_ratio * max(r.live_edges, 1)) < 1e-3
    # occupancy tracked incrementally must also match a direct count
    occ = np.bincount(np.asarray(eng.state.assignment)[np.asarray(eng.graph.node_mask)],
                      minlength=cfg.k)
    assert np.array_equal(occ, np.asarray(eng.tracker.occupancy))


def test_online_placement_beats_hash_on_community_arrivals():
    """Arrivals with community structure: the streaming placer lands them
    with their community; hash placement scatters them."""
    rng = np.random.default_rng(5)
    k, per, warm = 4, 120, 60             # 4 communities, 60 warm members each
    n = k * per
    # warm graph: intra-community edges among the first `warm` members
    src, dst = [], []
    for c in range(k):
        base = c * per
        for _ in range(warm * 4):
            a, b = rng.integers(0, warm, 2)
            if a != b:
                src.append(base + a)
                dst.append(base + b)
    from repro.graph.structure import from_edges
    g = from_edges(np.array(src), np.array(dst), n, n_cap=n, e_cap=len(src) + 4096)
    # only the warm cores are live; cold members arrive via the delta
    warm_mask = np.zeros((n,), bool)
    for c in range(k):
        warm_mask[c * per: c * per + warm] = True
    g = dataclasses.replace(g, node_mask=jnp.asarray(warm_mask))
    node_mask = warm_mask
    # warm labels: community c -> partition c (ideal), padding slots hashed
    from repro.core.initial import hash_partition
    labels = np.asarray(hash_partition(g, k)).copy()
    for c in range(k):
        labels[c * per: c * per + warm] = c
    labels = jnp.asarray(labels)
    # arrivals: cold members wire into their own community's warm core
    asrc, adst = [], []
    for c in range(k):
        base = c * per
        for i in range(warm, per):
            for _ in range(3):
                asrc.append(base + i)
                adst.append(base + int(rng.integers(0, warm)))
    delta = build_delta(np.array(asrc), np.array(adst), np.empty(0, np.int64),
                        a_cap=4096, d_cap=16)
    g_after = apply_delta(g, delta)
    occ = jnp.asarray(np.bincount(labels[node_mask], minlength=k))
    cap = jnp.full((k,), int(n / k * 1.5) + 1, jnp.int32)
    placed, stats = place_delta(delta, g.node_mask, labels, occ, cap,
                                jax.random.PRNGKey(7), k=k, passes=2)
    cut_online = float(cut_ratio(g_after, placed))
    cut_hash = float(cut_ratio(g_after, labels))
    assert int(stats.placed) == k * (per - warm)
    assert cut_online < 0.5 * cut_hash, (cut_online, cut_hash)
    assert cut_online < 0.05, cut_online          # arrivals land with their community


def test_backpressure_accounting_and_drain():
    """Overflow beyond a_cap stays queued, is reported, and drains later."""
    n = 300
    g = _empty_graph(n, 4000)
    cfg = StreamConfig(k=3, window=10**9, adapt_iters=0, a_cap=128, d_cap=64,
                       recompute_every=1)
    eng = StreamEngine(g, cfg)
    rng = np.random.default_rng(2)
    ev = np.stack([np.arange(500), rng.integers(0, n, 500),
                   rng.integers(0, n, 500)], axis=1)
    r = eng.superstep(ev, now=500)
    assert r.adds == 128 and r.backlog_adds == 500 - 128
    drained = eng.drain_backlog(now=500)
    assert drained[-1].backlog_adds == 0
    assert sum(d.adds for d in drained) == 500 - 128
    # incremental tracker stayed exact throughout the backlog flush
    assert all(d.drift == 0.0 for d in drained)


def test_placement_respects_capacity():
    """Arrivals all attracted to one full partition must spill to free room
    elsewhere instead of overfilling it."""
    n, k, warm = 64, 4, 8
    src = np.repeat(np.arange(warm), 2)
    dst = np.roll(src, 1)
    from repro.graph.structure import from_edges
    g = from_edges(src, dst, n, n_cap=n, e_cap=512)
    mask = np.zeros(n, bool)
    mask[:warm] = True                        # only the magnet core is live
    g = dataclasses.replace(g, node_mask=jnp.asarray(mask))
    labels = jnp.zeros((n,), jnp.int32)       # core all in partition 0
    # 24 arrivals, every one wired into partition 0's core
    asrc = np.arange(warm, warm + 24)
    adst = np.arange(24) % warm
    delta = build_delta(asrc, adst, np.empty(0, np.int64), a_cap=64, d_cap=4)
    occ = jnp.asarray(np.bincount(np.zeros(warm, np.int64), minlength=k))
    cap = jnp.full((k,), 12, jnp.int32)       # partition 0 has room for 4 more
    placed, stats = place_delta(delta, g.node_mask, labels, occ, cap,
                                jax.random.PRNGKey(0), k=k, passes=2)
    g_after = apply_delta(g, delta)
    occ_after = np.bincount(np.asarray(placed)[np.asarray(g_after.node_mask)],
                            minlength=k)
    assert int(stats.placed) == 24
    assert occ_after.max() <= 12, occ_after   # nothing exceeds capacity
    assert occ_after.sum() == warm + 24


def test_backlogged_changes_revalidated_against_window():
    """An edge stuck in the backlog must not resurrect an expired node into
    an untracked (never-expiring) state, and a queued deletion must not kill
    a node that became active again while it waited."""
    from repro.stream import WindowIngestor
    ing = WindowIngestor(n_cap=50, window=10, a_cap=2, d_cap=64)
    # t=0: three edges from node 0; a_cap=2 leaves (0,3)@t=0 backlogged
    ev = np.array([[0, 0, 1], [0, 0, 2], [0, 0, 3]])
    _, s = ing.ingest(ev, now=0)
    assert s.adds_out == 2 and s.adds_backlog == 1
    # t=25: window has moved past t=0; the backlogged edge is now stale and
    # must be dropped, not applied with untracked endpoints
    delta, s = ing.ingest(np.empty((0, 3)), now=25)
    assert s.stale_dropped >= 1 and s.adds_out == 0
    assert ing.tracker.tracked == 0           # nothing left tracked
    # queued deletion for a node that comes back: expire node 7, then touch
    # it again before the deletion would drain
    ing2 = WindowIngestor(n_cap=50, window=10, a_cap=8, d_cap=0)  # d_cap=0: dels queue
    ing2.ingest(np.array([[0, 7, 8]]), now=0)
    _, s = ing2.ingest(np.empty((0, 3)), now=20)      # 7, 8 expire; dels backlogged
    assert s.dels_backlog == 2
    ing2.d_cap = ing2.buffer.d_cap = 64                # capacity restored
    delta, s = ing2.ingest(np.array([[21, 7, 9]]), now=21)  # 7 is active again
    dn = np.asarray(delta.del_nodes)[np.asarray(delta.del_mask)]
    assert 7 not in dn and 8 in dn                     # stale del dropped for 7 only
    assert s.stale_dropped == 1


def test_stream_batches_rejects_nonpositive_span():
    import pytest
    with pytest.raises(ValueError):
        next(stream_batches(np.arange(10), np.arange(10), np.arange(10), 0))


def test_seed_mode_reports_overflow_as_dropped_not_backlog():
    from repro.stream import WindowIngestor
    ing = WindowIngestor(n_cap=50, window=100, a_cap=2, d_cap=8,
                         carry_backlog=False)
    ev = np.array([[0, 1, 2], [0, 3, 4], [0, 5, 6], [0, 7, 8]])
    _, s = ing.ingest(ev, now=0)
    assert s.adds_out == 2 and s.adds_backlog == 0 and s.overflow_dropped == 2
    _, s = ing.ingest(np.empty((0, 3)), now=1)    # the overflow is truly gone
    assert s.adds_out == 0


def test_engine_matches_sliding_window_graph_topology():
    """With placement/adaptation disabled, the engine's graph evolution equals
    the compat SlidingWindowGraph's on the same stream (modulo backpressure,
    which is off when caps exceed the batch size)."""
    n, window = 300, 150
    times, u, v = generators.sliding_window_stream(n, 3000, window, seed=9)
    cfg = StreamConfig(k=4, window=window, adapt_iters=0, placement="hash",
                       a_cap=4096, d_cap=4096, recompute_every=0)
    eng = StreamEngine(_empty_graph(n, 6000), cfg)
    swg = SlidingWindowGraph(_empty_graph(n, 6000), window, a_cap=4096, d_cap=4096)
    for now, events in stream_batches(times, u, v, window // 2):
        eng.superstep(events, now)
        swg.advance(events, now)
        assert _graphs_equal(eng.graph, swg.graph)


def test_buffer_pop_work_is_linear_in_popped_not_backlog():
    """The backlog-handling contract (DESIGN.md §14): servicing a pop
    copies O(popped) elements regardless of backlog depth.  The previous
    implementation re-concatenated the whole backlog every pop — under a
    sustained overload (pushes outpacing drains) total copy work grew
    quadratically.  ``copied_elements`` counts exactly the work done."""
    a_cap = 512
    buf = EdgeStreamBuffer(a_cap=a_cap, d_cap=64)
    rounds, push_per_round = 200, 1024
    popped = 0
    for i in range(rounds):
        e = np.arange(push_per_round, dtype=np.int64)
        buf.push_edges(e, e + 1, e)          # backlog grows every round
        src, _, _, _ = buf.pop()
        popped += src.shape[0]
    # total work == total popped (here: a_cap per round while backlogged),
    # NOT O(sum of backlog depths) ≈ rounds²·(push-pop)/2 ≈ 10M elements
    assert popped == rounds * a_cap
    assert buf.copied_elements == popped
    # FIFO survived the deque rework: the next element out is exactly the
    # (total popped)-th element pushed
    src, _, _, _ = buf.pop()
    assert src.shape[0] == a_cap
    assert src[0] == popped % push_per_round


def test_buffer_fifo_across_chunk_boundaries():
    buf = EdgeStreamBuffer(a_cap=5, d_cap=3)
    buf.push_edges([0, 1], [10, 11], [100, 101])
    buf.push_edges([2, 3, 4, 5], [12, 13, 14, 15], [102, 103, 104, 105])
    buf.push_node_removals([7, 8])
    buf.push_node_removals([9, 10])
    src, dst, t, dels = buf.pop()
    assert src.tolist() == [0, 1, 2, 3, 4]
    assert dst.tolist() == [10, 11, 12, 13, 14]
    assert t.tolist() == [100, 101, 102, 103, 104]
    assert dels.tolist() == [7, 8, 9]
    assert buf.backlog == (1, 1)
    src, _, _, dels = buf.pop()
    assert src.tolist() == [5] and dels.tolist() == [10]
    assert len(buf) == 0


def test_vectorized_ingest_throughput_beats_per_event_loop():
    """Pin the ROADMAP's "no per-event Python state" constraint with a
    wall-clock ratio: the vectorized buffer must drain a large batch at
    least 5x faster than the seed's per-event deque loop (it measures
    ~100x here; 5x keeps CI noise-proof)."""
    n_events = 50_000
    rng = np.random.default_rng(0)
    u = rng.integers(0, 1000, n_events)
    v = rng.integers(0, 1000, n_events)
    t = np.arange(n_events)

    t0 = time.perf_counter()
    seed_q = _SeedChangeQueue(a_cap=4096, d_cap=64)
    for i in range(n_events):
        seed_q.add_edge(int(u[i]), int(v[i]))
    while seed_q._adds:
        seed_q.drain()
    seed_seconds = time.perf_counter() - t0

    t0 = time.perf_counter()
    buf = EdgeStreamBuffer(a_cap=4096, d_cap=64)
    buf.push_edges(u, v, t)
    while len(buf):
        buf.pop()
    vec_seconds = time.perf_counter() - t0

    assert vec_seconds * 5 < seed_seconds, (
        f"vectorized drain {vec_seconds:.4f}s not 5x faster than "
        f"per-event loop {seed_seconds:.4f}s")
