"""Component tests: MoE dispatch equivalence, serving engine, elastic
rescaling, vertex programs, sampler, BSR, initial partitioners, dynamics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import initial_partition
from repro.core.vertex_program import (pagerank, run as vp_run,
                                       weakly_connected_components)
from repro.graph import cut_ratio, generators, to_csr
from repro.graph.bsr import bsr_density_stats, graph_to_bsr
from repro.graph.dynamics import SlidingWindowGraph, stream_batches
from repro.graph.sampler import NeighbourSampler
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.runtime import elastic_rescale


def test_moe_sorted_matches_einsum_no_drop():
    key = jax.random.PRNGKey(0)
    cfg_e = MoEConfig(n_experts=8, top_k=2, d_ff=32, capacity_factor=16.0,
                      dispatch="einsum")
    cfg_s = dataclasses.replace(cfg_e, dispatch="sorted")
    p = moe_init(key, 16, cfg_e)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y_e, aux_e = moe_apply(p, x, cfg_e)
    y_s, aux_s = moe_apply(p, x, cfg_s)
    np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_s), atol=1e-5)
    np.testing.assert_allclose(float(aux_e), float(aux_s), rtol=1e-5)


def test_moe_capacity_drops_tokens():
    key = jax.random.PRNGKey(0)
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff=16, capacity_factor=0.25,
                    dispatch="sorted")
    p = moe_init(key, 8, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
    y, _ = moe_apply(p, x, cfg)
    # with capacity 0.25 most tokens are dropped → many zero rows
    zero_rows = np.asarray((jnp.abs(y).sum(-1) == 0)).mean()
    assert zero_rows > 0.4


def test_serving_engine_completes():
    from repro.models import TransformerConfig, init_params
    from repro.serve import Request, ServeEngine
    cfg = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=2,
                            n_kv_heads=1, head_dim=16, d_ff=64, vocab=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=64)
    for uid in range(4):
        eng.submit(Request(uid=uid, prompt=np.array([3 + uid, 7]),
                           max_new_tokens=4))
    outs = eng.run_until_drained()
    assert sorted(c.uid for c in outs) == [0, 1, 2, 3]
    assert all(len(c.tokens) == 4 for c in outs)


def test_elastic_rescale_recovers_quality():
    g = generators.fem_cube(10)
    from repro.core import AdaptiveConfig, AdaptivePartitioner
    part = AdaptivePartitioner(AdaptiveConfig(k=8, max_iters=60, patience=60))
    st = part.init_state(g, initial_partition(g, 8, "hsh"))
    st, _ = part.adapt(g, st, 60)
    a, hist, rep = elastic_rescale(g, st.assignment, 8, 6, adapt_iters=40)
    assert rep["cut_after_adapt"] < rep["cut_after_rehash"]
    assert set(np.unique(np.asarray(a))) <= set(range(6))


def test_pagerank_conserves_mass():
    g = generators.power_law(200, seed=0)
    state = vp_run(pagerank(), g, 15)
    assert abs(float(state.sum()) - 1.0) < 1e-3


def test_wcc_two_components():
    import jax.numpy as jnp
    from repro.graph import from_edges
    # two disjoint triangles
    src = np.array([0, 1, 2, 3, 4, 5])
    dst = np.array([1, 2, 0, 4, 5, 3])
    g = from_edges(src, dst, 6)
    state = vp_run(weakly_connected_components(), g, 5)
    labels = np.asarray(state)[:, 0]
    assert len(set(labels[:3])) == 1 and len(set(labels[3:])) == 1
    assert labels[0] != labels[3]


def test_sampler_shapes_and_validity():
    g = generators.power_law(500, seed=1)
    indptr, indices = to_csr(g)
    s = NeighbourSampler(indptr, indices, fanouts=(5, 3), seed=0)
    block = s.sample(np.arange(32))
    n_max, e_max = s.block_caps(32)
    assert block.node_ids.shape == (n_max,)
    assert block.edge_src.shape == (e_max,)
    em = block.edge_mask
    assert (block.edge_src[em] >= 0).all()
    assert (block.edge_dst[em] < n_max).all()
    # all edges point to nodes present in the block
    assert block.node_mask[block.edge_src[em]].all()


def test_bsr_reorder_improves_locality_vs_scrambled():
    """Partition-contiguous relocation improves tile locality when vertex ids
    carry no locality (the production case: ids arrive hashed). NOTE: on a
    lexicographically-ordered FEM mesh the natural ordering is *already*
    banded and partition-sort loses it — a refuted-hypothesis lesson recorded
    in EXPERIMENTS.md §Perf (within-partition RCM ordering recovers it)."""
    import jax.numpy as jnp
    from repro.core import AdaptiveConfig, AdaptivePartitioner
    from repro.core.placement import apply_relocation, plan_relocation
    from repro.graph.structure import Graph, from_edges
    g0 = generators.fem_cube(10)
    # scramble ids (hashed arrival order)
    rng = np.random.default_rng(0)
    perm = rng.permutation(g0.n_cap)
    src = perm[np.asarray(g0.src)]
    dst = perm[np.asarray(g0.dst)]
    g = from_edges(src, dst, g0.n_cap)
    part = AdaptivePartitioner(AdaptiveConfig(k=8, max_iters=60, patience=60))
    st = part.init_state(g, initial_partition(g, 8, "hsh"))
    st, _ = part.adapt(g, st, 60)
    stats_before = bsr_density_stats(graph_to_bsr(g, blk=64))
    reloc = plan_relocation(g, st.assignment, 8)
    g2, _ = apply_relocation(g, reloc, jnp.zeros((g.n_cap, 1)))
    stats_after = bsr_density_stats(graph_to_bsr(g2, blk=64))
    assert stats_after["nnzb"] < stats_before["nnzb"]
    # RCM within partitions recovers banding beyond plain partition-sort
    from repro.core.placement import rcm_within_partitions
    reloc_rcm = rcm_within_partitions(g, st.assignment, 8)
    g3, _ = apply_relocation(g, reloc_rcm, jnp.zeros((g.n_cap, 1)))
    stats_rcm = bsr_density_stats(graph_to_bsr(g3, blk=64))
    assert stats_rcm["nnzb"] < stats_after["nnzb"]


def test_sliding_window_expires_nodes():
    import jax.numpy as jnp
    from repro.graph.structure import Graph
    n_cap, e_cap = 64, 256
    g = Graph(src=jnp.full((e_cap,), -1, jnp.int32),
              dst=jnp.full((e_cap,), -1, jnp.int32),
              node_mask=jnp.zeros((n_cap,), bool),
              edge_mask=jnp.zeros((e_cap,), bool))
    swg = SlidingWindowGraph(g, window=10, a_cap=64, d_cap=64)
    g = swg.advance(np.array([[0, 1, 2], [1, 3, 4]]), now=1)
    assert int(g.num_nodes) == 4
    # far future: everything expires
    g = swg.advance(np.array([[50, 9, 10]]), now=50)
    live = set(np.flatnonzero(np.asarray(g.node_mask)))
    assert live == {9, 10}


def test_initial_partitioners_balanced():
    g = generators.power_law(400, seed=3)
    n = int(g.num_nodes)
    for strat in ("hsh", "rnd", "dgr", "mnn"):
        lab = np.asarray(initial_partition(g, 8, strat))
        occ = np.bincount(lab[np.asarray(g.node_mask)], minlength=8)
        if strat in ("rnd", "dgr", "mnn"):
            assert occ.max() <= int(np.ceil(n / 8) * 1.15) + 2, (strat, occ)
        assert ((lab >= 0) & (lab < 8)).all()


def test_dgr_better_initial_cut_than_hash():
    g = generators.fem_cube(8)
    c_h = float(cut_ratio(g, initial_partition(g, 8, "hsh")))
    c_d = float(cut_ratio(g, initial_partition(g, 8, "dgr")))
    assert c_d < c_h  # paper Fig.5: DGR starts far better than hash
