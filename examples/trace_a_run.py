"""Trace a run: enable span tracing on a paper scenario and read the
per-phase report (DESIGN.md §11).

  PYTHONPATH=src python examples/trace_a_run.py

Tracing is off by default; one config knob turns it on.  The session then
records a span for every superstep phase (ingest → place → migrate →
compute → commit, plus the sharded backend's bucket/dispatch/comm
children), exports JSONL + Chrome trace_event files, and the report CLI
summarises where the time went:

  python -m repro.obs.report /tmp/trace_demo.jsonl
"""
import dataclasses
import tempfile
import os

from repro.api import DynamicGraphSystem
from repro.obs.report import render, summarize, _top_level_total
from repro.obs.schema import validate_trace_file
from repro.scenarios import SCENARIOS


def main() -> None:
    scn = SCENARIOS["cellular"]("smoke", seed=0)
    cfg = scn.system_config(strategy="xdgp")
    cfg = dataclasses.replace(cfg, telemetry=dataclasses.replace(
        cfg.telemetry, trace=True, metrics=True))

    system = DynamicGraphSystem(scn.graph, cfg)
    system.run(scn, max_supersteps=8)

    out = os.path.join(tempfile.mkdtemp(prefix="repro_trace_"),
                       "trace_demo.jsonl")
    system.tracer.write_jsonl(out)
    system.tracer.write_chrome(out.replace(".jsonl", ".trace.json"))

    # the same aggregation `python -m repro.obs.report <file>` prints
    events = validate_trace_file(out)
    print(render(summarize(events), _top_level_total(events), label=out))

    print("\nmetrics (Prometheus text, first lines):")
    print("\n".join(system.metrics.to_prometheus().splitlines()[:8]))
    print(f"\nfull trace -> {out} (open the .trace.json in ui.perfetto.dev)")


if __name__ == "__main__":
    main()
