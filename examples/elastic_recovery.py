"""Elastic failure recovery as session operations: snapshot the healthy
cluster, lose 2 of 16 workers mid-computation, re-home orphaned vertices
and let the adaptive heuristic re-converge — then restore the snapshot to
show the paper's §4.3 snapshot-restore path as well. All through the
``repro.api`` cluster lifecycle (``save`` / ``rescale`` / ``restore``),
no raw ``elastic_rescale`` plumbing.

  PYTHONPATH=src python examples/elastic_recovery.py
"""
import tempfile

import numpy as np

from repro.api import DynamicGraphSystem, PartitionSection, SystemConfig
from repro.graph import generators


def main() -> None:
    g = generators.fem_cube(18)
    k = 16
    system = DynamicGraphSystem(g, SystemConfig(
        partition=PartitionSection(strategy="xdgp", k=k, slack=0.1)))
    system.adapt(120)
    healthy = system.snapshot()
    print(f"healthy cluster (k=16): cut={healthy['cut_ratio']:.3f}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # checkpoint the healthy session (paper §4.3: snapshot for recovery)
        step = system.save(ckpt_dir)
        print(f"checkpointed session at step {step} -> {ckpt_dir}")

        # two workers die: one session op re-homes orphans by hash and
        # re-adapts with the same heuristic on the surviving partitions
        report = system.rescale(14, lost=(3, 11), adapt_iters=80)
        print(f"after losing workers 3,11 -> rehash orphans: "
              f"cut={report['cut_after_rehash']:.3f}")
        print(f"after re-adaptation (k=14): "
              f"cut={report['cut_after_adapt']:.3f} "
              f"({report['migrations']} migrations)")

        # capacity scales down with the cluster: verify balance
        occ = np.asarray(system.tracker.occupancy)
        print(f"occupancy: min={occ.min()} max={occ.max()} "
              f"(ideal {int(g.num_nodes) // 14})")
        assert (occ <= np.asarray(system.state.capacity)).all()

        # the paper's literal recovery: restore the pre-failure snapshot
        restored = DynamicGraphSystem.restore(ckpt_dir)
        snap = restored.snapshot()
        print(f"restored healthy snapshot: k={snap['k']} "
              f"cut={snap['cut_ratio']:.3f} "
              f"(matches: {abs(snap['cut_ratio'] - healthy['cut_ratio']) < 1e-9})")


if __name__ == "__main__":
    main()
