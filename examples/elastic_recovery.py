"""Elastic failure recovery: lose 2 of 16 workers mid-computation, re-home
orphaned vertices, and let the adaptive heuristic re-converge placement —
beyond the paper's snapshot-restore (§4.3).

  PYTHONPATH=src python examples/elastic_recovery.py
"""
from repro.api import DynamicGraphSystem, PartitionSection, SystemConfig
from repro.graph import generators
from repro.runtime import elastic_rescale


def main() -> None:
    g = generators.fem_cube(18)
    k = 16
    system = DynamicGraphSystem(g, SystemConfig(
        partition=PartitionSection(strategy="xdgp", k=k, slack=0.1)))
    system.adapt(120)
    print(f"healthy cluster (k=16): cut={system.snapshot()['cut_ratio']:.3f}")

    # two workers die
    assignment, hist, report = elastic_rescale(
        g, system.labels, old_k=16, new_k=14, lost=(3, 11), adapt_iters=80)
    print(f"after losing workers 3,11 -> rehash orphans: "
          f"cut={report['cut_after_rehash']:.3f}")
    print(f"after re-adaptation (k=14): cut={report['cut_after_adapt']:.3f} "
          f"({report['migrations']} migrations)")

    # capacity scales down with the cluster: verify balance
    import numpy as np
    occ = np.bincount(np.asarray(assignment)[np.asarray(g.node_mask)],
                      minlength=14)
    print(f"occupancy: min={occ.min()} max={occ.max()} "
          f"(ideal {int(g.num_nodes)//14})")


if __name__ == "__main__":
    main()
