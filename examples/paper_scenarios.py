"""Replay the paper's three real-world dynamic workloads (§5.3) through the
``repro.api`` front door with compute interleaved, and watch adaptive
partitioning beat static hash on the execution-cost proxy. The comparison
is one ``DynamicGraphSystem.compare`` call — the baseline is just the
``static`` strategy swapped into the same ``SystemConfig``.

  PYTHONPATH=src python examples/paper_scenarios.py [scenario ...]

Scenarios: twitter (mention stream + TunkRank), fem (refinement-wave mesh +
PageRank diffusion), cellular (roaming call graph + WCC). Runs smoke-scale
configs so the whole demo finishes in seconds; use
benchmarks/bench_scenarios_e2e.py for the measured reproduction.
"""
import sys

from repro.scenarios import SCENARIOS, compare_scenario


def main() -> None:
    names = sys.argv[1:] or list(SCENARIOS)
    for name in names:
        scn = SCENARIOS[name]("smoke", seed=0)
        print(f"\n=== {name}: {scn.notes} ===")
        print(f"{scn.n_events} events, window {scn.window}, k={scn.k}, "
              f"program {scn.program}")
        row = compare_scenario(scn)
        a, s = row["adaptive"], row["static"]
        print(f"static hash : cut {s['cut_final']:.3f}, "
              f"remote {s['remote_bytes'] / 1e6:.1f} MB, "
              f"exec cost {s['exec_cost_total'] / 1e6:.1f}")
        print(f"adaptive    : cut {a['cut_final']:.3f}, "
              f"remote {a['remote_bytes'] / 1e6:.1f} MB, "
              f"exec cost {a['exec_cost_total'] / 1e6:.1f} "
              f"({a['migrations_total']} migrations, "
              f"{a['placed_total']} placed online)")
        print(f"execution cost reduction: {row['exec_cost_reduction_pct']}%  "
              f"(BSR tiles -{row['bsr_tile_reduction_pct']}%)")


if __name__ == "__main__":
    main()
