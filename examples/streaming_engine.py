"""Serve a live dynamic graph with the streaming ingestion engine.

Replays a CDR-style call stream through ``StreamEngine`` — vectorized
ingest, online placement of arriving users, interleaved xDGP adaptation,
incremental cut/occupancy telemetry — and prints the per-superstep ops view.
A second pass with placement="hash" shows what online placement buys: the
hash run has to recover arrival damage via migrations every superstep.

  PYTHONPATH=src python examples/streaming_engine.py
"""
import numpy as np
import jax.numpy as jnp

from repro.graph import generators
from repro.graph.structure import Graph
from repro.stream import StreamConfig, StreamEngine


def fresh_graph(n_users: int, e_cap: int) -> Graph:
    return Graph(src=jnp.full((e_cap,), -1, jnp.int32),
                 dst=jnp.full((e_cap,), -1, jnp.int32),
                 node_mask=jnp.zeros((n_users,), bool),
                 edge_mask=jnp.zeros((e_cap,), bool))


def run(placement: str, times, callers, callees, n_users, window) -> None:
    cfg = StreamConfig(k=9, window=window, adapt_iters=4, placement=placement,
                       a_cap=8192, d_cap=4096, recompute_every=5)
    engine = StreamEngine(fresh_graph(n_users, 40000), cfg)
    print(f"\n=== placement={placement} ===")
    print(f"{'step':>4s} {'events':>7s} {'ev/s':>10s} {'backlog':>7s} "
          f"{'placed':>6s} {'moved':>6s} {'cut':>6s} {'imbal':>6s} {'drift':>5s}")
    for rec in engine.run_stream(times, callers, callees, window // 3,
                                 max_supersteps=16):
        drift = "-" if rec.drift is None else f"{rec.drift:.0f}"
        print(f"{rec.superstep:4d} {rec.events:7d} {rec.events_per_second:10.0f} "
              f"{rec.backlog_adds + rec.backlog_dels:7d} {rec.new_placed:6d} "
              f"{rec.migrations:6d} {rec.cut_ratio:6.3f} {rec.imbalance:6.2f} "
              f"{drift:>5s}")
    total_ev = sum(r.events for r in engine.telemetry)
    ingest_s = sum(r.ingest_seconds for r in engine.telemetry)
    moved = sum(r.migrations for r in engine.telemetry)
    print(f"ingested {total_ev} events at {total_ev / max(ingest_s, 1e-12):.0f} ev/s; "
          f"final cut {engine.telemetry[-1].cut_ratio:.3f}, "
          f"{moved} migrations total")


def main() -> None:
    n_users, n_events, window = 6000, 40000, 300
    times, callers, callees = generators.sliding_window_stream(
        n_users, n_events, window, seed=7)
    run("online", times, callers, callees, n_users, window)
    run("hash", times, callers, callees, n_users, window)


if __name__ == "__main__":
    main()
