"""Serve a live dynamic graph with the streaming front door.

Replays a CDR-style call stream through ``repro.api.DynamicGraphSystem`` —
vectorized ingest, strategy-driven placement of arriving users, interleaved
xDGP adaptation, incremental cut/occupancy telemetry — and prints the
per-superstep ops view. A second pass with the same config but
``XdgpAdaptive(placement="inherit")`` shows what online placement buys: the
inherit run has to recover arrival damage via migrations every superstep.

  PYTHONPATH=src python examples/streaming_engine.py
"""
import numpy as np

from repro.api import (DynamicGraphSystem, PartitionSection, StreamSection,
                       SystemConfig, TelemetrySection, XdgpAdaptive,
                       empty_graph)
from repro.graph import generators


def run(placement: str, times, callers, callees, n_users, window) -> None:
    cfg = SystemConfig(
        stream=StreamSection(window=window, batch_span=window // 3,
                             a_cap=8192, d_cap=4096),
        partition=PartitionSection(strategy="xdgp", k=9, adapt_iters=4),
        telemetry=TelemetrySection(recompute_every=5))
    system = DynamicGraphSystem(empty_graph(n_users, 40000), cfg,
                                strategy=XdgpAdaptive(placement=placement))
    print(f"\n=== placement={placement} ===")
    print(f"{'step':>4s} {'events':>7s} {'ev/s':>10s} {'backlog':>7s} "
          f"{'placed':>6s} {'moved':>6s} {'cut':>6s} {'imbal':>6s} {'drift':>5s}")
    for rec in system.run((times, callers, callees), max_supersteps=16):
        drift = "-" if rec.drift is None else f"{rec.drift:.0f}"
        print(f"{rec.superstep:4d} {rec.events:7d} {rec.events_per_second:10.0f} "
              f"{rec.backlog_adds + rec.backlog_dels:7d} {rec.new_placed:6d} "
              f"{rec.migrations:6d} {rec.cut_ratio:6.3f} {rec.imbalance:6.2f} "
              f"{drift:>5s}")
    total_ev = sum(r.events for r in system.telemetry)
    ingest_s = sum(r.ingest_seconds for r in system.telemetry)
    moved = sum(r.migrations for r in system.telemetry)
    print(f"ingested {total_ev} events at {total_ev / max(ingest_s, 1e-12):.0f} ev/s; "
          f"final cut {system.telemetry[-1].cut_ratio:.3f}, "
          f"{moved} migrations total")


def main() -> None:
    n_users, n_events, window = 6000, 40000, 300
    times, callers, callees = generators.sliding_window_stream(
        n_users, n_events, window, seed=7)
    run("online", times, callers, callees, n_users, window)
    run("inherit", times, callers, callees, n_users, window)


if __name__ == "__main__":
    main()
