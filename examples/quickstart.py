"""Quickstart: adaptive repartitioning of a dynamic graph in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py

Loads a FEM mesh, hash-partitions it across 9 workers (paper setup),
runs the xDGP heuristic to convergence, injects a 5% forest-fire burst,
and adapts again — printing cut ratio + balance at each stage.
"""
import numpy as np

from repro.core import (AdaptiveConfig, AdaptivePartitioner, imbalance,
                        initial_partition)
from repro.graph import apply_delta, cut_ratio, generators


def main() -> None:
    # graph with head-room for growth (static shapes, masked)
    g = generators.fem_cube(16, n_cap=5200, e_cap=16000)
    k = 9
    cfg = AdaptiveConfig(k=k, s=0.5, slack=0.3, max_iters=200, patience=30)
    part = AdaptivePartitioner(cfg)

    lab = initial_partition(g, k, "hsh")
    print(f"initial (hash):     cut={float(cut_ratio(g, lab)):.3f}")

    state = part.init_state(g, lab)
    state, hist = part.run_to_convergence(g, state)
    print(f"after adaptation:   cut={hist.cut_ratio[-1]:.3f} "
          f"({hist.iterations} iters, {hist.total_migrations} migrations, "
          f"imbalance={float(imbalance(state, g.node_mask)):.3f})")

    delta = generators.forest_fire_delta(g, 0.05, seed=1)
    g = apply_delta(g, delta)
    burst_cut = float(cut_ratio(g, state.assignment))
    print(f"after 5% burst:     cut={burst_cut:.3f}")

    state, hist = part.adapt(g, state, 40)
    print(f"after re-adaptation: cut={hist.cut_ratio[-1]:.3f} "
          f"({hist.total_migrations} migrations)")


if __name__ == "__main__":
    main()
