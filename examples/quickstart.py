"""Quickstart: adaptive repartitioning of a dynamic graph in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py

One front door: build a ``DynamicGraphSystem`` session over a FEM mesh with
the ``xdgp`` strategy (paper setup: 9 workers), converge, inject a 5%
forest-fire burst, adapt again — printing cut ratio + balance at each stage.
Swap ``strategy="xdgp"`` for ``"static"`` (or any other registered name) to
ablate the adaptive policy with no other change.
"""
from repro.api import DynamicGraphSystem, PartitionSection, SystemConfig
from repro.graph import generators


def main() -> None:
    # graph with head-room for growth (static shapes, masked)
    g = generators.fem_cube(16, n_cap=5200, e_cap=16000)
    cfg = SystemConfig(partition=PartitionSection(
        strategy="xdgp", k=9, s=0.5, slack=0.3, max_iters=200, patience=30))
    system = DynamicGraphSystem(g, cfg)

    snap = system.snapshot()
    print(f"initial (hash):     cut={snap['cut_ratio']:.3f}")

    hist = system.converge()
    snap = system.snapshot()
    print(f"after adaptation:   cut={snap['cut_ratio']:.3f} "
          f"({hist.iterations} iters, {hist.total_migrations} migrations, "
          f"imbalance={snap['imbalance']:.3f})")

    delta = generators.forest_fire_delta(system.graph, 0.05, seed=1)
    placed = system.inject(delta)
    snap = system.snapshot()
    print(f"after 5% burst:     cut={snap['cut_ratio']:.3f} "
          f"({placed} vertices placed online)")

    hist = system.adapt(40)
    snap = system.snapshot()
    print(f"after re-adaptation: cut={snap['cut_ratio']:.3f} "
          f"({hist.total_migrations} migrations)")


if __name__ == "__main__":
    main()
