"""The graph session server end to end (DESIGN.md §12): one ``GraphServer``
hosting several tenant sessions behind an admission front door, bursty
open-loop traffic, backpressure at the queue cap, a checkpoint, a simulated
crash, and a bit-exact recovery — plus the Prometheus scrape any collector
would poll.

  PYTHONPATH=src python examples/serve_sessions.py
"""
import tempfile

import numpy as np

from repro.api import SystemConfig
from repro.serve import (AdmissionPolicy, CheckpointPolicy, GraphServer,
                         TrafficShape, synthetic_stream, telemetry_digest,
                         tick_schedule)


def tenant_config(i: int) -> SystemConfig:
    return SystemConfig.from_dict({
        "graph": {"n_cap": 128, "e_cap": 2048},
        "stream": {"window": 400, "a_cap": 256, "d_cap": 128},
        "partition": {"k": 4},
        "seed": 7 + i,
    })


def main() -> None:
    with tempfile.TemporaryDirectory() as ckpt_dir:
        server = GraphServer(
            admission=AdmissionPolicy(queue_cap=50_000, on_full="reject"),
            checkpoint=CheckpointPolicy(directory=ckpt_dir, every=4))
        names = [f"tenant{i}" for i in range(3)]
        for i, name in enumerate(names):
            server.add_tenant(name, config=tenant_config(i))

        # three independent bursty open-loop arrival processes, quantised
        # onto 20 scheduling ticks so the run is deterministic
        shape = TrafficShape(rate=300.0, burst_rate=2500.0,
                             burst_every=0.5, burst_len=0.1)
        sched = {}
        for i, name in enumerate(names):
            t, u, v = synthetic_stream(96, 500, seed=7 + i)
            sched[name] = tick_schedule(t, u, v, shape, ticks=20, seed=7 + i)

        for tick in range(20):
            for name in names:
                chunk = sched[name][tick]
                if chunk is not None:
                    r = server.submit(name, chunk)
                    if r.rejected:
                        print(f"  tick {tick}: {name} rejected {r.rejected} "
                              f"events at pressure {r.pressure:.2f}")
            server.tick()
        server.drain()

        stats = server.stats()
        print("after the run:")
        for name, t in stats["tenants"].items():
            print(f"  {name}: {t['supersteps']} supersteps, "
                  f"{t['admitted']} events, cut={t['cut_ratio']:.3f}, "
                  f"p99 ingest={1e3 * (t['ingest_p99_s'] or 0):.1f}ms")

        # the cadence checkpointed at tick 20; "crash" and recover fresh
        digests_before = {n: telemetry_digest(server.tenants[n].system.telemetry)
                          for n in names}
        del server                       # the process is gone
        recovered = GraphServer.recover(ckpt_dir)
        report = recovered.last_recovery
        print(f"recovered {len(report['tenants'])} tenants from tick "
              f"{report['tick']} in {report['seconds'] * 1e3:.0f}ms")
        exact = all(
            telemetry_digest(recovered.tenants[n].system.telemetry)
            == digests_before[n] for n in names)
        print(f"bit-exact resume: {exact}")
        assert exact

        # what a Prometheus collector would scrape off the recovered server
        t, u, v = synthetic_stream(96, 50, seed=99)
        recovered.submit("tenant0", np.stack([t, u, v], axis=1))
        recovered.tick()
        scrape = recovered.scrape().splitlines()
        print("scrape sample:")
        for line in scrape[:6]:
            print(f"  {line}")


if __name__ == "__main__":
    main()
