"""Continuous processing of a dynamic graph (the paper's §5.3 CDR use case):
a sliding-window call graph is streamed in while TunkRank influence is
computed every superstep and the partitioning adapts online — one
``DynamicGraphSystem`` session owns the whole loop, including the message
accounting that drives the paper's execution-time model.

  PYTHONPATH=src python examples/dynamic_graph_processing.py
"""
import jax.numpy as jnp

from repro.api import (ComputeSection, DynamicGraphSystem, PartitionSection,
                       StreamSection, SystemConfig, empty_graph)
from repro.graph import generators
from repro.stream import stream_batches


def main() -> None:
    n_users, n_events, window = 4000, 20000, 300
    times, callers, callees = generators.sliding_window_stream(
        n_users, n_events, window, seed=7)
    cfg = SystemConfig(
        stream=StreamSection(window=window, batch_span=window // 3,
                             a_cap=8192, d_cap=4096),
        partition=PartitionSection(strategy="xdgp", k=9, adapt_iters=5,
                                   slack=0.4),
        compute=ComputeSection(program="tunkrank"))
    system = DynamicGraphSystem(empty_graph(n_users, 28000), cfg)

    print(f"{'batch':>5s} {'nodes':>7s} {'edges':>7s} {'cut':>6s} "
          f"{'remote_MB':>9s} {'top_influence':>13s}")
    for i, (now, events) in enumerate(
            stream_batches(times, callers, callees, window // 3)):
        rec = system.step(events, now)
        top = float(jnp.max(system.program_state))   # influence after this superstep
        print(f"{i:5d} {int(system.graph.num_nodes):7d} {rec.live_edges:7d} "
              f"{rec.cut_ratio:6.3f} {rec.remote_bytes / 1e6:9.2f} {top:13.3f}")
        if i >= 15:
            break


if __name__ == "__main__":
    main()
