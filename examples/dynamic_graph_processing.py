"""Continuous processing of a dynamic graph (the paper's §5.3 CDR use case):
a sliding-window call graph is streamed in while TunkRank influence is
computed every superstep and the partitioning adapts online.

  PYTHONPATH=src python examples/dynamic_graph_processing.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import AdaptiveConfig, AdaptivePartitioner, initial_partition
from repro.core.vertex_program import message_volume, run as vp_run, tunkrank
from repro.graph import cut_ratio, generators
from repro.graph.dynamics import SlidingWindowGraph, stream_batches
from repro.graph.structure import Graph


def main() -> None:
    n_users, n_events, window = 4000, 20000, 300
    times, callers, callees = generators.sliding_window_stream(
        n_users, n_events, window, seed=7)
    g = Graph(src=jnp.full((28000,), -1, jnp.int32),
              dst=jnp.full((28000,), -1, jnp.int32),
              node_mask=jnp.zeros((n_users,), bool),
              edge_mask=jnp.zeros((28000,), bool))
    swg = SlidingWindowGraph(g, window, a_cap=8192, d_cap=4096)
    k = 9
    part = AdaptivePartitioner(AdaptiveConfig(k=k, s=0.5, slack=0.4,
                                              max_iters=10, patience=10))
    state = None
    prog = tunkrank()
    print(f"{'batch':>5s} {'nodes':>7s} {'edges':>7s} {'cut':>6s} "
          f"{'remote_MB':>9s} {'top_influence':>13s}")
    for i, (now, events) in enumerate(
            stream_batches(times, callers, callees, window // 3)):
        graph = swg.advance(events, now)
        if state is None:
            state = part.init_state(graph, initial_partition(graph, k, "hsh"))
        state, _ = part.adapt(graph, state, 5)     # adapt between supersteps
        influence = vp_run(prog, graph, 3)          # continuous computation
        _, remote = message_volume(graph, state.assignment, state_dim=1)
        top = float(jnp.max(influence))
        print(f"{i:5d} {int(graph.num_nodes):7d} {int(graph.num_edges):7d} "
              f"{float(cut_ratio(graph, state.assignment)):6.3f} "
              f"{float(remote)/1e6:9.2f} {top:13.3f}")
        if i >= 15:
            break


if __name__ == "__main__":
    main()
