"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpoint/restart fault tolerance (deliverable (b)).

  PYTHONPATH=src python examples/train_lm_e2e.py [--steps 300] [--fail-at 150]

The model is a scaled-down phi4-mini-family decoder (~100M params). A worker
failure is injected mid-run; the Trainer restores the last checkpoint and
finishes. Loss curve is printed every 20 steps.
"""
import argparse
import tempfile

import jax

from repro.data import TokenStream
from repro.models import TransformerConfig, init_params, lm_loss, param_count
from repro.optim import AdamWConfig
from repro.train import (FailureInjector, TrainConfig, Trainer, TrainerConfig,
                         make_train_state, make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fail-at", type=int, default=150)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--size", choices=["demo", "100m"], default="demo",
                    help="'100m' is the deliverable config (use on real "
                         "hardware); 'demo' (~15M params) runs in minutes "
                         "on this CPU container")
    args = ap.parse_args()

    if args.size == "100m":
        cfg = TransformerConfig(
            name="lm-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab=32768, mlp_kind="swiglu",
            tie_embeddings=True)
        seq_len, batch = 256, 8
    else:
        cfg = TransformerConfig(
            name="lm-demo", n_layers=6, d_model=256, n_heads=4, n_kv_heads=2,
            head_dim=64, d_ff=1024, vocab=8192, mlp_kind="swiglu",
            tie_embeddings=True)
        seq_len, batch = 128, 8
    print(f"params: {param_count(cfg):,}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=3e-4, quantize_moments=True),
                       warmup_steps=50, total_steps=args.steps)
    state = make_train_state(params, tcfg)
    stream = TokenStream(vocab=cfg.vocab, seq_len=seq_len, batch=batch, seed=0)
    step_fn = make_train_step(lambda p, b: lm_loss(p, b, cfg), tcfg)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="lm100m_")
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                      checkpoint_dir=ckpt_dir, log_every=20),
        step_fn, stream.batch_at,
        injector=FailureInjector(fail_at=(args.fail_at,)) if args.fail_at else None)
    state = trainer.run(state)
    print(f"restarts: {trainer.restarts}, straggler steps: {trainer.straggler_steps}")
    for m in trainer.metrics_log:
        print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"({m['sec_per_step']*1e3:.0f} ms/step)")


if __name__ == "__main__":
    main()
