"""Continuous-batching LM serving demo (deliverable (b), serving flavour).

  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax

from repro.models import TransformerConfig, init_params
from repro.serve import Request, ServeEngine


def main() -> None:
    cfg = TransformerConfig(name="serve-demo", n_layers=4, d_model=128,
                            n_heads=4, n_kv_heads=2, head_dim=32, d_ff=512,
                            vocab=1024)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, batch_slots=4, max_seq=128)
    rng = np.random.default_rng(0)
    for uid in range(10):
        engine.submit(Request(uid=uid,
                              prompt=rng.integers(1, 1024, rng.integers(2, 8)),
                              max_new_tokens=int(rng.integers(4, 12))))
    done = engine.run_until_drained()
    for c in sorted(done, key=lambda c: c.uid):
        print(f"request {c.uid}: {len(c.tokens)} tokens -> {c.tokens}")


if __name__ == "__main__":
    main()
