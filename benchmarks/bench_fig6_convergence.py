"""Fig. 6 reproduction: cumulative migrations + cut-ratio evolution from
hash partitioning (paper uses LiveJournal; we use the largest CPU-feasible
power-law graph and a 64k FEM for contrast).

Paper claims: >50% of total migrations within the first ~10 iterations;
by the time 90% of migrations are done, ~90% of the cut improvement is
achieved.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.api import DynamicGraphSystem, PartitionSection, SystemConfig
from repro.graph import cut_ratio, generators


def run(quick: bool = False) -> List[Dict]:
    graphs = {
        "plc_large": lambda: generators.power_law(5000 if quick else 40000,
                                                  seed=11),
        "fem_cube": lambda: generators.fem_cube(16 if quick else 28),
    }
    rows: List[Dict] = []
    for gname, build in graphs.items():
        g = build()
        cfg = SystemConfig(partition=PartitionSection(
            strategy="xdgp", k=9, s=0.5, slack=0.1,
            max_iters=100 if quick else 200,
            patience=20 if quick else 30))
        system = DynamicGraphSystem(g, cfg)
        hist = system.converge()
        mig = np.asarray(hist.migrations, dtype=np.float64)
        cum = np.cumsum(mig)
        total = max(cum[-1], 1)
        cuts = np.asarray(hist.cut_ratio)
        c0, cf = cuts[0], cuts[-1]
        # iteration where >=50% of migrations are done
        i50 = int(np.searchsorted(cum, 0.5 * total))
        i90 = int(np.searchsorted(cum, 0.9 * total))
        # cut improvement achieved by i90
        imp_at_i90 = (c0 - cuts[min(i90, len(cuts) - 1)]) / max(c0 - cf, 1e-9)
        rows.append({
            "bench": "fig6", "graph": gname,
            "iters": hist.iterations,
            "total_migrations": int(total),
            "iter_50pct_migrations": i50,
            "iter_90pct_migrations": i90,
            "cut_initial": round(float(c0), 4),
            "cut_final": round(float(cf), 4),
            "cut_improvement_frac_at_90pct_migrations": round(float(imp_at_i90), 3),
            "cut_series_head": [round(float(c), 4) for c in cuts[:20]],
            "migrations_head": [int(m) for m in mig[:20]],
        })
        print(f"  fig6 {gname}: 50% moves by iter {i50}, 90% by {i90}; "
              f"cut {c0:.3f}->{cf:.3f}; {imp_at_i90:.0%} of improvement at i90",
              flush=True)
    return rows
