"""Fig. 5 reproduction: cut ratio after the adaptive heuristic over four
initial partitioning strategies (HSH/RND/DGR/MNN), FEM + power-law graphs,
9 partitions.

Paper claims: >0.6 improvement on FEM from HSH; substantial improvement for
RND/MNN; only slight improvement over DGR; power-law graphs end higher.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.api import DynamicGraphSystem, PartitionSection, SystemConfig
from repro.core import initial_partition
from repro.graph import cut_ratio, generators

GRAPHS_FULL = {
    "1e4_fem": lambda: generators.fem_cube(22),          # 10648 ≈ paper's 1e4
    "64kcube": lambda: generators.fem_cube(32),          # 32768 (scaled 64kcube)
    "4elt_like": lambda: generators.fem_grid2d(125),     # 15625 ≈ 4elt scale
    "plc10000": lambda: generators.power_law(10000, seed=1),
    "plc20000": lambda: generators.power_law(20000, seed=2),
}
GRAPHS_QUICK = {
    "1e4_fem": lambda: generators.fem_cube(16),
    "4elt_like": lambda: generators.fem_grid2d(48),
    "plc5000": lambda: generators.power_law(5000, seed=1),
}
STRATEGIES = ["hsh", "rnd", "dgr", "mnn"]


def run(quick: bool = False) -> List[Dict]:
    graphs = GRAPHS_QUICK if quick else GRAPHS_FULL
    k = 9
    rows: List[Dict] = []
    for gname, build in graphs.items():
        g = build()
        for strat in STRATEGIES:
            # the sweep variable IS the strategy's init hook; the adaptive
            # pass on top is the same xdgp session for every row
            lab = initial_partition(g, k, strat)
            initial = float(cut_ratio(g, lab))
            cfg = SystemConfig(partition=PartitionSection(
                strategy="xdgp", k=k, s=0.5, slack=0.1,
                max_iters=120 if quick else 220,
                patience=25 if quick else 35))
            system = DynamicGraphSystem(g, cfg, assignment=lab)
            hist = system.converge()
            final = float(cut_ratio(g, system.labels))
            rows.append({
                "bench": "fig5", "graph": gname, "strategy": strat,
                "initial_cut": round(initial, 4), "final_cut": round(final, 4),
                "improvement": round(initial - final, 4),
                "iters": hist.iterations,
                "is_fem": "fem" in gname or "cube" in gname or "elt" in gname,
            })
            print(f"  fig5 {gname} {strat}: {initial:.3f} -> {final:.3f} "
                  f"({hist.iterations} iters)", flush=True)
    return rows
