"""Streaming ingestion throughput: vectorized engine vs. the seed path.

Replays a synthetic CDR stream (default 100k events) through

  (a) the seed ingestion path — per-event Python loop over deques + dict
      window tracking (the pre-streaming-layer ``SlidingWindowGraph.advance``
      implementation, reproduced here verbatim as the baseline), and
  (b) the streaming layer — ``WindowIngestor`` (vectorized batch build +
      scatter-max expiry) driven by ``repro.api.DynamicGraphSystem``.

Reported per path:
  * ingest events/sec — the events → GraphDelta stage (the part the seed did
    with Python loops; graph application is identical jit code in both).
  * end-to-end events/sec — including ``apply_delta``.
The engine run also reports the cut trajectory (online placement + adaptive
migration active) and asserts the incremental cut tracker shows zero drift
at every check.

  PYTHONPATH=src python benchmarks/bench_stream_throughput.py [--events N]
"""
from __future__ import annotations

import argparse
import time
from collections import deque

import numpy as np
import jax.numpy as jnp

from benchmarks.common import save
from repro.api import (DynamicGraphSystem, PartitionSection, StreamSection,
                       SystemConfig, TelemetrySection, XdgpAdaptive,
                       empty_graph)
from repro.graph import generators
from repro.graph.structure import GraphDelta, apply_delta
from repro.stream import stream_batches


def seed_path(times, src, dst, n_cap, e_cap, window, a_cap, d_cap, span):
    """The seed per-event ingestion loop, instrumented at the same boundary
    as the engine (delta construction vs. graph application)."""
    graph = empty_graph(n_cap, e_cap)
    last_seen: dict = {}
    ingest_s = total_s = 0.0
    events_total = 0
    for now, events in stream_batches(times, src, dst, span):
        t0 = time.perf_counter()
        adds: deque = deque()
        dels: deque = deque()
        for t, u, v in events:                      # the seed's hot loop
            adds.append((int(u), int(v)))
            last_seen[int(u)] = int(t)
            last_seen[int(v)] = int(t)
        horizon = now - window
        for n in [n for n, t in last_seen.items() if t < horizon]:
            dels.append(n)
            del last_seen[n]
        a = min(len(adds), a_cap)
        d = min(len(dels), d_cap)
        add_src = np.full((a_cap,), -1, np.int32)
        add_dst = np.full((a_cap,), -1, np.int32)
        add_mask = np.zeros((a_cap,), bool)
        for i in range(a):                          # the seed's drain loop
            u, v = adds.popleft()
            add_src[i], add_dst[i] = u, v
            add_mask[i] = True
        del_nodes = np.full((d_cap,), -1, np.int32)
        del_mask = np.zeros((d_cap,), bool)
        for i in range(d):
            del_nodes[i] = dels.popleft()
            del_mask[i] = True
        delta = GraphDelta(add_src=jnp.asarray(add_src), add_dst=jnp.asarray(add_dst),
                           add_mask=jnp.asarray(add_mask),
                           del_nodes=jnp.asarray(del_nodes),
                           del_mask=jnp.asarray(del_mask))
        t1 = time.perf_counter()
        graph = apply_delta(graph, delta)
        graph.src.block_until_ready()
        t2 = time.perf_counter()
        ingest_s += t1 - t0
        total_s += t2 - t0
        events_total += len(events)
    return {"ingest_seconds": ingest_s, "total_seconds": total_s,
            "events": events_total,
            "ingest_eps": events_total / max(ingest_s, 1e-12),
            "total_eps": events_total / max(total_s, 1e-12)}


def engine_path(times, src, dst, n_cap, e_cap, window, a_cap, d_cap, span,
                placement: str, adapt_iters: int):
    cfg = SystemConfig(
        stream=StreamSection(window=window, batch_span=span,
                             a_cap=a_cap, d_cap=d_cap),
        partition=PartitionSection(strategy="xdgp", k=8,
                                   adapt_iters=adapt_iters),
        telemetry=TelemetrySection(recompute_every=5))
    system = DynamicGraphSystem(empty_graph(n_cap, e_cap), cfg,
                                strategy=XdgpAdaptive(placement=placement))
    recs = system.run((times, src, dst))
    drift = [r.drift for r in recs if r.drift is not None]
    assert drift and all(d == 0.0 for d in drift), f"tracker drift: {drift}"
    events = sum(r.events for r in recs)
    ingest_s = sum(r.ingest_seconds for r in recs)
    total_s = sum(r.step_seconds for r in recs)
    return {"ingest_seconds": ingest_s, "total_seconds": total_s,
            "events": events,
            "ingest_eps": events / max(ingest_s, 1e-12),
            "total_eps": events / max(total_s, 1e-12),
            "drift_checks": len(drift), "max_drift": max(drift),
            "cut_trajectory": [r.cut_ratio for r in recs],
            "imbalance_final": recs[-1].imbalance,
            "migrations_total": sum(r.migrations for r in recs),
            "placed_total": sum(r.new_placed for r in recs)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=100_000)
    ap.add_argument("--users", type=int, default=20_000)
    ap.add_argument("--window", type=int, default=600)
    args = ap.parse_args()

    times, callers, callees = generators.sliding_window_stream(
        args.users, args.events, args.window, seed=7)
    n_cap, e_cap = args.users, 4 * args.events // 10
    a_cap = d_cap = 16384
    span = args.window // 3

    # warm up apply_delta compilation outside the timed region (both paths
    # share the jit cache, so neither pays compile time in the comparison)
    warm = empty_graph(n_cap, e_cap)
    apply_delta(warm, GraphDelta.empty(a_cap, d_cap)).src.block_until_ready()

    print(f"stream: {len(times)} events, {args.users} users, window {args.window}")
    seed = seed_path(times, callers, callees, n_cap, e_cap, args.window,
                     a_cap, d_cap, span)
    print(f"seed  path: ingest {seed['ingest_eps']:12.0f} ev/s   "
          f"end-to-end {seed['total_eps']:12.0f} ev/s")
    eng = engine_path(times, callers, callees, n_cap, e_cap, args.window,
                      a_cap, d_cap, span, placement="online", adapt_iters=3)
    print(f"engine    : ingest {eng['ingest_eps']:12.0f} ev/s   "
          f"end-to-end {eng['total_eps']:12.0f} ev/s   "
          f"(+ placement/adaptation/metrics active)")
    speedup = eng["ingest_eps"] / seed["ingest_eps"]
    print(f"ingestion speedup: {speedup:.1f}x   "
          f"drift checks: {eng['drift_checks']} (max drift {eng['max_drift']})")
    print(f"cut trajectory: {eng['cut_trajectory'][0]:.3f} → "
          f"{eng['cut_trajectory'][-1]:.3f} over {len(eng['cut_trajectory'])} supersteps; "
          f"placed {eng['placed_total']}, migrated {eng['migrations_total']}")
    # acceptance target is defined at the 100k-event scale; smaller streams
    # amortise the fixed per-batch cost worse, so only warn there
    if args.events >= 100_000:
        assert speedup >= 10.0, f"ingestion speedup {speedup:.1f}x below 10x target"
    elif speedup < 10.0:
        print(f"note: {speedup:.1f}x below the 10x target "
              f"(measured off-scale: {args.events} < 100000 events)")

    path = save("bench_stream_throughput", {
        "events": len(times), "users": args.users, "window": args.window,
        "seed_path": seed, "engine": eng, "ingest_speedup": speedup})
    print("saved", path)


if __name__ == "__main__":
    main()
