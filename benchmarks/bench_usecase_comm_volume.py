"""§5.3 use-case reproduction: communication-volume reduction for the three
real-world workloads (Twitter TunkRank, CDR sliding-window, FEM biomedical).

Paper claims: Twitter mean iteration 2.5s → 0.5s (5×, incl. overhead); CDR
clique throughput >2×; FEM simulation speedup 2.44× after convergence — all
driven by cut reduction since messages dominate (>80%) iteration time.
We report remote-message-volume reduction + the modelled speedup
(CommModel, 80/20 network/cpu split) per workload.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import CommModel
from repro.api import DynamicGraphSystem, PartitionSection, SystemConfig
from repro.core import initial_partition
from repro.core.vertex_program import message_volume
from repro.graph import cut_ratio, generators


def _workload(name, build, state_dim, k=9, quick=False):
    g = build()
    lab0 = initial_partition(g, k, "hsh")
    system = DynamicGraphSystem(g, SystemConfig(
        partition=PartitionSection(strategy="xdgp", k=k, s=0.5, slack=0.1,
                                   max_iters=80 if quick else 180,
                                   patience=20 if quick else 30)),
        assignment=lab0)
    hist = system.converge()
    model = CommModel()
    l0, r0 = message_volume(g, lab0, state_dim)
    l1, r1 = message_volume(g, system.labels, state_dim)
    t0 = model.step_time(float(l0), float(r0))
    t1 = model.step_time(float(l1), float(r1))
    return {
        "bench": "usecase", "workload": name,
        "cut_before": round(float(cut_ratio(g, lab0)), 4),
        "cut_after": round(float(cut_ratio(g, system.labels)), 4),
        "remote_bytes_before": float(r0), "remote_bytes_after": float(r1),
        "remote_reduction_pct": round(100 * (1 - float(r1) / max(float(r0), 1)), 1),
        "modelled_speedup": round(t0 / t1, 2),
        "exec_time_reduction_pct": round(100 * (1 - t1 / t0), 1),
        "adapt_iters": hist.iterations,
    }


def run(quick: bool = False) -> List[Dict]:
    rows = [
        _workload("twitter_tunkrank",
                  lambda: generators.power_law(3000 if quick else 20000, seed=5),
                  state_dim=1, quick=quick),
        _workload("cdr_cliques",
                  lambda: generators.power_law(2000 if quick else 10000,
                                               seed=6, m=8),
                  state_dim=32, quick=quick),   # clique lists are heavy msgs
        _workload("fem_biomedical",
                  lambda: generators.fem_cube(14 if quick else 28),
                  state_dim=100, quick=quick),  # 100 state variables/cell
    ]
    for r in rows:
        print(f"  usecase {r['workload']}: cut {r['cut_before']:.3f}->"
              f"{r['cut_after']:.3f}, remote -{r['remote_reduction_pct']}%, "
              f"modelled speedup {r['modelled_speedup']}x", flush=True)
    return rows
