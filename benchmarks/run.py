"""Benchmark orchestrator: one module per paper figure/table + extensions.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig5,...]

Prints a ``name,us_per_call,derived`` CSV line per benchmark row and writes
full JSON to results/.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_elastic, bench_fig1_dynamic_cuts,
                        bench_fig2_s_sweep, bench_fig5_initial_partitioning,
                        bench_fig6_convergence, bench_fig7_dynamic_adaptation,
                        bench_usecase_comm_volume)
from benchmarks.common import save

BENCHES = {
    "fig1": bench_fig1_dynamic_cuts,
    "fig2": bench_fig2_s_sweep,
    "fig5": bench_fig5_initial_partitioning,
    "fig6": bench_fig6_convergence,
    "fig7": bench_fig7_dynamic_adaptation,
    "usecase": bench_usecase_comm_volume,
    "elastic": bench_elastic,
}


def _derived(row: dict) -> str:
    for key in ("improvement", "final_cut_mean", "cut_improvement_frac_at_90pct_migrations",
                "peak_time_vs_initial", "modelled_speedup", "recovered_pct",
                "mean_cut_last_half", "cut_after_adapt"):
        if key in row:
            return f"{key}={row[key]}"
    return ""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    all_rows = {}
    for name, mod in BENCHES.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        rows = mod.run(quick=args.quick)
        dt_us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
        all_rows[name] = rows
        for row in rows:
            label = "/".join(str(row.get(k)) for k in
                             ("bench", "graph", "strategy", "mode", "workload", "s")
                             if row.get(k) is not None)
            print(f"{label},{dt_us:.0f},{_derived(row)}")
        save(f"bench_{name}", rows)
    save("bench_all", all_rows)


if __name__ == "__main__":
    main()
