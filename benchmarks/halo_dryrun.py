import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""§Perf hillclimb cell 3 (paper-representative): gin-tu × ogb_products with
the xDGP halo-exchange engine instead of GSPMD global gathers.

Variants lowered on the single-pod mesh (256 devices ≡ 256 partitions):
  baseline       — GSPMD gather aggregation (recorded by the main dry-run)
  halo_hash      — halo engine, halo width from measured boundary fraction
                   under HASH partitioning (≈ every node is boundary)
  halo_adapted   — halo width from the xDGP-adapted partitioning (the
                   paper's technique as a sharding pass)

Halo widths come from results/boundary_fractions.json (measured on a
250k-node Chung–Lu proxy at k=256 — methodology in EXPERIMENTS.md §Perf).

  PYTHONPATH=src python -m benchmarks.halo_dryrun
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.halo_gnn import abstract_dist_graph, gin_halo_loss
from repro.launch.dryrun import parse_collective_bytes
from repro.models.gnn import GINConfig, gin_init
from repro.optim import AdamWConfig, apply_updates, init_state, warmup_cosine


def lower_variant(name: str, P: int, n_blk: int, e_blk: int, halo: int,
                  cfg: GINConfig):
    mesh = jax.make_mesh((P,), ("nodes",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    dg = abstract_dist_graph(P, n_blk, e_blk, halo)
    feats = jax.ShapeDtypeStruct((P * n_blk, cfg.d_in), jnp.float32)
    labels = jax.ShapeDtypeStruct((P * n_blk,), jnp.int32)
    key = jax.random.PRNGKey(0)
    ocfg = AdamWConfig()
    abstract = jax.eval_shape(
        lambda k: (lambda p: (p, init_state(p, ocfg)))(gin_init(k, cfg)), key)
    params_s, opt_s = abstract
    spec_n = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("nodes"))
    spec_n2 = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("nodes", None))
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def train_step(params, opt, dg, feats, labels):
        loss, grads = jax.value_and_grad(
            lambda p: gin_halo_loss(p, dg, feats, labels, cfg, mesh))(params)
        lr = warmup_cosine(opt.step, 100, 10_000)
        new_p, new_opt = apply_updates(params, grads, opt, ocfg, lr)
        return new_p, new_opt, loss

    dg_sh = type(dg)(*([spec_n] * 8))
    with mesh:
        compiled = jax.jit(
            train_step,
            in_shardings=(jax.tree.map(lambda _: repl, params_s),
                          jax.tree.map(lambda _: repl, opt_s), dg_sh,
                          spec_n2, spec_n),
            out_shardings=(jax.tree.map(lambda _: repl, params_s),
                           jax.tree.map(lambda _: repl, opt_s), repl),
        ).lower(params_s, opt_s, dg, feats, labels).compile()
    coll = parse_collective_bytes(compiled.as_text())
    ma = compiled.memory_analysis()
    rec = {
        "variant": name, "P": P, "n_blk": n_blk, "e_blk": e_blk, "halo": halo,
        "collective_gb": coll["total_bytes"] / 1e9,
        "per_kind": {k: v / 1e9 for k, v in coll["per_kind_bytes"].items() if v},
        "temp_gb": ma.temp_size_in_bytes / 1e9,
        "flops": float(compiled.cost_analysis().get("flops", 0.0)),
    }
    print(f"{name}: coll={rec['collective_gb']:.2f}GB temp={rec['temp_gb']:.2f}GB",
          flush=True)
    return rec


def main() -> None:
    """Boundary fractions (EXPERIMENTS.md §Perf cell 3 methodology):

    * measured: power-law (ogb-family) graphs saturate at fraction ≈ 1.0 even
      after adaptation (hubs touch every partition — consistent with the
      paper's "power-law graphs are harder to partition"). The halo win for
      that family is therefore nil and we report it honestly.
    * measured: FEM-family fractions follow ~1.6 × surface/volume
      (6/n_blk^{1/3}); validated at side 20/26, k=8 (0.70 / 0.73 measured vs
      0.60 / 0.46 ideal). Extrapolations: ogb-scale blocks (9.6k nodes)
      → 0.45; the paper's 100M-node biomedical FEM at k=256 (391k-node
      blocks) → 0.13.
    """
    P = 256
    cfg = GINConfig(n_layers=5, d_hidden=64, d_in=100, n_out=47,
                    readout="none", remat=True)
    rows = []
    workloads = [
        # (name, n, directed edges, adapted boundary fraction)
        ("ogb_products_powerlaw", 2_449_029, 2 * 61_859_140, 1.0),
        ("mesh_2.45M", 2_449_029, 2 * 3 * 2_449_029, 0.45),
        ("fem_1e8_paper_scale", 100_000_000, 2 * 297_000_000, 0.13),
    ]
    for name, n, e_dir, frac_adapted in workloads:
        n_blk = -(-n // P)
        e_blk = -(-e_dir // P)
        for variant, frac in (("halo_hash", 1.0), ("halo_adapted", frac_adapted)):
            halo = max(128, int(np.ceil(n_blk * frac / 128) * 128))
            rec = lower_variant(f"{name}:{variant}", P, n_blk, e_blk, halo, cfg)
            rec["boundary_fraction"] = frac
            rows.append(rec)
    with open("results/halo_hillclimb.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
