"""Fig. 7 reproduction: execution-time evolution when injecting forest-fire
bursts (1/2/5/10% growth) into a running graph, static HSH vs adaptive.

Both modes are one ``DynamicGraphSystem`` session each — the bursts go in
via ``inject()`` and the adaptive mode runs one ``adapt(1)`` round per
computing iteration (``XdgpAdaptive(placement="inherit")``: arrivals keep
their hash label, so the migration heuristic alone repairs burst damage,
matching the paper's setup).

Step time uses the paper's own cost structure (§5.3: >80% of iteration time
is network messages): t = c_cpu·local + c_net·remote + c_mig·migrations.
Paper claims: static degrades monotonically (up to +50%); adaptive spikes on
each injection (migration overhead) then returns to near its initial level.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import CommModel
from repro.api import (DynamicGraphSystem, PartitionSection, SystemConfig,
                       XdgpAdaptive)
from repro.core.vertex_program import message_volume
from repro.graph import generators


def run(quick: bool = False) -> List[Dict]:
    side = 16 if quick else 28
    n0 = side ** 3
    n_cap = int(n0 * 1.35)
    g = generators.fem_cube(side, n_cap=n_cap,
                            e_cap=int(side ** 3 * 3.2 * 1.4))
    k = 9
    model = CommModel()
    period = 20 if quick else 50
    bursts = [0.01, 0.02, 0.05, 0.10]

    rows: List[Dict] = []
    for mode in ("static_hsh", "adaptive"):
        # capacity is provisioned on the slot space (n_cap = 1.35·n0);
        # slack 0.08 keeps the same ~1.45·n0/k headroom the seed run had
        cfg = SystemConfig(partition=PartitionSection(
            strategy="xdgp" if mode == "adaptive" else "static",
            k=k, s=0.5, slack=0.08))
        strategy = XdgpAdaptive(placement="inherit") if mode == "adaptive" else None
        system = DynamicGraphSystem(g, cfg, strategy=strategy)
        times: List[float] = []
        cuts: List[float] = []
        phase_means: List[float] = []
        seed = 100
        for phase, growth in enumerate([0.0] + bursts):
            if growth > 0:
                delta = generators.forest_fire_delta(system.graph, growth,
                                                     seed=seed)
                seed += 1
                system.inject(delta)
            for it in range(period):
                hist = system.adapt(1)
                migrations = hist.migrations[0] if hist.migrations else 0
                local_b, remote_b = message_volume(system.graph, system.labels,
                                                   state_dim=1)
                times.append(model.step_time(float(local_b) / 4,
                                             float(remote_b) / 4,
                                             float(migrations)))
                cuts.append(system.cut_ratio)
            phase_means.append(float(np.mean(times[-period // 2:])))
        base = phase_means[0]
        rows.append({
            "bench": "fig7", "mode": mode,
            "phase_steady_time": [round(t, 1) for t in phase_means],
            "phase_time_vs_initial": [round(t / base, 3) for t in phase_means],
            "final_cut": round(cuts[-1], 4),
            "peak_time_vs_initial": round(max(times) / base, 3),
        })
        print(f"  fig7 {mode}: steady-state time vs initial per phase "
              f"{[round(t / base, 2) for t in phase_means]}", flush=True)
    return rows
