"""Fig. 7 reproduction: execution-time evolution when injecting forest-fire
bursts (1/2/5/10% growth) into a running graph, static HSH vs adaptive.

Step time uses the paper's own cost structure (§5.3: >80% of iteration time
is network messages): t = c_cpu·local + c_net·remote + c_mig·migrations.
Paper claims: static degrades monotonically (up to +50%); adaptive spikes on
each injection (migration overhead) then returns to near its initial level.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import CommModel
from repro.core import AdaptiveConfig, AdaptivePartitioner, initial_partition
from repro.core.vertex_program import message_volume
from repro.graph import apply_delta, cut_ratio, generators


def run(quick: bool = False) -> List[Dict]:
    side = 16 if quick else 28
    n0 = side ** 3
    n_cap = int(n0 * 1.35)
    g = generators.fem_cube(side, n_cap=n_cap,
                            e_cap=int(side ** 3 * 3.2 * 1.4))
    k = 9
    model = CommModel()
    period = 20 if quick else 50
    bursts = [0.01, 0.02, 0.05, 0.10]

    rows: List[Dict] = []
    for mode in ("static_hsh", "adaptive"):
        graph = g
        lab = initial_partition(graph, k, "hsh")
        part = AdaptivePartitioner(AdaptiveConfig(
            k=k, s=0.5, max_iters=period, patience=period,
            slack=0.45))        # headroom for +18% total growth
        state = part.init_state(graph, lab) if mode == "adaptive" else None
        times: List[float] = []
        cuts: List[float] = []
        phase_means: List[float] = []
        seed = 100
        phase_start = 0
        for phase, growth in enumerate([0.0] + bursts):
            if growth > 0:
                delta = generators.forest_fire_delta(graph, growth, seed=seed)
                seed += 1
                graph = apply_delta(graph, delta)
            for it in range(period):
                migrations = 0
                if mode == "adaptive":
                    state, stats = part.step(state, graph)
                    lab = state.assignment
                    migrations = stats["committed"]
                local_b, remote_b = message_volume(graph, lab, state_dim=1)
                times.append(model.step_time(float(local_b) / 4,
                                             float(remote_b) / 4,
                                             float(migrations)))
                cuts.append(float(cut_ratio(graph, lab)))
            phase_means.append(float(np.mean(times[-period // 2:])))
        base = phase_means[0]
        rows.append({
            "bench": "fig7", "mode": mode,
            "phase_steady_time": [round(t, 1) for t in phase_means],
            "phase_time_vs_initial": [round(t / base, 3) for t in phase_means],
            "final_cut": round(cuts[-1], 4),
            "peak_time_vs_initial": round(max(times) / base, 3),
        })
        print(f"  fig7 {mode}: steady-state time vs initial per phase "
              f"{[round(t / base, 2) for t in phase_means]}", flush=True)
    return rows
