"""Distributed end-to-end: one paper scenario, local vs sharded execution.

The cluster engine's selling points, measured from the session itself:

  * parity     — the sharded (partition-per-device shard_map) run produces
                 bit-identical assignments and cut trajectories to the
                 local run (DESIGN.md §10), so distribution is free of
                 modelling error;
  * comm bill  — per-superstep halo/collective byte telemetry. The halo
                 volume is the boundary the adaptive heuristic shrinks, so
                 the adaptive run's comm bill falls as the cut falls —
                 "cut == comm volume" made measurable end to end;
  * gap trace  — both runs execute with span tracing on (plus the sharded
                 comm probe, DESIGN.md §11) and emit
                 ``results/trace_distributed_e2e_{local,sharded}.jsonl``, a
                 Chrome/Perfetto export, and a per-phase local-vs-sharded
                 gap summary — the measurement baseline attributing the
                 sharded slowdown to named phases (bucketing, dispatch,
                 halo exchange, quota collective, kernel, host sync).

Must launch with enough devices; the script re-execs itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=<k>`` if the host
doesn't already expose them.

  PYTHONPATH=src:. python benchmarks/bench_distributed_e2e.py --scale smoke
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

K_DEFAULT = 8

if __name__ == "__main__" and "_REPRO_REEXEC" not in os.environ:
    # the fake-device count must be pinned before jax initialises
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (flags + " --xla_force_host_platform_device_count="
                            + str(K_DEFAULT)).strip()
        env["_REPRO_REEXEC"] = "1"
        raise SystemExit(subprocess.call([sys.executable, *sys.argv], env=env))

import dataclasses

import numpy as np

from benchmarks.common import RESULTS_DIR, save
from repro.api import DynamicGraphSystem
from repro.scenarios import SCENARIOS

SCALES = {"smoke": 12, "small": 40, "full": None}   # max supersteps


def run_one(scn, *, cluster: str, max_supersteps):
    cfg = scn.system_config(strategy="xdgp", cluster=cluster)
    cfg = dataclasses.replace(cfg, telemetry=dataclasses.replace(
        cfg.telemetry, trace=True, trace_comm_probe=True))
    if cluster == "sharded":
        # the scenario streams through its growth phase, so give the
        # padded buckets doubling head-room: shapes jump O(log) times
        # instead of creeping every superstep, and each jump is the only
        # recompile in its bucket
        cfg = dataclasses.replace(cfg, cluster=dataclasses.replace(
            cfg.cluster, halo_pad=1.0, block_pad=1.0, edge_pad=1.0))
    system = DynamicGraphSystem(scn.graph, cfg)
    t0 = time.perf_counter()
    recs = system.run(scn, max_supersteps=max_supersteps)
    wall = time.perf_counter() - t0
    score = system.score()
    row = {
        "cluster": cluster,
        "wall_seconds": wall,
        "supersteps": len(recs),
        "cut_final": score["cut_final"],
        "cut_trajectory": score["cut_trajectory"],
        "migrations_total": score["migrations_total"],
        "halo_bytes_total": score["halo_bytes"],
        "halo_live_bytes_total": score["halo_live_bytes"],
        "collective_bytes_total": score["collective_bytes"],
        "halo_bytes_per_superstep": [r.halo_bytes for r in recs],
        "halo_live_bytes_per_superstep": [r.halo_live_bytes for r in recs],
        "live_edges_per_superstep": [r.live_edges for r in recs],
        "cut_ratio_per_superstep": [r.cut_ratio for r in recs],
        "cluster_stats": system.snapshot()["cluster"],
    }
    return row, np.asarray(system.labels), system.tracer


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="cellular",
                    choices=sorted(SCENARIOS))
    ap.add_argument("--scale", default="smoke", choices=sorted(SCALES))
    args = ap.parse_args()

    scn = SCENARIOS[args.scenario](
        "smoke" if args.scale == "smoke" else "small", seed=0)
    max_ss = SCALES[args.scale]

    local_row, local_labels, local_tr = run_one(scn, cluster="local",
                                                max_supersteps=max_ss)
    shard_row, shard_labels, shard_tr = run_one(scn, cluster="sharded",
                                                max_supersteps=max_ss)

    bit_identical = bool(np.array_equal(local_labels, shard_labels))
    cuts_identical = (local_row["cut_trajectory"]
                      == shard_row["cut_trajectory"])
    # the padded halo is shape-stable by design, so the "cut == comm
    # volume" trajectory lives in the *live* (unpadded) halo bytes
    halo = shard_row["halo_live_bytes_per_superstep"]
    edges = [max(1, e) for e in shard_row["live_edges_per_superstep"]]
    # the headline: comm volume *per live edge* tracks the cut the
    # heuristic is shrinking (the raw bill also grows with the graph)
    per_edge = [h / e for h, e in zip(halo, edges)]
    head = max(1, len(halo) // 3)
    halo_head = float(np.mean(per_edge[:head])) if halo else 0.0
    halo_tail = float(np.mean(per_edge[-head:])) if halo else 0.0

    # compile accounting straight off the trace: every dispatch is tagged
    # compiled=True/False, and cluster/recompile fires once per shape bucket
    dispatches = [ev for ev in shard_tr.events
                  if ev["name"] == "cluster/dispatch"]
    compiles = sum(1 for ev in dispatches
                   if ev.get("attrs", {}).get("compiled"))
    recompile_spans = sum(1 for ev in shard_tr.events
                          if ev["name"] == "cluster/recompile")
    compiled_steps = shard_row["cluster_stats"]["compiled_steps"]

    payload = {
        "scenario": scn.name,
        "k": scn.k,
        "scale": args.scale,
        "events": scn.n_events,
        "assignments_bit_identical": bit_identical,
        "cut_trajectories_identical": cuts_identical,
        "halo_live_bytes_per_edge_early": halo_head,
        "halo_live_bytes_per_edge_late": halo_tail,
        "dispatches": len(dispatches),
        "compiled_dispatches": compiles,
        "compiled_steps": compiled_steps,
        "local": local_row,
        "sharded": shard_row,
    }
    path = save("bench_distributed_e2e", payload)

    # -- the gap trace (DESIGN.md §11): where does local-vs-sharded go? ----
    os.makedirs(RESULTS_DIR, exist_ok=True)
    local_trace = local_tr.write_jsonl(
        os.path.join(RESULTS_DIR, "trace_distributed_e2e_local.jsonl"))
    shard_trace = shard_tr.write_jsonl(
        os.path.join(RESULTS_DIR, "trace_distributed_e2e_sharded.jsonl"))
    shard_tr.write_chrome(
        os.path.join(RESULTS_DIR, "trace_distributed_e2e.trace.json"))
    sum_l, sum_s = local_tr.phase_totals(), shard_tr.phase_totals()
    gap = {
        "scenario": scn.name, "k": scn.k, "scale": args.scale,
        "wall_local_s": local_row["wall_seconds"],
        "wall_sharded_s": shard_row["wall_seconds"],
        "slowdown": shard_row["wall_seconds"] / local_row["wall_seconds"],
        "dispatches": len(dispatches),
        "compiled_dispatches": compiles,
        "compiled_steps": compiled_steps,
        "phases_local": sum_l,
        "phases_sharded": sum_s,
        # phases only the sharded path has, ranked: the slowdown, named
        "sharded_only_total_s": {n: sum_s[n]["total_s"]
                                 for n in sorted(set(sum_s) - set(sum_l),
                                                 key=lambda n:
                                                 -sum_s[n]["total_s"])},
    }
    save("trace_distributed_e2e", gap)
    print(f"{'phase':<24} {'local':>10} {'sharded':>10}")
    for name in sorted(set(sum_l) | set(sum_s),
                       key=lambda n: -sum_s.get(n, {"total_s": 0})["total_s"]):
        tl = sum_l.get(name, {}).get("total_s", 0.0)
        ts = sum_s.get(name, {}).get("total_s", 0.0)
        print(f"{name:<24} {tl * 1e3:9.1f}ms {ts * 1e3:9.1f}ms")
    print(f"traces -> {local_trace}, {shard_trace}")

    print(f"scenario={scn.name} k={scn.k} scale={args.scale}")
    print(f"  parity: assignments bit-identical={bit_identical} "
          f"cut trajectories identical={cuts_identical}")
    print(f"  compile cache: {compiles}/{len(dispatches)} dispatches "
          f"compiled ({compiled_steps} shape buckets, "
          f"{recompile_spans} recompile spans)")
    print(f"  sharded comm: halo={shard_row['halo_bytes_total']}B "
          f"(live {shard_row['halo_live_bytes_total']}B) "
          f"collective={shard_row['collective_bytes_total']}B "
          f"over {shard_row['supersteps']} supersteps")
    print(f"  live halo bytes per live edge early->late: "
          f"{halo_head:.2f}B -> {halo_tail:.2f}B "
          f"(cut {shard_row['cut_ratio_per_superstep'][0]:.3f} -> "
          f"{shard_row['cut_ratio_per_superstep'][-1]:.3f})")
    print(f"  wall: local={local_row['wall_seconds']:.2f}s "
          f"sharded={shard_row['wall_seconds']:.2f}s")
    print(f"saved -> {path}")
    assert bit_identical and cuts_identical, "sharded parity violated"
    # the bugfix's contract: at most one compile per shape bucket
    assert compiles == recompile_spans == compiled_steps, \
        (compiles, recompile_spans, compiled_steps)
    assert compiles < max(2, len(dispatches)), \
        f"every dispatch recompiled ({compiles}/{len(dispatches)})"


if __name__ == "__main__":
    main()
