"""Strategy arena: every registered partitioner, head to head.

Sweeps every canonical strategy in the ``repro.api`` registry (xDGP's
migrator, the rival partitioners — Spinner-style balanced LPA, SDP-style
real-time refinement, Le Merrer-style restreaming — and the non-adapting
baselines) across the three §5.3 paper scenarios plus the adversarial
rotating-community churn stream, scoring each run on the metrics the
partitioning papers fight over:

  cut        final + mean cut ratio (communication volume proxy)
  balance    final max/mean occupancy
  migrations total vertices moved (the cost of adaptivity)
  wall       end-to-end wall seconds for the run
  exec cost  the §5.3 cost-model total, vs. the shared static baseline

Every (scenario, strategy) cell is one ``DynamicGraphSystem.compare`` dual
run against the ``static`` baseline on the identical event stream — the
candidate and baseline sessions differ by exactly one config field.

  PYTHONPATH=src:. python benchmarks/bench_strategy_arena.py [--scale small]
      [--scenarios twitter adversarial] [--strategies xdgp spinner]

Writes results/bench_strategy_arena.json (validated in CI by
``repro.obs.schema.validate_arena_bench``).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

from benchmarks.common import save
from repro.api import canonical_strategy_names
from repro.scenarios import ARENA_SCENARIOS, CostModel, compare_scenario

METRICS = ("cut_final", "imbalance_final", "migrations_total",
           "wall_seconds", "exec_cost_total")


def _row(scenario: str, strategy: str, res: Dict) -> Dict:
    cand = res["adaptive"]          # compare()'s candidate row, whatever the
    return {                        # strategy actually is
        "scenario": scenario,
        "strategy": strategy,
        "events": res["events"],
        "supersteps": cand["supersteps"],
        "cut_final": cand["cut_final"],
        "cut_mean": cand["cut_mean"],
        "imbalance_final": cand["imbalance_final"],
        "migrations_total": cand["migrations_total"],
        "wall_seconds": round(cand["wall_seconds"], 3),
        "exec_cost_total": cand["exec_cost_total"],
        "exec_cost_reduction_pct": res["exec_cost_reduction_pct"],
        "cut_improvement": res["cut_improvement"],
        "meets_50pct_claim": res["meets_50pct_claim"],
    }


def _winners(rows: List[Dict], scenario: str) -> Dict[str, str]:
    cell = [r for r in rows if r["scenario"] == scenario]
    lowest = lambda key: min(cell, key=lambda r: r[key])["strategy"]
    return {
        "cut": lowest("cut_final"),
        "balance": lowest("imbalance_final"),
        "exec_cost": lowest("exec_cost_total"),
        "wall": lowest("wall_seconds"),
    }


def run(scale: str, scenarios: List[str], strategies: List[str], seed: int,
        backend: str = "auto") -> Dict:
    cost = CostModel()
    rows: List[Dict] = []
    for sname in scenarios:
        scn = ARENA_SCENARIOS[sname](scale, seed=seed)
        print(f"  {sname} [{scn.program}] k={scn.k}, "
              f"{scn.n_events} events, {scn.supersteps} supersteps")
        for strat in strategies:
            t0 = time.perf_counter()
            res = compare_scenario(scn, strategy=strat, cost=cost,
                                   backend=backend)
            row = _row(sname, strat, res)
            row["compare_seconds"] = round(time.perf_counter() - t0, 2)
            rows.append(row)
            print(f"    {strat:9s} cut={row['cut_final']:.3f} "
                  f"imb={row['imbalance_final']:.2f} "
                  f"migr={row['migrations_total']:6d} "
                  f"wall={row['wall_seconds']:6.2f}s "
                  f"cost-{row['exec_cost_reduction_pct']:5.1f}%", flush=True)
    winners = {s: _winners(rows, s) for s in scenarios}
    for s in scenarios:
        print(f"  winners[{s}]: " + ", ".join(
            f"{m}={w}" for m, w in winners[s].items()))
    return {
        "bench": "strategy_arena",
        "scale": scale,
        "seed": seed,
        "backend": backend,
        "baseline": "static",
        "cost_model": {"c_cpu": cost.c_cpu, "c_net": cost.c_net,
                       "c_mig": cost.c_mig},
        "scenarios": list(scenarios),
        "strategies": list(strategies),
        "metrics": list(METRICS),
        "rows": rows,
        "winners": winners,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=("smoke", "small", "full"),
                    default="small")
    ap.add_argument("--scenarios", nargs="*",
                    default=list(ARENA_SCENARIOS),
                    choices=list(ARENA_SCENARIOS))
    ap.add_argument("--strategies", nargs="*",
                    default=list(canonical_strategy_names()),
                    choices=list(canonical_strategy_names()),
                    help="canonical registry names only — aliases would "
                         "run the same strategy twice")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("auto", "ref", "pallas"),
                    default="auto")
    args = ap.parse_args()

    print(f"strategy arena (scale={args.scale}, backend={args.backend}, "
          f"{len(args.strategies)} strategies x {len(args.scenarios)} "
          f"scenarios)")
    payload = run(args.scale, args.scenarios, args.strategies, args.seed,
                  backend=args.backend)
    path = save("bench_strategy_arena", payload)
    print("saved", path)


if __name__ == "__main__":
    main()
