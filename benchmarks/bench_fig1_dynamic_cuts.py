"""Fig. 1 reproduction: evolution of cut ratio on a dynamic CDR-like call
graph under HSH (static hash), DGR (streaming greedy, placed once on
arrival) and ADP (adaptive repartitioning).

All three modes replay the identical stream through a
``repro.api.DynamicGraphSystem`` session; the mode is the partitioning
strategy — ``static`` for HSH, ``XdgpAdaptive(placement="inherit")`` with
interleaved rounds for ADP, and a host-side reference DGR pass layered on a
``static`` replay (DGR is an arrival-time policy the paper treats as
place-once: no adaptation afterwards).

Paper claim: static/streaming placements degrade as the graph evolves; the
adaptive heuristic holds the cut ratio flat (and lower).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax.numpy as jnp

from repro.api import (DynamicGraphSystem, PartitionSection, StreamSection,
                       SystemConfig, XdgpAdaptive, empty_graph)
from repro.graph import cut_ratio, generators
from repro.stream import stream_batches


def _replayer(mode: str, n_cap: int, e_cap: int, window: int, k: int,
              ) -> DynamicGraphSystem:
    cfg = SystemConfig(
        stream=StreamSection(window=window, batch_span=window // 3,
                             a_cap=8192, d_cap=4096,
                             carry_backlog=False),      # seed replay semantics
        partition=PartitionSection(
            strategy="xdgp" if mode == "adp" else "static",
            k=k, s=0.5, adapt_iters=15))
    # adaptation runs every computing iteration in the paper; 15 interleaved
    # rounds per stream batch approximate the continuous mode. Arrivals keep
    # their padded-slot hash label (placement="inherit") so the adaptive
    # heuristic — not online placement — is what the figure isolates.
    strategy = XdgpAdaptive(placement="inherit") if mode == "adp" else None
    return DynamicGraphSystem(empty_graph(n_cap, e_cap), cfg,
                              strategy=strategy)


def run(quick: bool = False) -> List[Dict]:
    n_users = 2000 if quick else 8000
    n_events = 6000 if quick else 30000
    window = 300
    k = 9
    times, callers, callees = generators.sliding_window_stream(
        n_users, n_events, window, seed=7)
    n_cap = n_users
    e_cap = 4 * n_events // 3

    modes = ["hsh", "dgr_stream", "adp"]
    rows: List[Dict] = []
    for mode in modes:
        system = _replayer(mode, n_cap, e_cap, window, k)
        hsh_lab = np.asarray(system.labels)     # padded-slot hash labels
        dgr_sizes = np.zeros(k, dtype=np.int64)
        dgr_lab = np.full(n_cap, -1, np.int32)
        series = []
        for now, events in stream_batches(times, callers, callees, window // 3):
            rec = system.step(events, now)
            if mode == "dgr_stream":
                # place newly-seen vertices greedily (one streaming pass)
                g = system.graph
                src_np = np.asarray(g.src)
                dst_np = np.asarray(g.dst)
                em = np.asarray(g.edge_mask)
                for _, u, v in events:
                    for w in (int(u), int(v)):
                        if dgr_lab[w] < 0:
                            # neighbours already placed
                            nb = np.concatenate([
                                dst_np[em & (src_np == w)],
                                src_np[em & (dst_np == w)]])
                            counts = np.zeros(k)
                            placed = dgr_lab[nb[nb >= 0]]
                            placed = placed[placed >= 0]
                            if placed.size:
                                np.add.at(counts, placed, 1)
                            score = counts * (1 - dgr_sizes / max(1, dgr_sizes.max() + 1e-9) * 0.5)
                            best = int(np.argmax(score)) if placed.size else int(np.argmin(dgr_sizes))
                            dgr_lab[w] = best
                            dgr_sizes[best] += 1
                lab = jnp.asarray(np.where(dgr_lab >= 0, dgr_lab, hsh_lab))
                series.append(float(cut_ratio(system.graph, lab)))
            else:
                # hsh and adp read the session's own incremental tracker
                series.append(float(rec.cut_ratio))
        rows.append({"bench": "fig1", "mode": mode,
                     "cut_series": [round(c, 4) for c in series],
                     "final_cut": round(series[-1], 4),
                     "mean_cut_last_half": round(float(np.mean(series[len(series)//2:])), 4)})
        print(f"  fig1 {mode}: final {series[-1]:.3f} "
              f"mean(last half) {np.mean(series[len(series)//2:]):.3f}", flush=True)
    return rows
