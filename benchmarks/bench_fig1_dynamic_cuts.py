"""Fig. 1 reproduction: evolution of cut ratio on a dynamic CDR-like call
graph under HSH (static hash), DGR (streaming greedy, placed once on
arrival) and ADP (adaptive repartitioning).

Paper claim: static/streaming placements degrade as the graph evolves; the
adaptive heuristic holds the cut ratio flat (and lower).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax.numpy as jnp

from repro.core import AdaptiveConfig, AdaptivePartitioner, initial_partition
from repro.core.initial import _mix
from repro.graph import Graph, apply_delta, cut_ratio, generators
from repro.graph.dynamics import SlidingWindowGraph, stream_batches


def _empty_graph(n_cap: int, e_cap: int) -> Graph:
    return Graph(src=jnp.full((e_cap,), -1, jnp.int32),
                 dst=jnp.full((e_cap,), -1, jnp.int32),
                 node_mask=jnp.zeros((n_cap,), bool),
                 edge_mask=jnp.zeros((e_cap,), bool))


def run(quick: bool = False) -> List[Dict]:
    n_users = 2000 if quick else 8000
    n_events = 6000 if quick else 30000
    window = 300
    k = 9
    times, callers, callees = generators.sliding_window_stream(
        n_users, n_events, window, seed=7)
    n_cap = n_users
    e_cap = 4 * n_events // 3

    modes = ["hsh", "dgr_stream", "adp"]
    rows: List[Dict] = []
    for mode in modes:
        swg = SlidingWindowGraph(_empty_graph(n_cap, e_cap), window,
                                 a_cap=8192, d_cap=4096)
        # every vertex has a static home under hsh; dgr assigns on arrival
        hsh_lab = np.asarray((
            _mix(np.arange(n_cap, dtype=np.int64)) % np.uint64(k))).astype(np.int32)
        lab = jnp.asarray(hsh_lab)
        dgr_sizes = np.zeros(k, dtype=np.int64)
        dgr_lab = np.full(n_cap, -1, np.int32)
        part = AdaptivePartitioner(AdaptiveConfig(k=k, s=0.5, max_iters=15,
                                                  patience=15))
        state = None
        series = []
        for now, events in stream_batches(times, callers, callees, window // 3):
            g = swg.advance(events, now)
            if mode == "dgr_stream":
                # place newly-seen vertices greedily (one streaming pass)
                src_np = np.asarray(g.src)
                dst_np = np.asarray(g.dst)
                em = np.asarray(g.edge_mask)
                for _, u, v in events:
                    for w in (int(u), int(v)):
                        if dgr_lab[w] < 0:
                            # neighbours already placed
                            nb = np.concatenate([
                                dst_np[em & (src_np == w)],
                                src_np[em & (dst_np == w)]])
                            counts = np.zeros(k)
                            placed = dgr_lab[nb[nb >= 0]]
                            placed = placed[placed >= 0]
                            if placed.size:
                                np.add.at(counts, placed, 1)
                            score = counts * (1 - dgr_sizes / max(1, dgr_sizes.max() + 1e-9) * 0.5)
                            best = int(np.argmax(score)) if placed.size else int(np.argmin(dgr_sizes))
                            dgr_lab[w] = best
                            dgr_sizes[best] += 1
                lab = jnp.asarray(np.where(dgr_lab >= 0, dgr_lab, hsh_lab))
            elif mode == "adp":
                if state is None:
                    state = part.init_state(g, lab)
                # paper: adaptation runs every computing iteration; 15 per
                # stream batch approximates the continuous mode
                state, _ = part.adapt(g, state, 15)
                lab = state.assignment
            series.append(float(cut_ratio(g, lab)))
        rows.append({"bench": "fig1", "mode": mode,
                     "cut_series": [round(c, 4) for c in series],
                     "final_cut": round(series[-1], 4),
                     "mean_cut_last_half": round(float(np.mean(series[len(series)//2:])), 4)})
        print(f"  fig1 {mode}: final {series[-1]:.3f} "
              f"mean(last half) {np.mean(series[len(series)//2:]):.3f}", flush=True)
    return rows
