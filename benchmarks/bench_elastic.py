"""Beyond-paper benchmark: elastic rescaling (worker loss/gain) — cut
quality after re-homing + re-adaptation vs naive re-hash. The paper only
snapshot-restores (§4.3); our runtime re-converges placement."""
from __future__ import annotations

from typing import Dict, List

from repro.api import DynamicGraphSystem, PartitionSection, SystemConfig
from repro.graph import generators
from repro.runtime import elastic_rescale


def run(quick: bool = False) -> List[Dict]:
    g = generators.fem_cube(14 if quick else 24)
    k0 = 16
    system = DynamicGraphSystem(g, SystemConfig(
        partition=PartitionSection(strategy="xdgp", k=k0, s=0.5, slack=0.1)))
    system.adapt(60 if quick else 120)
    base_cut = system.cut_ratio
    rows: List[Dict] = []
    for new_k in (15, 12, 8):
        _, _, rep = elastic_rescale(g, system.labels, k0, new_k,
                                    adapt_iters=40 if quick else 80)
        rep.update({"bench": "elastic", "baseline_cut_k16": round(base_cut, 4)})
        rep["cut_after_rehash"] = round(rep["cut_after_rehash"], 4)
        rep["cut_after_adapt"] = round(rep["cut_after_adapt"], 4)
        rep["recovered_pct"] = round(
            100 * (rep["cut_after_rehash"] - rep["cut_after_adapt"])
            / max(rep["cut_after_rehash"] - base_cut, 1e-9), 1)
        rows.append(rep)
        print(f"  elastic k{k0}->{new_k}: rehash {rep['cut_after_rehash']} "
              f"-> adapted {rep['cut_after_adapt']}", flush=True)
    return rows
