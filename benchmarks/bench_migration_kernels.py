"""Superstep microbenchmark: unfused reference vs fused migration kernels.

Measures the xDGP adaptation superstep — ``adapt_iters`` migration
iterations compiled into one ``lax.scan`` program (exactly what the
streaming engine dispatches per batch, see ``core/repartitioner.adapt_jit``)
— under the two scoring backends of DESIGN.md §9:

  ref     the unfused op pipeline: (2E, k) one-hot materialisation +
          segment-sum counts, separate decide/damp passes, stable-sort
          quota ranking (``core/migration.py`` seed path).
  pallas  the fused path (``kernels/migration_kernels.py``): one pass over
          the packed adjacency builds the histogram, selects greedy
          targets and applies damping; quota ranks via the single-key
          sort. Executor resolved by ``repro.compat.pallas_executor()``
          (native Mosaic on TPU; the bit-identical pure-jax oracle on this
          CPU container).

Both backends produce bit-identical assignments (asserted per size), so the
speedup is pure implementation. Plan packing (host-side, once per graph) is
timed separately and also amortised into the reported fused time at one
pack per superstep — the streaming worst case.

  PYTHONPATH=src:. python benchmarks/bench_migration_kernels.py

Writes results/bench_migration_kernels.json and asserts the fused superstep
is ≥2× faster than ref at the largest benchmarked graph size.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import save
from repro import compat
from repro.core.initial import initial_partition
from repro.core.partition_state import make_state
from repro.core.repartitioner import adapt_jit
from repro.graph import generators
from repro.kernels.migration_kernels import build_plan


def _bench(fn, *args, repeats: int) -> float:
    jax.block_until_ready(fn(*args))                     # compile + warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def bench_size(graph, name: str, k: int, iters: int, s: float,
               repeats: int) -> Dict:
    lab = initial_partition(graph, k, "hsh")
    state = make_state(graph, lab, k, slack=0.2, seed=0)

    t0 = time.perf_counter()
    plan = build_plan(graph)
    plan_seconds = time.perf_counter() - t0

    step_ref = jax.jit(lambda g, st: adapt_jit(g, st, s=s, iters=iters,
                                               backend="ref"))
    step_fused = jax.jit(lambda g, st, p: adapt_jit(g, st, s=s, iters=iters,
                                                    backend="pallas", plan=p))

    # identical assignments or the comparison is meaningless
    out_ref = step_ref(graph, state)
    out_fused = step_fused(graph, state, plan)
    identical = bool(np.array_equal(np.asarray(out_ref.assignment),
                                    np.asarray(out_fused.assignment)))

    t_ref = _bench(step_ref, graph, state, repeats=repeats)
    t_fused = _bench(step_fused, graph, state, plan, repeats=repeats)
    t_fused_repack = t_fused + plan_seconds              # streaming worst case

    n = int(np.asarray(graph.node_mask).sum())
    e = int(np.asarray(graph.edge_mask).sum())
    row = {
        "graph": name, "nodes": n, "edges": e, "k": k,
        "iters_per_superstep": iters,
        "plan_kind": plan.kind,
        "executor": compat.pallas_executor(),
        "plan_build_seconds": round(plan_seconds, 6),
        "ref_superstep_seconds": round(t_ref, 6),
        "fused_superstep_seconds": round(t_fused, 6),
        "fused_superstep_seconds_with_repack": round(t_fused_repack, 6),
        "speedup": round(t_ref / t_fused, 3),
        "speedup_with_repack": round(t_ref / t_fused_repack, 3),
        "assignments_identical": identical,
    }
    print(f"  {name:12s} n={n:7d} e={e:8d} plan={plan.kind:4s} "
          f"ref={t_ref * 1e3:8.1f}ms fused={t_fused * 1e3:7.1f}ms "
          f"({row['speedup']:.2f}x; {row['speedup_with_repack']:.2f}x with "
          f"per-superstep repack) identical={identical}", flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sides", type=int, nargs="*", default=[16, 24, 32, 40, 48],
                    help="fem_cube sides (|V| = side³), ascending")
    ap.add_argument("--plc-nodes", type=int, default=20000,
                    help="power-law graph size (0 = skip)")
    ap.add_argument("--k", type=int, default=9)
    ap.add_argument("--iters", type=int, default=5,
                    help="migration iterations per superstep")
    ap.add_argument("--s", type=float, default=0.5)
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args()

    print(f"migration-kernel superstep bench (k={args.k}, "
          f"iters={args.iters}, executor={compat.pallas_executor()})")
    rows: List[Dict] = []
    for side in sorted(args.sides):
        g = generators.fem_cube(side)
        rows.append(bench_size(g, f"fem_cube({side})", args.k, args.iters,
                               args.s, args.repeats))
    if args.plc_nodes:
        g = generators.power_law(args.plc_nodes, seed=0)
        rows.append(bench_size(g, f"power_law({args.plc_nodes})", args.k,
                               args.iters, args.s, args.repeats))

    if not rows:
        ap.error("nothing to benchmark: pass --sides and/or --plc-nodes")
    # the ≥2x claim is asserted on the FEM meshes (the paper's core
    # workload); a power-law-only run still reports but asserts on its rows
    fem_rows = [r for r in rows if r["graph"].startswith("fem_cube")] or rows
    largest = max(fem_rows, key=lambda r: r["nodes"])
    payload = {
        "bench": "migration_kernels",
        "k": args.k, "iters_per_superstep": args.iters, "s": args.s,
        "repeats": args.repeats,
        "executor": compat.pallas_executor(),
        "rows": rows,
        "claim": {
            "statement": "fused superstep ≥2× faster than the unfused "
                         "reference at the largest benchmarked graph size, "
                         "with bit-identical assignments",
            "largest_graph": largest["graph"],
            "largest_nodes": largest["nodes"],
            "speedup_at_largest": largest["speedup"],
            "speedup_with_repack_at_largest": largest["speedup_with_repack"],
            "met": bool(largest["speedup"] >= 2.0),
        },
    }
    path = save("bench_migration_kernels", payload)
    print(f"largest graph {largest['graph']}: {largest['speedup']:.2f}x "
          f"(claim ≥2x: {'MET' if payload['claim']['met'] else 'NOT MET'})")
    print("saved", path)
    assert all(r["assignments_identical"] for r in rows), \
        "fused and ref paths diverged — parity violation"
    assert payload["claim"]["met"], (
        f"fused superstep only {largest['speedup']:.2f}x faster than ref at "
        f"{largest['graph']}; expected ≥2x")


if __name__ == "__main__":
    main()
