"""Serving-layer benchmark (DESIGN.md §12): N concurrent tenant sessions
under sustained bursty open-loop load, plus the kill-and-recover drill.

Two measurements, one committed artifact (results/bench_serve_sessions.json,
schema-checked by ``repro.obs.schema.validate_serve_bench`` in CI):

* **Sustained throughput + tail latency** — every tenant gets its own
  open-loop arrival process (Poisson base + periodic bursts; arrivals do
  NOT wait for the server, so a slow server accumulates real backlog).
  Headline: aggregate events/sec and the pooled p50/p99 submit→commit
  ingest latency across all tenants.

* **Kill-and-recover drill** — a checkpointed serving process is started
  and SIGKILLed mid-run (real subprocess, no cleanup), a fresh process
  recovers from the last committed checkpoint and replays; the bench
  asserts every tenant's telemetry digest equals the uninterrupted
  reference bit for bit and reports the recovery wall time.

    PYTHONPATH=src python -m benchmarks.bench_serve_sessions [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time
from typing import Any, Dict, List

import numpy as np

from benchmarks.common import save
from repro.api import SystemConfig
from repro.serve import (AdmissionPolicy, GraphServer, OpenLoopLoad,
                         TrafficShape, synthetic_stream)
from repro.serve import drill

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tenant_config(i: int, *, n_cap: int, e_cap: int) -> SystemConfig:
    return SystemConfig.from_dict({
        "graph": {"n_cap": n_cap, "e_cap": e_cap},
        "stream": {"window": 600, "a_cap": 2048, "d_cap": 1024},
        "partition": {"k": 4},
        "seed": 11 + i,
    })


def serve_open_loop(n_tenants: int, n_events: int, *, quick: bool,
                    ) -> Dict[str, Any]:
    """Drive N tenants with independent bursty open-loop arrivals until
    every load is delivered and drained; measure sustained ingest."""
    # offered aggregate ≈ tenants · (0.8·rate + 0.2·burst) — sized so bursts
    # overrun service capacity (queues form, p99 ≫ p50) but the server
    # catches up between bursts instead of saturating for the whole run
    shape = TrafficShape(rate=1000.0, burst_rate=8000.0,
                         burst_every=1.0, burst_len=0.2)
    server = GraphServer(admission=AdmissionPolicy(queue_cap=200_000,
                                                   max_batch_events=4096))
    loads: Dict[str, OpenLoopLoad] = {}
    for i in range(n_tenants):
        name = f"tenant{i}"
        server.add_tenant(name, config=_tenant_config(
            i, n_cap=128 if quick else 256, e_cap=4096 if quick else 8192))
        t, u, v = synthetic_stream(96 if quick else 192, n_events,
                                   seed=11 + i, span=3000)
        loads[name] = OpenLoopLoad(t, u, v, shape, seed=31 + i)

    # warm the jit caches off the clock (the first superstep compiles, which
    # would otherwise dominate the recorded ingest latencies)
    for name in loads:
        server.submit(name, loads[name].take_due(0.002))
    server.drain()
    for t in server.tenants.values():
        t.latencies.clear()

    t0 = time.perf_counter()
    ticks = 0
    while True:
        elapsed = time.perf_counter() - t0
        for name, load in loads.items():
            batch = load.take_due(elapsed)
            if batch.size:
                server.submit(name, batch)
        busy = any(t.chunks or t.stream_backlog
                   for t in server.tenants.values())
        if not busy and all(l.remaining == 0 for l in loads.values()):
            break
        server.tick()
        ticks += 1
    wall = time.perf_counter() - t0

    stats = server.stats()
    pooled = np.concatenate([np.asarray(t.latencies, np.float64)
                             for t in server.tenants.values()])
    events_total = int(sum(t.admitted for t in server.tenants.values()))
    return {
        "tenants": n_tenants,
        "ticks": ticks,
        "events_total": events_total,
        "supersteps_total": int(sum(t["supersteps"] for t in
                                    stats["tenants"].values())),
        "wall_seconds": wall,
        "events_per_sec": events_total / wall,
        "ingest_p50_s": float(np.percentile(pooled, 50)),
        "ingest_p99_s": float(np.percentile(pooled, 99)),
        "per_tenant": {
            name: {"events": server.tenants[name].admitted,
                   "supersteps": int(t["supersteps"]),
                   "rejected": server.tenants[name].rejected,
                   "shed": server.tenants[name].shed,
                   "p50_s": t["ingest_p50_s"], "p99_s": t["ingest_p99_s"]}
            for name, t in stats["tenants"].items()},
    }


def kill_recover_drill(n_tenants: int, *, quick: bool) -> Dict[str, Any]:
    """Real-process SIGKILL drill via ``repro.serve.drill``; returns recovery
    seconds + bit-exactness against the uninterrupted reference."""
    workdir = tempfile.mkdtemp(prefix="serve_drill_")
    cfg = dict(drill.DEFAULT_CONFIG)
    cfg.update(tenants=n_tenants, workdir=workdir,
               ticks=16 if quick else 24, kill_tick=11 if quick else 14,
               n_events=300 if quick else 600)
    cfg_path = os.path.join(workdir, "cfg.json")
    with open(cfg_path, "w") as f:
        json.dump(cfg, f)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")

    def run(command: str) -> subprocess.CompletedProcess:
        return subprocess.run(
            [sys.executable, "-m", "repro.serve.drill", command,
             "--config", cfg_path],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=900)

    victim = run("run")
    if victim.returncode != -signal.SIGKILL:
        raise RuntimeError(f"drill run did not die by SIGKILL "
                           f"(rc={victim.returncode}): {victim.stderr}")
    rec = run("recover")
    if rec.returncode != 0:
        raise RuntimeError(f"drill recover failed: {rec.stderr}")
    drill.cmd_reference(cfg)
    with open(os.path.join(workdir, "recovered.json")) as f:
        recovered = json.load(f)
    with open(os.path.join(workdir, "reference.json")) as f:
        reference = json.load(f)
    bit_exact = recovered["digests"] == reference["digests"]
    if not bit_exact:
        raise RuntimeError("kill-recover drill diverged from the reference")
    return {
        "seconds": recovered["recovery"]["seconds"],
        "replay_total_seconds": recovered["total_seconds"],
        "manifest_tick": recovered["recovery"]["tick"],
        "kill_tick": cfg["kill_tick"],
        "tenants": n_tenants,
        "bit_exact": bit_exact,
    }


def run(quick: bool = False) -> Dict[str, Any]:
    n_tenants = 8
    n_events = 1500 if quick else 4000
    payload = serve_open_loop(n_tenants, n_events, quick=quick)
    payload["recovery"] = kill_recover_drill(n_tenants, quick=quick)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)

    from repro.obs.schema import validate_serve_bench
    validate_serve_bench(payload)
    path = save("bench_serve_sessions", payload)
    print(f"tenants={payload['tenants']} "
          f"events/sec={payload['events_per_sec']:.0f} "
          f"p50={payload['ingest_p50_s'] * 1e3:.1f}ms "
          f"p99={payload['ingest_p99_s'] * 1e3:.1f}ms "
          f"recovery={payload['recovery']['seconds']:.2f}s "
          f"bit_exact={payload['recovery']['bit_exact']}")
    print(path)


if __name__ == "__main__":
    main()
