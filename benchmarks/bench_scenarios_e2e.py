"""§5.3 end-to-end scenario benchmark: the paper's ">50% execution time"
claim, measured.

Drives the three real-world dynamic workloads (Twitter mentions + TunkRank,
adaptively refined FEM mesh, mobile/cellular call churn) end to end through
``repro.api.DynamicGraphSystem.compare`` — vertex-program compute
interleaved with ingestion and adaptation — under the ``xdgp`` strategy and
under the ``static`` baseline (one ``SystemConfig`` field apart), on
identical event streams. The execution-cost proxy per superstep is

  c_cpu·local_bytes + c_net·remote_bytes + c_mig·migrations·unit

(c_net/c_cpu = 25, messages dominate iteration time per §5.3; the adaptive
run is charged for its own migration overhead). A final BSR snapshot
(partition-relabelled adjacency) reports the TPU tile-count reduction.

  PYTHONPATH=src:. python benchmarks/bench_scenarios_e2e.py [--scale small]

Writes results/bench_scenarios_e2e.json. At small/full scale the run asserts
the paper's claim — >50% cost reduction on at least two of the three
scenarios — and documents any scenario that falls short.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

from benchmarks.common import save
from repro.scenarios import SCENARIOS, CostModel, compare_scenario


def run(scale: str, scenarios: List[str], bsr_blk: int, seed: int,
        backend: str = "auto") -> Dict:
    cost = CostModel()
    rows = []
    for name in scenarios:
        t0 = time.perf_counter()
        scn = SCENARIOS[name](scale, seed=seed)
        row = compare_scenario(scn, bsr_blk=bsr_blk, cost=cost,
                               backend=backend)
        row["build_seconds"] = round(time.perf_counter() - t0, 2)
        rows.append(row)
        a, s = row["adaptive"], row["static"]
        print(f"  {name:9s} [{row['program']:8s}] k={row['k']:2d} "
              f"{a['supersteps']:3d} supersteps, {row['events']:7d} events")
        print(f"            cut {s['cut_final']:.3f} -> {a['cut_final']:.3f} "
              f"(improvement {row['cut_improvement']:.2f}), "
              f"remote -{row['remote_reduction_pct']}%, "
              f"migrations {a['migrations_total']}")
        print(f"            exec cost -{row['exec_cost_reduction_pct']}% "
              f"(claim >50%: {'MET' if row['meets_50pct_claim'] else 'NOT MET'}), "
              f"BSR tiles -{row['bsr_tile_reduction_pct']}%", flush=True)
    met = sum(r["meets_50pct_claim"] for r in rows)
    payload = {
        "bench": "scenarios_e2e", "scale": scale, "seed": seed,
        "backend": backend,
        "cost_model": {"c_cpu": cost.c_cpu, "c_net": cost.c_net,
                       "c_mig": cost.c_mig},
        "rows": rows,
        "claim": {
            "statement": "adaptive repartitioning reduces execution time by "
                         "over 50% (paper abstract / §5.3)",
            "met_on": met, "out_of": len(rows),
            "shortfalls": [
                {"scenario": r["scenario"],
                 "exec_cost_reduction_pct": r["exec_cost_reduction_pct"],
                 "note": "below the 50% threshold at this scale; the gap is "
                         "migration overhead charged to the adaptive run "
                         "plus residual cut on a churning community graph"}
                for r in rows if not r["meets_50pct_claim"]],
        },
    }
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=("smoke", "small", "full"),
                    default="small")
    ap.add_argument("--scenarios", nargs="*", default=list(SCENARIOS),
                    choices=list(SCENARIOS))
    ap.add_argument("--bsr-blk", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("auto", "ref", "pallas"),
                    default="auto",
                    help="migration-scoring backend (DESIGN.md §9); results "
                         "are bit-identical across backends")
    args = ap.parse_args()

    print(f"scenario e2e suite (scale={args.scale}, backend={args.backend})")
    payload = run(args.scale, args.scenarios, args.bsr_blk, args.seed,
                  backend=args.backend)
    path = save("bench_scenarios_e2e", payload)
    met, out_of = payload["claim"]["met_on"], payload["claim"]["out_of"]
    print(f">50% execution-cost reduction met on {met}/{out_of} scenarios")
    for s in payload["claim"]["shortfalls"]:
        print(f"  shortfall: {s['scenario']} at "
              f"{s['exec_cost_reduction_pct']}% — {s['note']}")
    print("saved", path)
    if args.scale != "smoke" and out_of >= 3:
        assert met >= 2, (
            f"paper claim not reproduced: only {met}/{out_of} scenarios "
            f"above 50% execution-cost reduction")


if __name__ == "__main__":
    main()
