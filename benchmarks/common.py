"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def save(name: str, payload: Any) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


class CommModel:
    """Iteration-time model from the paper's observation that network
    messages dominate (>80% of iteration time, §5.3): t = c_cpu·msgs_local +
    c_net·msgs_remote, with c_net/c_cpu = 25 (≈ 10GbE RTT vs in-memory
    hand-off). Used where wall-clock would only reflect this CPU container.
    """

    def __init__(self, c_cpu: float = 1.0, c_net: float = 25.0):
        self.c_cpu = c_cpu
        self.c_net = c_net

    def step_time(self, local_msgs: float, remote_msgs: float,
                  migrations: float = 0.0, c_mig: float = 50.0) -> float:
        return (self.c_cpu * local_msgs + self.c_net * remote_msgs
                + c_mig * migrations)
