"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.vertex_program import CostModel
from repro.obs.manifest import run_manifest

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def save(name: str, payload: Any, *, config: Any = None) -> str:
    """Write a result payload, stamped with a provenance manifest (git sha,
    jax versions, device kind, timestamp — DESIGN.md §11) so committed
    numbers stay citable.  ``config`` adds its hash to the manifest."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if isinstance(payload, dict) and "manifest" not in payload:
        payload = {**payload, "manifest": run_manifest(config)}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def timed(fn, *args, repeats: int = 1, warmup: int = 0, **kw):
    """Mean wall time of ``fn`` with a sync fence per call.

    JAX dispatch is asynchronous: without ``jax.block_until_ready`` on the
    result this would measure dispatch, not device time.  ``warmup`` extra
    un-timed calls first absorb jit compilation.
    """
    import jax
    out = None
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = jax.block_until_ready(fn(*args, **kw))
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


class CommModel(CostModel):
    """Iteration-time model from the paper's observation that network
    messages dominate (>80% of iteration time, §5.3): t = c_cpu·msgs_local +
    c_net·msgs_remote, with c_net/c_cpu = 25 (≈ 10GbE RTT vs in-memory
    hand-off). Used where wall-clock would only reflect this CPU container.

    Thin message-unit façade over ``repro.core.vertex_program.CostModel`` —
    the single source of truth for the cost constants, shared with the
    scenario suite.
    """

    def step_time(self, local_msgs: float, remote_msgs: float,
                  migrations: float = 0.0, c_mig: Optional[float] = None) -> float:
        model = self if c_mig is None else dataclasses.replace(self, c_mig=c_mig)
        return model.superstep_cost(local_msgs, remote_msgs, migrations,
                                    unit_bytes=1.0)
