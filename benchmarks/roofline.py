"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

  PYTHONPATH=src python -m benchmarks.roofline [--mesh single_pod_256]

Three terms per (arch × shape × mesh), all in seconds-per-step-per-chip:

  compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = collective_bytes / link_bw      (50 GB/s/link ICI)

Sources: ``compiled.cost_analysis()`` per-device flops/bytes;
collective bytes parsed from optimised HLO (dryrun.parse_collective_bytes).

**Scan-body correction**: XLA's cost analysis counts a while-loop body ONCE
regardless of trip count (calibrated in EXPERIMENTS.md §Dry-run). For
scan-over-layers LMs we difference two lowerings (L and L//2 layers) to
recover per-layer cost and extrapolate: total = outside + L·body. GNN/recsys
models unroll natively — no correction. MODEL_FLOPS uses the standard
6·N·D (dense) / 6·N_active·D (MoE) formulas for train; 2·N·D for inference.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Any, Dict, Optional

# peaks live in the observability layer (single source, shared with
# plan_cost kernel estimates — DESIGN.md §11)
from repro.obs.profiling import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: E402


def _param_counts():
    """(total, active) params per LM arch; analytic for gnn/recsys."""
    from repro.configs import registry
    from repro.models import active_param_count, param_count
    out = {}
    for arch in ("granite-34b", "gemma2-9b", "phi4-mini-3.8b", "arctic-480b",
                 "deepseek-v2-lite-16b"):
        cfg = registry.get(arch).config()
        out[arch] = (param_count(cfg), active_param_count(cfg))
    return out


def model_flops(arch: str, shape: Dict[str, Any], info: Dict[str, Any],
                counts: Dict[str, tuple]) -> Optional[float]:
    """6·N·D for train (fwd+bwd), 2·N·D for inference forwards/steps."""
    if arch in counts:
        total, active = counts[arch]
        n = active
        kind = info.get("kind", "")
        tokens = info.get("tokens", 0)
        if kind == "train":
            return 6.0 * n * tokens
        return 2.0 * n * tokens
    return None


def analyze(results: Dict[str, Any], chips: int, lm_correction: Dict[str, float],
            counts) -> Dict[str, Any]:
    """Three roofline terms per cell.

    compute:    scan-corrected HLO flops / peak.
    memory:     HBM-traffic model from memory_analysis — (arguments + outputs
                + 2·temps) / bandwidth. (XLA's "bytes accessed" counts
                logical operand bytes pre-fusion and is not HBM traffic;
                recorded in JSON as ``hlo_bytes_accessed_s`` for reference.)
    collective: parsed HLO collective bytes / per-link ICI bandwidth.

    roofline_fraction: for LM cells, MFU-at-bound = ideal MODEL_FLOPS time /
    step lower bound (max of the three terms); for GNN/recsys, the
    compute-share of the bound (how compute-limited the cell is).
    """
    table = {}
    for key, rec in results.items():
        if rec.get("status") != "OK":
            table[key] = {"status": rec.get("status"),
                          "skip_reason": rec.get("skip_reason")}
            continue
        arch, shape_name = key.split(":")
        cost = rec.get("cost", {})
        flops_dev = float(cost.get("flops", 0.0))
        raw_bytes_dev = float(cost.get("bytes accessed", 0.0))
        corr = lm_correction.get(key, 1.0)
        flops_dev *= corr
        mem = rec.get("memory", {})
        traffic = ((mem.get("argument_bytes") or 0)
                   + (mem.get("output_bytes") or 0)
                   + 2 * (mem.get("temp_bytes") or 0))
        coll_dev = float(rec.get("collectives", {}).get("total_bytes", 0))
        t_compute = flops_dev / PEAK_FLOPS
        t_memory = traffic / HBM_BW
        t_coll = coll_dev / ICI_BW
        dominant = max((t_compute, "compute"), (t_memory, "memory"),
                       (t_coll, "collective"))[1]
        bound = max(t_compute, t_memory, t_coll, 1e-12)
        mf = model_flops(arch, {}, rec.get("static_info", {}), counts)
        if mf:
            ideal = mf / chips / PEAK_FLOPS
            frac = ideal / bound
            useful = mf / (flops_dev * chips) if flops_dev else None
        else:
            frac = t_compute / bound
            useful = None
        table[key] = {
            "status": "OK",
            "compute_s": t_compute, "memory_s": t_memory,
            "collective_s": t_coll, "dominant": dominant,
            "step_lower_bound_s": bound,
            "roofline_fraction": frac,
            "model_flops": mf,
            "useful_flops_ratio": useful,
            "scan_correction": corr,
            "hlo_bytes_accessed_s": raw_bytes_dev * corr / HBM_BW,
            "temp_gb_per_dev": (mem.get("temp_bytes") or 0) / 1e9,
        }
    return table


def scan_corrections(results: Dict[str, Any]) -> Dict[str, float]:
    """Correction factor ≈ (outside + L·body)/(outside + body) estimated from
    the arch layer count; body share measured per kind (documented in
    EXPERIMENTS.md). We approximate body share via per-arch layer count:
    reported ≈ outside + body, true ≈ outside + L·body. With lm_head
    dominating `outside` for small models this is conservative."""
    from repro.configs import registry
    out = {}
    for key, rec in results.items():
        if rec.get("status") != "OK":
            continue
        arch = key.split(":")[0]
        try:
            mod = registry.get(arch)
        except KeyError:
            continue
        if mod.FAMILY != "lm":
            continue
        cfg = mod.config()
        kind = rec.get("static_info", {}).get("kind", "")
        # measured decomposition (EXPERIMENTS §Dry-run): for train cells the
        # scan body is ~(1-r) of reported cost with r the unscanned share.
        # We lower-bound by assuming reported = outside + body and body from
        # analytic per-layer share.
        L = cfg.n_layers - cfg.moe_first_dense
        out[key] = _measured_correction(arch, kind, L)
    return out


_CORRECTIONS_PATH = os.path.join("results", "scan_corrections.json")


def _measured_correction(arch: str, kind: str, L: int) -> float:
    """Load measured correction factors (produced by --calibrate)."""
    if os.path.exists(_CORRECTIONS_PATH):
        with open(_CORRECTIONS_PATH) as f:
            data = json.load(f)
        k = f"{arch}:{kind}"
        if k in data:
            return float(data[k])
    return float(L)          # worst-case: everything is in the body


def calibrate(mesh_name: str = "single_pod_256") -> None:
    """Measure per-(arch, kind) scan-correction factors by differencing a
    2-layer and 4-layer lowering of the same cell on the production mesh."""
    import os as _os
    _os.environ.setdefault("XLA_FLAGS",
                           "--xla_force_host_platform_device_count=512")
    import dataclasses as dc
    import jax
    from repro.configs import registry
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell
    from repro.configs.base import Cell

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi_pod_512"))
    out = {}
    for arch in ("granite-34b", "gemma2-9b", "phi4-mini-3.8b", "arctic-480b",
                 "deepseek-v2-lite-16b"):
        mod = registry.get(arch)
        real_cfg = mod.config
        for shape_name, shape in mod.SHAPES.items():
            if mod.SKIPS.get(shape_name):
                continue
            kind = shape["kind"]
            key = f"{arch}:{kind}"
            if key in out:
                continue
            costs = {}
            try:
                # UNROLLED 2- and 4-layer lowerings: flops scale with L, so
                # differencing recovers the true per-layer cost (under scan
                # the body is counted once at any L — differencing measures 0)
                for L, unroll in ((2, True), (4, True), (4, False)):
                    def patched(L=L, unroll=unroll):
                        cfg = real_cfg()
                        nd = min(cfg.moe_first_dense, 1)
                        return dc.replace(cfg, n_layers=L + nd,
                                          unroll_layers=unroll)
                    mod.config = patched
                    cell = Cell(arch, shape_name, "lm", shape)
                    spec = build_cell(cell, mesh)
                    with mesh:
                        c = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                                    out_shardings=spec.out_shardings,
                                    donate_argnums=spec.donate_argnums
                                    ).lower(*spec.args).compile()
                    costs[(L, unroll)] = float(c.cost_analysis().get("flops", 0.0))
            finally:
                mod.config = real_cfg
            body = max(costs[(4, True)] - costs[(2, True)], 0.0) / 2.0
            outside = max(costs[(2, True)] - 2 * body, 0.0)
            cfg = real_cfg()
            L_full = cfg.n_layers - cfg.moe_first_dense
            true_full = outside + L_full * body
            # what the scan-based production lowering reports at L=4:
            reported_l4 = costs[(4, False)]
            reported_full = max(reported_l4, 1.0)   # scan: L-independent
            corr = true_full / reported_full
            out[key] = corr
            print(f"calibrate {key}: body={body:.3g} outside={outside:.3g} "
                  f"reported(scan)={reported_l4:.3g} correction x{corr:.1f}",
                  flush=True)
    os.makedirs("results", exist_ok=True)
    with open(_CORRECTIONS_PATH, "w") as f:
        json.dump(out, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single_pod_256")
    ap.add_argument("--calibrate", action="store_true")
    ap.add_argument("--results", default="results")
    args = ap.parse_args()
    if args.calibrate:
        calibrate(args.mesh)
        return
    path = os.path.join(args.results, f"dryrun_{args.mesh}.json")
    with open(path) as f:
        results = json.load(f)
    chips = 512 if "multi" in args.mesh else 256
    counts = _param_counts()
    corr = scan_corrections(results)
    table = analyze(results, chips, corr, counts)
    out_path = os.path.join(args.results, f"roofline_{args.mesh}.json")
    with open(out_path, "w") as f:
        json.dump(table, f, indent=1, default=float)
    # pretty print
    hdr = (f"{'cell':38s} {'compute':>9s} {'memory':>9s} {'collect':>9s} "
           f"{'dominant':>10s} {'roofl%':>7s} {'useful%':>8s}")
    print(hdr)
    for key in sorted(table):
        r = table[key]
        if r.get("status") != "OK":
            print(f"{key:38s} {r.get('status')}")
            continue
        rf = r["roofline_fraction"]
        uf = r["useful_flops_ratio"]
        print(f"{key:38s} {r['compute_s']:9.4f} {r['memory_s']:9.4f} "
              f"{r['collective_s']:9.4f} {r['dominant']:>10s} "
              f"{100 * (rf or 0):6.1f}% "
              f"{('%7.1f%%' % (100 * uf)) if uf else '     - '}")


if __name__ == "__main__":
    main()
