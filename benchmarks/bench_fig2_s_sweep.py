"""Fig. 2 reproduction: effect of the damping factor s on convergence time
and final cut ratio (64kcube + epinions-like power-law, 9 partitions).

Paper claims: final cut statistically flat in s; convergence time suffers at
the extremes (slow at low s, chasing-waste at high s); s = 0.5 is a good
default.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.api import DynamicGraphSystem, PartitionSection, SystemConfig
from repro.graph import cut_ratio, generators

S_VALUES = [0.1, 0.3, 0.5, 0.7, 0.9]


def run(quick: bool = False) -> List[Dict]:
    graphs = {
        "64kcube": lambda: generators.fem_cube(16 if quick else 30),  # 27k (CPU-tractable stand-in)
        "epinions_like": lambda: generators.power_law(
            4000 if quick else 20000, seed=3),
    }
    rows: List[Dict] = []
    n_rep = 2
    for gname, build in graphs.items():
        g = build()
        for s in S_VALUES:
            finals, iters_list = [], []
            for rep in range(n_rep):
                cfg = SystemConfig(partition=PartitionSection(
                    strategy="xdgp", k=9, s=s, slack=0.1,
                    max_iters=150 if quick else 220,
                    patience=20 if quick else 30), seed=rep)
                system = DynamicGraphSystem(g, cfg)
                hist = system.converge()
                finals.append(float(cut_ratio(g, system.labels)))
                # convergence = first iteration reaching within 2% of final cut
                target = finals[-1] * 1.02
                conv = next((i for i, c in enumerate(hist.cut_ratio)
                             if c <= target), hist.iterations)
                iters_list.append(conv)
            rows.append({
                "bench": "fig2", "graph": gname, "s": s,
                "final_cut_mean": round(float(np.mean(finals)), 4),
                "final_cut_std": round(float(np.std(finals)), 4),
                "convergence_iters_mean": round(float(np.mean(iters_list)), 1),
            })
            print(f"  fig2 {gname} s={s}: cut {np.mean(finals):.3f} "
                  f"conv {np.mean(iters_list):.0f} iters", flush=True)
    return rows
