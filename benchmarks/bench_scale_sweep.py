"""Scale sweep: events/sec ingest + superstep seconds vs |V| (DESIGN.md §14).

The scale tier's headline artifact: for each (vertex count, backend) cell,
build a power-law graph through the streaming generators (chunked, bounded
host memory), run a live ingest→place→measure stream through a full
``DynamicGraphSystem`` session, run adaptation rounds, and attempt a
budget-gated chunked BSR packing — recording wall times, throughput, cut
movement, the packing outcome, and the process peak-RSS high-water mark.

    PYTHONPATH=src:. python benchmarks/bench_scale_sweep.py --scale smoke
    PYTHONPATH=src:. XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python benchmarks/bench_scale_sweep.py --scale full

Writes results/bench_scale_sweep.json (schema: obs.schema.validate_scale_
bench; re-validated in CI against both a fresh smoke run and the committed
full artifact).
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List

import numpy as np

from benchmarks.common import save

SCALES = {
    "smoke": {"sizes": [200_000], "steps": 3, "adapt_iters": 3},
    "full": {"sizes": [100_000, 300_000, 1_000_000], "steps": 3,
             "adapt_iters": 4},
}


def run_cell(n: int, backend: str, *, generator: str, avg_degree: float,
             chunk_edges: int, k: int, steps: int, adapt_iters: int,
             blk: int, bsr_budget_mb: int, seed: int) -> Dict[str, Any]:
    from repro.api import DynamicGraphSystem, SystemConfig
    from repro.api.config import (ClusterSection, GraphSection,
                                  PartitionSection, StreamSection,
                                  TelemetrySection)
    from repro.obs.profiling import peak_rss_bytes
    from repro.scale import (MemoryBudgetError, graph_to_bsr_chunked,
                             make_edge_stream, stream_events)
    from repro.stream.metrics import cut_ratio_of

    a_cap = 1 << 16
    cfg = SystemConfig(
        graph=GraphSection(generator=generator, n=n, avg_degree=avg_degree,
                           chunk_edges=chunk_edges),
        stream=StreamSection(window=1 << 40, a_cap=a_cap, d_cap=1024),
        partition=PartitionSection(strategy="xdgp", k=k,
                                   adapt_iters=adapt_iters),
        cluster=ClusterSection(backend=backend),
        telemetry=TelemetrySection(recompute_every=0),
        seed=seed)

    t0 = time.perf_counter()
    system = DynamicGraphSystem(config=cfg)   # generator builds the graph
    build_seconds = time.perf_counter() - t0
    edges0 = int(system.graph.num_edges)
    cut_before = float(cut_ratio_of(system.tracker))

    # live stream: fresh edges from a disjoint seed, capped per step so the
    # whole batch clears capacity (this measures ingest, not backpressure)
    live = make_edge_stream(generator, n, avg_degree=avg_degree,
                            chunk_edges=min(a_cap // 2, chunk_edges),
                            seed=seed + 1)
    records = []
    for i, batch in enumerate(stream_events(live, t0=1)):
        if i >= steps:
            break
        records.append(system.step(batch))
    events = sum(r.events for r in records)
    ingest_seconds = sum(r.ingest_seconds for r in records)
    step_secs = [r.step_seconds for r in records]
    # first step pays jit compilation; the median of the rest is steady state
    superstep_seconds = float(np.median(step_secs[1:] if len(step_secs) > 1
                                        else step_secs))

    t0 = time.perf_counter()
    hist = system.adapt(adapt_iters)
    adapt_seconds = time.perf_counter() - t0
    cut_after = float(cut_ratio_of(system.tracker))
    migrations = sum(r.migrations for r in records) + hist.total_migrations

    budget = bsr_budget_mb * (1 << 20)
    t0 = time.perf_counter()
    try:
        bsr = graph_to_bsr_chunked(system.graph, blk=blk,
                                   chunk_edges=chunk_edges,
                                   memory_budget=budget)
        nnzb = int(bsr.nnzb)
        bsr_out: Dict[str, Any] = {
            "nnzb": nnzb, "blocks_bytes": int(nnzb * blk * blk * 4),
            "build_seconds": time.perf_counter() - t0}
    except MemoryBudgetError as e:
        # the budget refusing an over-sized packing IS the bounded-memory
        # contract working — record it instead of OOMing the sweep
        bsr_out = {"skipped": str(e)}

    return {"vertices": n, "backend": backend, "edges": edges0,
            "events": int(events), "supersteps": len(records),
            "build_seconds": build_seconds,
            "ingest_events_per_sec": events / max(ingest_seconds, 1e-12),
            "superstep_seconds": superstep_seconds,
            "adapt_seconds": adapt_seconds, "adapt_iters": adapt_iters,
            "migrations": int(migrations),
            "cut_before": cut_before, "cut_after": cut_after,
            "bsr": bsr_out, "peak_rss_bytes": peak_rss_bytes()}


def main(argv: List[str] = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", choices=sorted(SCALES), default="smoke")
    ap.add_argument("--sizes", type=int, nargs="*", default=None,
                    help="override the scale preset's vertex counts")
    ap.add_argument("--backends", nargs="*", default=["local", "sharded"])
    ap.add_argument("--generator", default="rmat")
    ap.add_argument("--avg-degree", type=float, default=8.0)
    ap.add_argument("--chunk-edges", type=int, default=1 << 18)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--blk", type=int, default=8,
                    help="BSR tile size; power-law graphs scatter edges so "
                         "nearly every edge lands in its own tile — small "
                         "blocks keep the pack inside the memory budget")
    ap.add_argument("--bsr-budget-mb", type=int, default=2048)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    preset = SCALES[args.scale]
    sizes = args.sizes if args.sizes else preset["sizes"]
    backends = list(args.backends)
    if "sharded" in backends and jax.device_count() < args.k:
        print(f"[scale] sharded needs {args.k} devices, have "
              f"{jax.device_count()} — dropping it from the sweep "
              f"(set XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{args.k})")
        backends = [b for b in backends if b != "sharded"]
    if not backends:
        raise SystemExit("no runnable backends")

    rows = []
    for n in sizes:
        for backend in backends:
            t0 = time.perf_counter()
            row = run_cell(n, backend, generator=args.generator,
                           avg_degree=args.avg_degree,
                           chunk_edges=args.chunk_edges, k=args.k,
                           steps=preset["steps"],
                           adapt_iters=preset["adapt_iters"], blk=args.blk,
                           bsr_budget_mb=args.bsr_budget_mb, seed=args.seed)
            rows.append(row)
            print(f"[scale] |V|={n:>9,} {backend:>7}: "
                  f"build {row['build_seconds']:6.1f}s  "
                  f"ingest {row['ingest_events_per_sec']:>11,.0f} ev/s  "
                  f"superstep {row['superstep_seconds']*1e3:8.1f} ms  "
                  f"cut {row['cut_before']:.3f}->{row['cut_after']:.3f}  "
                  f"rss {row['peak_rss_bytes']/2**30:.2f} GiB  "
                  f"({time.perf_counter()-t0:.0f}s)")

    from repro.obs.manifest import run_manifest
    from repro.obs.profiling import memory_probe
    payload = {"bench": "scale_sweep", "generator": args.generator,
               "k": args.k, "chunk_edges": args.chunk_edges,
               "blk": args.blk,
               "avg_degree": args.avg_degree, "scale": args.scale,
               "sizes": sizes, "backends": backends, "rows": rows,
               "manifest": run_manifest(None, memory=memory_probe())}
    from repro.obs.schema import validate_scale_bench
    validate_scale_bench(payload)
    path = save("bench_scale_sweep", payload)
    print(f"[scale] wrote {path}")
    return payload


if __name__ == "__main__":
    main()
