"""Vectorized edge-stream ingestion (the streaming layer's front end).

The seed path (`graph/dynamics.py`) buffered changes in Python deques and
built every padded ``GraphDelta`` with a per-event ``for`` loop — at high
event rates the dynamic benchmarks were bottlenecked on that loop, not on
the adaptive heuristic. This module replaces it with NumPy batch builders:

* ``build_delta``        — one padded ``GraphDelta`` from host arrays, no
                           Python-level per-event work.
* ``EdgeStreamBuffer``   — array-backed change queue with capacity
                           (``a_cap``/``d_cap``) backpressure: what does not
                           fit in a drain stays queued and is accounted for.
* ``WindowTracker``      — vectorized sliding-window expiry (``last_seen``
                           as a dense array; stale scan via boolean masks).
* ``stream_batches``     — time-span batching of a (t, u, v) event stream
                           (vectorized ``np.searchsorted`` span cuts).

Everything here is host-side NumPy by design: ingestion is the host→device
boundary, and the output (``GraphDelta``) is the only thing that crosses it.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Iterator, NamedTuple, Optional, Tuple

import numpy as np

from repro.graph.structure import GraphDelta


class IngestStats(NamedTuple):
    """Accounting for one drain: what was released vs. held back."""

    adds_out: int          # edge additions packed into the delta
    dels_out: int          # node deletions packed into the delta
    adds_backlog: int      # additions still queued (capacity backpressure)
    dels_backlog: int      # deletions still queued
    invalid: int = 0       # events rejected at the door (ids outside [0, n_cap))
    stale_dropped: int = 0  # backlogged changes invalidated by window movement
    overflow_dropped: int = 0  # over-capacity changes discarded (carry_backlog=False)
    dup_dropped: int = 0   # additions dropped because the edge is already live


def build_delta(add_src: np.ndarray, add_dst: np.ndarray,
                del_nodes: np.ndarray, a_cap: int, d_cap: int) -> GraphDelta:
    """Materialise one padded GraphDelta from host arrays (no Python loop).

    Callers must pre-truncate to capacity; this is the pure packing step.
    Leaves stay host-side NumPy: the device transfer happens exactly once,
    when the delta enters a jit'd consumer (``apply_delta``/``place_delta``),
    instead of eagerly per field here.
    """
    a = int(add_src.shape[0])
    d = int(del_nodes.shape[0])
    if a > a_cap or d > d_cap:
        raise ValueError(f"batch exceeds capacity: adds {a}>{a_cap} or dels {d}>{d_cap}")
    asrc = np.full((a_cap,), -1, np.int32)
    adst = np.full((a_cap,), -1, np.int32)
    amask = np.zeros((a_cap,), bool)
    asrc[:a] = add_src
    adst[:a] = add_dst
    amask[:a] = True
    dnodes = np.full((d_cap,), -1, np.int32)
    dmask = np.zeros((d_cap,), bool)
    dnodes[:d] = del_nodes
    dmask[:d] = True
    return GraphDelta(add_src=asrc, add_dst=adst, add_mask=amask,
                      del_nodes=dnodes, del_mask=dmask)


class EdgeStreamBuffer:
    """Array-backed change queue with capacity backpressure.

    Same contract as the seed ``ChangeQueue`` (append changes, drain up to
    ``a_cap``/``d_cap`` per superstep, leftovers stay queued). Pushes append
    whole chunks to a deque — O(1) per push, whether the chunk is one
    event (seed-compat API) or a full batch — and a drain consumes whole
    chunks off the *front*, slicing at most one chunk boundary, so the
    copy work per pop is O(popped), independent of how deep the backlog
    is.  (The previous implementation re-concatenated the entire backlog
    on every pop — O(backlog) per superstep, quadratic over a sustained
    overload; the scale tier's sweep holds million-edge backlogs, where
    that is the difference between draining and thrashing.)  Additions
    optionally carry their event timestamps so a windowed consumer can
    re-validate backlogged edges against the window.
    """

    def __init__(self, a_cap: int = 4096, d_cap: int = 1024):
        self.a_cap = int(a_cap)
        self.d_cap = int(d_cap)
        self._add_chunks: Deque = collections.deque()  # (src, dst, t) int64
        self._del_chunks: Deque = collections.deque()
        self._n_adds = 0
        self._n_dels = 0
        # elements copied servicing pops — the O(popped) contract is pinned
        # by tests/test_stream.py against this counter
        self.copied_elements = 0

    # -- producers ---------------------------------------------------------
    def push_edges(self, src: np.ndarray, dst: np.ndarray,
                   t: Optional[np.ndarray] = None) -> None:
        src = np.asarray(src, np.int64).reshape(-1)
        dst = np.asarray(dst, np.int64).reshape(-1)
        if src.shape != dst.shape:
            raise ValueError("src/dst length mismatch")
        t = (np.zeros_like(src) if t is None
             else np.broadcast_to(np.asarray(t, np.int64), src.shape))
        self._add_chunks.append((src, dst, t))
        self._n_adds += int(src.shape[0])

    def push_node_removals(self, nodes: np.ndarray) -> None:
        nodes = np.asarray(nodes, np.int64).reshape(-1)
        self._del_chunks.append(nodes)
        self._n_dels += int(nodes.shape[0])

    # -- consumers ---------------------------------------------------------
    def __len__(self) -> int:
        return self._n_adds + self._n_dels

    @property
    def backlog(self) -> Tuple[int, int]:
        return self._n_adds, self._n_dels

    @property
    def pressure(self) -> float:
        """Queued work relative to one pop()'s drain capacity — 1.0 means
        the next superstep clears the queue exactly; above that, deferral
        (capacity backpressure) is already happening."""
        return max(self._n_adds / self.a_cap, self._n_dels / self.d_cap)

    def _take_adds(self, want: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Consume up to ``want`` additions off the front, FIFO; copies only
        the elements returned (a partially-consumed chunk stays queued as a
        zero-copy view of its tail)."""
        pieces, got = [], 0
        while got < want and self._add_chunks:
            s, d, t = self._add_chunks.popleft()
            take = min(s.shape[0], want - got)
            if take < s.shape[0]:
                self._add_chunks.appendleft((s[take:], d[take:], t[take:]))
            pieces.append((s[:take], d[:take], t[:take]))
            got += take
        self._n_adds -= got
        self.copied_elements += got
        if not pieces:
            return (np.empty((0,), np.int64),) * 3
        if len(pieces) == 1:
            return pieces[0]
        return tuple(np.concatenate(x) for x in zip(*pieces))

    def _take_dels(self, want: int) -> np.ndarray:
        pieces, got = [], 0
        while got < want and self._del_chunks:
            n = self._del_chunks.popleft()
            take = min(n.shape[0], want - got)
            if take < n.shape[0]:
                self._del_chunks.appendleft(n[take:])
            pieces.append(n[:take])
            got += take
        self._n_dels -= got
        self.copied_elements += got
        if not pieces:
            return np.empty((0,), np.int64)
        return pieces[0] if len(pieces) == 1 else np.concatenate(pieces)

    def peek_all(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """The entire queued backlog — (add_src, add_dst, add_t, del_nodes) —
        without dequeueing anything (checkpointing reads this)."""
        src, dst, t = ((np.concatenate(x) for x in zip(*self._add_chunks))
                       if self._add_chunks else (np.empty((0,), np.int64),) * 3)
        dels = (np.concatenate(list(self._del_chunks)) if self._del_chunks
                else np.empty((0,), np.int64))
        return src, dst, t, dels

    def pop(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Dequeue up to capacity changes (FIFO): (add_src, add_dst, add_t,
        del_nodes) as host arrays; leftovers stay queued."""
        src, dst, t = self._take_adds(self.a_cap)
        dels = self._take_dels(self.d_cap)
        return src, dst, t, dels

    def drain(self) -> Tuple[GraphDelta, IngestStats]:
        """Release up to capacity changes as one padded delta (FIFO order)."""
        add_src, add_dst, _, dels = self.pop()
        delta = build_delta(add_src, add_dst, dels, self.a_cap, self.d_cap)
        return delta, IngestStats(adds_out=int(add_src.shape[0]),
                                  dels_out=int(dels.shape[0]),
                                  adds_backlog=self._n_adds,
                                  dels_backlog=self._n_dels)


class WindowTracker:
    """Vectorized sliding-window liveness: ``last_seen`` as a dense array.

    Replaces the seed's per-event ``dict`` updates + Python stale scan with
    ``np.maximum.at`` (one scatter-max per batch) and a masked comparison.
    ``last_seen[v] == NEVER`` means v is not tracked (never seen / expired).
    """

    NEVER = np.int64(np.iinfo(np.int64).min)

    def __init__(self, n_cap: int):
        self.last_seen = np.full((n_cap,), self.NEVER, np.int64)

    def touch(self, times: np.ndarray, src: np.ndarray, dst: np.ndarray) -> None:
        """Mark both endpoints of each event active at its timestamp."""
        nodes = np.concatenate([np.asarray(src, np.int64),
                                np.asarray(dst, np.int64)])
        t2 = np.concatenate([np.asarray(times, np.int64)] * 2)
        np.maximum.at(self.last_seen, nodes, t2)

    def expire(self, horizon: int) -> np.ndarray:
        """Pop every tracked node idle since before ``horizon`` (ascending ids)."""
        stale = (self.last_seen != self.NEVER) & (self.last_seen < horizon)
        out = np.flatnonzero(stale).astype(np.int64)
        self.last_seen[stale] = self.NEVER
        return out

    @property
    def tracked(self) -> int:
        return int((self.last_seen != self.NEVER).sum())


@dataclasses.dataclass
class WindowIngestor:
    """Full windowed ingest stage: events in → (GraphDelta, IngestStats) out.

    The streaming analogue of the seed ``SlidingWindowGraph.advance`` minus
    the graph application itself (the engine owns ``apply_delta`` so it can
    interleave placement and metrics). ``carry_backlog=False`` reproduces the
    seed semantics exactly (overflow beyond capacity is dropped per batch);
    ``carry_backlog=True`` keeps overflow queued for the next superstep and
    reports it, which is what a production pipeline wants.
    """

    n_cap: int
    window: int
    a_cap: int = 8192
    d_cap: int = 4096
    carry_backlog: bool = True
    dedupe: bool = False

    def __post_init__(self):
        self.tracker = WindowTracker(self.n_cap)
        self.buffer = EdgeStreamBuffer(self.a_cap, self.d_cap)
        # canonical (lo, hi) endpoints of currently-live edges (dedupe=True):
        # lets repeated events (the same mention/call/mesh edge re-observed
        # inside the window) refresh the window without duplicating the edge
        self._live_lo = np.empty((0,), np.int64)
        self._live_hi = np.empty((0,), np.int64)

    @property
    def live_edge_count(self) -> int:
        """Size of the mirrored live edge set (dedupe mode only)."""
        return int(self._live_lo.shape[0])

    def live_edge_keys(self) -> np.ndarray:
        """Sorted canonical keys (lo·n_cap + hi) of the mirrored live edges."""
        return np.sort(self._live_lo * np.int64(self.n_cap) + self._live_hi)

    def seed_live_edges(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Register edges that are already live (engine startup from a
        non-empty graph); without this every pre-existing edge would pass
        the duplicate check once and be inserted a second time."""
        src = np.asarray(src, np.int64).reshape(-1)
        dst = np.asarray(dst, np.int64).reshape(-1)
        self._live_lo = np.concatenate([self._live_lo, np.minimum(src, dst)])
        self._live_hi = np.concatenate([self._live_hi, np.maximum(src, dst)])

    def ingest(self, events: np.ndarray, now: int) -> Tuple[GraphDelta, IngestStats]:
        """Vectorized: push the batch, expire stale nodes, drain one delta.

        ``events`` rows are (t, u, v), time-ordered within the batch. Events
        with an endpoint outside [0, n_cap) are rejected and counted (the
        seed path let them through, leaving dangling edge endpoints behind).

        Because backlogged changes can sit queued while the window moves,
        every drain is re-validated against the current window state:
        * an addition whose event time has already fallen out of the window
          is dropped (it would be expired on arrival anyway);
        * an addition that survives re-touches its endpoints, so a node that
          expired while the edge was queued is tracked again when the edge
          resurrects it;
        * a deletion whose node was re-activated after it was queued is
          dropped (expiring it now would kill a live node).
        """
        events = np.asarray(events)
        invalid = 0
        if events.size:
            t, u, v = events[:, 0], events[:, 1], events[:, 2]
            ok = (u >= 0) & (u < self.n_cap) & (v >= 0) & (v < self.n_cap)
            invalid = int((~ok).sum())
            if invalid:
                t, u, v = t[ok], u[ok], v[ok]
            self.buffer.push_edges(u, v, t)
            self.tracker.touch(t, u, v)
        horizon = now - self.window
        stale = self.tracker.expire(horizon)
        if stale.size:
            self.buffer.push_node_removals(stale)

        add_src, add_dst, add_t, dels = self.buffer.pop()
        fresh = add_t >= horizon
        live_again = self.tracker.last_seen[dels] != WindowTracker.NEVER
        stale_dropped = int((~fresh).sum()) + int(live_again.sum())
        if stale_dropped:
            add_src, add_dst, add_t = add_src[fresh], add_dst[fresh], add_t[fresh]
            dels = dels[~live_again]
        dup_dropped = 0
        if self.dedupe:
            # mirror apply_delta's order: expiring nodes take their incident
            # edges with them first, then the surviving additions land
            if dels.size and self._live_lo.size:
                gone = (np.isin(self._live_lo, dels)
                        | np.isin(self._live_hi, dels))
                if gone.any():
                    self._live_lo = self._live_lo[~gone]
                    self._live_hi = self._live_hi[~gone]
            if add_src.size:
                lo = np.minimum(add_src, add_dst)
                hi = np.maximum(add_src, add_dst)
                key = lo * np.int64(self.n_cap) + hi
                _, first = np.unique(key, return_index=True)
                keep = np.zeros(key.shape[0], bool)
                keep[first] = True                     # first copy in the batch wins
                live_key = self._live_lo * np.int64(self.n_cap) + self._live_hi
                keep &= ~np.isin(key, live_key)        # already-live edges repeat
                dup_dropped = int((~keep).sum())
                if dup_dropped:
                    add_src, add_dst = add_src[keep], add_dst[keep]
                    add_t, lo, hi = add_t[keep], lo[keep], hi[keep]
                self._live_lo = np.concatenate([self._live_lo, lo])
                self._live_hi = np.concatenate([self._live_hi, hi])
        if add_src.size:
            self.tracker.touch(add_t, add_src, add_dst)
        delta = build_delta(add_src, add_dst, dels, self.a_cap, self.d_cap)
        stats = IngestStats(adds_out=int(add_src.shape[0]),
                            dels_out=int(dels.shape[0]),
                            adds_backlog=self.buffer.backlog[0],
                            dels_backlog=self.buffer.backlog[1],
                            invalid=invalid, stale_dropped=stale_dropped,
                            dup_dropped=dup_dropped)
        if not self.carry_backlog:
            # seed semantics: over-capacity changes are discarded, not queued
            # — report them as dropped, not as phantom backlog
            stats = stats._replace(
                adds_backlog=0, dels_backlog=0,
                overflow_dropped=stats.adds_backlog + stats.dels_backlog)
            self.buffer = EdgeStreamBuffer(self.a_cap, self.d_cap)
        return delta, stats


def stream_batches(times: np.ndarray, src: np.ndarray, dst: np.ndarray,
                   batch_span: int) -> Iterator[Tuple[int, np.ndarray]]:
    """Group a stream into time-span batches.

    Span boundaries are located with ``np.searchsorted`` (one binary search
    per batch) instead of a full boolean scan per span; an unsorted stream
    is stably sorted by time first so the binary search stays valid.
    """
    if batch_span <= 0:
        raise ValueError(f"batch_span must be positive, got {batch_span}")
    times = np.asarray(times)
    if times.size == 0:
        return
    if np.any(np.diff(times) < 0):
        order = np.argsort(times, kind="stable")
        times, src, dst = times[order], np.asarray(src)[order], np.asarray(dst)[order]
    t0, t_end = int(times.min()), int(times.max())
    lo = t0
    while lo <= t_end:
        hi = lo + batch_span
        i0 = int(np.searchsorted(times, lo, side="left"))
        i1 = int(np.searchsorted(times, hi, side="left"))
        rows = np.stack([times[i0:i1], src[i0:i1], dst[i0:i1]], axis=1)
        yield hi, rows
        lo = hi
