"""Deprecated streaming front end — a thin shim over ``repro.api``.

``StreamEngine`` was the PR-1 entry point for the ingest → place → adapt →
measure loop. That loop now lives in ``repro.api.DynamicGraphSystem`` behind
the pluggable ``PartitionStrategy`` protocol; this module keeps the old
constructor/telemetry surface working by translating ``StreamConfig`` into a
``SystemConfig`` + strategy pair:

    placement="online", adapt_iters>0  → XdgpAdaptive()            ("xdgp")
    placement="online", adapt_iters=0  → OnlineFennel()            ("fennel")
    placement="hash",   adapt_iters>0  → XdgpAdaptive("inherit")
    placement="hash",   adapt_iters=0  → Static()                  ("static")

``SuperstepRecord`` remains the shared per-superstep telemetry record (the
session emits the identical dataclass), so downstream consumers are
unaffected either way.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional

import jax
import numpy as np

# safe during package init: telemetry is a leaf module of repro.api, and by
# the time stream/__init__ reaches this file ingest/placement/metrics (all
# the api layer needs) are already in sys.modules
from repro.api.telemetry import SuperstepRecord
from repro.core.vertex_program import VertexProgram
from repro.graph.structure import Graph

__all__ = ["StreamConfig", "StreamEngine", "SuperstepRecord"]


@dataclasses.dataclass
class StreamConfig:
    k: int = 8                     # partitions
    s: float = 0.5                 # migration damping (paper §3.4)
    adapt_iters: int = 5           # migration rounds interleaved per superstep
    tie_break: str = "random"
    window: int = 300              # sliding-window length (event time units)
    a_cap: int = 8192              # max edge additions per superstep
    d_cap: int = 4096              # max node expiries per superstep
    slack: float = 0.2             # capacity head-room over n_cap/k
    placement: str = "online"      # "online" | "hash" (inherit padded-slot hash)
    placement_passes: int = 2
    recompute_every: int = 10      # supersteps between full-recompute drift checks
    dedupe: bool = False           # drop additions whose edge is already live
    seed: int = 0


def _system_config(graph: Graph, cfg: StreamConfig):
    """Map the flat StreamConfig knob set onto the layered SystemConfig."""
    from repro.api import (GraphSection, PartitionSection, StreamSection,
                           SystemConfig, TelemetrySection)
    from repro.api.strategy import OnlineFennel, Static, XdgpAdaptive

    if cfg.adapt_iters > 0:
        strategy = XdgpAdaptive(
            placement="online" if cfg.placement == "online" else "inherit")
    elif cfg.placement == "online":
        strategy = OnlineFennel()
    else:
        strategy = Static()
    sys_cfg = SystemConfig(
        graph=GraphSection(n_cap=graph.n_cap, e_cap=graph.e_cap),
        stream=StreamSection(window=cfg.window, a_cap=cfg.a_cap,
                             d_cap=cfg.d_cap, dedupe=cfg.dedupe),
        partition=PartitionSection(
            strategy=strategy.name, k=cfg.k, s=cfg.s,
            adapt_iters=cfg.adapt_iters, tie_break=cfg.tie_break,
            slack=cfg.slack, placement_passes=cfg.placement_passes),
        telemetry=TelemetrySection(recompute_every=cfg.recompute_every),
        seed=cfg.seed)
    return sys_cfg, strategy


class StreamEngine:
    """Deprecated: use ``repro.api.DynamicGraphSystem``."""

    def __init__(self, graph: Graph, config: StreamConfig,
                 assignment: Optional[jax.Array] = None,
                 program: Optional[VertexProgram] = None):
        warnings.warn(
            "StreamEngine is deprecated; use repro.api.DynamicGraphSystem "
            "with a SystemConfig (strategy 'xdgp' replaces "
            "placement='online' + adapt_iters>0, 'static' the hash baseline)",
            DeprecationWarning, stacklevel=2)
        from repro.api import DynamicGraphSystem
        self.config = config
        sys_cfg, strategy = _system_config(graph, config)
        self._system = DynamicGraphSystem(graph, sys_cfg,
                                          assignment=assignment,
                                          strategy=strategy, program=program)

    # -- delegated state ----------------------------------------------------
    @property
    def graph(self):
        return self._system.graph

    @property
    def state(self):
        return self._system.state

    @property
    def tracker(self):
        return self._system.tracker

    @property
    def ingestor(self):
        return self._system.ingestor

    @property
    def telemetry(self) -> List[SuperstepRecord]:
        return self._system.telemetry

    @property
    def program(self):
        return self._system.program

    @property
    def program_state(self):
        return self._system.program_state

    # -- delegated behaviour ------------------------------------------------
    def superstep(self, events: np.ndarray, now: int) -> SuperstepRecord:
        return self._system.step(events, now)

    def run_stream(self, times: np.ndarray, src: np.ndarray, dst: np.ndarray,
                   batch_span: int,
                   max_supersteps: Optional[int] = None) -> List[SuperstepRecord]:
        """Replay a (t, u, v) stream window-by-window through the engine."""
        return self._system.run((times, src, dst), batch_span=batch_span,
                                max_supersteps=max_supersteps)

    def drain_backlog(self, now: int, max_supersteps: int = 64,
                      ) -> List[SuperstepRecord]:
        """Flush capacity-deferred changes with empty-input supersteps."""
        return self._system.drain(now, max_supersteps=max_supersteps)
