"""StreamEngine: the ingest → place → adapt → measure production loop.

One object owns the full dynamic-graph serving path:

    events ──► WindowIngestor (vectorized batch + expiry, backpressure)
                   │ GraphDelta
                   ▼
               apply_delta (static-shape scatter, jit)
                   │
                   ▼
               place_delta (online Fennel/DGR placement of arrivals, jit)
                   │
                   ▼
               adapt_jit  (xDGP migration rounds, lax.scan, jit)
                   │
                   ▼
               QualityTracker (incremental cut / occupancy, drift-checked)

Each superstep emits one ``SuperstepRecord`` of telemetry — ingest rate,
backlog, cut trajectory, imbalance, migrations, placement quality — which is
what the throughput benchmark and the ops dashboard consume.

The engine can additionally run a Pregel-style ``VertexProgram`` every
superstep (pass ``program=`` at construction): after the adaptation rounds it
executes one BSP compute superstep on the current graph and charges the
message traffic it generated (``local_bytes``/``remote_bytes`` under the
current assignment) to the superstep record. This is the paper's execution
model — computation interleaved with adaptation, iteration time bound by
cross-partition messages (§5.3) — and is what the scenario harness
(``repro.scenarios``) measures end to end.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition_state import PartitionState, default_capacity, make_state
from repro.core.initial import initial_partition
from repro.core.repartitioner import adapt_jit
from repro.core.vertex_program import VertexProgram, message_volume
from repro.core.vertex_program import superstep as program_superstep
from repro.graph.structure import Graph, apply_delta
from repro.stream.ingest import IngestStats, WindowIngestor, stream_batches
from repro.stream.metrics import (QualityTracker, cut_ratio_of, delta_update,
                                  drift_check, imbalance_of, init_tracker,
                                  move_update)
from repro.stream.placement import place_delta


@dataclasses.dataclass
class StreamConfig:
    k: int = 8                     # partitions
    s: float = 0.5                 # migration damping (paper §3.4)
    adapt_iters: int = 5           # migration rounds interleaved per superstep
    tie_break: str = "random"
    window: int = 300              # sliding-window length (event time units)
    a_cap: int = 8192              # max edge additions per superstep
    d_cap: int = 4096              # max node expiries per superstep
    slack: float = 0.2             # capacity head-room over n_cap/k
    placement: str = "online"      # "online" | "hash" (inherit padded-slot hash)
    placement_passes: int = 2
    recompute_every: int = 10      # supersteps between full-recompute drift checks
    dedupe: bool = False           # drop additions whose edge is already live
    seed: int = 0


@dataclasses.dataclass
class SuperstepRecord:
    """Telemetry for one engine superstep."""

    superstep: int
    now: int                   # stream time at the end of the batch
    events: int                # events offered this superstep
    adds: int                  # edge additions released into the graph
    dels: int                  # node expiries released
    backlog_adds: int          # additions held back by a_cap backpressure
    backlog_dels: int
    invalid_events: int        # events rejected at ingest (ids out of range)
    stale_dropped: int         # backlogged changes invalidated by window movement
    new_placed: int            # vertices placed online this superstep
    migrations: int            # vertices moved by the adaptation rounds
    cut_edges: int
    live_edges: int
    cut_ratio: float
    imbalance: float
    ingest_seconds: float      # delta construction (the streaming front end)
    step_seconds: float        # full superstep wall clock
    drift: Optional[float]     # set on drift-check supersteps (must be 0.0)
    dup_dropped: int = 0       # additions dropped as already-live (dedupe mode)
    local_bytes: int = 0       # program message traffic staying intra-partition
    remote_bytes: int = 0      # program message traffic crossing partitions
    compute_seconds: float = 0.0  # vertex-program superstep wall clock

    @property
    def events_per_second(self) -> float:
        return self.events / max(self.ingest_seconds, 1e-12)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["events_per_second"] = self.events_per_second
        return d


class StreamEngine:
    """Continuous dynamic-graph partitioning over an event stream."""

    def __init__(self, graph: Graph, config: StreamConfig,
                 assignment: Optional[jax.Array] = None,
                 program: Optional[VertexProgram] = None):
        self.config = config
        self.graph = graph
        if assignment is None:
            assignment = initial_partition(graph, config.k, "hsh")
        # capacity is provisioned for the slot space, not the current live
        # set: a stream can legally grow the graph to n_cap vertices.
        capacity = default_capacity(graph.n_cap, config.k, config.slack)
        self.state: PartitionState = make_state(
            graph, assignment, config.k, slack=config.slack,
            seed=config.seed, capacity=capacity)
        self.ingestor = WindowIngestor(
            n_cap=graph.n_cap, window=config.window,
            a_cap=config.a_cap, d_cap=config.d_cap, dedupe=config.dedupe)
        if config.dedupe:
            em = np.asarray(graph.edge_mask)
            if em.any():
                self.ingestor.seed_live_edges(np.asarray(graph.src)[em],
                                              np.asarray(graph.dst)[em])
        self.tracker: QualityTracker = init_tracker(graph, self.state.assignment,
                                                    config.k)
        self.telemetry: List[SuperstepRecord] = []
        self._superstep = 0
        self._place_key = jax.random.PRNGKey(config.seed ^ 0x5EED)
        cfg = config
        self._adapt = jax.jit(lambda g, st: adapt_jit(
            g, st, s=cfg.s, iters=cfg.adapt_iters, tie_break=cfg.tie_break))
        # optional interleaved vertex program (think-like-a-vertex compute)
        self.program = program
        self.program_state: Optional[jax.Array] = None
        if program is not None:
            self.program_state = program.init(graph)

            def _prog_step(before_mask, g, st, step):
                # vertices born this superstep enter with their init state
                born = g.node_mask & ~before_mask
                st = jnp.where(born[:, None], program.init(g), st)
                return program_superstep(program, g, st, step)

            self._prog_step = jax.jit(_prog_step)
            self._msg_volume = jax.jit(
                lambda g, lab: message_volume(g, lab, program.state_dim))

    # -- one superstep ------------------------------------------------------
    def superstep(self, events: np.ndarray, now: int) -> SuperstepRecord:
        cfg = self.config
        t_start = time.perf_counter()

        # 1. INGEST: vectorized batch → one padded GraphDelta
        delta, istats = self.ingestor.ingest(events, now)
        t_ingest = time.perf_counter() - t_start

        # 2. APPLY + PLACE: grow/shrink the graph, place arrivals online.
        # A provably empty delta skips the device pipeline entirely (quiet
        # stream gaps would otherwise pay full-graph scatters for no-ops).
        before = self.graph
        labels_before = self.state.assignment
        if istats.adds_out == 0 and istats.dels_out == 0:
            after = before
            labels_placed = labels_before
            new_placed = 0
        else:
            after = apply_delta(before, delta)
            if cfg.placement == "online":
                self._place_key, sub = jax.random.split(self._place_key)
                labels_placed, pstats = place_delta(
                    delta, before.node_mask, labels_before,
                    self.tracker.occupancy, self.state.capacity, sub,
                    k=cfg.k, passes=cfg.placement_passes)
                new_placed = int(pstats.placed)
            else:
                labels_placed = labels_before
                new_placed = int(jnp.sum(~before.node_mask & after.node_mask))

            # 3. MEASURE the ingest: incremental cut/occupancy from diffs only
            self.tracker, _ = delta_update(self.tracker, before, after,
                                           labels_before, labels_placed)

        # 4. ADAPT: interleaved xDGP migration rounds on the new graph
        state = dataclasses.replace(self.state, assignment=labels_placed)
        state = self._adapt(after, state)
        self.tracker, moved = move_update(self.tracker, after,
                                          labels_placed, state.assignment)

        self.graph = after
        self.state = state
        self._superstep += 1

        # dedupe mode models the live edge set exactly, which makes e_cap
        # exhaustion detectable: apply_delta drops additions silently once
        # free slots run out, and the mirror would drift forever after
        if cfg.dedupe and self.ingestor.live_edge_count != int(self.tracker.edges):
            raise RuntimeError(
                f"edge capacity exhausted at superstep {self._superstep}: "
                f"graph holds {int(self.tracker.edges)} live edges but "
                f"{self.ingestor.live_edge_count} were released "
                f"(e_cap={after.e_cap}); increase e_cap or lower a_cap")

        # 5. COMPUTE: one BSP superstep of the vertex program on the adapted
        # graph; its message traffic under the current assignment is the
        # paper's execution-time driver (§5.3: remote messages dominate).
        local_bytes = remote_bytes = 0
        compute_seconds = 0.0
        if self.program is not None:
            t_c = time.perf_counter()
            self.program_state = self._prog_step(
                before.node_mask, after, self.program_state,
                jnp.asarray(self._superstep, jnp.int32))
            self.program_state.block_until_ready()
            compute_seconds = time.perf_counter() - t_c
            lb, rb = self._msg_volume(after, state.assignment)
            local_bytes, remote_bytes = int(lb), int(rb)

        # 6. DRIFT CHECK: periodic full recompute validates the tracker
        drift = None
        if cfg.recompute_every and self._superstep % cfg.recompute_every == 0:
            self.tracker, drift = drift_check(self.tracker, after, state.assignment)

        record = SuperstepRecord(
            superstep=self._superstep, now=int(now),
            events=int(np.asarray(events).shape[0]) if np.asarray(events).size else 0,
            adds=istats.adds_out, dels=istats.dels_out,
            backlog_adds=istats.adds_backlog, backlog_dels=istats.dels_backlog,
            invalid_events=istats.invalid, stale_dropped=istats.stale_dropped,
            new_placed=new_placed, migrations=int(moved),
            cut_edges=int(self.tracker.cut), live_edges=int(self.tracker.edges),
            cut_ratio=float(cut_ratio_of(self.tracker)),
            imbalance=float(imbalance_of(self.tracker)),
            ingest_seconds=t_ingest,
            step_seconds=time.perf_counter() - t_start,
            drift=drift,
            dup_dropped=istats.dup_dropped,
            local_bytes=local_bytes, remote_bytes=remote_bytes,
            compute_seconds=compute_seconds,
        )
        self.telemetry.append(record)
        return record

    # -- windowed replay of a whole stream ---------------------------------
    def run_stream(self, times: np.ndarray, src: np.ndarray, dst: np.ndarray,
                   batch_span: int,
                   max_supersteps: Optional[int] = None) -> List[SuperstepRecord]:
        """Replay a (t, u, v) stream window-by-window through the engine."""
        out: List[SuperstepRecord] = []
        for now, events in stream_batches(times, src, dst, batch_span):
            out.append(self.superstep(events, now))
            if max_supersteps is not None and len(out) >= max_supersteps:
                break
        return out

    def drain_backlog(self, now: int, max_supersteps: int = 64,
                      ) -> List[SuperstepRecord]:
        """Flush capacity-deferred changes with empty-input supersteps."""
        out: List[SuperstepRecord] = []
        empty = np.empty((0, 3), np.int64)
        while len(self.ingestor.buffer) and len(out) < max_supersteps:
            out.append(self.superstep(empty, now))
        return out
