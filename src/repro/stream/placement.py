"""Online vertex placement for arriving stream deltas (streaming layer §2).

In the seed, a vertex that arrives mid-stream inherits whatever partition
label the padded-slot hash assigned at startup — effectively random — and
the migration heuristic has to undo that damage over many supersteps. This
module places arriving vertices *at ingest time* with a jit-compatible
Fennel/DGR-style streaming rule:

    score(v, j) = |N(v) ∩ P_j| · (1 − occ_j / C_j)        (greedy · balance)

computed only from the delta's own edges plus the current assignment, so the
whole placer is one fused device program over static shapes (a_cap, n_cap, k).
A small number of refinement passes lets new vertices that only connect to
*other new vertices* see their neighbours' tentative labels (the streaming
equivalent of DGR's sequential scan, without the sequential dependency).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.migration import _rank_within_group
from repro.graph.structure import GraphDelta


class PlacementStats(NamedTuple):
    placed: jax.Array          # () int32 — vertices placed by this call
    with_anchor: jax.Array     # () int32 — placed vertices that had ≥1 placed neighbour
    intra_edges: jax.Array     # () int32 — delta edges made intra-partition


@partial(jax.jit, static_argnames=("k", "passes"))
def place_delta(delta: GraphDelta, node_mask: jax.Array, assignment: jax.Array,
                occupancy: jax.Array, capacity: jax.Array, rng: jax.Array,
                *, k: int, passes: int = 2,
                ) -> Tuple[jax.Array, PlacementStats]:
    """Assign partitions to vertices arriving in ``delta``.

    Args:
      node_mask:  liveness *before* the delta is applied — endpoints outside
                  it are the arriving vertices to place.
      assignment: (n_cap,) current labels (old vertices keep theirs).
      occupancy:  (k,) live-vertex count per partition before the delta.
      capacity:   (k,) hard per-partition capacity.

    Returns the updated assignment and placement stats.
    """
    n_cap = node_mask.shape[0]
    a_cap = delta.add_mask.shape[0]

    su = jnp.clip(delta.add_src, 0, n_cap - 1)
    sv = jnp.clip(delta.add_dst, 0, n_cap - 1)
    m = delta.add_mask

    # arriving vertices: delta endpoints not live before the delta
    is_new = jnp.zeros((n_cap,), bool)
    is_new = is_new.at[jnp.where(m, su, 0)].max(m & ~node_mask[su], mode="drop")
    is_new = is_new.at[jnp.where(m, sv, 0)].max(m & ~node_mask[sv], mode="drop")

    # symmetrised delta edges (the only adjacency the placer may use)
    e_src = jnp.concatenate([su, sv])
    e_dst = jnp.concatenate([sv, su])
    e_ok = jnp.concatenate([m, m]) & (e_src != e_dst)

    labels = assignment.astype(jnp.int32)
    noise = jax.random.uniform(rng, (n_cap, k)) * 1e-3   # spread ties across parts

    def one_pass(labels: jax.Array, include_new: bool) -> jax.Array:
        # neighbour-partition counts for new vertices, from placed endpoints
        placed_src = e_ok & (node_mask[e_src] | include_new)
        seg = jnp.where(placed_src & is_new[e_dst], e_dst, n_cap)
        onehot = jax.nn.one_hot(labels[e_src], k, dtype=jnp.int32)
        counts = jax.ops.segment_sum(onehot * placed_src[:, None].astype(jnp.int32),
                                     seg, num_segments=n_cap + 1)[:n_cap]
        # occupancy including tentative placements of new vertices
        if include_new:
            occ_new = jnp.sum(jax.nn.one_hot(labels, k, dtype=jnp.int32)
                              * is_new[:, None].astype(jnp.int32), axis=0)
        else:
            occ_new = 0
        occ_eff = occupancy + occ_new
        room = occ_eff < capacity
        balance = 1.0 - occ_eff / jnp.maximum(capacity, 1).astype(jnp.float32)
        score = counts.astype(jnp.float32) * balance[None, :]
        # zero-count fallback: least-loaded partition (scaled below any real count)
        score = score + 1e-2 * balance[None, :] + noise
        score = jnp.where(room[None, :], score, -jnp.inf)
        best = jnp.argmax(score, axis=1).astype(jnp.int32)
        all_full = ~jnp.any(room)
        best = jnp.where(all_full, jnp.argmin(occ_eff).astype(jnp.int32), best)
        return jnp.where(is_new, best, labels)

    labels = one_pass(labels, include_new=False)
    for _ in range(max(passes - 1, 0)):
        labels = one_pass(labels, include_new=True)

    # hard-capacity admission: arrivals choosing the same partition are
    # ranked deterministically; those beyond its free room spill across the
    # remaining free slots of all partitions (prefix-sum assignment), so
    # capacity holds whenever total arrivals ≤ total free room. Beyond that
    # the residue lands in the last partition — there is nowhere legal left.
    free = jnp.maximum(capacity - occupancy, 0)
    chosen = jnp.clip(labels, 0, k - 1)
    rank = _rank_within_group(chosen, is_new)
    over = is_new & (rank >= free[chosen])
    adm_seg = jnp.where(is_new & ~over, chosen, k)
    admitted = jax.ops.segment_sum(jnp.ones_like(chosen), adm_seg,
                                   num_segments=k + 1)[:k]
    room_left = jnp.maximum(free - admitted, 0)
    spill_rank = _rank_within_group(jnp.zeros_like(chosen), over)
    spill_to = jnp.searchsorted(jnp.cumsum(room_left), spill_rank, side="right")
    spill_to = jnp.clip(spill_to, 0, k - 1).astype(jnp.int32)
    labels = jnp.where(over, spill_to, labels)

    # stats: anchored placements + intra-partition delta edges
    anchor_seg = jnp.where(e_ok & node_mask[e_src] & is_new[e_dst], e_dst, n_cap)
    anchored = jax.ops.segment_max(
        jnp.ones((2 * a_cap,), jnp.int32), anchor_seg, num_segments=n_cap + 1)[:n_cap]
    stats = PlacementStats(
        placed=jnp.sum(is_new).astype(jnp.int32),
        with_anchor=jnp.sum((anchored > 0) & is_new).astype(jnp.int32),
        intra_edges=jnp.sum((labels[e_src] == labels[e_dst]) & e_ok).astype(jnp.int32) // 2,
    )
    return jnp.where(is_new, labels, assignment.astype(jnp.int32)), stats
