"""Streaming ingestion engine: vectorized ingest → online placement →
interleaved adaptation → incremental quality metrics (ROADMAP: serve heavy
dynamic-graph traffic as fast as the hardware allows)."""
from repro.stream.ingest import (EdgeStreamBuffer, IngestStats, WindowIngestor,
                                 WindowTracker, build_delta, stream_batches)
from repro.stream.placement import PlacementStats, place_delta
from repro.stream.metrics import (DeltaStats, QualityTracker, cut_ratio_of,
                                  delta_update, drift_check, imbalance_of,
                                  init_tracker, move_update)
from repro.stream.engine import StreamConfig, StreamEngine, SuperstepRecord

__all__ = [
    "EdgeStreamBuffer", "IngestStats", "WindowIngestor", "WindowTracker",
    "build_delta", "stream_batches",
    "PlacementStats", "place_delta",
    "DeltaStats", "QualityTracker", "cut_ratio_of", "delta_update",
    "drift_check", "imbalance_of", "init_tracker", "move_update",
    "StreamConfig", "StreamEngine", "SuperstepRecord",
]
