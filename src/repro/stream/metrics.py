"""Incremental partition-quality maintenance (streaming layer §3).

The seed recomputed ``cut_ratio`` — a full scan plus the O(E·k) neighbour
count — from scratch every superstep. Here the engine carries a
``QualityTracker`` (cut edges, live edges, per-partition occupancy) and
updates it from *diffs only*:

* ``delta_update``  — after ``apply_delta`` + placement: added/removed cut
  edges from the changed edge slots, occupancy from born/died vertices.
* ``move_update``   — after an adaptation round: cut change restricted to
  edges incident to moved vertices (moves × boundary-degree), occupancy from
  the moved labels.

Both updates are exact (integer arithmetic over masked diffs), so the
tracker matches a full recompute bit-for-bit; ``drift_check`` verifies that
periodically and resyncs, guarding against any future approximation.

Invariant maintained throughout:
    tracker.cut_edges  == cut_edges(graph, assignment)
    tracker.live_edges == graph.num_edges
    tracker.occupancy  == occupancy(assignment | node_mask)

``delta_update`` relies on placement only relabelling vertices that were
dead before the delta (surviving edges keep both endpoint labels, so their
cut contribution cannot change); ``place_delta`` guarantees exactly that.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.graph.structure import Graph, cut_edges


class QualityTracker(NamedTuple):
    cut: jax.Array           # () int32 — live cut edges
    edges: jax.Array         # () int32 — live edges
    occupancy: jax.Array     # (k,) int32 — live vertices per partition


class DeltaStats(NamedTuple):
    added_cut: jax.Array     # cut edges introduced by the delta
    removed_cut: jax.Array   # cut edges retired by the delta
    born: jax.Array          # vertices that became live
    died: jax.Array          # vertices that expired


def _occ(assignment: jax.Array, node_mask: jax.Array, k: int) -> jax.Array:
    seg = jnp.where(node_mask, assignment, k)
    return jax.ops.segment_sum(jnp.ones_like(seg), seg, num_segments=k + 1)[:k]


@partial(jax.jit, static_argnames=("k",))
def init_tracker(graph: Graph, assignment: jax.Array, k: int) -> QualityTracker:
    """Full O(E) computation — used once at startup and at drift resyncs."""
    return QualityTracker(
        cut=cut_edges(graph, assignment).astype(jnp.int32),
        edges=graph.num_edges.astype(jnp.int32),
        occupancy=_occ(assignment.astype(jnp.int32), graph.node_mask, k),
    )


def _cross(src: jax.Array, dst: jax.Array, assignment: jax.Array) -> jax.Array:
    n_cap = assignment.shape[0]
    a = assignment[jnp.clip(src, 0, n_cap - 1)]
    b = assignment[jnp.clip(dst, 0, n_cap - 1)]
    return a != b


@jax.jit
def delta_update(tracker: QualityTracker, before: Graph, after: Graph,
                 labels_before: jax.Array, labels_after: jax.Array,
                 ) -> Tuple[QualityTracker, DeltaStats]:
    """Fold one ingest superstep (apply_delta + placement) into the tracker.

    ``labels_before`` is the assignment when ``before`` was current;
    ``labels_after`` additionally carries the online placement of vertices
    born in this delta. Edge slots are compared content-wise so slot reuse
    (a retired slot refilled by a new edge in the same delta) is counted as
    one removal plus one addition.
    """
    same = (before.src == after.src) & (before.dst == after.dst)
    removed = before.edge_mask & (~after.edge_mask | ~same)
    added = after.edge_mask & (~before.edge_mask | ~same)

    removed_cut = jnp.sum(removed & _cross(before.src, before.dst, labels_before))
    added_cut = jnp.sum(added & _cross(after.src, after.dst, labels_after))

    born = ~before.node_mask & after.node_mask
    died = before.node_mask & ~after.node_mask
    k = tracker.occupancy.shape[0]
    occ = (tracker.occupancy
           + _occ(labels_after.astype(jnp.int32), born, k)
           - _occ(labels_before.astype(jnp.int32), died, k))

    new = QualityTracker(
        cut=(tracker.cut + added_cut - removed_cut).astype(jnp.int32),
        edges=(tracker.edges + jnp.sum(added) - jnp.sum(removed)).astype(jnp.int32),
        occupancy=occ.astype(jnp.int32),
    )
    stats = DeltaStats(added_cut=added_cut.astype(jnp.int32),
                       removed_cut=removed_cut.astype(jnp.int32),
                       born=jnp.sum(born).astype(jnp.int32),
                       died=jnp.sum(died).astype(jnp.int32))
    return new, stats


@jax.jit
def move_update(tracker: QualityTracker, graph: Graph,
                labels_before: jax.Array, labels_after: jax.Array,
                ) -> Tuple[QualityTracker, jax.Array]:
    """Fold an adaptation round into the tracker: O(moves × boundary degree).

    The cut can only change on edges incident to a moved vertex, so the diff
    is restricted to that boundary set.
    """
    n_cap = graph.n_cap
    moved = (labels_before != labels_after) & graph.node_mask
    touched = (moved[jnp.clip(graph.src, 0, n_cap - 1)]
               | moved[jnp.clip(graph.dst, 0, n_cap - 1)]) & graph.edge_mask
    before_cut = jnp.sum(touched & _cross(graph.src, graph.dst, labels_before))
    after_cut = jnp.sum(touched & _cross(graph.src, graph.dst, labels_after))

    k = tracker.occupancy.shape[0]
    occ = (tracker.occupancy
           + _occ(labels_after.astype(jnp.int32), moved, k)
           - _occ(labels_before.astype(jnp.int32), moved, k))
    new = QualityTracker(
        cut=(tracker.cut + after_cut - before_cut).astype(jnp.int32),
        edges=tracker.edges,
        occupancy=occ.astype(jnp.int32),
    )
    return new, jnp.sum(moved).astype(jnp.int32)


def cut_ratio_of(tracker: QualityTracker) -> jax.Array:
    return tracker.cut / jnp.maximum(tracker.edges, 1)


def imbalance_of(tracker: QualityTracker) -> jax.Array:
    occ = tracker.occupancy
    mean = jnp.maximum(jnp.sum(occ) / occ.shape[0], 1)
    return jnp.max(occ) / mean


def drift_check(tracker: QualityTracker, graph: Graph, assignment: jax.Array,
                ) -> Tuple[QualityTracker, float]:
    """Compare the tracker against a full recompute; resync and report drift."""
    k = tracker.occupancy.shape[0]
    fresh = init_tracker(graph, assignment, k)
    drift = float(jnp.abs(tracker.cut - fresh.cut)
                  + jnp.abs(tracker.edges - fresh.edges)
                  + jnp.sum(jnp.abs(tracker.occupancy - fresh.occupancy)))
    return fresh, drift
