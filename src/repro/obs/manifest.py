"""Run manifests: the provenance block every result file carries
(DESIGN.md §11).

A committed benchmark number is only citable if the environment that
produced it is recorded next to it.  ``run_manifest()`` captures the facts
that change results — git sha, jax/jaxlib versions, device kind and count,
the resolved kernel executor — plus a UTC timestamp and (optionally) a
stable hash of the ``SystemConfig`` that drove the run.
``benchmarks.common.save`` attaches one to every payload automatically.
"""
from __future__ import annotations

import datetime
import hashlib
import json
import platform
import subprocess
import sys
from typing import Any, Dict, Optional

MANIFEST_VERSION = 1


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    """Current commit sha (+ ``-dirty`` suffix), None outside a repo."""
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"], cwd=cwd,
                             capture_output=True, text=True, timeout=10)
        if sha.returncode != 0:
            return None
        dirty = subprocess.run(["git", "status", "--porcelain"], cwd=cwd,
                               capture_output=True, text=True, timeout=10)
        suffix = "-dirty" if dirty.returncode == 0 and dirty.stdout.strip() \
            else ""
        return sha.stdout.strip() + suffix
    except (OSError, subprocess.SubprocessError):
        return None


def config_hash(config: Any) -> Optional[str]:
    """Stable short hash of a ``SystemConfig`` (or any ``to_dict`` object /
    plain dict) — two runs with the same hash ran the same knobs."""
    if config is None:
        return None
    d = config.to_dict() if hasattr(config, "to_dict") else config
    blob = json.dumps(d, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def run_manifest(config: Any = None, **extra: Any) -> Dict[str, Any]:
    """The provenance block: environment facts that make a number citable.

    Imports jax lazily so manifest writing works (with nulled device
    fields) even where jax failed to initialise.
    """
    out: Dict[str, Any] = {
        "manifest_version": MANIFEST_VERSION,
        "git_sha": git_sha(),
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    try:
        import jax
        import jaxlib
        from repro import compat
        dev = jax.devices()[0]
        out.update(
            jax_version=jax.__version__,
            jaxlib_version=jaxlib.__version__,
            backend=jax.default_backend(),
            device_kind=getattr(dev, "device_kind", str(dev)),
            device_count=jax.device_count(),
            pallas_executor=compat.pallas_executor(),
        )
    except Exception as e:                           # pragma: no cover
        out.update(jax_version=None, jaxlib_version=None, backend=None,
                   device_kind=None, device_count=0,
                   pallas_executor=None, jax_error=repr(e))
    h = config_hash(config)
    if h is not None:
        out["config_hash"] = h
    out.update(extra)
    return out
