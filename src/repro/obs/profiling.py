"""Kernel profiling hooks + roofline cost estimates for migration plans
(DESIGN.md §11).

Two complementary views of the fused migration kernels
(``repro.kernels.migration_kernels``):

* ``kernel_profile(logdir)`` — optional ``jax.profiler`` capture around a
  region (XPlane/TensorBoard format; on TPU this is the real per-kernel
  timeline).  Profiling is strictly opt-in and failure-tolerant: hosts
  without a working profiler get a disabled no-op capture, never a crash
  on the hot path.
* ``plan_cost(plan, graph, k)`` — an analytic FLOP/byte bill of one fused
  score/select pass over a ``MigrationPlan``, per packing kind, with the
  same peak numbers ``benchmarks/roofline.py`` uses (imported from here so
  the constants have one home).  Comparing a measured ``kernel/score``
  span against ``t_bound`` says how far the kernel sits from the roofline.
"""
from __future__ import annotations

import contextlib
import sys
from typing import Any, Dict, Iterator, Optional

import numpy as np

# Roofline peaks (TPU v5e) — single source of truth, re-exported by
# benchmarks/roofline.py.
PEAK_FLOPS = 197e12           # bf16 FLOP/s per chip
HBM_BW = 819e9                # HBM bytes/s per chip
ICI_BW = 50e9                 # bytes/s per ICI link (conservative)


@contextlib.contextmanager
def kernel_profile(logdir: Optional[str],
                   enabled: bool = True) -> Iterator[Dict[str, Any]]:
    """Optional ``jax.profiler`` capture around a region.

    Yields a status dict: ``{"enabled": bool, "logdir": ..., "error": ...}``.
    Disabled (``logdir=None`` / ``enabled=False``) or failing captures are
    no-ops — profiling must never take down the run it observes.
    """
    status: Dict[str, Any] = {"enabled": False, "logdir": logdir,
                              "error": None}
    if not enabled or logdir is None:
        yield status
        return
    try:
        import jax
        jax.profiler.start_trace(logdir)
        status["enabled"] = True
    except Exception as e:                           # pragma: no cover
        status["error"] = repr(e)
        yield status
        return
    try:
        yield status
    finally:
        try:
            jax.profiler.stop_trace()
        except Exception as e:                       # pragma: no cover
            status["error"] = repr(e)


def _proc_status_kb(field: str) -> Optional[int]:
    """One ``/proc/self/status`` field in kB (Linux; None elsewhere)."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith(field + ":"):
                    return int(line.split()[1])
    except OSError:
        pass
    return None


def current_rss_bytes() -> Optional[int]:
    """Resident set size right now (``VmRSS``); None off-Linux."""
    kb = _proc_status_kb("VmRSS")
    return None if kb is None else kb * 1024


def peak_rss_bytes() -> int:
    """Peak resident set size of this process, in bytes.

    The scale tier's headline memory number (DESIGN.md §14): a monotonic
    high-water mark, so a bounded-memory claim holds iff this stays flat
    while |V| grows.  Source: ``VmHWM`` from ``/proc/self/status`` where
    available, else ``getrusage`` (kB on Linux, bytes on macOS)."""
    kb = _proc_status_kb("VmHWM")
    if kb is not None:
        return kb * 1024
    try:
        import resource
        peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        return int(peak if sys.platform == "darwin" else peak * 1024)
    except Exception:                                # pragma: no cover
        return 0


def memory_probe() -> Dict[str, Any]:
    """One host-memory sample for manifests and per-row bench records."""
    return {"peak_rss_bytes": peak_rss_bytes(),
            "current_rss_bytes": current_rss_bytes()}


def _live_edges(graph: Any) -> int:
    return int(np.asarray(graph.edge_mask).sum())


def plan_cost(plan: Any, graph: Any, k: int,
              label_bytes: int = 4) -> Dict[str, Any]:
    """Analytic cost of one fused score/select pass over ``plan``.

    Counts the histogram (the dominant term) plus the (n, k) epilogue, per
    packing kind (DESIGN.md §9):

      flat — scatter-adds over the 2E symmetrised COO edges;
      ell  — dense gather+compare over the (n_cap, deg_cap) pad;
      bsr  — blk×blk×k MXU dots per nonzero tile.

    Returns flops / hbm_bytes plus the roofline terms ``t_compute`` /
    ``t_memory`` (seconds at peak), their max ``t_bound``, the dominant
    side, and the arithmetic intensity — directly comparable to a measured
    ``kernel/score`` span and to ``benchmarks/roofline.py`` cells.
    """
    n_cap = int(graph.n_cap)
    e2 = 2 * _live_edges(graph)
    epilogue_flops = 4.0 * n_cap * k          # argmax/gain/select epilogue
    epilogue_bytes = float(n_cap * k * label_bytes)
    kind = plan.kind if plan is not None else "flat"
    if kind == "bsr":
        nnzb, blk, _ = plan.blocks.shape
        flops = 2.0 * nnzb * blk * blk * k + epilogue_flops
        hbm = (nnzb * blk * blk * 4.0          # adjacency tiles (f32)
               + nnzb * blk * label_bytes      # column-block labels
               + epilogue_bytes)
        shape = {"nnzb": int(nnzb), "blk": int(blk),
                 "max_per_row": int(plan.max_per_row)}
    elif kind == "ell":
        n_rows, deg_cap = plan.nbrs.shape
        flops = 2.0 * n_rows * deg_cap * k + epilogue_flops
        hbm = (n_rows * deg_cap * 2.0 * label_bytes   # nbr ids + their labels
               + epilogue_bytes)
        shape = {"rows": int(n_rows), "deg_cap": int(deg_cap)}
    elif kind == "flat":
        flops = 2.0 * e2 + epilogue_flops
        hbm = (e2 * 3.0 * label_bytes          # src, dst, gathered labels
               + epilogue_bytes)
        shape = {"edges2": int(e2)}
    else:
        raise ValueError(f"unknown plan kind {kind!r}")
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_bound = max(t_compute, t_memory)
    return {
        "kind": kind, "k": int(k), "n_cap": n_cap, "live_edges2": e2,
        "flops": float(flops), "hbm_bytes": float(hbm),
        "intensity_flops_per_byte": float(flops / max(hbm, 1.0)),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_bound_s": t_bound,
        "dominant": "compute" if t_compute >= t_memory else "memory",
        **shape,
    }
