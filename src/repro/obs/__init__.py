"""Observability layer: span tracing, metrics, profiling, provenance.

See DESIGN.md §11.  Everything here is off the hot path unless
``SystemConfig.telemetry.trace`` / ``.metrics`` turns it on — the session
holds ``NULL_TRACER`` otherwise, whose hooks are constant-time no-ops.
"""
from repro.obs.manifest import config_hash, git_sha, run_manifest
from repro.obs.metrics import (MetricsRegistry, record_cluster,
                               record_superstep)
from repro.obs.profiling import (HBM_BW, ICI_BW, PEAK_FLOPS, kernel_profile,
                                 plan_cost)
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Span",
    "MetricsRegistry", "record_superstep", "record_cluster",
    "kernel_profile", "plan_cost", "PEAK_FLOPS", "HBM_BW", "ICI_BW",
    "run_manifest", "git_sha", "config_hash",
]
