"""Trace report CLI: ``python -m repro.obs.report <trace.jsonl>``.

Summarises a span trace into a per-phase table (count, total, mean, share
of traced wall time).  With two trace files it prints them side by side
plus the per-phase ratio — the local-vs-sharded comparison the
``bench_distributed_e2e`` deliverable is built on.  ``--json`` emits the
same aggregation as machine-readable JSON.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

from repro.obs.schema import validate_trace_file


def summarize(events: List[Dict[str, Any]]) -> Dict[str, Dict[str, float]]:
    """Per-span-name aggregation (mirrors ``Tracer.phase_totals``)."""
    out: Dict[str, Dict[str, float]] = {}
    for ev in events:
        if ev.get("type") != "span":
            continue
        row = out.setdefault(ev["name"],
                             {"count": 0, "total_s": 0.0, "mean_s": 0.0})
        row["count"] += 1
        row["total_s"] += ev["dur_us"] / 1e6
    for row in out.values():
        row["mean_s"] = row["total_s"] / max(row["count"], 1)
    return out


def _top_level_total(events: List[Dict[str, Any]]) -> float:
    """Sum of depth-0 spans — the traced wall time shares are against."""
    return sum(ev["dur_us"] / 1e6 for ev in events
               if ev.get("type") == "span" and ev.get("depth") == 0)


def _fmt_s(v: float) -> str:
    if v >= 1.0:
        return f"{v:8.3f}s"
    return f"{v * 1e3:7.2f}ms"


def render(summary: Dict[str, Dict[str, float]], wall_s: float,
           label: str = "trace") -> str:
    lines = [f"# {label}  (traced wall {wall_s:.3f}s)",
             f"{'phase':<28} {'count':>6} {'total':>9} {'mean':>9} "
             f"{'share':>6}"]
    for name, row in sorted(summary.items(),
                            key=lambda kv: -kv[1]["total_s"]):
        share = row["total_s"] / wall_s * 100 if wall_s > 0 else 0.0
        lines.append(f"{name:<28} {row['count']:>6d} "
                     f"{_fmt_s(row['total_s'])} {_fmt_s(row['mean_s'])} "
                     f"{share:5.1f}%")
    return "\n".join(lines)


def render_compare(a: Dict[str, Dict[str, float]], wall_a: float,
                   b: Dict[str, Dict[str, float]], wall_b: float,
                   label_a: str, label_b: str) -> str:
    names = sorted(set(a) | set(b),
                   key=lambda n: -(b.get(n, a.get(n))["total_s"]))
    lines = [f"# {label_a} ({wall_a:.3f}s)  vs  {label_b} ({wall_b:.3f}s)"
             f"  —  overall ×{wall_b / wall_a:.2f}" if wall_a > 0 else
             f"# {label_a}  vs  {label_b}",
             f"{'phase':<28} {label_a:>10} {label_b:>10} {'ratio':>7}"]
    for name in names:
        ta = a.get(name, {}).get("total_s", 0.0)
        tb = b.get(name, {}).get("total_s", 0.0)
        ratio = f"x{tb / ta:6.2f}" if ta > 0 else "     —"
        lines.append(f"{name:<28} {_fmt_s(ta):>10} {_fmt_s(tb):>10} "
                     f"{ratio:>7}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarise a repro trace JSONL into a per-phase table.")
    p.add_argument("trace", help="trace JSONL file (Tracer.write_jsonl)")
    p.add_argument("other", nargs="?", default=None,
                   help="second trace to compare against (e.g. sharded)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit the aggregation as JSON instead of a table")
    args = p.parse_args(argv)

    events = validate_trace_file(args.trace)
    summary = summarize(events)
    wall = _top_level_total(events)

    if args.other is None:
        if args.as_json:
            print(json.dumps({"trace": args.trace, "wall_s": wall,
                              "phases": summary}, indent=1))
        else:
            print(render(summary, wall, label=args.trace))
        return 0

    events_b = validate_trace_file(args.other)
    summary_b = summarize(events_b)
    wall_b = _top_level_total(events_b)
    if args.as_json:
        print(json.dumps({
            "a": {"trace": args.trace, "wall_s": wall, "phases": summary},
            "b": {"trace": args.other, "wall_s": wall_b,
                  "phases": summary_b},
        }, indent=1))
    else:
        print(render_compare(summary, wall, summary_b, wall_b,
                             label_a=args.trace, label_b=args.other))
    return 0


if __name__ == "__main__":
    sys.exit(main())
