"""Metrics registry: counters / gauges / histograms over the runtime
(DESIGN.md §11).

``SuperstepRecord`` already carries the per-superstep facts (halo bytes,
collective bytes, migrations, backlog, …) as ad-hoc dataclass fields, and
``snapshot()["cluster"]`` carries the per-device comm bill — but neither is
a time series a scrape can consume.  This module unifies them behind one
registry:

    reg = MetricsRegistry(namespace="repro")
    reg.counter("events_total").inc(128)
    reg.gauge("cut_ratio").set(0.21)
    reg.histogram("step_seconds").observe(0.04)

``record_superstep`` maps a ``SuperstepRecord`` onto the registry (the one
place the mapping lives, snapshot-tested so exporters fail loudly instead
of drifting), and ``record_cluster`` maps the per-device stats with a
``device`` label.  Two exports:

* ``write_jsonl(path)``  — one sample per line plus a ``meta`` header
  (validated by ``repro.obs.schema``);
* ``to_prometheus()``    — Prometheus text exposition format (the serving
  layer's scrape endpoint body).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Optional, Tuple

METRICS_SCHEMA_VERSION = 1

# default histogram buckets: wall-clock seconds, log-ish spaced
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0)

LabelKey = Tuple[Tuple[str, str], ...]


def _labelkey(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _labelstr(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    """Monotonically increasing total, optionally per label set."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.values: Dict[LabelKey, float] = {}

    def inc(self, value: float = 1.0, **labels: Any) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc({value}))")
        key = _labelkey(labels)
        self.values[key] = self.values.get(key, 0.0) + value

    def samples(self) -> Iterable[Tuple[str, LabelKey, float]]:
        for key, v in sorted(self.values.items()):
            yield self.name, key, v


class Gauge:
    """Point-in-time value, optionally per label set."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels: Any) -> None:
        self.values[_labelkey(labels)] = float(value)

    def samples(self) -> Iterable[Tuple[str, LabelKey, float]]:
        for key, v in sorted(self.values.items()):
            yield self.name, key, v


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: le-bounded)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(buckets))
        self.counts: Dict[LabelKey, List[int]] = {}
        self.sums: Dict[LabelKey, float] = {}
        self.totals: Dict[LabelKey, int] = {}

    def observe(self, value: float, **labels: Any) -> None:
        key = _labelkey(labels)
        counts = self.counts.setdefault(key, [0] * len(self.buckets))
        for i, le in enumerate(self.buckets):
            if value <= le:
                counts[i] += 1
        self.sums[key] = self.sums.get(key, 0.0) + float(value)
        self.totals[key] = self.totals.get(key, 0) + 1

    def quantile(self, q: float, **labels: Any) -> Optional[float]:
        """Estimated q-quantile (0 ≤ q ≤ 1) from the cumulative buckets —
        Prometheus ``histogram_quantile`` semantics: linear interpolation
        inside the first bucket whose cumulative count reaches q·total.
        Labels select one series; None when that series has no samples."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        key = _labelkey(labels)
        total = self.totals.get(key, 0)
        if total == 0:
            return None
        rank = q * total
        cum_prev, lo = 0, 0.0
        for le, c in zip(self.buckets, self.counts[key]):
            if c >= rank:
                frac = (rank - cum_prev) / max(c - cum_prev, 1)
                return lo + (le - lo) * frac
            cum_prev, lo = c, le
        return self.buckets[-1]     # beyond the last finite bucket

    def samples(self) -> Iterable[Tuple[str, LabelKey, float]]:
        for key in sorted(self.totals):
            for le, c in zip(self.buckets, self.counts[key]):
                yield (f"{self.name}_bucket", key + (("le", repr(le)),),
                       float(c))
            yield (f"{self.name}_bucket", key + (("le", "+Inf"),),
                   float(self.totals[key]))
            yield f"{self.name}_sum", key, self.sums[key]
            yield f"{self.name}_count", key, float(self.totals[key])


class MetricsRegistry:
    """Named metrics with get-or-create semantics and a fixed namespace."""

    def __init__(self, namespace: str = "repro"):
        self.namespace = namespace
        self._metrics: Dict[str, Any] = {}

    def _get(self, cls, name: str, help: str, **kw: Any):
        metric = self._metrics.get(name)
        if metric is None:
            metric = cls(f"{self.namespace}_{name}", help=help, **kw)
            self._metrics[name] = metric
        elif not isinstance(metric, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{metric.kind}, not {cls.kind}")
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._metrics))

    # -- export -------------------------------------------------------------
    def collect(self) -> List[Dict[str, Any]]:
        """Flat sample list (the JSONL body)."""
        out: List[Dict[str, Any]] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            for sample_name, key, value in m.samples():
                out.append({"type": "sample", "name": sample_name,
                            "kind": m.kind, "labels": dict(key),
                            "value": value})
        return out

    def write_jsonl(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            f.write(json.dumps({"type": "meta",
                                "schema": METRICS_SCHEMA_VERSION,
                                "namespace": self.namespace}) + "\n")
            for s in self.collect():
                f.write(json.dumps(s, default=float) + "\n")
        return path

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for sample_name, key, value in m.samples():
                lines.append(f"{sample_name}{_labelstr(key)} {value}")
        return "\n".join(lines) + "\n"

    def __repr__(self) -> str:
        return f"<MetricsRegistry {self.namespace!r} {len(self._metrics)} metrics>"


# ---------------------------------------------------------------------------
# The SuperstepRecord / cluster-stats mappings (snapshot-tested)
# ---------------------------------------------------------------------------

# SuperstepRecord fields that accumulate across supersteps → counters
_RECORD_COUNTERS = ("events", "adds", "dels", "invalid_events",
                    "stale_dropped", "dup_dropped", "new_placed",
                    "migrations", "local_bytes", "remote_bytes",
                    "halo_bytes", "halo_live_bytes", "collective_bytes")
# instantaneous state → gauges
_RECORD_GAUGES = ("superstep", "now", "backlog_adds", "backlog_dels",
                  "cut_edges", "live_edges", "cut_ratio", "imbalance")
# wall-clock phases → histograms
_RECORD_HISTOGRAMS = ("ingest_seconds", "step_seconds", "compute_seconds")


def record_superstep(reg: MetricsRegistry, record: Any,
                     **labels: Any) -> None:
    """Fold one ``SuperstepRecord`` into the registry (counters for the
    accumulating fields, gauges for state, histograms for phase seconds),
    plus the process memory high-water mark — per superstep, so a scrape of
    a long-running session shows whether host memory is staying bounded
    while the graph grows (DESIGN.md §14)."""
    for f in _RECORD_COUNTERS:
        reg.counter(f"{f}_total").inc(getattr(record, f), **labels)
    for f in _RECORD_GAUGES:
        reg.gauge(f).set(getattr(record, f), **labels)
    for f in _RECORD_HISTOGRAMS:
        reg.histogram(f).observe(getattr(record, f), **labels)
    from repro.obs.profiling import peak_rss_bytes
    reg.gauge("peak_rss_bytes").set(peak_rss_bytes(), **labels)


def record_cluster(reg: MetricsRegistry,
                   stats: Optional[Dict[str, Any]]) -> None:
    """Fold ``snapshot()["cluster"]`` into the registry with per-device
    labels (None — the local backend — is a no-op)."""
    if stats is None:
        return
    reg.gauge("cluster_devices").set(stats["devices"])
    reg.gauge("cluster_halo_slots").set(stats["halo_slots"])
    for dev, live in enumerate(stats["boundary_live_per_device"]):
        reg.gauge("cluster_boundary_live").set(live, device=dev)
    reg.gauge("cluster_halo_bytes_per_iter").set(
        stats["halo_bytes_per_iter_per_device"])
    reg.gauge("cluster_collective_bytes_per_iter").set(
        stats["collective_bytes_per_iter_per_device"])
    reg.gauge("cluster_iterations_total").set(stats["iterations_total"])
    reg.gauge("cluster_halo_bytes_total").set(stats["halo_bytes_total"])
    reg.gauge("cluster_halo_live_bytes_total").set(
        stats["halo_live_bytes_total"])
    reg.gauge("cluster_compiled_steps").set(stats["compiled_steps"])
    reg.gauge("cluster_collective_bytes_total").set(
        stats["collective_bytes_total"])
