"""Span tracing for the superstep pipeline (DESIGN.md §11).

A ``Tracer`` records wall-clock spans around the named phases of a
superstep (ingest → place → migrate → compute → commit, plus the sharded
backend's bucket/dispatch/comm/host-sync children).  Three rules keep the
numbers honest:

* **monotonic clocks** — every timestamp comes from
  ``time.perf_counter_ns`` (never ``time.time``), so NTP adjustments can't
  fold a phase negative;
* **explicit fences** — JAX dispatch is asynchronous, so a span that
  closes without a ``fence`` on the arrays it produced measures *dispatch*
  time, not device time.  ``Span.fence``/``Tracer.fence`` call
  ``jax.block_until_ready`` and are no-ops when tracing is disabled;
* **null object when disabled** — ``NULL_TRACER`` hands out one shared
  no-op span, so the instrumented hot path does no clock reads, no
  allocation and no fencing unless ``SystemConfig.telemetry.trace`` turned
  tracing on (the overhead budget is §11's <3%).

Spans nest: depth is tracked per tracer, and the Chrome export relies on
timestamp containment (Perfetto renders same-track ``X`` events as a flame
graph).  Two exports share one in-memory event list:

* ``write_jsonl(path)``  — one JSON object per line; first line is a
  ``meta`` header (schema version, clock, run manifest).  This is the file
  ``python -m repro.obs.report`` summarises and ``repro.obs.schema``
  validates.
* ``write_chrome(path)`` — Chrome ``trace_event`` JSON for
  chrome://tracing / Perfetto (``ui.perfetto.dev``).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional

import jax

TRACE_SCHEMA_VERSION = 1


class _NullSpan:
    """The shared do-nothing span ``NULL_TRACER`` hands out."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> None:
        pass

    def fence(self, *arrays: Any) -> None:
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracing disabled: every hook is a constant-time no-op.

    The session always holds *a* tracer, so the instrumented code never
    branches on "is tracing on?" — the null object absorbs the calls.
    """

    enabled = False
    events: tuple = ()

    def span(self, name: str, **attrs: Any) -> _NullSpan:
        return _NULL_SPAN

    def fence(self, *arrays: Any) -> None:
        pass

    def counter(self, name: str, value: float, **attrs: Any) -> None:
        pass

    def add_span(self, name: str, duration_s: float, **attrs: Any) -> None:
        pass

    def __repr__(self) -> str:
        return "<NullTracer (tracing disabled)>"


NULL_TRACER = NullTracer()


class Span:
    """One live span: created by ``Tracer.span``, used as a context manager."""

    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._t0 = 0

    def set(self, **attrs: Any) -> None:
        """Attach attributes to the span (recorded at exit)."""
        self.attrs.update(attrs)

    def fence(self, *arrays: Any) -> None:
        """``jax.block_until_ready`` on the span's products, so async
        dispatch cannot move their device time out of this span."""
        for a in arrays:
            jax.block_until_ready(a)

    def __enter__(self) -> "Span":
        tr = self._tracer
        tr._depth += 1
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc: Any) -> bool:
        t1 = time.perf_counter_ns()
        tr = self._tracer
        tr._depth -= 1
        tr._emit(self.name, self._t0, t1 - self._t0, tr._depth, self.attrs)
        return False


class Tracer:
    """Collects span/counter events in memory; exports JSONL + Chrome."""

    enabled = True

    def __init__(self, *, meta: Optional[Dict[str, Any]] = None):
        self._origin = time.perf_counter_ns()
        self._depth = 0
        self.meta: Dict[str, Any] = dict(meta or {})
        self.events: List[Dict[str, Any]] = []

    # -- recording ----------------------------------------------------------
    def span(self, name: str, **attrs: Any) -> Span:
        """A context-manager span; ``attrs`` land in the record at exit."""
        return Span(self, name, attrs)

    def fence(self, *arrays: Any) -> None:
        """Standalone fence (outside any span): block until ready."""
        for a in arrays:
            jax.block_until_ready(a)

    def _emit(self, name: str, t0_ns: int, dur_ns: int, depth: int,
              attrs: Dict[str, Any]) -> None:
        ev: Dict[str, Any] = {
            "type": "span", "name": name,
            "ts_us": (t0_ns - self._origin) / 1000.0,
            "dur_us": dur_ns / 1000.0,
            "depth": depth,
        }
        if attrs:
            ev["attrs"] = attrs
        self.events.append(ev)

    def add_span(self, name: str, duration_s: float, **attrs: Any) -> None:
        """Record a synthetic span ending *now* with a known duration —
        how probe-measured phases (comm decomposition) enter the trace."""
        t1 = time.perf_counter_ns()
        dur_ns = int(duration_s * 1e9)
        self._emit(name, t1 - dur_ns, dur_ns, self._depth, attrs)

    def counter(self, name: str, value: float, **attrs: Any) -> None:
        """Record a counter sample (renders as a counter track in Perfetto)."""
        ev: Dict[str, Any] = {
            "type": "counter", "name": name,
            "ts_us": (time.perf_counter_ns() - self._origin) / 1000.0,
            "value": float(value),
        }
        if attrs:
            ev["attrs"] = attrs
        self.events.append(ev)

    # -- export -------------------------------------------------------------
    def header(self) -> Dict[str, Any]:
        return {"type": "meta", "schema": TRACE_SCHEMA_VERSION,
                "clock": "perf_counter_ns", "unit": "us", **self.meta}

    def write_jsonl(self, path: str) -> str:
        """One event per line, ``meta`` header first (the report/schema
        contract — see ``repro.obs.schema``)."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            f.write(json.dumps(self.header()) + "\n")
            for ev in self.events:
                f.write(json.dumps(ev, default=float) + "\n")
        return path

    def write_chrome(self, path: str) -> str:
        """Chrome ``trace_event`` export (open in Perfetto / chrome://tracing)."""
        pid = os.getpid()
        out: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": self.meta.get("label", "repro")},
        }]
        for ev in self.events:
            if ev["type"] == "span":
                out.append({"name": ev["name"], "ph": "X", "pid": pid,
                            "tid": 0, "ts": ev["ts_us"], "dur": ev["dur_us"],
                            "args": ev.get("attrs", {})})
            elif ev["type"] == "counter":
                out.append({"name": ev["name"], "ph": "C", "pid": pid,
                            "tid": 0, "ts": ev["ts_us"],
                            "args": {"value": ev["value"]}})
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump({"traceEvents": out, "displayTimeUnit": "ms"}, f)
        return path

    # -- summaries ----------------------------------------------------------
    def phase_totals(self) -> Dict[str, Dict[str, float]]:
        """Per-span-name totals (count / total / mean seconds) — the same
        aggregation the report CLI prints, available in-process."""
        out: Dict[str, Dict[str, float]] = {}
        for ev in self.events:
            if ev["type"] != "span":
                continue
            row = out.setdefault(ev["name"],
                                 {"count": 0, "total_s": 0.0, "mean_s": 0.0})
            row["count"] += 1
            row["total_s"] += ev["dur_us"] / 1e6
        for row in out.values():
            row["mean_s"] = row["total_s"] / max(row["count"], 1)
        return out

    def __repr__(self) -> str:
        return f"<Tracer events={len(self.events)}>"
