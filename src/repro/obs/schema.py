"""Schema validation for the observability file formats (DESIGN.md §11).

The trace and metrics JSONL files are contracts: the report CLI, the CI
smoke job, and any external consumer parse them blind.  These validators
are deliberately hand-rolled (no jsonschema dependency) and strict about
the fields the consumers rely on, so an exporter drift fails the schema
tests loudly instead of silently producing unreadable artifacts.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from repro.obs.metrics import METRICS_SCHEMA_VERSION
from repro.obs.trace import TRACE_SCHEMA_VERSION


class SchemaError(ValueError):
    """A trace/metrics line violated the published schema."""


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise SchemaError(msg)


def _num(d: Dict[str, Any], key: str, line: int) -> None:
    _require(isinstance(d.get(key), (int, float))
             and not isinstance(d.get(key), bool),
             f"line {line}: {key!r} must be a number, got {d.get(key)!r}")


# ---------------------------------------------------------------------------
# trace JSONL
# ---------------------------------------------------------------------------

def validate_trace_line(obj: Dict[str, Any], line: int = 0) -> None:
    """One trace event (post-header).  Raises SchemaError on violation."""
    _require(isinstance(obj, dict), f"line {line}: not an object")
    kind = obj.get("type")
    if kind == "span":
        _require(isinstance(obj.get("name"), str) and obj["name"],
                 f"line {line}: span needs a non-empty name")
        _num(obj, "ts_us", line)
        _num(obj, "dur_us", line)
        _require(obj["dur_us"] >= 0, f"line {line}: negative dur_us")
        _num(obj, "depth", line)
        _require(obj["depth"] >= 0, f"line {line}: negative depth")
        if "attrs" in obj:
            _require(isinstance(obj["attrs"], dict),
                     f"line {line}: attrs must be an object")
    elif kind == "counter":
        _require(isinstance(obj.get("name"), str) and obj["name"],
                 f"line {line}: counter needs a non-empty name")
        _num(obj, "ts_us", line)
        _num(obj, "value", line)
    else:
        raise SchemaError(f"line {line}: unknown event type {kind!r}")


def validate_trace_header(obj: Dict[str, Any]) -> None:
    _require(isinstance(obj, dict) and obj.get("type") == "meta",
             "first line must be a meta header")
    _require(obj.get("schema") == TRACE_SCHEMA_VERSION,
             f"trace schema {obj.get('schema')!r}, "
             f"expected {TRACE_SCHEMA_VERSION}")
    _require(obj.get("clock") == "perf_counter_ns",
             f"unknown clock {obj.get('clock')!r}")
    _require(obj.get("unit") == "us", f"unknown unit {obj.get('unit')!r}")


def validate_trace_file(path: str) -> List[Dict[str, Any]]:
    """Validate a trace JSONL file; returns the parsed events (header
    excluded) so callers can validate *and* consume in one pass."""
    events: List[Dict[str, Any]] = []
    with open(path) as f:
        for i, raw in enumerate(f):
            raw = raw.strip()
            if not raw:
                continue
            try:
                obj = json.loads(raw)
            except json.JSONDecodeError as e:
                raise SchemaError(f"line {i}: invalid JSON: {e}") from e
            if i == 0:
                validate_trace_header(obj)
                continue
            validate_trace_line(obj, line=i)
            events.append(obj)
    return events


# ---------------------------------------------------------------------------
# metrics JSONL
# ---------------------------------------------------------------------------

_METRIC_KINDS = ("counter", "gauge", "histogram")


def validate_metrics_line(obj: Dict[str, Any], line: int = 0) -> None:
    _require(isinstance(obj, dict), f"line {line}: not an object")
    _require(obj.get("type") == "sample",
             f"line {line}: expected type 'sample', got {obj.get('type')!r}")
    _require(isinstance(obj.get("name"), str) and obj["name"],
             f"line {line}: sample needs a non-empty name")
    _require(obj.get("kind") in _METRIC_KINDS,
             f"line {line}: unknown metric kind {obj.get('kind')!r}")
    _require(isinstance(obj.get("labels"), dict),
             f"line {line}: labels must be an object")
    _num(obj, "value", line)


def validate_serve_bench(payload: Dict[str, Any]) -> None:
    """The serving benchmark result contract (results/bench_serve_sessions
    .json, DESIGN.md §12): headline throughput + tail latency across N
    tenants and the kill-and-recover drill outcome.  CI re-validates the
    committed file so the schema and the artifact cannot drift apart."""
    _require(isinstance(payload, dict), "serve bench: not an object")
    _require(isinstance(payload.get("tenants"), int)
             and payload["tenants"] >= 1,
             f"serve bench: 'tenants' must be a positive int, "
             f"got {payload.get('tenants')!r}")
    for key in ("events_total", "supersteps_total", "ticks"):
        _require(isinstance(payload.get(key), int) and payload[key] >= 0,
                 f"serve bench: {key!r} must be a non-negative int, "
                 f"got {payload.get(key)!r}")
    for key in ("wall_seconds", "events_per_sec",
                "ingest_p50_s", "ingest_p99_s"):
        _num(payload, key, 0)
        _require(payload[key] >= 0, f"serve bench: negative {key!r}")
    _require(payload["ingest_p99_s"] >= payload["ingest_p50_s"],
             "serve bench: p99 below p50")
    per = payload.get("per_tenant")
    _require(isinstance(per, dict) and len(per) == payload["tenants"],
             "serve bench: 'per_tenant' must map every tenant")
    for name, t in per.items():
        _require(isinstance(t, dict), f"serve bench: tenant {name!r} entry "
                 f"must be an object")
        for key in ("events", "supersteps", "rejected", "shed"):
            _require(isinstance(t.get(key), int) and t[key] >= 0,
                     f"serve bench: tenant {name!r} {key!r} must be a "
                     f"non-negative int, got {t.get(key)!r}")
    rec = payload.get("recovery")
    _require(isinstance(rec, dict), "serve bench: 'recovery' must be an "
             "object (the kill-and-recover drill outcome)")
    _num(rec, "seconds", 0)
    _require(rec.get("bit_exact") is True,
             "serve bench: recovery was not bit-exact")
    _require(isinstance(rec.get("tenants"), int)
             and rec["tenants"] == payload["tenants"],
             "serve bench: recovery must cover every tenant")


def validate_serve_bench_file(path: str) -> Dict[str, Any]:
    with open(path) as f:
        payload = json.load(f)
    validate_serve_bench(payload)
    return payload


_ARENA_ROW_NUMS = ("cut_final", "cut_mean", "imbalance_final",
                   "wall_seconds", "exec_cost_total")


def validate_arena_bench(payload: Dict[str, Any]) -> None:
    """The strategy-arena result contract (results/bench_strategy_arena
    .json, DESIGN.md §13): one row per (scenario, strategy) cell — full
    cross product, no silently missing cells — scoring cut, balance,
    migration volume, wall time and the cost-model total, plus per-scenario
    winners drawn from the swept strategies.  CI re-validates the committed
    file so the schema and the artifact cannot drift apart."""
    _require(isinstance(payload, dict), "arena bench: not an object")
    _require(payload.get("bench") == "strategy_arena",
             f"arena bench: 'bench' must be 'strategy_arena', "
             f"got {payload.get('bench')!r}")
    for key in ("scenarios", "strategies"):
        val = payload.get(key)
        _require(isinstance(val, list) and val
                 and all(isinstance(x, str) and x for x in val),
                 f"arena bench: {key!r} must be a non-empty list of names")
        _require(len(set(val)) == len(val),
                 f"arena bench: duplicate entries in {key!r} — canonical "
                 f"names only, aliases would run a strategy twice")
    scenarios = payload["scenarios"]
    strategies = payload["strategies"]
    rows = payload.get("rows")
    _require(isinstance(rows, list), "arena bench: 'rows' must be a list")
    _require(len(rows) == len(scenarios) * len(strategies),
             f"arena bench: expected {len(scenarios) * len(strategies)} rows "
             f"(full scenario x strategy cross product), got "
             f"{len(rows) if isinstance(rows, list) else rows!r}")
    seen = set()
    for i, row in enumerate(rows):
        _require(isinstance(row, dict), f"arena bench: row {i} not an object")
        _require(row.get("scenario") in scenarios,
                 f"arena bench: row {i} scenario {row.get('scenario')!r} "
                 f"not in 'scenarios'")
        _require(row.get("strategy") in strategies,
                 f"arena bench: row {i} strategy {row.get('strategy')!r} "
                 f"not in 'strategies'")
        cell = (row["scenario"], row["strategy"])
        _require(cell not in seen, f"arena bench: duplicate cell {cell}")
        seen.add(cell)
        for key in ("events", "supersteps", "migrations_total"):
            _require(isinstance(row.get(key), int) and row[key] >= 0,
                     f"arena bench: row {i} {key!r} must be a non-negative "
                     f"int, got {row.get(key)!r}")
        for key in _ARENA_ROW_NUMS:
            _num(row, key, i)
            _require(row[key] >= 0, f"arena bench: row {i} negative {key!r}")
        _require(0.0 <= row["cut_final"] <= 1.0,
                 f"arena bench: row {i} cut_final out of [0, 1]")
    winners = payload.get("winners")
    _require(isinstance(winners, dict)
             and set(winners) == set(scenarios),
             "arena bench: 'winners' must map every scenario")
    for scn, w in winners.items():
        _require(isinstance(w, dict) and w, f"arena bench: winners[{scn!r}] "
                 f"must be a non-empty object")
        for metric, strat in w.items():
            _require(strat in strategies,
                     f"arena bench: winners[{scn!r}][{metric!r}] = "
                     f"{strat!r} is not a swept strategy")


def validate_arena_bench_file(path: str) -> Dict[str, Any]:
    with open(path) as f:
        payload = json.load(f)
    validate_arena_bench(payload)
    return payload


_SCALE_ROW_NUMS = ("build_seconds", "ingest_events_per_sec",
                   "superstep_seconds", "adapt_seconds",
                   "cut_before", "cut_after")


def validate_scale_bench(payload: Dict[str, Any]) -> None:
    """The scale-sweep result contract (results/bench_scale_sweep.json,
    DESIGN.md §14): one row per (vertices, backend) cell — full cross
    product — recording end-to-end build / ingest / adapt timings and the
    host-memory high-water mark, plus the chunked-BSR outcome (packed
    stats, or the budget refusal that bounded memory).  CI re-validates
    both a fresh smoke sweep and the committed million-vertex artifact."""
    _require(isinstance(payload, dict), "scale bench: not an object")
    _require(payload.get("bench") == "scale_sweep",
             f"scale bench: 'bench' must be 'scale_sweep', "
             f"got {payload.get('bench')!r}")
    _require(isinstance(payload.get("generator"), str) and payload["generator"],
             "scale bench: 'generator' must name the edge stream")
    for key in ("k", "chunk_edges"):
        _require(isinstance(payload.get(key), int) and payload[key] >= 1,
                 f"scale bench: {key!r} must be a positive int, "
                 f"got {payload.get(key)!r}")
    sizes = payload.get("sizes")
    _require(isinstance(sizes, list) and sizes
             and all(isinstance(s, int) and s > 0 for s in sizes)
             and len(set(sizes)) == len(sizes),
             "scale bench: 'sizes' must be distinct positive vertex counts")
    backends = payload.get("backends")
    _require(isinstance(backends, list) and backends
             and all(isinstance(b, str) and b for b in backends)
             and len(set(backends)) == len(backends),
             "scale bench: 'backends' must be distinct backend names")
    rows = payload.get("rows")
    _require(isinstance(rows, list), "scale bench: 'rows' must be a list")
    _require(len(rows) == len(sizes) * len(backends),
             f"scale bench: expected {len(sizes) * len(backends)} rows "
             f"(full size x backend cross product), got "
             f"{len(rows) if isinstance(rows, list) else rows!r}")
    seen = set()
    for i, row in enumerate(rows):
        _require(isinstance(row, dict), f"scale bench: row {i} not an object")
        _require(row.get("vertices") in sizes,
                 f"scale bench: row {i} vertices {row.get('vertices')!r} "
                 f"not in 'sizes'")
        _require(row.get("backend") in backends,
                 f"scale bench: row {i} backend {row.get('backend')!r} "
                 f"not in 'backends'")
        cell = (row["vertices"], row["backend"])
        _require(cell not in seen, f"scale bench: duplicate cell {cell}")
        seen.add(cell)
        for key in ("edges", "events", "supersteps", "migrations",
                    "peak_rss_bytes"):
            _require(isinstance(row.get(key), int) and row[key] >= 0,
                     f"scale bench: row {i} {key!r} must be a non-negative "
                     f"int, got {row.get(key)!r}")
        _require(row["edges"] > 0 and row["peak_rss_bytes"] > 0,
                 f"scale bench: row {i} edges/peak_rss_bytes must be "
                 f"positive (an empty run measures nothing)")
        for key in _SCALE_ROW_NUMS:
            _num(row, key, i)
            _require(row[key] >= 0, f"scale bench: row {i} negative {key!r}")
        for key in ("cut_before", "cut_after"):
            _require(0.0 <= row[key] <= 1.0,
                     f"scale bench: row {i} {key!r} out of [0, 1]")
        bsr = row.get("bsr")
        _require(isinstance(bsr, dict), f"scale bench: row {i} 'bsr' must "
                 f"be an object (packed stats or a budget refusal)")
        if "skipped" in bsr:
            _require(isinstance(bsr["skipped"], str) and bsr["skipped"],
                     f"scale bench: row {i} bsr 'skipped' needs a reason")
        else:
            for key in ("nnzb", "blocks_bytes"):
                _require(isinstance(bsr.get(key), int) and bsr[key] >= 0,
                         f"scale bench: row {i} bsr {key!r} must be a "
                         f"non-negative int, got {bsr.get(key)!r}")
            _num(bsr, "build_seconds", i)


def validate_scale_bench_file(path: str) -> Dict[str, Any]:
    with open(path) as f:
        payload = json.load(f)
    validate_scale_bench(payload)
    return payload


def validate_metrics_file(path: str) -> List[Dict[str, Any]]:
    samples: List[Dict[str, Any]] = []
    with open(path) as f:
        for i, raw in enumerate(f):
            raw = raw.strip()
            if not raw:
                continue
            try:
                obj = json.loads(raw)
            except json.JSONDecodeError as e:
                raise SchemaError(f"line {i}: invalid JSON: {e}") from e
            if i == 0:
                _require(obj.get("type") == "meta",
                         "first line must be a meta header")
                _require(obj.get("schema") == METRICS_SCHEMA_VERSION,
                         f"metrics schema {obj.get('schema')!r}, "
                         f"expected {METRICS_SCHEMA_VERSION}")
                continue
            validate_metrics_line(obj, line=i)
            samples.append(obj)
    return samples
