"""Fault-tolerant checkpointing: atomic, sharded, resumable.

Design (scaled-down analogue of a production multi-host checkpointer):
  * every leaf of the train-state pytree is written as one ``.npy`` entry in a
    per-step directory; a ``manifest.json`` records the treedef + dtypes
  * writes go to ``step_XXXX.tmp`` then ``os.rename`` → crash-atomic
  * ``latest`` resolution scans for the highest complete step, so a partial
    write (simulated node failure mid-checkpoint) is never restored
  * background thread pool for async save (training continues while the
    previous step serialises), with ``wait()`` barrier
  * keep_last garbage collection
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3, use_async: bool = True):
        self.directory = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1) if use_async else None
        self._pending: Optional[Future] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Write one checkpoint. ``extra`` is an optional JSON-compatible
        sidecar (session config, counters, telemetry) stored inside the
        step directory before the atomic rename, so a step is either fully
        present — arrays *and* sidecar — or absent."""
        leaves, treedef = jax.tree.flatten(state)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        if self._pool is not None:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host_leaves,
                                              str(treedef), extra)
        else:
            self._write(step, host_leaves, str(treedef), extra)

    def _write(self, step: int, leaves: List[np.ndarray], treedef: str,
               extra: Optional[Dict[str, Any]] = None) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "treedef": treedef, "n_leaves": len(leaves),
                    "dtypes": [str(x.dtype) for x in leaves],
                    "shapes": [list(x.shape) for x in leaves]}
        for i, arr in enumerate(leaves):
            # extension dtypes (bfloat16, fp8, ...) are not npy-portable:
            # store as float32 and cast back on restore (lossless for bf16)
            if arr.dtype.kind not in "fiub c":
                arr = arr.astype(np.float32)
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr,
                    allow_pickle=False)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if extra is not None:
            with open(os.path.join(tmp, "extra.json"), "w") as f:
                json.dump(extra, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)       # atomic commit
        self._gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                path = os.path.join(self.directory, name)
                if os.path.exists(os.path.join(path, "manifest.json")):
                    steps.append(int(name[5:]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_extra(self, step: Optional[int] = None) -> Optional[Dict[str, Any]]:
        """The JSON sidecar saved alongside a step (None if absent)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}", "extra.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def restore(self, like: Any, step: Optional[int] = None) -> Tuple[Any, int]:
        """Restore into the structure of ``like`` (shape/dtype template)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(like)
        if manifest["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, template has {len(leaves)}")
        new_leaves = []
        for i, template in enumerate(leaves):
            arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"),
                          allow_pickle=False)
            target_dtype = manifest["dtypes"][i]
            if str(arr.dtype) != target_dtype:
                # ml_dtypes names (e.g. bfloat16) resolve via jnp
                import jax.numpy as jnp
                arr = np.asarray(jnp.asarray(arr).astype(target_dtype))
            dev = jax.device_put(arr)
            if dev.dtype != arr.dtype:
                # jax canonicalises 64-bit leaves to 32-bit when x64 is off,
                # which would wrap sentinels (e.g. int64 min) and epoch-ms
                # timestamps — keep such leaves as host numpy, lossless
                new_leaves.append(arr)
            else:
                new_leaves.append(dev)
        return jax.tree.unflatten(treedef, new_leaves), step
