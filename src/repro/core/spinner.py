"""Spinner-style balanced label propagation (arXiv 1404.3861, §3).

Spinner partitions by iterative label propagation with an additive balance
penalty: every vertex scores each partition by the *normalised* share of its
neighbours there plus a bonus for partitions with free capacity,

    score(v, j) = counts[v, j] / deg(v)  +  w · max(C_j − occ_j, 0) / C_j

and greedily moves to the argmax (staying on ties — LPA's fixpoint rule).
Like xDGP, candidate moves pass a Bernoulli(s) gate (Spinner §3.3's
probabilistic migration, which breaks label oscillation) and a free-capacity
admission: movers targeting partition j are ranked deterministically and
only the first ``free_j`` admitted, so the capacity invariant holds by
construction. Unlike xDGP there is no deferral — admitted moves commit
within the step (``pending`` stays empty).

The neighbour-label histogram is the same quantity the xDGP migration
kernels compute, so ``backend="pallas"`` serves it from the fused BSR
kernels (``repro.kernels.migration_kernels.label_histogram``) while
``"ref"`` uses the unfused segment-sum path — bit-identical counts (pinned
by the kernel parity suite), hence bit-identical steps.

All scoring is float32 elementwise arithmetic in a fixed op order, so the
numpy oracle in ``tests/test_strategy_differential.py`` reproduces the jax
path bit-for-bit.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.migration import (MigrationStats, _rank_within_group,
                                  neighbour_partition_counts)
from repro.core.partition_state import PartitionState, occupancy
from repro.graph.structure import Graph


def spinner_scores(counts: jax.Array, occ: jax.Array, capacity: jax.Array,
                   balance_weight: float) -> jax.Array:
    """(n_cap, k) float32 Spinner score; the differential oracle mirrors
    this exact op order (divide, divide, multiply-add)."""
    deg = jnp.sum(counts, axis=1)
    degf = jnp.maximum(deg, 1).astype(jnp.float32)
    norm = counts.astype(jnp.float32) / degf[:, None]
    capf = jnp.maximum(capacity, 1).astype(jnp.float32)
    penalty = jnp.maximum(capacity - occ, 0).astype(jnp.float32) / capf
    return norm + jnp.float32(balance_weight) * penalty[None, :]


@partial(jax.jit, static_argnames=("balance_weight", "s", "backend",
                                   "executor"))
def spinner_step(state: PartitionState, graph: Graph, plan=None, *,
                 balance_weight: float = 0.5, s: float = 0.5,
                 backend: str = "ref", executor: Optional[str] = None,
                 ) -> Tuple[PartitionState, MigrationStats]:
    """One balanced-LPA iteration: score → stay-on-tie argmax → damp →
    free-capacity admission → immediate commit."""
    k = state.k
    node_mask = graph.node_mask
    assignment = state.assignment

    rng, sub = jax.random.split(state.rng)
    if backend == "pallas":
        from repro.kernels.migration_kernels import label_histogram
        counts = label_histogram(graph, plan, assignment, k,
                                 executor=executor)
    elif backend == "ref":
        counts = neighbour_partition_counts(graph, assignment, k)
    else:
        raise ValueError(f"unknown backend {backend!r}; valid: ref, pallas")

    occ = occupancy(state, node_mask)
    score = spinner_scores(counts, occ, state.capacity, balance_weight)

    cur = jnp.clip(assignment, 0, k - 1)
    cur_score = jnp.take_along_axis(score, cur[:, None], axis=1)[:, 0]
    best = jnp.max(score, axis=1)
    deg = jnp.sum(counts, axis=1)
    isolated = (deg == 0) | ~node_mask
    stay = (cur_score >= best) | isolated          # LPA: prefer current on ties
    target = jnp.where(stay, cur,
                       jnp.argmax(score, axis=1).astype(jnp.int32))

    wants_move = (target != cur) & node_mask
    gate = jax.random.bernoulli(sub, p=s, shape=wants_move.shape)
    willing = wants_move & gate
    n_willing = jnp.sum(willing).astype(jnp.int32)

    free = jnp.maximum(state.capacity - occ, 0)
    tgt = jnp.clip(target, 0, k - 1)
    rank = _rank_within_group(tgt, willing)
    admitted = willing & (rank < free[tgt])
    moved = jnp.sum(admitted).astype(jnp.int32)

    new_assignment = jnp.where(admitted, target, assignment)
    new_state = PartitionState(
        assignment=new_assignment,
        pending=jnp.full_like(state.pending, -1),   # no deferral in Spinner
        capacity=state.capacity,
        rng=rng,
        iteration=state.iteration + 1,
        last_moves=moved,
    )
    return new_state, MigrationStats(committed=moved, willing=n_willing,
                                     admitted=moved)


def spinner_adapt_jit(graph: Graph, state: PartitionState, *,
                      iters: int = 5, balance_weight: float = 0.5,
                      s: float = 0.5, backend: str = "ref",
                      plan=None) -> PartitionState:
    """Fixed-iteration Spinner adaptation as one lax.scan program — the
    per-superstep dispatch shape, mirroring ``repartitioner.adapt_jit``."""

    def body(st, _):
        st, stats = spinner_step(st, graph, plan, balance_weight=balance_weight,
                                 s=s, backend=backend)
        return st, stats.committed

    state, _ = jax.lax.scan(body, state, None, length=iters)
    return state
