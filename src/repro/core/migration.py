"""One iteration of the greedy vertex-migration heuristic (paper §3.2–§3.4, §4.2).

Fully vectorised SPMD formulation of the paper's per-vertex loop:

  1. COMMIT   — apply migrations decided in the previous iteration
                (deferred vertex migration, §4.2).
  2. SCORE    — per vertex, count neighbours per partition:
                counts = segment_sum(one_hot(assignment[src]), dst)  (both directions).
  3. DECIDE   — greedy rule: go to argmax partition; stay if the current
                partition is among the argmax set or the vertex is isolated.
  4. DAMP     — Bernoulli(s) gate on willing vertices (anti-chasing, §3.4).
  5. QUOTA    — per (src-partition i, dst-partition j) pair, only the first
                Q^{i,j} = C_free^j / (k-1) movers are admitted (§3.3). Ranking
                is a deterministic within-group prefix count (order-free).
  6. DEFER    — admitted moves are written to ``pending``; they commit at the
                start of the next iteration (step 1).

Steps 2–4 have two implementations behind ``migrate_step``'s static
``backend`` switch (DESIGN.md §9): ``"ref"`` is the unfused op-by-op
pipeline below (the correctness oracle), ``"pallas"`` dispatches through the
fused kernels in ``repro.kernels.migration_kernels`` — bit-identical
assignments, shared RNG draws, one pass over the adjacency. Steps 5–6 are
shared; the fused path ranks movers with the single-key sort
(``_rank_within_group_fast``), which produces identical ranks.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.graph.structure import Graph
from repro.core.partition_state import PartitionState, occupancy


class MigrationStats(NamedTuple):
    committed: jax.Array     # () int32 — migrations committed this iteration
    willing: jax.Array       # () int32 — vertices that wanted to move (post-damping)
    admitted: jax.Array      # () int32 — moves admitted by quotas (== next commit)


def neighbour_partition_counts(graph: Graph, assignment: jax.Array, k: int,
                               chunked: bool = False) -> jax.Array:
    """counts[v, j] = number of v's neighbours currently in partition j.

    The (2E, k) one-hot intermediate is the memory hot spot; ``chunked=True``
    loops over partitions instead (O(2E) per partition) for large graphs.
    On TPU this computation is served by the bsr_spmm Pallas kernel
    (counts = A_bsr @ one_hot(labels)); see repro.kernels.
    """
    n_cap = graph.n_cap
    src2, dst2, mask2 = graph.symmetrized()
    src_safe = jnp.clip(src2, 0, n_cap - 1)
    dst_seg = jnp.where(mask2, dst2, n_cap)          # padding -> dropped segment
    lab = assignment[src_safe]
    if not chunked:
        onehot = jax.nn.one_hot(lab, k, dtype=jnp.int32) * mask2[:, None].astype(jnp.int32)
        counts = jax.ops.segment_sum(onehot, dst_seg, num_segments=n_cap + 1)[:n_cap]
        return counts

    def per_part(j):
        contrib = ((lab == j) & mask2).astype(jnp.int32)
        return jax.ops.segment_sum(contrib, dst_seg, num_segments=n_cap + 1)[:n_cap]

    counts = jax.vmap(per_part)(jnp.arange(k)).T     # (n_cap, k)
    return counts


def greedy_targets(counts: jax.Array, assignment: jax.Array,
                   node_mask: jax.Array, rng: Optional[jax.Array] = None,
                   tie_break: str = "random") -> jax.Array:
    """Paper §3.2 decision rule. Returns desired partition per vertex.

    tie_break="stay":   the paper's literal rule — prefer the current partition
                        whenever it is among the argmax candidates. Converges to
                        zero migrations but freezes tied boundaries (≈0.54 cut
                        improvement on FEM vs the paper's claimed ≥0.6).
    tie_break="random": break argmax ties uniformly at random *including* the
                        current partition (the rule Spinner — the authors'
                        follow-up system — makes explicit). Tied boundaries
                        fluctuate and coarsen, matching the paper's claimed
                        quality (≥0.66 improvement on FEM in our runs).
    """
    k = counts.shape[1]
    best_count = jnp.max(counts, axis=1)
    cur = jnp.clip(assignment, 0, k - 1)
    cur_count = jnp.take_along_axis(counts, cur[:, None], axis=1)[:, 0]
    isolated = (best_count == 0) | ~node_mask
    if tie_break == "stay":
        stay = (cur_count >= best_count) | isolated
        target = jnp.where(stay, cur, jnp.argmax(counts, axis=1).astype(jnp.int32))
    elif tie_break == "random":
        if rng is None:
            raise ValueError("tie_break='random' requires an rng key")
        noise = jax.random.uniform(rng, counts.shape)
        score = counts.astype(jnp.float32) + noise      # < 1 gap → only ties shuffle
        target = jnp.argmax(score, axis=1).astype(jnp.int32)
        target = jnp.where(isolated, cur, target)
    else:
        raise ValueError(f"unknown tie_break {tie_break!r}")
    return target


def _rank_within_group(group: jax.Array, active: jax.Array) -> jax.Array:
    """Deterministic 0-based rank of each active element within its group.

    Sort by group id (inactive pushed to the end), then rank = position −
    position-of-group-start, scattered back. O(n log n), jit-friendly.
    """
    n = group.shape[0]
    big = jnp.iinfo(jnp.int32).max
    keyed = jnp.where(active, group, big)
    order = jnp.argsort(keyed)                       # stable in jax
    sorted_g = keyed[order]
    pos = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sorted_g[1:] != sorted_g[:-1]])
    start_pos = jnp.where(is_start, pos, 0)
    run_start = jax.lax.associative_scan(jnp.maximum, start_pos)
    rank_sorted = pos - run_start
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    return jnp.where(active, rank, jnp.int32(0))


def _rank_within_group_fast(group: jax.Array, active: jax.Array,
                            num_groups: int) -> jax.Array:
    """Bit-identical ranks to ``_rank_within_group`` via one unstable sort.

    Packs ``(group, position)`` into a single int32 key (unique ⇒ the
    unstable sort recovers exactly the stable order), so XLA sorts one
    array instead of a stable key/index pair — ~2× faster on CPU. Falls
    back to the stable variant when the packed key would overflow int32.
    """
    n = group.shape[0]
    if (num_groups + 1) * n >= 2 ** 31:      # static shapes: a Python check
        return _rank_within_group(group, active)
    pos = jnp.arange(n, dtype=jnp.int32)
    key = jnp.where(active, group, num_groups) * n + pos
    skey = jnp.sort(key)
    g_s = skey // n
    pos_s = skey % n
    is_start = jnp.concatenate([jnp.ones((1,), bool), g_s[1:] != g_s[:-1]])
    start_pos = jnp.where(is_start, pos, 0)
    run_start = jax.lax.associative_scan(jnp.maximum, start_pos)
    rank_sorted = pos - run_start
    rank = jnp.zeros((n,), jnp.int32).at[pos_s].set(rank_sorted)
    return jnp.where(active, rank, jnp.int32(0))


@partial(jax.jit, static_argnames=("s", "use_chunked_counts", "tie_break",
                                   "backend", "executor"))
def migrate_step(state: PartitionState, graph: Graph, plan=None, *,
                 s: float = 0.5, use_chunked_counts: bool = False,
                 tie_break: str = "random", backend: str = "ref",
                 executor: Optional[str] = None,
                 ) -> Tuple[PartitionState, MigrationStats]:
    """One full adaptive iteration (commit → score → decide → damp → quota → defer).

    ``backend="ref"`` runs the unfused op pipeline below; ``"pallas"``
    dispatches score/decide/damp through the fused kernels
    (``repro.kernels.migration_kernels.score_select``), optionally over a
    pre-packed ``plan`` (None = the packing-free flat plan — what the
    streaming path uses). Both backends draw the same RNG and produce
    bit-identical assignments. ``executor`` pins the kernel executor
    (``native``/``interpret``/``jax``); None resolves via
    ``repro.compat.pallas_executor()`` at trace time, so an env override
    must be in place before the first traced call.
    """
    k = state.k
    node_mask = graph.node_mask

    # ---- 1. COMMIT deferred migrations from t-1 -------------------------
    has_pending = state.pending >= 0
    assignment = jnp.where(has_pending, state.pending, state.assignment)
    committed = jnp.sum(has_pending & node_mask).astype(jnp.int32)

    rng, tie_key, sub = jax.random.split(state.rng, 3)
    if backend == "pallas":
        # ---- 2–4. fused SCORE + DECIDE + DAMP (DESIGN.md §9) ------------
        from repro.kernels.migration_kernels import score_select
        n_cap = graph.n_cap
        if tie_break == "random":
            noise = jax.random.uniform(tie_key, (n_cap, k))
        else:
            noise = jnp.zeros((n_cap, k), jnp.float32)
        gate = jax.random.bernoulli(sub, p=s, shape=(n_cap,))
        _, target, willing, _ = score_select(
            graph, plan, assignment, node_mask, noise, gate, k,
            tie_break=tie_break, executor=executor)
        n_willing = jnp.sum(willing).astype(jnp.int32)
        rank_fn = partial(_rank_within_group_fast, num_groups=k * k)
    elif backend == "ref":
        # ---- 2. SCORE ---------------------------------------------------
        counts = neighbour_partition_counts(graph, assignment, k,
                                            chunked=use_chunked_counts)

        # ---- 3. DECIDE --------------------------------------------------
        target = greedy_targets(counts, assignment, node_mask, rng=tie_key,
                                tie_break=tie_break)
        wants_move = (target != assignment) & node_mask

        # ---- 4. DAMP (Bernoulli(s), paper §3.4) --------------------------
        gate = jax.random.bernoulli(sub, p=s, shape=wants_move.shape)
        willing = wants_move & gate
        n_willing = jnp.sum(willing).astype(jnp.int32)
        rank_fn = _rank_within_group
    else:
        raise ValueError(f"unknown backend {backend!r}; valid: ref, pallas")

    # ---- 5. QUOTA (paper §3.3) -------------------------------------------
    occ = occupancy(
        PartitionState(assignment, state.pending, state.capacity, rng,
                       state.iteration, state.last_moves), node_mask)
    free = jnp.maximum(state.capacity - occ, 0)                    # C^j_free(t)
    quota = free // jnp.maximum(k - 1, 1)                          # Q^{i,j}, same for all i
    src_part = jnp.clip(assignment, 0, k - 1)
    group = src_part * k + jnp.clip(target, 0, k - 1)              # (i, j) pair id
    rank = rank_fn(group, willing)
    admitted = willing & (rank < quota[jnp.clip(target, 0, k - 1)])
    n_admitted = jnp.sum(admitted).astype(jnp.int32)

    # ---- 6. DEFER ---------------------------------------------------------
    pending = jnp.where(admitted, target, jnp.int32(-1))

    new_state = PartitionState(
        assignment=assignment,
        pending=pending,
        capacity=state.capacity,
        rng=rng,
        iteration=state.iteration + 1,
        last_moves=committed,
    )
    return new_state, MigrationStats(committed=committed, willing=n_willing,
                                     admitted=n_admitted)


@jax.jit
def flush_pending(state: PartitionState, graph: Graph) -> PartitionState:
    """Commit any pending moves without taking new decisions (used at drain)."""
    has_pending = state.pending >= 0
    assignment = jnp.where(has_pending, state.pending, state.assignment)
    return PartitionState(
        assignment=assignment,
        pending=jnp.full_like(state.pending, -1),
        capacity=state.capacity,
        rng=state.rng,
        iteration=state.iteration + 1,
        last_moves=jnp.sum(has_pending & graph.node_mask).astype(jnp.int32),
    )
