"""Halo-exchange GNN training: the paper's technique as a sharding pass.

Under pure GSPMD, distributed aggregation all-gathers node features
regardless of where edges actually point — collective volume is
shape-determined. The xDGP runtime instead buckets edges per owning device
(core.distributed.DistGraph) and exchanges only each block's *boundary
segment*; the halo width B is a static shape derived from the partition
quality, so better partitioning (the paper's contribution) shrinks the
compiled collective term directly.

This module provides shard_map GIN / GatedGCN forwards + train steps over a
DistGraph, plus the boundary-fraction measurement used to size the halo for
the dry-run (measured on a same-family graph at feasible scale, then applied
to the full-scale shapes — methodology in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.core.distributed import AXIS, DistGraph, _halo_exchange
from repro.models.gnn import GINConfig, _layernorm, _linear, _mlp2

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# boundary-fraction measurement (sizes the halo)
# ---------------------------------------------------------------------------

def measure_boundary_fraction(n: int, avg_degree: float, k: int,
                              adapt_iters: int = 60, seed: int = 0,
                              strategy: str = "adapted") -> float:
    """Max over partitions of |boundary(P_i)| / |P_i| on a Chung–Lu graph.

    strategy "hash" → initial hash partitioning; "adapted" → after running
    the xDGP heuristic for ``adapt_iters`` iterations.
    """
    from repro.graph import generators
    from repro.core import adapt_rounds, initial_partition, make_state

    g = generators.chung_lu(n, avg_degree, seed=seed)
    lab = initial_partition(g, k, "hsh")
    if strategy == "adapted":
        state = make_state(g, lab, k)
        state, _ = adapt_rounds(g, state, adapt_iters)
        lab = state.assignment
    lab_np = np.asarray(lab)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    em = np.asarray(g.edge_mask)
    s, d = src[em], dst[em]
    cross = lab_np[s] != lab_np[d]
    boundary_nodes = np.unique(np.concatenate([s[cross], d[cross]]))
    counts = np.bincount(lab_np[: g.n_cap], minlength=k).astype(np.float64)
    bcounts = np.bincount(lab_np[boundary_nodes], minlength=k).astype(np.float64)
    frac = bcounts / np.maximum(counts, 1)
    return float(frac.max())


# ---------------------------------------------------------------------------
# shard_map GIN over DistGraph
# ---------------------------------------------------------------------------

def _gin_layer_local(lp, h_loc, dgl: DistGraph, halo_size: int):
    halo = _halo_exchange(h_loc, dgl)
    src_owner = dgl.src_owner[0]
    src_slot = dgl.src_slot[0]
    src_local = dgl.src_local[0]
    dst_local = dgl.dst_local[0]
    edge_ok = dgl.edge_ok[0]
    feat_remote = halo[jnp.clip(src_owner * halo_size + src_slot, 0,
                                halo.shape[0] - 1)]
    feat_local = h_loc[src_slot]
    feat = jnp.where(src_local[:, None], feat_local, feat_remote)
    feat = jnp.where(edge_ok[:, None], feat, 0)
    n_blk = h_loc.shape[0]
    agg = jax.ops.segment_sum(feat, jnp.where(edge_ok, dst_local, n_blk),
                              num_segments=n_blk + 1)[:n_blk]
    h = _mlp2(lp["mlp"], (1.0 + lp["eps"]) * h_loc + agg)
    h = jax.nn.relu(_layernorm(lp["ln"], h))
    return jnp.where(dgl.node_ok[0][:, None], h, 0)


def gin_halo_forward(params: Params, dg: DistGraph, feats: jax.Array,
                     cfg: GINConfig, mesh) -> jax.Array:
    """GIN over the halo engine. feats: (P*n_blk, d_in) node features."""
    P = dg.num_devices
    halo = dg.halo_size
    spec_n = jax.sharding.PartitionSpec(AXIS, None)
    dg_specs = DistGraph(*([jax.sharding.PartitionSpec(AXIS)] * 8))

    def body(feats_loc, dgl):
        h = _linear(params["encode"], feats_loc)

        def layer(lp, h):
            return _gin_layer_local(lp, h, dgl, halo)

        step = jax.checkpoint(layer) if cfg.remat else layer
        for lp in params["layers"]:
            h = step(lp, h)
        return _mlp2(params["decode"], h)

    return shard_map(body, mesh=mesh, in_specs=(spec_n, dg_specs),
                     out_specs=spec_n)(feats, dg)


def gin_halo_loss(params: Params, dg: DistGraph, feats: jax.Array,
                  labels: jax.Array, cfg: GINConfig, mesh) -> jax.Array:
    logits = gin_halo_forward(params, dg, feats, cfg, mesh)
    node_ok = dg.node_ok.reshape(-1)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    ll = jnp.take_along_axis(logp, jnp.clip(labels, 0, cfg.n_out - 1)[:, None],
                             -1)[:, 0]
    m = node_ok.astype(jnp.float32)
    return -(ll * m).sum() / jnp.maximum(m.sum(), 1.0)


def abstract_dist_graph(num_devices: int, n_blk: int, e_blk: int,
                        halo: int) -> DistGraph:
    """ShapeDtypeStruct DistGraph for dry-run lowering (no allocation)."""
    P = num_devices
    i32, b8 = jnp.int32, jnp.bool_
    sds = jax.ShapeDtypeStruct
    return DistGraph(
        src_owner=sds((P, e_blk), i32), src_slot=sds((P, e_blk), i32),
        src_local=sds((P, e_blk), b8), dst_local=sds((P, e_blk), i32),
        edge_ok=sds((P, e_blk), b8), boundary=sds((P, halo), i32),
        boundary_ok=sds((P, halo), b8), node_ok=sds((P, n_blk), b8))
