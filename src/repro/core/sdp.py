"""SDP-style scalable real-time dynamic placement (arXiv 2110.15669).

SDP keeps partitions good *as the graph changes* with two cheap mechanisms
instead of xDGP's full iterate-to-convergence loop:

  1. arrivals are placed online with a Fennel-style streaming rule
     (the existing ``repro.stream.placement.place_delta`` path — the
     strategy layer wires it in by subclassing ``OnlineFennel``), and
  2. a *boundary-only* refinement sweep: only vertices with at least one
     external neighbour reconsider their placement, scoring partitions with
     the same greedy·balance objective the placer uses,

         score(v, j) = counts[v, j] · (1 − occ_j / C_j)

     and moving only on a *strict* improvement over the current partition
     (ties stay — refinement must be a descent step, or churn never ends).

Like the other migrating strategies, movers pass a Bernoulli(s) gate and a
deterministic free-capacity admission ranking, so the capacity invariant
holds by construction and steps are reproducible from the state's RNG key.
Moves commit within the step (real-time placement cannot defer).

Scoring is float32 elementwise in a fixed op order; the numpy oracle in
``tests/test_strategy_differential.py`` reproduces it bit-for-bit.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.migration import (MigrationStats, _rank_within_group,
                                  neighbour_partition_counts)
from repro.core.partition_state import PartitionState, occupancy
from repro.graph.structure import Graph


def sdp_scores(counts: jax.Array, occ: jax.Array,
               capacity: jax.Array) -> jax.Array:
    """(n_cap, k) float32 greedy·balance score (same objective as the
    streaming placer); the differential oracle mirrors this op order."""
    capf = jnp.maximum(capacity, 1).astype(jnp.float32)
    balance = 1.0 - occ.astype(jnp.float32) / capf
    return counts.astype(jnp.float32) * balance[None, :]


@partial(jax.jit, static_argnames=("s", "backend", "executor"))
def sdp_refine_step(state: PartitionState, graph: Graph, plan=None, *,
                    s: float = 0.5, backend: str = "ref",
                    executor: Optional[str] = None,
                    ) -> Tuple[PartitionState, MigrationStats]:
    """One boundary-refinement sweep: boundary mask → strict-improvement
    argmax → damp → free-capacity admission → immediate commit."""
    k = state.k
    node_mask = graph.node_mask
    assignment = state.assignment

    rng, sub = jax.random.split(state.rng)
    if backend == "pallas":
        from repro.kernels.migration_kernels import label_histogram
        counts = label_histogram(graph, plan, assignment, k,
                                 executor=executor)
    elif backend == "ref":
        counts = neighbour_partition_counts(graph, assignment, k)
    else:
        raise ValueError(f"unknown backend {backend!r}; valid: ref, pallas")

    occ = occupancy(state, node_mask)
    score = sdp_scores(counts, occ, state.capacity)

    cur = jnp.clip(assignment, 0, k - 1)
    cur_count = jnp.take_along_axis(counts, cur[:, None], axis=1)[:, 0]
    cur_score = jnp.take_along_axis(score, cur[:, None], axis=1)[:, 0]
    deg = jnp.sum(counts, axis=1)
    boundary = (deg - cur_count) > 0               # ≥1 external neighbour
    best = jnp.max(score, axis=1)
    target = jnp.argmax(score, axis=1).astype(jnp.int32)

    wants_move = (boundary & (best > cur_score)    # strict improvement only
                  & (target != cur) & node_mask)
    gate = jax.random.bernoulli(sub, p=s, shape=wants_move.shape)
    willing = wants_move & gate
    n_willing = jnp.sum(willing).astype(jnp.int32)

    free = jnp.maximum(state.capacity - occ, 0)
    tgt = jnp.clip(target, 0, k - 1)
    rank = _rank_within_group(tgt, willing)
    admitted = willing & (rank < free[tgt])
    moved = jnp.sum(admitted).astype(jnp.int32)

    new_assignment = jnp.where(admitted, target, assignment)
    new_state = PartitionState(
        assignment=new_assignment,
        pending=jnp.full_like(state.pending, -1),   # no deferral in SDP
        capacity=state.capacity,
        rng=rng,
        iteration=state.iteration + 1,
        last_moves=moved,
    )
    return new_state, MigrationStats(committed=moved, willing=n_willing,
                                     admitted=moved)


def sdp_adapt_jit(graph: Graph, state: PartitionState, *, iters: int = 5,
                  s: float = 0.5, backend: str = "ref",
                  plan=None) -> PartitionState:
    """Fixed-iteration refinement as one lax.scan program (per-superstep
    dispatch shape, mirroring ``repartitioner.adapt_jit``)."""

    def body(st, _):
        st, stats = sdp_refine_step(st, graph, plan, s=s, backend=backend)
        return st, stats.committed

    state, _ = jax.lax.scan(body, state, None, length=iters)
    return state
