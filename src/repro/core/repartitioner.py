"""Run-to-convergence drivers for the adaptive heuristic (paper §3, Fig. 2/6).

The paper's convergence criterion: zero migrations for 30 consecutive
iterations. ``run_to_convergence`` is a host loop around the jit'd
``migrate_step`` so we can record per-iteration history (cut ratio,
migrations) exactly like the paper's figures; ``adapt_rounds`` runs a fixed
number of iterations (continuous mode); ``converge_jit`` is a pure
``lax.while_loop`` variant for embedding the adaptation inside larger jit
programs (the distributed engine uses it).

These module-level functions are the implementation behind the
``XdgpAdaptive`` strategy in ``repro.api``. ``AdaptivePartitioner`` remains
as a deprecated shim over them for seed-era callers.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph, cut_ratio
from repro.core.partition_state import PartitionState, make_state, imbalance
from repro.core.migration import migrate_step, flush_pending


@dataclasses.dataclass
class AdaptiveConfig:
    k: int = 9                    # paper's microbenchmarks use 9 partitions
    s: float = 0.5                # paper's recommended damping (§3.4)
    slack: float = 0.1            # capacity head-room over perfect balance
    patience: int = 30            # paper: converged after 30 quiet iterations
    max_iters: int = 500
    seed: int = 0
    chunked_counts: bool = False  # memory-light scoring for very large graphs
    tie_break: str = "random"     # "stay" = paper's literal rule; "random" = Spinner-style
    rel_tol: float = 1e-3         # cut-ratio plateau tolerance (random tie-break mode)


@dataclasses.dataclass
class History:
    cut_ratio: List[float]
    migrations: List[int]
    willing: List[int]
    imbalance: List[float]

    def as_dict(self) -> Dict[str, list]:
        return dataclasses.asdict(self)

    @property
    def total_migrations(self) -> int:
        return int(np.sum(self.migrations))

    @property
    def iterations(self) -> int:
        return len(self.migrations)

    @staticmethod
    def empty() -> "History":
        return History([], [], [], [])


def run_to_convergence(graph: Graph, state: PartitionState, *, s: float = 0.5,
                       patience: int = 30, max_iters: int = 500,
                       tie_break: str = "random", rel_tol: float = 1e-3,
                       chunked_counts: bool = False,
                       record_history: bool = True,
                       backend: str = "ref", plan=None,
                       step_fn=None,
                       ) -> Tuple[PartitionState, History]:
    """Iterate until converged.

    Convergence: tie_break="stay" → zero migrations for ``patience``
    consecutive iterations (the paper's criterion). tie_break="random" →
    tied boundaries keep fluctuating forever, so we additionally stop when
    the cut ratio has not improved by ``rel_tol`` over a ``patience``
    iteration window.

    ``backend``/``plan`` select the scoring implementation per iteration
    (see ``migrate_step``); the graph is fixed for the whole loop, so one
    pre-packed ``plan`` amortises over every iteration. ``step_fn``
    overrides the whole iteration — ``state -> (state, MigrationStats)`` —
    which is how the sharded execution backend reuses this control flow
    (same stopping rule, same history) over the cluster engine.
    """
    if step_fn is None:
        step_fn = lambda st: migrate_step(st, graph, plan, s=s,
                                          use_chunked_counts=chunked_counts,
                                          tie_break=tie_break, backend=backend)
    hist = History.empty()
    quiet = 0
    best_cut = float("inf")
    stale = 0
    for _ in range(max_iters):
        state, stats = step_fn(state)
        moved = int(stats.committed)
        pending = int(stats.admitted)
        cut = float(cut_ratio(graph, state.assignment))
        if record_history:
            hist.cut_ratio.append(cut)
            hist.migrations.append(moved)
            hist.willing.append(int(stats.willing))
            hist.imbalance.append(float(imbalance(state, graph.node_mask)))
        quiet = quiet + 1 if (moved == 0 and pending == 0) else 0
        if cut < best_cut * (1.0 - rel_tol):
            best_cut = cut
            stale = 0
        else:
            stale += 1
        if quiet >= patience:
            break
        if tie_break == "random" and stale >= patience:
            break
    state = flush_pending(state, graph)
    return state, hist


def adapt_rounds(graph: Graph, state: PartitionState, iters: int, *,
                 s: float = 0.5, tie_break: str = "random",
                 chunked_counts: bool = False,
                 record_history: bool = True,
                 backend: str = "ref", plan=None,
                 step_fn=None,
                 ) -> Tuple[PartitionState, History]:
    """Run a fixed number of adaptation iterations (continuous mode).

    Pending moves stay deferred at return (paper §4.2) — the next call's
    first iteration commits them, exactly like the interleaved stream mode.
    ``step_fn`` overrides the iteration like in ``run_to_convergence``.
    """
    if step_fn is None:
        step_fn = lambda st: migrate_step(st, graph, plan, s=s,
                                          use_chunked_counts=chunked_counts,
                                          tie_break=tie_break, backend=backend)
    hist = History.empty()
    for _ in range(iters):
        state, stats = step_fn(state)
        if record_history:
            hist.cut_ratio.append(float(cut_ratio(graph, state.assignment)))
            hist.migrations.append(int(stats.committed))
            hist.willing.append(int(stats.willing))
            hist.imbalance.append(float(imbalance(state, graph.node_mask)))
    return state, hist


class AdaptivePartitioner:
    """Deprecated seed-era driver; use ``repro.api.DynamicGraphSystem`` (or
    the ``XdgpAdaptive`` strategy / the module-level driver functions)."""

    def __init__(self, config: AdaptiveConfig):
        warnings.warn(
            "AdaptivePartitioner is deprecated; use "
            "repro.api.DynamicGraphSystem (converge()/adapt()) with the "
            "'xdgp' PartitionStrategy, or the module-level "
            "run_to_convergence/adapt_rounds drivers",
            DeprecationWarning, stacklevel=2)
        self.config = config

    def init_state(self, graph: Graph, assignment: jax.Array,
                   capacity: Optional[jax.Array] = None) -> PartitionState:
        return make_state(graph, assignment, self.config.k,
                          slack=self.config.slack, seed=self.config.seed,
                          capacity=capacity)

    def step(self, state: PartitionState, graph: Graph) -> Tuple[PartitionState, dict]:
        state, stats = migrate_step(state, graph, s=self.config.s,
                                    use_chunked_counts=self.config.chunked_counts,
                                    tie_break=self.config.tie_break)
        return state, {k: int(v) for k, v in stats._asdict().items()}

    def run_to_convergence(self, graph: Graph, state: PartitionState,
                           record_history: bool = True,
                           ) -> Tuple[PartitionState, History]:
        cfg = self.config
        return run_to_convergence(
            graph, state, s=cfg.s, patience=cfg.patience,
            max_iters=cfg.max_iters, tie_break=cfg.tie_break,
            rel_tol=cfg.rel_tol, chunked_counts=cfg.chunked_counts,
            record_history=record_history)

    def adapt(self, graph: Graph, state: PartitionState, iters: int,
              ) -> Tuple[PartitionState, History]:
        cfg = self.config
        return adapt_rounds(graph, state, iters, s=cfg.s,
                            tie_break=cfg.tie_break,
                            chunked_counts=cfg.chunked_counts)


def converge_jit(graph: Graph, state: PartitionState, *, s: float = 0.5,
                 patience: int = 30, max_iters: int = 500,
                 tie_break: str = "stay", backend: str = "ref",
                 plan=None) -> PartitionState:
    """Pure lax.while_loop convergence (no history) — embeddable inside jit.

    Used by the distributed engine and the dry-run lowering of the
    partitioner program. Uses the paper's zero-migration criterion, so the
    default tie_break here is the paper's "stay" rule.
    """

    def cond(carry):
        st, quiet, it = carry
        return (quiet < patience) & (it < max_iters)

    def body(carry):
        st, quiet, it = carry
        st, stats = migrate_step(st, graph, plan, s=s, tie_break=tie_break,
                                 backend=backend)
        moved = stats.committed + stats.admitted
        quiet = jnp.where(moved == 0, quiet + 1, 0)
        return st, quiet, it + 1

    state, _, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)))
    return flush_pending(state, graph)


def adapt_jit(graph: Graph, state: PartitionState, *, s: float = 0.5,
              iters: int = 30, tie_break: str = "random",
              backend: str = "ref", plan=None) -> PartitionState:
    """Fixed-iteration adaptation as a single jit program (lax.scan) — the
    fused superstep the streaming engine dispatches per batch."""

    def body(st, _):
        st, stats = migrate_step(st, graph, plan, s=s, tie_break=tie_break,
                                 backend=backend)
        return st, stats.committed

    state, _ = jax.lax.scan(body, state, None, length=iters)
    return flush_pending(state, graph)
