"""Partition state for the xDGP adaptive repartitioner (paper §3).

The state is a pytree so the whole iterate → converge loop can live inside
jit / lax.while_loop, and so it shards cleanly over a device mesh (node-slot
arrays are sharded on their leading axis by the distributed engine).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.graph.structure import Graph


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionState:
    """Full state of the adaptive partitioner.

    Attributes:
      assignment: (n_cap,) int32 — current partition of every node slot.
      pending:    (n_cap,) int32 — deferred destination decided last iteration
                  (-1 = no pending move). Paper §4.2 "Deferred Vertex Migration":
                  decisions taken at t are committed at t+1 so message routing
                  never races placement.
      capacity:   (k,) int32 — hard per-partition capacity C^i (paper §3.3).
      rng:        PRNG key for the Bernoulli(s) damping (paper §3.4).
      iteration:  scalar int32 — iteration counter t.
      last_moves: scalar int32 — number of migrations committed at the last
                  commit phase (convergence detection, paper: 30 quiet iters).
    """

    assignment: jax.Array
    pending: jax.Array
    capacity: jax.Array
    rng: jax.Array
    iteration: jax.Array
    last_moves: jax.Array

    @property
    def k(self) -> int:
        return self.capacity.shape[0]

    @property
    def n_cap(self) -> int:
        return self.assignment.shape[0]


def default_capacity(num_nodes: int, k: int, slack: float = 0.1) -> jax.Array:
    """Balanced capacity with head-room: C^i = ceil(|V|/k · (1+slack))."""
    per = int(-(-num_nodes // k))  # ceil
    cap = int(round(per * (1.0 + slack))) + 1
    return jnp.full((k,), cap, dtype=jnp.int32)


def make_state(graph: Graph, assignment: jax.Array, k: int,
               slack: float = 0.1, seed: int = 0,
               capacity: Optional[jax.Array] = None) -> PartitionState:
    n_live = int(jax.device_get(graph.num_nodes))
    cap = capacity if capacity is not None else default_capacity(n_live, k, slack)
    return PartitionState(
        assignment=assignment.astype(jnp.int32),
        pending=jnp.full((graph.n_cap,), -1, jnp.int32),
        capacity=cap.astype(jnp.int32),
        rng=jax.random.PRNGKey(seed),
        iteration=jnp.zeros((), jnp.int32),
        last_moves=jnp.zeros((), jnp.int32),
    )


def occupancy(state: PartitionState, node_mask: jax.Array) -> jax.Array:
    """|P^i(t)| for every partition (live nodes only)."""
    lab = jnp.where(node_mask, state.assignment, state.k)
    return jax.ops.segment_sum(jnp.ones_like(lab), lab, num_segments=state.k + 1)[: state.k]


def imbalance(state: PartitionState, node_mask: jax.Array) -> jax.Array:
    """max/mean occupancy — load-balance quality metric."""
    occ = occupancy(state, node_mask)
    mean = jnp.maximum(jnp.sum(occ) / state.k, 1)
    return jnp.max(occ) / mean
