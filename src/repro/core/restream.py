"""Le Merrer-style restreaming repartitioning (arXiv 1310.8211).

Restreaming re-runs a one-pass streaming partitioner over the *current*
graph, seeded with the *current* assignment: each live vertex, in id order,
is removed from its partition and immediately re-placed with the same
Fennel-style greedy·balance rule the online placer uses,

    score(v, j) = counts[v, j] · (1 − occ_j / C_j)

restricted to partitions with free room, preferring the current partition
on ties (so a converged placement is a fixpoint and repeated passes are
idempotent once quiet). Because each vertex is removed before it is
re-placed, total occupancy during the scan is ``live − 1`` which is
strictly below total capacity (capacities are provisioned with slack over
the slot count), so a partition with room always exists and the capacity
invariant holds by construction.

The pass is a deliberate *host-side* numpy scan over the CSR adjacency —
restreaming is inherently sequential (each placement sees the occupancies
left by every earlier one), which is exactly the property the streaming
papers exploit and the reason it cannot share the vectorised migration
kernels. It is deterministic: no RNG, stable id order, pure integer/float64
arithmetic — the differential oracle in
``tests/test_strategy_differential.py`` is a literal replay of this loop.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.migration import MigrationStats
from repro.core.partition_state import PartitionState
from repro.graph.structure import Graph, to_csr


def restream_pass(graph: Graph, assignment: np.ndarray, capacity: np.ndarray,
                  k: int) -> Tuple[np.ndarray, int]:
    """One restreaming sweep over the live vertices in id order.

    Args:
      assignment: (n_cap,) current labels (host array, any int dtype).
      capacity:   (k,) hard per-partition capacities.

    Returns ``(labels, moved)`` — the updated (n_cap,) int32 labels and the
    number of vertices that changed partition. ``moved == 0`` means the
    assignment is a fixpoint of the pass (further passes are no-ops).
    """
    indptr, indices = to_csr(graph)
    nm = np.asarray(graph.node_mask)
    lab = np.asarray(assignment).astype(np.int64).copy()
    cap = np.asarray(capacity).astype(np.int64)
    live = np.flatnonzero(nm)
    occ = np.bincount(np.clip(lab[live], 0, k - 1), minlength=k)
    moved = 0
    for v in live:
        cur = int(np.clip(lab[v], 0, k - 1))
        occ[cur] -= 1                     # remove v, then re-place it
        nbrs = indices[indptr[v]:indptr[v + 1]]
        nbrs = nbrs[nm[nbrs]]
        counts = np.bincount(np.clip(lab[nbrs], 0, k - 1),
                             minlength=k).astype(np.float64)
        room = occ < cap
        score = counts * (1.0 - occ / np.maximum(cap, 1))
        score = np.where(room, score, -np.inf)
        if not room.any():
            best = cur                    # oversubscribed state: don't worsen
        elif room[cur] and score[cur] >= score.max():
            best = cur                    # prefer current on ties → fixpoint
        else:
            best = int(np.argmax(score))
        lab[v] = best
        occ[best] += 1
        moved += int(best != cur)
    return lab.astype(np.int32), moved


def restream_state(state: PartitionState, graph: Graph,
                   ) -> Tuple[PartitionState, MigrationStats]:
    """Run one pass and thread the result back into the device-side
    ``PartitionState`` (the strategy's step_fn shape)."""
    lab, moved = restream_pass(graph, np.asarray(state.assignment),
                               np.asarray(state.capacity), state.k)
    new_state = PartitionState(
        assignment=jnp.asarray(lab),
        pending=jnp.full_like(state.pending, -1),
        capacity=state.capacity,
        rng=state.rng,
        iteration=state.iteration + 1,
        last_moves=jnp.asarray(moved, jnp.int32),
    )
    m = jnp.asarray(moved, jnp.int32)
    return new_state, MigrationStats(committed=m, willing=m, admitted=m)
