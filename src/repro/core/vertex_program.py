"""Pregel-style vertex programs ("think like a vertex", paper §4.1).

A ``VertexProgram`` defines per-superstep message/combine/update functions;
the engine executes them with vectorised segment ops over the padded COO
graph — the SPMD analogue of xDGP's per-vertex executor threads.

Shipped programs (used by the paper's use cases, §5.3):
  * PageRank        — content ranking (paper §2 motivation)
  * TunkRank        — Twitter influence (use case 1)
  * WCC             — weakly-connected components (min-label propagation)
  * DegreeStats     — per-vertex degree (used for diameter-style probes)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.graph.structure import Graph


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    """Vectorised vertex program.

    init(graph)                      -> state (n_cap, d)
    message(state_src, graph)        -> per-directed-edge messages (2e_cap, d)
    combine                          -> 'sum' | 'max' | 'min'
    update(state, agg, graph, step)  -> new state
    """

    name: str
    state_dim: int
    init: Callable[[Graph], jax.Array]
    message: Callable[[jax.Array, Graph], jax.Array]
    update: Callable[[jax.Array, jax.Array, Graph, jax.Array], jax.Array]
    combine: str = "sum"


def superstep(prog: VertexProgram, graph: Graph, state: jax.Array,
              step: jax.Array) -> jax.Array:
    """One BSP superstep: gather src state → message → combine by dst → update."""
    n_cap = graph.n_cap
    src2, dst2, mask2 = graph.symmetrized()
    src_safe = jnp.clip(src2, 0, n_cap - 1)
    msg = prog.message(state[src_safe], graph)          # (2e_cap, d)
    msg = jnp.where(mask2[:, None], msg, 0.0 if prog.combine == "sum" else msg)
    seg = jnp.where(mask2, dst2, n_cap)
    if prog.combine == "sum":
        agg = jax.ops.segment_sum(msg, seg, num_segments=n_cap + 1)[:n_cap]
    elif prog.combine == "max":
        agg = jax.ops.segment_max(jnp.where(mask2[:, None], msg, -jnp.inf),
                                  seg, num_segments=n_cap + 1)[:n_cap]
        agg = jnp.where(jnp.isfinite(agg), agg, 0.0)
    elif prog.combine == "min":
        agg = jax.ops.segment_min(jnp.where(mask2[:, None], msg, jnp.inf),
                                  seg, num_segments=n_cap + 1)[:n_cap]
    else:
        raise ValueError(prog.combine)
    return prog.update(state, agg, graph, step)


def run(prog: VertexProgram, graph: Graph, num_steps: int,
        state: Optional[jax.Array] = None) -> jax.Array:
    """Run ``num_steps`` supersteps under jit (lax.scan over steps)."""
    if state is None:
        state = prog.init(graph)

    def body(st, i):
        return superstep(prog, graph, st, i), None

    state, _ = jax.lax.scan(body, state, jnp.arange(num_steps))
    return state


@dataclasses.dataclass(frozen=True)
class CostModel:
    """The paper's execution-time model (§5.3): iteration time is bound by
    messages, remote ≈ 25× local (10GbE RTT vs in-memory hand-off), one
    migration ≈ 50 message units (state shipping + routing updates).
    Single source of truth for the cost constants — the scenario harness
    and ``benchmarks.common.CommModel`` both build on it."""

    c_cpu: float = 1.0     # per local message byte
    c_net: float = 25.0    # per remote message byte
    c_mig: float = 50.0    # per migrated vertex, in message-byte units

    def superstep_cost(self, local_bytes: float, remote_bytes: float,
                       migrations: float, unit_bytes: float) -> float:
        return (self.c_cpu * local_bytes + self.c_net * remote_bytes
                + self.c_mig * migrations * unit_bytes)


def message_volume(graph: Graph, assignment: jax.Array, state_dim: int,
                   bytes_per_elem: int = 4) -> Tuple[jax.Array, jax.Array]:
    """Per-superstep message traffic split into (local, cross-partition) bytes.

    The paper's §5.3 observation — "execution time is bound by the number of
    messages sent over the network" (>80% of iteration time) — makes this the
    execution-time model for the use-case benchmarks: remote bytes dominate.
    """
    n_cap = graph.n_cap
    a = assignment[jnp.clip(graph.src, 0, n_cap - 1)]
    b = assignment[jnp.clip(graph.dst, 0, n_cap - 1)]
    live = graph.edge_mask
    cross = jnp.sum((a != b) & live) * 2    # both directions
    local = jnp.sum((a == b) & live) * 2
    unit = state_dim * bytes_per_elem
    return local * unit, cross * unit


# ---------------------------------------------------------------------------
# Shipped programs
# ---------------------------------------------------------------------------

def pagerank(damping: float = 0.85) -> VertexProgram:
    def init(g: Graph) -> jax.Array:
        n = jnp.maximum(g.num_nodes, 1).astype(jnp.float32)
        return jnp.where(g.node_mask[:, None], 1.0 / n, 0.0)

    def message(src_state: jax.Array, g: Graph) -> jax.Array:
        deg = jnp.maximum(g.degrees(), 1).astype(jnp.float32)
        src2 = jnp.clip(jnp.concatenate([g.src, g.dst]), 0, g.n_cap - 1)
        return src_state / deg[src2][:, None]

    def update(state, agg, g: Graph, step) -> jax.Array:
        n = jnp.maximum(g.num_nodes, 1).astype(jnp.float32)
        new = (1.0 - damping) / n + damping * agg
        return jnp.where(g.node_mask[:, None], new, 0.0)

    return VertexProgram("pagerank", 1, init, message, update, "sum")


def tunkrank(p_read: float = 0.05) -> VertexProgram:
    """TunkRank (Tunkelang's Twitter influence analogue of PageRank).

    Influence(v) = Σ_{w ∈ followers(v)} (1 + p·Influence(w)) / |following(w)|
    — paper use case 1 (§5.3, London tweets).
    """

    def init(g: Graph) -> jax.Array:
        return jnp.where(g.node_mask[:, None], 1.0, 0.0)

    def message(src_state: jax.Array, g: Graph) -> jax.Array:
        deg = jnp.maximum(g.degrees(), 1).astype(jnp.float32)
        src2 = jnp.clip(jnp.concatenate([g.src, g.dst]), 0, g.n_cap - 1)
        return (1.0 + p_read * src_state) / deg[src2][:, None]

    def update(state, agg, g: Graph, step) -> jax.Array:
        return jnp.where(g.node_mask[:, None], agg, 0.0)

    return VertexProgram("tunkrank", 1, init, message, update, "sum")


def weakly_connected_components() -> VertexProgram:
    def init(g: Graph) -> jax.Array:
        ids = jnp.arange(g.n_cap, dtype=jnp.float32)[:, None]
        return jnp.where(g.node_mask[:, None], ids, jnp.inf)

    def message(src_state: jax.Array, g: Graph) -> jax.Array:
        return src_state

    def update(state, agg, g: Graph, step) -> jax.Array:
        new = jnp.minimum(state, agg)
        return jnp.where(g.node_mask[:, None], new, jnp.inf)

    return VertexProgram("wcc", 1, init, message, update, "min")


def degree_stats() -> VertexProgram:
    def init(g: Graph) -> jax.Array:
        return jnp.zeros((g.n_cap, 1), jnp.float32)

    def message(src_state: jax.Array, g: Graph) -> jax.Array:
        return jnp.ones_like(src_state)

    def update(state, agg, g: Graph, step) -> jax.Array:
        return agg

    return VertexProgram("degree", 1, init, message, update, "sum")


PROGRAMS = {
    "pagerank": pagerank,
    "tunkrank": tunkrank,
    "wcc": weakly_connected_components,
    "degree": degree_stats,
}


def make_program(name: str, **kwargs) -> VertexProgram:
    """Instantiate a shipped program by name (scenario drivers carry string
    keys so Scenario objects stay serialisable)."""
    try:
        factory = PROGRAMS[name]
    except KeyError:
        raise KeyError(f"unknown vertex program {name!r}; "
                       f"available: {sorted(PROGRAMS)}") from None
    return factory(**kwargs)
