"""Partition → device placement and locality-preserving reordering.

The bridge between the paper's logical partitions and the TPU mesh: a
partition is a contiguous block of node *slots* on one device (or device
group). After the adaptive heuristic improves the assignment, ``relocation``
computes the permutation that makes each partition contiguous — the SPMD
analogue of physically migrating vertices between workers. The permutation's
cross-block traffic is exactly the migration volume the paper identifies as
the dominant overhead (§5.2.3), and we report it as such.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph


class Relocation(NamedTuple):
    perm: jax.Array          # (n_cap,) new_slot -> old_slot (gather indices)
    inv_perm: jax.Array      # (n_cap,) old_slot -> new_slot
    block_of: jax.Array      # (n_cap,) partition id per NEW slot
    moved: jax.Array         # () int32 — slots whose partition block changed
    moved_bytes_per_unit: jax.Array  # () int32 — same, for traffic accounting


def plan_relocation(graph: Graph, assignment: jax.Array, k: int) -> Relocation:
    """Stable sort nodes by partition id → contiguous blocks per partition.

    Padding slots sort to the end of their partition block (they keep their
    assignment so future additions inherit a home partition).
    """
    n_cap = assignment.shape[0]
    key = assignment.astype(jnp.int32) * 2 + (~graph.node_mask).astype(jnp.int32)
    perm = jnp.argsort(key, stable=True)
    inv_perm = jnp.zeros((n_cap,), jnp.int32).at[perm].set(
        jnp.arange(n_cap, dtype=jnp.int32))
    block_of = assignment[perm]
    old_block = jnp.arange(n_cap) * k // n_cap  # previous contiguous blocking
    moved = jnp.sum((inv_perm != jnp.arange(n_cap)) & graph.node_mask)
    return Relocation(perm=perm, inv_perm=inv_perm, block_of=block_of,
                      moved=moved.astype(jnp.int32),
                      moved_bytes_per_unit=moved.astype(jnp.int32))


def apply_relocation(graph: Graph, reloc: Relocation,
                     features: jax.Array) -> Tuple[Graph, jax.Array]:
    """Permute node storage (features + edge endpoints) to the new layout.

    In the distributed engine this gather is an ``all_to_all`` between device
    blocks — the physical vertex migration.
    """
    n_cap = graph.n_cap
    new_feat = features[reloc.perm]
    remap = reloc.inv_perm
    src = jnp.where(graph.edge_mask, remap[jnp.clip(graph.src, 0, n_cap - 1)], -1)
    dst = jnp.where(graph.edge_mask, remap[jnp.clip(graph.dst, 0, n_cap - 1)], -1)
    new_graph = Graph(src=src, dst=dst,
                      node_mask=graph.node_mask[reloc.perm],
                      edge_mask=graph.edge_mask)
    return new_graph, new_feat


def rcm_within_partitions(graph: Graph, assignment: jax.Array, k: int
                          ) -> Relocation:
    """Partition-contiguous relocation with reverse-Cuthill–McKee ordering
    *inside* each partition block.

    Plain partition-sort preserves arrival order within blocks, which
    destroys any natural banding (EXPERIMENTS.md §Perf refuted-hypothesis);
    a BFS/RCM pass per partition restores near-diagonal BSR structure, so
    the Pallas SpMM streams fewer tiles. Host-side (it is a data-layout
    pass, run at relocation events, not per step).
    """
    import collections

    from repro.graph.structure import to_csr

    lab = np.asarray(assignment)
    node_mask = np.asarray(graph.node_mask)
    indptr, indices = to_csr(graph)
    n_cap = graph.n_cap
    order: list = []
    for p in range(k):
        members = np.flatnonzero((lab == p) & node_mask)
        if members.size == 0:
            continue
        member_set = set(members.tolist())
        visited = set()
        # start from the minimum-degree member (RCM heuristic)
        degs = {int(v): int(indptr[v + 1] - indptr[v]) for v in members}
        for seed in sorted(members, key=lambda v: degs[int(v)]):
            seed = int(seed)
            if seed in visited:
                continue
            queue = collections.deque([seed])
            visited.add(seed)
            comp = []
            while queue:
                v = queue.popleft()
                comp.append(v)
                nbrs = [int(w) for w in indices[indptr[v]:indptr[v + 1]]
                        if int(w) in member_set and int(w) not in visited]
                nbrs.sort(key=lambda w: degs[w])
                visited.update(nbrs)
                queue.extend(nbrs)
            order.extend(reversed(comp))          # the "reverse" in RCM
    # padding slots go last, keeping their assignment
    pad = np.flatnonzero(~node_mask)
    perm = np.concatenate([np.asarray(order, np.int64), pad]).astype(np.int64)
    inv = np.zeros(n_cap, np.int32)
    inv[perm] = np.arange(n_cap, dtype=np.int32)
    block_of = lab[perm]
    moved = int((inv != np.arange(n_cap))[node_mask].sum())
    return Relocation(perm=jnp.asarray(perm), inv_perm=jnp.asarray(inv),
                      block_of=jnp.asarray(block_of),
                      moved=jnp.asarray(moved, jnp.int32),
                      moved_bytes_per_unit=jnp.asarray(moved, jnp.int32))


def device_blocks(n_cap: int, num_devices: int) -> np.ndarray:
    """Contiguous slot ranges per device: device d owns [starts[d], starts[d+1])."""
    per = -(-n_cap // num_devices)
    starts = np.minimum(np.arange(num_devices + 1) * per, n_cap)
    return starts


def cross_device_edge_fraction(graph: Graph, assignment: jax.Array,
                               k: int) -> jax.Array:
    """Fraction of live edges crossing partition blocks == collective traffic
    fraction of the distributed engine's neighbour gather."""
    n_cap = graph.n_cap
    a = assignment[jnp.clip(graph.src, 0, n_cap - 1)]
    b = assignment[jnp.clip(graph.dst, 0, n_cap - 1)]
    cut = jnp.sum((a != b) & graph.edge_mask)
    return cut / jnp.maximum(jnp.sum(graph.edge_mask), 1)
