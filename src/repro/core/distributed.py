"""Distributed xDGP engine: shard_map over a device mesh.

Paper ↔ SPMD mapping (see DESIGN.md §2):

  worker/JVM            → device; partition p ≡ node-slot block p (k == P)
  vertex objects        → rows of sharded feature / assignment arrays
  capacity messages     → ``jax.lax.psum`` of a k-vector (O(k) traffic, the
                          paper's scalability argument verbatim)
  neighbour messages    → halo exchange: each device ``all_gather``s only the
                          *boundary segment* of every block; cut edges decide
                          how large that segment must be, so partition quality
                          IS the collective volume (roofline collective term)
  deferred migration    → pending committed next superstep; the physical move
                          is the block-permuted relocation (all_to_all)

The engine keeps every shape static: edges are bucketed per destination
device and padded to the max bucket; the halo is padded to the max boundary
(optionally with head-room, see ``halo_pad``).

Two migration engines share the bucketing/halo machinery:

* ``make_distributed_migrator`` — the pure O(k)-message engine: per-block
  quota ranking, per-device RNG streams. Decentralised exactly like the
  paper, but its trajectories differ from the single-host heuristic.
* ``make_cluster_migrator`` — the *parity* engine behind the ``"sharded"``
  ``ExecutionBackend`` (DESIGN.md §10): a bit-exact SPMD mirror of
  ``core.migration.migrate_step``. RNG draws are made in the session's
  original slot order, quota ranking is a global order recovered from one
  all_gather of packed rank keys, and the capacity vector is psum'd —
  so a cluster session produces bit-identical assignments to a local one.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import axis_size, shard_map
from repro.graph.structure import Graph

# Trace-time counters: bumped inside jitted function *bodies*, so they count
# traces (→ compiles), not calls. The compile-cache tests assert on these;
# the sharded backend's whole performance story is that after warmup these
# stop moving (DESIGN.md §10).
TRACE_COUNTS = {"cluster_step": 0}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DistGraph:
    """Device-bucketed graph. Leading axis of every field = device axis P.

    Edge endpoints are encoded for halo addressing:
      src_owner (P,E): owning device of the edge source
      src_slot  (P,E): slot of the source *within its owner's boundary segment*
                       if remote, or within the local block if local
      src_local (P,E): bool — source lives on this device
      dst_local (P,E): destination slot within the local block
      edge_ok   (P,E): validity mask
      boundary  (P,B): local slots exported to other devices (halo source),
                       padded with 0 and masked by boundary_ok
    """

    src_owner: jax.Array
    src_slot: jax.Array
    src_local: jax.Array
    dst_local: jax.Array
    edge_ok: jax.Array
    boundary: jax.Array
    boundary_ok: jax.Array
    node_ok: jax.Array        # (P, n_blk) live-node mask per block

    @property
    def num_devices(self) -> int:
        return self.src_owner.shape[0]

    @property
    def block_size(self) -> int:
        return self.node_ok.shape[1]

    @property
    def halo_size(self) -> int:
        return self.boundary.shape[1]


@dataclasses.dataclass(frozen=True)
class BlockLayout:
    """Host-side mapping between session slot space and device-block space.

    The cluster engine stores vertices in partition-per-device blocks while
    the session keeps its canonical arrays in the original slot order; this
    is the dictionary between the two (the cluster migrator turns it into
    device-side gathers, so per-iteration conversion never touches the
    host).
    """

    perm: np.ndarray        # (n_cap,) new-slot-order -> old id (lexsort order)
    new_global: np.ndarray  # (n_cap,) old id -> block-space slot (-1 = dead)
    orig_id: np.ndarray     # (P*n_blk,) block-space slot -> old id (-1 = pad)
    n_cap: int
    n_blk: int
    num_devices: int


def build_dist_graph(graph: Graph, assignment: np.ndarray, num_devices: int,
                     block_size: Optional[int] = None,
                     ) -> Tuple[DistGraph, np.ndarray]:
    """Host-side bucketing of a partitioned graph onto P devices.

    Nodes are permuted so partition p occupies block p (the "vertex
    migration" materialised). Returns (DistGraph, perm) where perm maps
    new global slot -> old node id. (Compat surface over
    ``build_cluster_graph``, which additionally returns the full layout.)
    """
    dg, layout = build_cluster_graph(graph, assignment, num_devices,
                                     block_size=block_size)
    return dg, layout.perm


def _grow(need: int, floor: int, pad: float) -> int:
    """Padded-bucket growth policy (DESIGN.md §10).

    Reuse the previous size while the need fits (shape-stable: the jit
    executable keyed on it stays valid); on genuine growth jump by a
    fractional head-room so the next few supersteps fit too — O(log) shape
    buckets over a stream instead of one per superstep.
    """
    if need <= floor:
        return floor
    return max(need, int(np.ceil(need * (1.0 + pad))))


def build_cluster_graph(graph: Graph, assignment: np.ndarray, num_devices: int,
                        *, block_size: Optional[int] = None,
                        halo_pad: float = 0.0,
                        block_pad: float = 0.0, edge_pad: float = 0.0,
                        min_block: int = 0, min_edges: int = 0,
                        min_halo: int = 0,
                        ) -> Tuple[DistGraph, "BlockLayout"]:
    """Bucketing + halo build behind the backend interface.

    ``halo_pad`` is the halo padding policy: fractional head-room added on
    top of the largest boundary segment, so that all devices exchange the
    same (padded) halo volume and a later engine could grow boundaries
    without an immediate rebuild. ``block_pad`` / ``edge_pad`` are the
    sibling policies for the node-block and edge-bucket dimensions, and the
    ``min_*`` floors carry the previous build's shapes so a streaming
    rebuild keeps them unless the graph genuinely outgrew them — shape
    stability is what lets the backend reuse one compiled step across
    rebuilds instead of re-jitting every superstep.
    """
    if halo_pad < 0:
        raise ValueError(f"halo_pad must be >= 0, got {halo_pad}")
    if block_pad < 0 or edge_pad < 0:
        raise ValueError(f"block_pad/edge_pad must be >= 0, got "
                         f"{block_pad}/{edge_pad}")
    P = num_devices
    assignment = np.asarray(assignment)
    node_mask = np.asarray(graph.node_mask)
    n_cap = node_mask.shape[0]

    # --- permute nodes into partition blocks (stable: live first) --------
    order = np.lexsort((np.arange(n_cap), ~node_mask, assignment))
    perm = order                                   # new slot -> old id
    counts = np.bincount(assignment[node_mask], minlength=P)
    if block_size:
        n_blk = int(block_size)
    else:
        n_blk = _grow(int(max(1, counts.max())), min_block, block_pad)
    over = np.flatnonzero(counts > n_blk)
    if over.size:
        p = int(over[0])
        raise ValueError(f"partition {p} has {counts[p]} nodes > block {n_blk}")
    # per-partition compaction: slot within block — the lexsort already
    # groups each partition's live nodes contiguously in original-id order,
    # so a searchsorted over the sorted labels yields every in-block slot
    sorted_live = node_mask[order]
    live_pos = np.flatnonzero(sorted_live)
    lab_live = assignment[order][live_pos]          # non-decreasing
    ids_live = order[live_pos]
    p_starts = np.searchsorted(lab_live, np.arange(P))
    new_global = np.full(n_cap, -1, dtype=np.int64)
    new_global[ids_live] = (lab_live * n_blk
                            + np.arange(live_pos.size) - p_starts[lab_live])
    live_ids = np.flatnonzero(node_mask)
    assert (new_global[live_ids] >= 0).all()

    # --- symmetrised live edges in new coordinates ------------------------
    em = np.asarray(graph.edge_mask)
    s = np.asarray(graph.src)[em]
    d = np.asarray(graph.dst)[em]
    s2 = np.concatenate([s, d]).astype(np.int64)
    d2 = np.concatenate([d, s]).astype(np.int64)
    gs = new_global[s2]
    gd = new_global[d2]
    src_dev, src_off = gs // n_blk, gs % n_blk
    dst_dev, dst_off = gd // n_blk, gd % n_blk

    # --- boundary sets: local slots referenced by remote edges ------------
    # one sorted unique over packed (dev, off) keys replaces the per-device
    # set builds + the (dev, off) -> halo-index dict
    cut = src_dev != dst_dev
    b_uniq = np.unique(src_dev[cut] * n_blk + src_off[cut])   # sorted keys
    b_dev = b_uniq // n_blk
    b_counts = np.bincount(b_dev, minlength=P) if P else np.zeros(0, np.int64)
    b_starts = np.searchsorted(b_dev, np.arange(P))
    b_max = int(b_counts.max()) if P else 1
    B = _grow(max(1, b_max), min_halo, halo_pad)
    boundary = np.zeros((P, B), dtype=np.int32)
    boundary_ok = np.zeros((P, B), dtype=bool)
    b_pos = np.arange(b_uniq.size) - b_starts[b_dev]
    boundary[b_dev, b_pos] = b_uniq % n_blk
    boundary_ok[b_dev, b_pos] = True

    # --- bucket edges by destination device --------------------------------
    e_counts = np.bincount(dst_dev, minlength=P) if P else np.zeros(0, np.int64)
    E = _grow(int(max(1, e_counts.max())) if P else 1, min_edges, edge_pad)
    src_owner = np.zeros((P, E), dtype=np.int32)
    src_slot = np.zeros((P, E), dtype=np.int32)
    src_local = np.zeros((P, E), dtype=bool)
    dst_local = np.zeros((P, E), dtype=np.int32)
    edge_ok = np.zeros((P, E), dtype=bool)
    # stable sort keeps each bucket in original edge order, matching the
    # per-device flatnonzero scan this replaces bit for bit
    e_order = np.argsort(dst_dev, kind="stable")
    e_dev = dst_dev[e_order]
    e_pos = np.arange(e_order.size) - np.searchsorted(e_dev, np.arange(P))[e_dev]
    loc = (src_dev == dst_dev)[e_order]
    # halo index of a remote source = rank of its packed key within its
    # owner's boundary set (valid only where ~loc; masked by the where)
    halo_of = (np.searchsorted(b_uniq, (src_dev * n_blk + src_off)[e_order])
               - b_starts[src_dev[e_order]])
    src_owner[e_dev, e_pos] = src_dev[e_order]
    src_slot[e_dev, e_pos] = np.where(loc, src_off[e_order], halo_of)
    src_local[e_dev, e_pos] = loc
    dst_local[e_dev, e_pos] = dst_off[e_order]
    edge_ok[e_dev, e_pos] = True

    node_ok = np.arange(n_blk)[None, :] < counts[:, None]

    dg = DistGraph(
        src_owner=jnp.asarray(src_owner), src_slot=jnp.asarray(src_slot),
        src_local=jnp.asarray(src_local), dst_local=jnp.asarray(dst_local),
        edge_ok=jnp.asarray(edge_ok), boundary=jnp.asarray(boundary),
        boundary_ok=jnp.asarray(boundary_ok), node_ok=jnp.asarray(node_ok))
    orig_id = np.full((P * n_blk,), -1, np.int64)
    orig_id[new_global[live_ids]] = live_ids
    layout = BlockLayout(perm=perm, new_global=new_global, orig_id=orig_id,
                         n_cap=n_cap, n_blk=n_blk, num_devices=P)
    return dg, layout


# ---------------------------------------------------------------------------
# shard_map programs (mesh axis name: "nodes")
# ---------------------------------------------------------------------------

AXIS = "nodes"


def _halo_exchange(local_feat: jax.Array, dg_local: DistGraph,
                   axis: str = AXIS) -> jax.Array:
    """all_gather of every device's boundary segment → (P*B, d) halo buffer.

    Collective volume per device = P·B·d — proportional to the cut, which is
    what the adaptive heuristic minimises.
    """
    bnd = local_feat[dg_local.boundary[0]]              # (B, d)
    bnd = jnp.where(dg_local.boundary_ok[0][:, None], bnd, 0)
    halo = jax.lax.all_gather(bnd, axis, tiled=True)     # (P*B, d)
    return halo


def superstep_shard(local_feat: jax.Array, dg_local: DistGraph,
                    halo_size: int, combine: str = "sum") -> jax.Array:
    """One distributed neighbour aggregation for a (n_blk, d) feature block."""
    halo = _halo_exchange(local_feat, dg_local)
    src_owner = dg_local.src_owner[0]
    src_slot = dg_local.src_slot[0]
    src_local = dg_local.src_local[0]
    dst_local = dg_local.dst_local[0]
    edge_ok = dg_local.edge_ok[0]
    halo_idx = src_owner * halo_size + src_slot
    feat_remote = halo[jnp.clip(halo_idx, 0, halo.shape[0] - 1)]
    feat_local = local_feat[src_slot]
    feat_src = jnp.where(src_local[:, None], feat_local, feat_remote)
    feat_src = jnp.where(edge_ok[:, None], feat_src, 0)
    n_blk = local_feat.shape[0]
    seg = jnp.where(edge_ok, dst_local, n_blk)
    agg = jax.ops.segment_sum(feat_src, seg, num_segments=n_blk + 1)[:n_blk]
    return agg


def make_distributed_aggregate(mesh: jax.sharding.Mesh, dg: DistGraph):
    """Returns jit'd (features -> aggregated neighbour sum) over the mesh."""
    P = dg.num_devices
    halo = dg.halo_size
    spec = jax.sharding.PartitionSpec(AXIS)
    dg_specs = DistGraph(*([spec] * 8))  # all fields sharded on leading axis

    @jax.jit
    def agg_fn(features: jax.Array) -> jax.Array:
        f = shard_map(
            lambda lf, dgl: superstep_shard(lf, dgl, halo),
            mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(AXIS, None), dg_specs),
            out_specs=jax.sharding.PartitionSpec(AXIS, None),
        )
        flat = features.reshape(P * dg.block_size, -1)
        return f(flat, dg).reshape(features.shape)

    return agg_fn


def migrate_step_shard(assignment_blk: jax.Array, pending_blk: jax.Array,
                       rng_blk: jax.Array, dg_local: DistGraph,
                       capacity: jax.Array, k: int, halo_size: int,
                       s: float = 0.5) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One adaptive-migration iteration per device block (k == P).

    The label halo plays the role of the paper's neighbour-location
    knowledge; the psum'd occupancy vector is the capacity message.
    Because partition i is device i, quota ranking of partition i's movers
    is fully local — the paper's decentralisation argument holds exactly.
    """
    my = jax.lax.axis_index(AXIS)
    node_ok = dg_local.node_ok[0]
    # COMMIT
    assignment_blk = jnp.where(pending_blk >= 0, pending_blk, assignment_blk)
    # label halo exchange (int32 labels travel as-is: no float32 round-trip,
    # precision-safe for label spaces beyond 2^24)
    halo = _halo_exchange(assignment_blk[:, None], dg_local)[:, 0]
    src_owner = dg_local.src_owner[0]
    src_slot = dg_local.src_slot[0]
    src_is_local = dg_local.src_local[0]
    dst_local = dg_local.dst_local[0]
    edge_ok = dg_local.edge_ok[0]
    lab_remote = halo[jnp.clip(src_owner * halo_size + src_slot, 0, halo.shape[0] - 1)]
    lab_local = assignment_blk[src_slot]
    lab_src = jnp.where(src_is_local, lab_local, lab_remote)
    n_blk = assignment_blk.shape[0]
    seg = jnp.where(edge_ok, dst_local, n_blk)
    onehot = jax.nn.one_hot(lab_src, k, dtype=jnp.int32) * edge_ok[:, None]
    counts = jax.ops.segment_sum(onehot, seg, num_segments=n_blk + 1)[:n_blk]
    # DECIDE (random tie-break) + DAMP
    # (rng_blk is replicated; fold in the device id for per-device randomness
    #  but return a device-independent successor key)
    rng, k1, k2 = jax.random.split(rng_blk, 3)
    r1 = jax.random.fold_in(k1, my)
    r2 = jax.random.fold_in(k2, my)
    noise = jax.random.uniform(r1, counts.shape)
    target = jnp.argmax(counts.astype(jnp.float32) + noise, axis=1).astype(jnp.int32)
    isolated = jnp.max(counts, axis=1) == 0
    target = jnp.where(isolated | ~node_ok, assignment_blk, target)
    wants = (target != assignment_blk) & node_ok
    gate = jax.random.bernoulli(r2, s, wants.shape)
    willing = wants & gate
    # CAPACITY psum (k-vector, the paper's worker-to-worker message)
    occ_local = jax.ops.segment_sum(node_ok.astype(jnp.int32),
                                    jnp.where(node_ok, assignment_blk, k),
                                    num_segments=k + 1)[:k]
    occ = jax.lax.psum(occ_local, AXIS)
    free = jnp.maximum(capacity - occ, 0)
    # Paper's Q^{i,j} assumes partition i lives wholly on worker i; with
    # deferred physical relocation a partition's vertices can span several
    # storage blocks, so the per-block quota must bound the TOTAL influx:
    # free // P guarantees sum over blocks ≤ free for any label placement.
    n_blocks = axis_size(AXIS)
    quota = free // jnp.maximum(n_blocks, 1)
    # QUOTA: local ranking of this block's movers per destination
    tgt_safe = jnp.clip(target, 0, k - 1)
    order = jnp.argsort(jnp.where(willing, tgt_safe, k + 1))
    sorted_t = jnp.where(willing, tgt_safe, k + 1)[order]
    pos = jnp.arange(n_blk, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sorted_t[1:] != sorted_t[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, pos, 0))
    rank_sorted = pos - run_start
    rank = jnp.zeros((n_blk,), jnp.int32).at[order].set(rank_sorted)
    admitted = willing & (rank < quota[tgt_safe])
    pending = jnp.where(admitted, target, jnp.int32(-1))
    return assignment_blk, pending, rng


def make_distributed_migrator(mesh: jax.sharding.Mesh, dg: DistGraph, k: int,
                              s: float = 0.5):
    """jit'd distributed migration step over the mesh (k == P required)."""
    P = dg.num_devices
    if k != P:
        raise ValueError(f"distributed engine requires k == num_devices ({k} != {P})")
    halo = dg.halo_size
    spec_n = jax.sharding.PartitionSpec(AXIS)
    dg_specs = DistGraph(*([spec_n] * 8))

    @jax.jit
    def step(assignment: jax.Array, pending: jax.Array, rng: jax.Array,
             capacity: jax.Array):
        f = shard_map(
            partial(migrate_step_shard, k=k, halo_size=halo, s=s),
            mesh=mesh,
            in_specs=(spec_n, spec_n, jax.sharding.PartitionSpec(), dg_specs,
                      jax.sharding.PartitionSpec()),
            out_specs=(spec_n, spec_n, jax.sharding.PartitionSpec()),
        )
        return f(assignment, pending, rng, dg, capacity)

    return step


# ---------------------------------------------------------------------------
# Parity engine: bit-exact SPMD mirror of core.migration.migrate_step
# (the execution layer behind repro.api's "sharded" backend, DESIGN.md §10)
# ---------------------------------------------------------------------------


def rank_key_dtype(k: int, n_cap: int):
    """The narrowest dtype the quota ranking's packed ``group·n_cap +
    orig_id`` keys fit in — int32 while they fit (the historical layout,
    byte-identical on the wire), uint32 out to ~4.3e9 key values (k=8 at
    ~66M vertices without needing x64), int64 beyond that when JAX x64 is
    enabled.  Fails loudly instead of wrapping: a silently aliased key
    would merge two (src, dst) quota groups and admit the wrong movers."""
    span = (k * k) * n_cap + n_cap       # strict upper bound on any key
    if span < 2 ** 31:
        return jnp.int32
    if span < 2 ** 32:
        return jnp.uint32
    if span < 2 ** 63 and jax.dtypes.canonicalize_dtype(jnp.int64) == jnp.int64:
        return jnp.int64
    raise OverflowError(
        f"quota rank keys span {span} values (k={k}, n_cap={n_cap}), which "
        f"overflows uint32 and JAX x64 is disabled — enable jax_enable_x64 "
        f"or reduce n_cap")


def cluster_migrate_shard(assignment_blk: jax.Array, pending_blk: jax.Array,
                          noise_blk: jax.Array, gate_blk: jax.Array,
                          orig_blk: jax.Array, dg_local: DistGraph,
                          capacity: jax.Array, *, k: int, halo_size: int,
                          n_cap: int, tie_break: str, axis: str = AXIS,
                          key_dtype=jnp.int32,
                          ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                     jax.Array, jax.Array]:
    """One adaptive iteration per device block — decision-identical to the
    single-host ``migrate_step`` (commit → score → decide → damp → quota →
    defer), with the distribution showing only in *where* terms come from:

      neighbour labels      → boundary-segment halo exchange (all_gather)
      occupancy/capacity    → psum of a k-vector (the paper's O(k) message)
      quota ranking         → the single-host rank orders movers of a
                              (src, dst) pair by original slot id; that order
                              is recovered exactly from one all_gather of
                              packed ``group · n_cap + orig_id`` keys

    ``noise_blk``/``gate_blk`` are the *same* RNG draws the local step makes
    (drawn over the original slot space and scattered into blocks by the
    caller), so damping and tie-breaking match draw for draw.
    """
    node_ok = dg_local.node_ok[0]
    # ---- 1. COMMIT deferred migrations from t-1 -------------------------
    has_pending = pending_blk >= 0
    assignment_blk = jnp.where(has_pending, pending_blk, assignment_blk)
    committed = jax.lax.psum(
        jnp.sum(has_pending & node_ok).astype(jnp.int32), axis)

    # ---- 2. SCORE: neighbour-label histogram via the label halo ----------
    # int32 labels exchanged directly (no float32 round-trip on the hot path)
    halo = _halo_exchange(assignment_blk[:, None], dg_local, axis)[:, 0]
    src_owner = dg_local.src_owner[0]
    src_slot = dg_local.src_slot[0]
    src_is_local = dg_local.src_local[0]
    dst_local = dg_local.dst_local[0]
    edge_ok = dg_local.edge_ok[0]
    lab_remote = halo[jnp.clip(src_owner * halo_size + src_slot,
                               0, halo.shape[0] - 1)]
    lab_src = jnp.where(src_is_local, assignment_blk[src_slot], lab_remote)
    n_blk = assignment_blk.shape[0]
    seg = jnp.where(edge_ok, dst_local, n_blk)
    onehot = jax.nn.one_hot(lab_src, k, dtype=jnp.int32) * edge_ok[:, None]
    counts = jax.ops.segment_sum(onehot, seg, num_segments=n_blk + 1)[:n_blk]

    # ---- 3. DECIDE (same rule, expressions and dtypes as greedy_targets) --
    best_count = jnp.max(counts, axis=1)
    cur = jnp.clip(assignment_blk, 0, k - 1)
    isolated = (best_count == 0) | ~node_ok
    if tie_break == "stay":
        cur_count = jnp.take_along_axis(counts, cur[:, None], axis=1)[:, 0]
        stay = (cur_count >= best_count) | isolated
        target = jnp.where(stay, cur,
                           jnp.argmax(counts, axis=1).astype(jnp.int32))
    else:                                   # "random" (validated by caller)
        score = counts.astype(jnp.float32) + noise_blk
        target = jnp.argmax(score, axis=1).astype(jnp.int32)
        target = jnp.where(isolated, cur, target)
    wants_move = (target != assignment_blk) & node_ok

    # ---- 4. DAMP (the session's own Bernoulli(s) draw, pre-scattered) ----
    willing = wants_move & gate_blk
    n_willing = jax.lax.psum(jnp.sum(willing).astype(jnp.int32), axis)

    # ---- 5. QUOTA: psum'd occupancy + globally-ordered ranking -----------
    occ_local = jax.ops.segment_sum(
        node_ok.astype(jnp.int32),
        jnp.where(node_ok, assignment_blk, k), num_segments=k + 1)[:k]
    occ = jax.lax.psum(occ_local, axis)
    free = jnp.maximum(capacity - occ, 0)
    quota = free // jnp.maximum(k - 1, 1)
    src_part = jnp.clip(assignment_blk, 0, k - 1)
    tgt_safe = jnp.clip(target, 0, k - 1)
    group = src_part * k + tgt_safe
    # keys pack (src, dst, orig slot) into one integer; the dtype is chosen
    # by rank_key_dtype so the packing can never silently wrap at scale
    big = jnp.iinfo(key_dtype).max
    group_base = group.astype(key_dtype) * jnp.asarray(n_cap, key_dtype)
    key = jnp.where(willing, group_base + orig_blk.astype(key_dtype), big)
    all_keys = jnp.sort(jax.lax.all_gather(key, axis, tiled=True))
    # rank within (i, j) group in original slot order: position of my key
    # among all active keys minus the position where my group begins
    rank = (jnp.searchsorted(all_keys, key)
            - jnp.searchsorted(all_keys, group_base)).astype(jnp.int32)
    admitted = willing & (rank < quota[tgt_safe])
    n_admitted = jax.lax.psum(jnp.sum(admitted).astype(jnp.int32), axis)

    # ---- 6. DEFER ---------------------------------------------------------
    pending = jnp.where(admitted, target, jnp.int32(-1))
    return assignment_blk, pending, committed, n_willing, n_admitted


def layout_device_arrays(layout: BlockLayout
                         ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                    jax.Array]:
    """The four scatter/gather arrays a cluster step consumes, as device
    arrays: ``(blk_live, orig, ng_safe, slot_live)``. They are jit
    *arguments* of ``make_cluster_step`` (not closure constants), so a
    rebuilt layout with the same shapes reuses the compiled executable.
    """
    blk_live = jnp.asarray(layout.orig_id >= 0)
    orig = jnp.asarray(np.maximum(layout.orig_id, 0), jnp.int32)
    slot_live = jnp.asarray(layout.new_global >= 0)
    ng_safe = jnp.asarray(
        np.clip(layout.new_global, 0, layout.orig_id.shape[0] - 1), jnp.int32)
    return blk_live, orig, ng_safe, slot_live


def make_cluster_step(mesh: jax.sharding.Mesh, *, k: int, n_cap: int,
                      tie_break: str = "random", axis: str = AXIS,
                      key_dtype=None):
    """jit'd parity migration step over the mesh (k == P required).

    Returns ``step(assignment, pending, rng, capacity, s, dg, blk_live,
    orig, ng_safe, slot_live) -> (assignment, pending, rng, (committed,
    willing, admitted))`` operating on the session's canonical (n_cap,)
    slot-space arrays: the slot↔block permutation happens as device-side
    gathers inside the one jit program, so an iteration costs no host
    round-trip. Stats are the same integers the local ``migrate_step``
    reports, and successive calls thread the session RNG exactly like the
    local step does (one 3-way split per iteration).

    Everything that changes across streaming rebuilds — the bucketing
    (``dg``), the layout scatter/gather arrays, the damping ``s`` — enters
    as a jit *argument*, so the compiled executable is keyed only on array
    shapes: as long as the padded bucket shapes hold (see ``_grow``), a
    rebuilt graph dispatches straight into the cached executable instead of
    re-tracing every superstep. ``s`` is traced as a weak scalar, so
    different damping values share one executable too (``bernoulli(key, p)``
    is ``uniform(key) < p`` — bitwise-identical to a baked-in constant).
    """
    P = int(np.prod(mesh.devices.shape))
    if k != P:
        raise ValueError(f"cluster engine is partition-per-device: k must "
                         f"equal the device count ({k} != {P})")
    if tie_break not in ("random", "stay"):
        raise ValueError(f"unknown tie_break {tie_break!r}")
    if key_dtype is None:       # widen past int32 as n_cap·k² grows; the
        key_dtype = rank_key_dtype(k, n_cap)   # ranks are dtype-invariant
    spec_n = jax.sharding.PartitionSpec(axis)
    spec_r = jax.sharding.PartitionSpec()
    dg_specs = DistGraph(*([spec_n] * 8))

    @jax.jit
    def step(assignment: jax.Array, pending: jax.Array, rng: jax.Array,
             capacity: jax.Array, s: jax.Array, dg: DistGraph,
             blk_live: jax.Array, orig: jax.Array, ng_safe: jax.Array,
             slot_live: jax.Array):
        # body runs only when jit traces → counts compiles, not dispatches
        TRACE_COUNTS["cluster_step"] += 1
        halo = dg.halo_size                     # static under trace
        orig_safe = jnp.clip(orig, 0, n_cap - 1)
        # scatter slot-space state into blocks (pad slots: stay, no pending)
        assignment_blk = jnp.where(blk_live, assignment[orig_safe], 0)
        pending_blk = jnp.where(blk_live, pending[orig_safe], -1)
        # identical split order and draw shapes to migrate_step: the draws
        # live in ORIGINAL slot space and are scattered into blocks
        rng_next, tie_key, sub = jax.random.split(rng, 3)
        if tie_break == "random":
            noise_blk = jax.random.uniform(tie_key, (n_cap, k))[orig_safe]
        else:
            noise_blk = jnp.zeros((orig.shape[0], k), jnp.float32)
        gate_blk = jax.random.bernoulli(sub, p=s, shape=(n_cap,))[orig_safe]
        f = shard_map(
            partial(cluster_migrate_shard, k=k, halo_size=halo, n_cap=n_cap,
                    tie_break=tie_break, axis=axis, key_dtype=key_dtype),
            mesh=mesh,
            in_specs=(spec_n, spec_n, spec_n, spec_n, spec_n, dg_specs,
                      spec_r),
            out_specs=(spec_n, spec_n, spec_r, spec_r, spec_r),
        )
        a_blk, p_blk, committed, willing, admitted = f(
            assignment_blk, pending_blk, noise_blk, gate_blk, orig, dg,
            capacity)
        # gather back to slot space; dead slots keep their labels (they
        # never migrate locally either) and carry no pending
        a = jnp.where(slot_live, a_blk[ng_safe], assignment)
        p = jnp.where(slot_live, p_blk[ng_safe], -1)
        return a, p, rng_next, (committed, willing, admitted)

    replicated = jax.sharding.NamedSharding(mesh,
                                            jax.sharding.PartitionSpec())

    def step_on_mesh(assignment: jax.Array, pending: jax.Array,
                     rng: jax.Array, capacity: jax.Array, s, dg: DistGraph,
                     blk_live: jax.Array, orig: jax.Array,
                     ng_safe: jax.Array, slot_live: jax.Array):
        # state arrays may still be committed to a previous mesh (local
        # execution, or a pre-rescale device count) — a no-op when already
        # placed here, a copy exactly once after a backend/mesh change.
        # Pinning the placement also pins the jit cache key: every dispatch
        # sees identically-sharded avals.
        args = jax.device_put((assignment, pending, rng, capacity),
                              replicated)
        return step(*args, float(s), dg, blk_live, orig, ng_safe, slot_live)

    return step_on_mesh


def make_cluster_migrator(mesh: jax.sharding.Mesh, dg: DistGraph,
                          layout: BlockLayout, k: int, *, s: float = 0.5,
                          tie_break: str = "random", axis: str = AXIS):
    """Compat surface over ``make_cluster_step``: binds one bucketing and a
    fixed ``s`` and returns ``step(assignment, pending, rng, capacity)``.

    The backend no longer uses this (it keys ``make_cluster_step``
    executables by shape signature and threads ``dg``/layout per call); it
    remains for direct callers and the parity tests.
    """
    step = make_cluster_step(mesh, k=k, n_cap=layout.n_cap,
                             tie_break=tie_break, axis=axis)
    mig_args = (dg, *layout_device_arrays(layout))

    def bound_step(assignment: jax.Array, pending: jax.Array,
                   rng: jax.Array, capacity: jax.Array):
        return step(assignment, pending, rng, capacity, s, *mig_args)

    return bound_step


def comm_model(dg: DistGraph, k: int, label_bytes: int = 4) -> dict:
    """Per-iteration communication bill of the cluster engine, per device.

    Derived host-side from the (static) bucketing shapes — the wire volume
    of a shard_map iteration is fully determined by them:

      halo          — each device receives every boundary segment: P·B·b
                      bytes (padded); the *live* fraction is the cut
                      frontier, which is what the heuristic shrinks.
      capacity psum — the paper's O(k) worker message: k·b bytes.
      rank gather   — the quota-parity all_gather: P·n_blk·b bytes (the
                      price of bit-exact global ranking; the pure O(k)
                      engine in ``make_distributed_migrator`` skips it).
    """
    P, B, n_blk = dg.num_devices, dg.halo_size, dg.block_size
    live_boundary = np.asarray(dg.boundary_ok).sum(axis=1).astype(int)
    return {
        "devices": P,
        "halo_slots": B,
        "halo_bytes_per_device": P * B * label_bytes,
        "halo_live_bytes_per_device": int(live_boundary.sum()) * label_bytes,
        "boundary_live_per_device": live_boundary.tolist(),
        "collective_bytes_per_device": (k + P * n_blk) * label_bytes,
        "rank_gather_bytes_per_device": P * n_blk * label_bytes,
        "capacity_psum_bytes_per_device": k * label_bytes,
    }
