"""Initial partitioning strategies evaluated in the paper (§5.2.1, Fig. 5).

* HSH — modulo hash of a mixed vertex id (the de-facto standard; scatters).
* RND — pseudorandom balanced assignment.
* DGR — streaming "linear deterministic greedy" (Stanton & Kliot, KDD'12).
* MNN — streaming "minimum number of neighbours" (Prabhakaran et al., ATC'12).

DGR/MNN are host-side streaming passes (they are *initial* partitioners and
the paper itself notes they need full graph knowledge, limiting scalability).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.graph.structure import Graph, to_csr


def _mix(ids: np.ndarray) -> np.ndarray:
    """64-bit splitmix-style mixer so sequential ids scatter like real hashes."""
    x = ids.astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    return x


def hash_partition(graph: Graph, k: int) -> jnp.ndarray:
    """HSH: H(v) mod k."""
    ids = np.arange(graph.n_cap, dtype=np.int64)
    lab = (_mix(ids) % np.uint64(k)).astype(np.int32)
    return jnp.asarray(lab)


def modulo_partition(graph: Graph, k: int) -> jnp.ndarray:
    """Plain v mod k (no mixing) — keeps sequential locality; for ablations."""
    ids = np.arange(graph.n_cap, dtype=np.int64)
    return jnp.asarray((ids % k).astype(np.int32))


def block_partition(graph: Graph, k: int) -> jnp.ndarray:
    """Contiguous blocks of ids (what a range-sharded store would do)."""
    ids = np.arange(graph.n_cap, dtype=np.int64)
    per = -(-graph.n_cap // k)
    return jnp.asarray(np.minimum(ids // per, k - 1).astype(np.int32))


def random_partition(graph: Graph, k: int, seed: int = 0) -> jnp.ndarray:
    """RND: balanced pseudorandom assignment (shuffle + round-robin)."""
    rng = np.random.default_rng(seed)
    n = graph.n_cap
    lab = np.arange(n, dtype=np.int64) % k
    rng.shuffle(lab)
    return jnp.asarray(lab.astype(np.int32))


def _streaming(graph: Graph, k: int, mode: str, slack: float = 0.1,
               seed: int = 0) -> jnp.ndarray:
    indptr, indices = to_csr(graph)
    node_mask = np.asarray(graph.node_mask)
    n_cap = graph.n_cap
    n_live = int(node_mask.sum())
    cap = int(round(-(-n_live // k) * (1.0 + slack))) + 1
    sizes = np.zeros(k, dtype=np.int64)
    lab = np.full(n_cap, -1, dtype=np.int32)
    rng = np.random.default_rng(seed)
    order = np.flatnonzero(node_mask)
    counts = np.zeros(k, dtype=np.int64)
    for v in order:
        nbrs = indices[indptr[v]:indptr[v + 1]]
        counts[:] = 0
        if nbrs.size:
            placed = lab[nbrs]
            placed = placed[placed >= 0]
            if placed.size:
                np.add.at(counts, placed, 1)
        room = sizes < cap
        if mode == "dgr":
            # linear deterministic greedy: |N(v) ∩ P_i| * (1 - |P_i|/C)
            score = counts * (1.0 - sizes / cap)
            score = np.where(room, score, -np.inf)
            best = int(np.argmax(score))
            if not np.isfinite(score[best]):
                best = int(np.argmin(sizes))
        elif mode == "mnn":
            # minimum number of neighbours among partitions with room
            score = np.where(room, counts, np.iinfo(np.int64).max)
            best = int(np.argmin(score))
        else:
            raise ValueError(mode)
        lab[v] = best
        sizes[best] += 1
    # padding slots: hash them so future node additions have a home
    pad = lab < 0
    if pad.any():
        ids = np.flatnonzero(pad).astype(np.int64)
        lab[pad] = (_mix(ids) % np.uint64(k)).astype(np.int32)
    return jnp.asarray(lab)


def deterministic_greedy(graph: Graph, k: int, slack: float = 0.1) -> jnp.ndarray:
    """DGR (Stanton & Kliot linear deterministic greedy), streaming."""
    return _streaming(graph, k, "dgr", slack)


def min_neighbours(graph: Graph, k: int, slack: float = 0.1) -> jnp.ndarray:
    """MNN streaming heuristic."""
    return _streaming(graph, k, "mnn", slack)


# Legacy name → function map, kept for direct callers; ``initial_partition``
# itself now resolves through the ``repro.api`` strategy registry, so every
# registered ``PartitionStrategy`` (including user-defined ones) is reachable
# from the seed-era entry point too.
STRATEGIES = {
    "hsh": hash_partition,
    "rnd": random_partition,
    "dgr": deterministic_greedy,
    "mnn": min_neighbours,
    "mod": modulo_partition,
    "blk": block_partition,
}


def initial_partition(graph: Graph, k: int, strategy: str = "hsh", **kw) -> jnp.ndarray:
    """Initial labels for ``graph`` under a named strategy.

    ``strategy`` is resolved through the ``repro.api`` registry (an unknown
    name raises a ``ValueError`` listing every registered strategy); extra
    keyword arguments are forwarded to the strategy constructor
    (e.g. ``seed=`` for ``rnd``, ``slack=`` for ``dgr``/``mnn``).
    """
    # imported lazily: the api layer is built on top of repro.core
    from repro.api.strategy import resolve_strategy
    return resolve_strategy(strategy, **kw).init(graph, k)
