"""xDGP core: adaptive iterative graph (re)partitioning (the paper's contribution)."""
from repro.core.partition_state import (PartitionState, default_capacity,
                                        imbalance, make_state, occupancy)
from repro.core.migration import (MigrationStats, flush_pending,
                                  greedy_targets, migrate_step,
                                  neighbour_partition_counts)
from repro.core.initial import STRATEGIES, initial_partition
from repro.core.repartitioner import (AdaptiveConfig, AdaptivePartitioner,
                                      History, adapt_jit, adapt_rounds,
                                      converge_jit, run_to_convergence)

__all__ = [
    "PartitionState", "default_capacity", "imbalance", "make_state", "occupancy",
    "MigrationStats", "flush_pending", "greedy_targets", "migrate_step",
    "neighbour_partition_counts", "STRATEGIES", "initial_partition",
    "AdaptiveConfig", "AdaptivePartitioner", "History",
    "adapt_jit", "adapt_rounds", "converge_jit", "run_to_convergence",
]
