"""Beyond-paper: MoE expert placement via the xDGP migration heuristic.

The token→expert routing of a top-k MoE induces a weighted co-activation
graph over experts: experts that fire for the same token exchange activations
through the same all_to_all. Placing co-activated experts on the same device
(while keeping per-device expert load balanced) reduces cross-device dispatch
traffic — a dynamic partitioning problem with exactly the paper's structure:

  vertices   = experts (weighted by routing load)
  edges      = co-routing counts (experts chosen together for one token)
  partitions = devices, capacity = experts/device (hard balance)
  dynamism   = routing statistics drift during training → re-adapt online

DESIGN.md §4 marks the core technique inapplicable to MoE *models*; this is
its transfer to the *placement* layer. Used by examples and tested in
tests/test_expert_placement.py; wiring it into the dispatch permutation is a
one-line gather on the expert axis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition_state import make_state
from repro.core.repartitioner import adapt_rounds
from repro.graph.structure import Graph, from_edges


def co_routing_graph(expert_choices: np.ndarray, n_experts: int,
                     max_edges: int = 100_000) -> Tuple[Graph, np.ndarray]:
    """Build the expert co-activation graph from routing decisions.

    expert_choices: (T, k) int array of per-token top-k expert ids.
    Returns (graph over experts, per-expert load).
    """
    t, k = expert_choices.shape
    load = np.bincount(expert_choices.reshape(-1), minlength=n_experts)
    srcs, dsts, counts = [], [], {}
    for a in range(k):
        for b in range(a + 1, k):
            pairs = expert_choices[:, [a, b]]
            lo = pairs.min(1)
            hi = pairs.max(1)
            key = lo.astype(np.int64) * n_experts + hi
            uniq, cnt = np.unique(key, return_counts=True)
            for u, c in zip(uniq, cnt):
                counts[int(u)] = counts.get(int(u), 0) + int(c)
    # keep the strongest co-activations (cap for static shapes)
    items = sorted(counts.items(), key=lambda kv: -kv[1])[:max_edges]
    src = np.array([u // n_experts for u, _ in items], np.int64)
    dst = np.array([u % n_experts for u, _ in items], np.int64)
    return from_edges(src, dst, n_experts), load


def place_experts(expert_choices: np.ndarray, n_experts: int, n_devices: int,
                  adapt_iters: int = 80, seed: int = 0
                  ) -> Tuple[np.ndarray, dict]:
    """Returns (placement (E,) device id per expert, report).

    Balance is hard: exactly E/n_devices experts per device (capacity slack
    0 + final greedy fix-up), matching the fixed expert-parallel layout.
    """
    if n_experts % n_devices:
        raise ValueError("n_experts must divide n_devices")
    g, load = co_routing_graph(expert_choices, n_experts)
    per = n_experts // n_devices
    # initial: contiguous blocks (the default layout)
    init = (np.arange(n_experts) // per).astype(np.int32)
    # soft capacity during adaptation: quotas are floor(free/(k-1)), so the
    # head-room must be at least k-1 for any move to be admitted; the
    # fix-up below restores exact balance afterwards
    cap = per + max(n_devices - 1, per // 4)
    state = make_state(g, jnp.asarray(init), n_devices, seed=seed,
                       capacity=jnp.full((n_devices,), cap, jnp.int32))
    state, hist = adapt_rounds(g, state, adapt_iters)
    placement = np.asarray(state.assignment)[:n_experts].copy()
    # hard fix-up: enforce exact per-device count (move overflow greedily)
    counts = np.bincount(placement, minlength=n_devices)
    over = [d for d in range(n_devices) if counts[d] > per]
    under = [d for d in range(n_devices) if counts[d] < per]
    for d in over:
        extra = np.flatnonzero(placement == d)[per:]
        for e in extra:
            tgt = under[0]
            placement[e] = tgt
            counts[tgt] += 1
            if counts[tgt] == per:
                under.pop(0)
    report = {
        "cross_traffic_before": _cross_traffic(expert_choices, init, n_devices),
        "cross_traffic_after": _cross_traffic(expert_choices, placement,
                                              n_devices),
        "iters": hist.iterations,
    }
    report["reduction_pct"] = round(
        100 * (1 - report["cross_traffic_after"] /
               max(report["cross_traffic_before"], 1)), 1)
    return placement, report


def _cross_traffic(expert_choices: np.ndarray, placement: np.ndarray,
                   n_devices: int) -> int:
    """Pairs of same-token expert choices landing on different devices."""
    t, k = expert_choices.shape
    dev = placement[expert_choices]                      # (T, k)
    cross = 0
    for a in range(k):
        for b in range(a + 1, k):
            cross += int((dev[:, a] != dev[:, b]).sum())
    return cross
