"""Serving layer (DESIGN.md §12): the LM decode engine and the multi-tenant
graph session server with its open-loop load generation and crash drill."""
from repro.serve.engine import Completion, Request, ServeEngine
from repro.serve.loadgen import (OpenLoopLoad, TrafficShape, arrival_offsets,
                                 synthetic_stream, tick_schedule)
from repro.serve.server import (AdmissionPolicy, AutoscalePolicy,
                                CheckpointPolicy, GraphServer, SubmitResult,
                                Tenant, telemetry_digest)

__all__ = [
    "Completion", "Request", "ServeEngine",
    "GraphServer", "Tenant", "SubmitResult",
    "AdmissionPolicy", "AutoscalePolicy", "CheckpointPolicy",
    "telemetry_digest",
    "TrafficShape", "OpenLoopLoad", "arrival_offsets", "tick_schedule",
    "synthetic_stream",
]
