"""Kill-and-recover drill for the graph session server (DESIGN.md §12).

The drill proves the serving layer's recovery contract end to end, the way
an operator would: a real process is killed with SIGKILL (no atexit, no
flush — the kernel just takes it) mid-way through a multi-tenant run, a
fresh process recovers from the last committed checkpoint, replays the
deterministic submission schedule from the checkpointed tick, and the
resulting per-tenant telemetry digests must equal an uninterrupted
reference run's bit for bit.

Three subcommands over one JSON config:

    python -m repro.serve.drill reference --config cfg.json
        run every tick uninterrupted, write per-tenant digests
    python -m repro.serve.drill run --config cfg.json
        run with checkpoint cadence, SIGKILL self after ``kill_tick``
    python -m repro.serve.drill recover --config cfg.json
        recover from the checkpoint, replay the remaining schedule,
        write digests + recovery wall time

Determinism hinges on two properties: the submission schedule is a pure
function of the config (``loadgen.tick_schedule``), and the server
checkpoint captures everything the schedule's replay point needs (every
session bit-exactly via PR 5's atomic save/restore, plus admitted-but-
unserved queue chunks and the tick counter).  Wall-clock never influences
scheduling — only latency *measurement* — so the replay takes the same
steps the lost process would have.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.api import SystemConfig
from repro.serve.loadgen import TrafficShape, synthetic_stream, tick_schedule
from repro.serve.server import (AdmissionPolicy, CheckpointPolicy,
                                GraphServer, telemetry_digest)

DEFAULT_CONFIG: Dict[str, Any] = {
    "tenants": 4,
    "ticks": 24,
    "kill_tick": 14,          # run: SIGKILL after this tick completes
    "checkpoint_every": 4,
    "n_nodes": 96,
    "n_events": 600,          # per tenant
    "seed": 7,
    "k": 4,
    "n_cap": 128,
    "e_cap": 2048,
    "window": 400,
    "a_cap": 256,
    "d_cap": 128,
    "queue_cap": 100_000,
    "rate": 400.0,            # open-loop shape (relative; only the per-tick
    "burst_rate": 2000.0,     # quantisation matters for the drill)
    "burst_every": 0.5,
    "burst_len": 0.1,
}


def load_config(path: Optional[str]) -> Dict[str, Any]:
    cfg = dict(DEFAULT_CONFIG)
    if path:
        with open(path) as f:
            user = json.load(f)
        unknown = sorted(set(user) - set(cfg) - {"workdir"})
        if unknown:
            raise ValueError(f"unknown drill config keys: {unknown}")
        cfg.update(user)
    if "workdir" not in cfg:
        raise ValueError("drill config needs a 'workdir' directory")
    return cfg


def _system_config(cfg: Dict[str, Any], i: int) -> SystemConfig:
    return SystemConfig.from_dict({
        "graph": {"n_cap": cfg["n_cap"], "e_cap": cfg["e_cap"]},
        "stream": {"window": cfg["window"], "a_cap": cfg["a_cap"],
                   "d_cap": cfg["d_cap"]},
        "partition": {"k": cfg["k"]},
        "seed": cfg["seed"] + i,
    })


def build_server(cfg: Dict[str, Any], *, checkpoints: bool) -> GraphServer:
    ckpt = CheckpointPolicy(
        directory=os.path.join(cfg["workdir"], "ckpt"),
        every=cfg["checkpoint_every"]) if checkpoints else CheckpointPolicy()
    server = GraphServer(
        admission=AdmissionPolicy(queue_cap=cfg["queue_cap"]),
        checkpoint=ckpt)
    for i in range(cfg["tenants"]):
        server.add_tenant(f"tenant{i}", config=_system_config(cfg, i))
    return server


def schedules(cfg: Dict[str, Any]) -> Dict[str, List[Optional[np.ndarray]]]:
    """Per-tenant deterministic submission schedule (pure function of cfg)."""
    shape = TrafficShape(rate=cfg["rate"], burst_rate=cfg["burst_rate"],
                         burst_every=cfg["burst_every"],
                         burst_len=cfg["burst_len"])
    out = {}
    for i in range(cfg["tenants"]):
        t, u, v = synthetic_stream(cfg["n_nodes"], cfg["n_events"],
                                   seed=cfg["seed"] + i)
        out[f"tenant{i}"] = tick_schedule(t, u, v, shape,
                                          ticks=cfg["ticks"],
                                          seed=cfg["seed"] + i)
    return out

def replay(server: GraphServer, cfg: Dict[str, Any],
           start_tick: int) -> None:
    """Submit + tick the schedule from ``start_tick`` (0 = whole run), then
    drain whatever is still queued or deferred."""
    sched = schedules(cfg)
    for i in range(start_tick, cfg["ticks"]):
        for name, chunks in sched.items():
            if chunks[i] is not None:
                server.submit(name, chunks[i])
        server.tick()
    server.drain()


def digests(server: GraphServer) -> Dict[str, Any]:
    return {name: telemetry_digest(t.system.telemetry)
            for name, t in server.tenants.items()}


def _write(path: str, payload: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, default=float)


def cmd_reference(cfg: Dict[str, Any]) -> str:
    """Uninterrupted run: the ground truth the recovered run must match."""
    server = build_server(cfg, checkpoints=False)
    replay(server, cfg, 0)
    out = os.path.join(cfg["workdir"], "reference.json")
    _write(out, {"digests": digests(server), "stats": server.stats()})
    return out

def cmd_run(cfg: Dict[str, Any]) -> None:
    """Checkpointed run that dies hard: SIGKILL to self after ``kill_tick``
    ticks — everything since the last checkpoint cadence is lost, which is
    exactly the failure recover must absorb."""
    server = build_server(cfg, checkpoints=True)
    sched = schedules(cfg)
    for i in range(cfg["ticks"]):
        for name, chunks in sched.items():
            if chunks[i] is not None:
                server.submit(name, chunks[i])
        server.tick()
        if server.tick_count >= cfg["kill_tick"]:
            sys.stdout.flush()
            os.kill(os.getpid(), signal.SIGKILL)     # no cleanup, no flush
    raise RuntimeError(f"kill_tick {cfg['kill_tick']} > ticks "
                       f"{cfg['ticks']}: the drill never died")


def cmd_recover(cfg: Dict[str, Any]) -> str:
    """Recover from the last committed checkpoint, replay the lost ticks,
    write digests + the recovery report."""
    t0 = time.perf_counter()
    server = GraphServer.recover(os.path.join(cfg["workdir"], "ckpt"))
    recovery = dict(server.last_recovery)
    replay(server, cfg, server.tick_count)
    out = os.path.join(cfg["workdir"], "recovered.json")
    _write(out, {"digests": digests(server), "stats": server.stats(),
                 "recovery": recovery,
                 "total_seconds": time.perf_counter() - t0})
    return out


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(prog="repro.serve.drill", description=__doc__)
    p.add_argument("command", choices=("reference", "run", "recover"))
    p.add_argument("--config", help="JSON config path (see DEFAULT_CONFIG); "
                                    "must include 'workdir'")
    ns = p.parse_args(argv)
    cfg = load_config(ns.config)
    if ns.command == "reference":
        print(cmd_reference(cfg))
    elif ns.command == "run":
        cmd_run(cfg)
    else:
        print(cmd_recover(cfg))
    return 0


if __name__ == "__main__":
    sys.exit(main())
