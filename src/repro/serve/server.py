"""Graph session server: a multi-tenant serving layer over the xDGP runtime
(DESIGN.md §12).

One ``GraphServer`` owns many named ``DynamicGraphSystem`` sessions — one
per tenant/graph — and puts a production front door in front of them:

    submit(tenant, events)          admission: per-tenant queue with a cap
        │                           and a backpressure policy (reject /
        │                           shed / queue) fed by the queue depth
        │                           PLUS the session's own EdgeStreamBuffer
        │                           backlog (pressure is end-to-end)
        ▼
    tick()                          scheduling round: per tenant, coalesce
        │                           queued chunks into ONE vectorized
        │                           ``step()`` batch (≤ max_batch_events),
        │                           observe ingest latency at commit
        ▼
    autoscale                       sustained step-latency EWMA or partition
        │                           occupancy over thresholds → ``rescale()``
        ▼                           (cooldown-gated, min_k..max_k)
    checkpoint cadence              every N ticks: atomic per-tenant
                                    ``save()`` + queue snapshot + manifest;
                                    ``GraphServer.recover(dir)`` resumes
                                    every tenant bit-exactly

All counters/gauges/histograms land in one shared ``MetricsRegistry``
labelled per tenant; ``scrape()`` returns the Prometheus text body.

Wall-clock is injected (``clock=``) so tests can drive virtual time; only
latency *measurement* uses it — scheduling is tick-driven, so replays of a
deterministic submission schedule (``loadgen.tick_schedule``) are exact,
which is what the kill-recovery drill (``repro.serve.drill``) asserts.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, NamedTuple, Optional

import numpy as np

from repro.api import DynamicGraphSystem, SystemConfig
from repro.api.telemetry import SuperstepRecord
from repro.graph.structure import Graph
from repro.obs.metrics import MetricsRegistry

MANIFEST_NAME = "MANIFEST.json"
SERVER_CKPT_VERSION = 1

# SuperstepRecord fields that are wall-clock measurements, not decisions —
# excluded from the bit-exactness digest (two identical trajectories never
# agree on nanoseconds)
_WALL_CLOCK_FIELDS = ("ingest_seconds", "step_seconds", "compute_seconds")


def telemetry_digest(records: List[SuperstepRecord]) -> List[Dict[str, Any]]:
    """The deterministic projection of a telemetry trail: every
    SuperstepRecord field except wall-clock timings.  Two runs of the same
    stream through the same session state must produce EQUAL digests —
    the serving layer's isolation and recovery contracts are asserted on
    this."""
    out = []
    for r in records:
        d = dataclasses.asdict(r)
        for f in _WALL_CLOCK_FIELDS:
            d.pop(f, None)
        out.append(d)
    return out


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Front-door traffic shaping for one tenant.

    ``queue_cap`` bounds the events a tenant may have waiting end-to-end:
    admission queue + the session's EdgeStreamBuffer backlog (events already
    stepped but deferred past a_cap/d_cap).  ``on_full`` decides what
    happens to a submit that would exceed it:

    * ``"reject"`` — refuse the overflow (the caller is told how many);
    * ``"shed"``   — accept the new events, drop the OLDEST queued ones
                     (bounded staleness: fresh traffic wins);
    * ``"queue"``  — accept unconditionally (the cap only drives the
                     pressure gauge; memory is the caller's problem).
    """

    queue_cap: int = 100_000
    on_full: str = "reject"            # "reject" | "shed" | "queue"
    max_batch_events: int = 8192       # events coalesced per step() call

    def __post_init__(self):
        if self.on_full not in ("reject", "shed", "queue"):
            raise ValueError(f"unknown on_full policy {self.on_full!r}; "
                             f"expected 'reject', 'shed' or 'queue'")
        if self.queue_cap <= 0 or self.max_batch_events <= 0:
            raise ValueError("queue_cap and max_batch_events must be positive")


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """When to ``rescale()`` a tenant's partition count.

    Scale up (k+1) when the step-latency EWMA crosses ``latency_high`` or
    the fullest partition's occupancy/capacity fraction crosses
    ``occupancy_high``; scale down (k-1) when both sit below their low
    water marks AND the front door is idle.  ``cooldown`` ticks must pass
    between rescales so one burst cannot thrash the partition count.
    """

    enabled: bool = False
    min_k: int = 2
    max_k: int = 64
    latency_high: float = 1.0          # EWMA step seconds
    latency_low: float = 0.05
    occupancy_high: float = 0.85       # max_i occupancy_i / capacity_i
    occupancy_low: float = 0.30
    ewma: float = 0.3                  # EWMA weight of the newest step
    cooldown: int = 8                  # ticks between rescale decisions
    adapt_iters: int = 8               # re-adapt budget after a rescale


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Crash-recovery cadence: every ``every`` ticks the server checkpoints
    every tenant (atomic per-tenant ``save()`` + queue snapshot) and then
    commits the manifest last — a torn checkpoint is never recoverable-to."""

    directory: Optional[str] = None
    every: int = 0                     # ticks between checkpoints (0 = off)


class SubmitResult(NamedTuple):
    accepted: int
    rejected: int
    shed: int
    pressure: float                    # post-submit, fraction of queue_cap


class _Chunk:
    """One submitted batch awaiting ingestion (arrival stamp + cursor)."""

    __slots__ = ("arrival", "events", "taken")

    def __init__(self, arrival: float, events: np.ndarray, taken: int = 0):
        self.arrival = arrival
        self.events = events
        self.taken = taken

    @property
    def left(self) -> int:
        return self.events.shape[0] - self.taken


class Tenant:
    """One named session plus its front-door state."""

    def __init__(self, name: str, system: DynamicGraphSystem,
                 admission: AdmissionPolicy, autoscale: AutoscalePolicy):
        self.name = name
        self.system = system
        self.admission = admission
        self.autoscale = autoscale
        self.chunks: Deque[_Chunk] = deque()
        self.queued = 0                # events waiting in self.chunks
        self.admitted = 0
        self.rejected = 0
        self.shed = 0
        self.lat_ewma: Optional[float] = None
        self.cooldown_left = 0
        self.rescales = 0
        self.latencies: Deque[float] = deque(maxlen=4096)  # raw, for quantiles

    # -- backpressure -------------------------------------------------------
    @property
    def stream_backlog(self) -> int:
        """Events the session itself is still holding back (EdgeStreamBuffer
        capacity backpressure — DESIGN.md §3)."""
        adds, dels = self.system.backlog
        return int(adds) + int(dels)

    @property
    def pressure(self) -> float:
        """End-to-end queued work as a fraction of the queue cap."""
        return (self.queued + self.stream_backlog) / self.admission.queue_cap

    # -- queue ops ----------------------------------------------------------
    def push(self, events: np.ndarray, arrival: float) -> None:
        self.chunks.append(_Chunk(arrival, events))
        self.queued += events.shape[0]

    def shed_oldest(self, n: int) -> int:
        """Drop up to n of the oldest queued events; returns dropped count."""
        dropped = 0
        while dropped < n and self.chunks:
            c = self.chunks[0]
            take = min(c.left, n - dropped)
            c.taken += take
            dropped += take
            if c.left == 0:
                self.chunks.popleft()
        self.queued -= dropped
        return dropped

    def take_batch(self, cap: int) -> tuple:
        """Coalesce queued chunks into one (m,3) batch of ≤ cap events (always
        at least one event if any are queued).  Returns (batch, arrivals of
        chunks fully drained by this batch)."""
        rows: List[np.ndarray] = []
        done_arrivals: List[float] = []
        taken = 0
        while self.chunks and taken < cap:
            c = self.chunks[0]
            take = min(c.left, cap - taken)
            rows.append(c.events[c.taken:c.taken + take])
            c.taken += take
            taken += take
            if c.left == 0:
                done_arrivals.append(c.arrival)
                self.chunks.popleft()
        self.queued -= taken
        batch = (np.concatenate(rows, axis=0) if rows
                 else np.empty((0, 3), np.int64))
        return batch, done_arrivals


class GraphServer:
    """Multi-tenant serving front end over ``DynamicGraphSystem`` sessions."""

    def __init__(self, *, admission: Optional[AdmissionPolicy] = None,
                 autoscale: Optional[AutoscalePolicy] = None,
                 checkpoint: Optional[CheckpointPolicy] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.admission = admission or AdmissionPolicy()
        self.autoscale = autoscale or AutoscalePolicy()
        self.checkpoint_policy = checkpoint or CheckpointPolicy()
        self.metrics = metrics if metrics is not None \
            else MetricsRegistry(namespace="serve")
        self.clock = clock
        self.tenants: Dict[str, Tenant] = {}
        self.tick_count = 0
        self.last_recovery: Optional[Dict[str, Any]] = None

    # -- tenant lifecycle ---------------------------------------------------
    def add_tenant(self, name: str, graph: Optional[Graph] = None,
                   config: Optional[SystemConfig] = None, *,
                   system: Optional[DynamicGraphSystem] = None,
                   admission: Optional[AdmissionPolicy] = None,
                   autoscale: Optional[AutoscalePolicy] = None) -> Tenant:
        """Register a named session (built here from graph+config unless an
        existing ``system`` is handed over)."""
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already exists")
        if any(ch in name for ch in "/\\\0") or name in ("", ".", ".."):
            raise ValueError(f"tenant name {name!r} is not a valid path leaf")
        if system is None:
            system = DynamicGraphSystem(graph, config)
        t = Tenant(name, system,
                   admission or self.admission, autoscale or self.autoscale)
        self.tenants[name] = t
        self.metrics.gauge("tenants").set(len(self.tenants))
        return t

    def tenant(self, name: str) -> Tenant:
        try:
            return self.tenants[name]
        except KeyError:
            raise KeyError(f"unknown tenant {name!r}; have "
                           f"{sorted(self.tenants)}") from None

    # -- admission front door ------------------------------------------------
    def submit(self, tenant: str, events: np.ndarray,
               now: Optional[float] = None) -> SubmitResult:
        """Admit an event batch for ``tenant`` under its backpressure policy.

        ``events`` rows are (t, u, v) in the tenant's logical stream time.
        Returns what happened: accepted/rejected/shed counts and the
        post-submit pressure — a caller seeing pressure near 1.0 should
        back off (that is the open-loop generator's problem, not ours)."""
        t = self.tenant(tenant)
        ev = np.asarray(events, np.int64)
        if ev.size == 0:
            return SubmitResult(0, 0, 0, t.pressure)
        if ev.ndim != 2 or ev.shape[1] != 3:
            raise ValueError(f"events must be (m, 3) rows of (t, u, v); "
                             f"got shape {ev.shape}")
        arrival = self.clock() if now is None else now
        pol = t.admission
        room = pol.queue_cap - (t.queued + t.stream_backlog)
        n = ev.shape[0]
        accepted, rejected, shed = n, 0, 0
        if n > room and pol.on_full == "reject":
            accepted = max(room, 0)
            rejected = n - accepted
            ev = ev[:accepted]
        if accepted:
            t.push(ev, arrival)
        if pol.on_full == "shed":
            over = (t.queued + t.stream_backlog) - pol.queue_cap
            if over > 0:
                shed = t.shed_oldest(min(over, t.queued))
        t.admitted += accepted
        t.rejected += rejected
        t.shed += shed
        m = self.metrics
        m.counter("events_submitted_total",
                  "events offered at the front door").inc(n, tenant=tenant)
        if accepted:
            m.counter("events_admitted_total",
                      "events accepted into tenant queues").inc(
                accepted, tenant=tenant)
        if rejected:
            m.counter("events_rejected_total",
                      "events refused at queue cap").inc(rejected,
                                                         tenant=tenant)
        if shed:
            m.counter("events_shed_total",
                      "queued events dropped for fresh traffic").inc(
                shed, tenant=tenant)
        m.gauge("queue_depth").set(t.queued, tenant=tenant)
        m.gauge("pressure").set(t.pressure, tenant=tenant)
        return SubmitResult(accepted, rejected, shed, t.pressure)

    # -- scheduling ---------------------------------------------------------
    def tick(self) -> Dict[str, Optional[SuperstepRecord]]:
        """One scheduling round over every tenant: coalesce each tenant's
        queued chunks into one vectorized ``step()`` (or an empty drain step
        if only deferred stream backlog remains), observe ingest latency at
        commit, apply autoscale, honour the checkpoint cadence."""
        self.tick_count += 1
        out: Dict[str, Optional[SuperstepRecord]] = {}
        for name, t in self.tenants.items():
            if not t.chunks and t.stream_backlog == 0:
                out[name] = None
                continue
            batch, done_arrivals = t.take_batch(t.admission.max_batch_events)
            rec = t.system.step(batch)
            commit = self.clock()
            m = self.metrics
            for arrival in done_arrivals:
                lat = max(commit - arrival, 0.0)
                t.latencies.append(lat)
                m.histogram("ingest_latency_seconds",
                            "submit → superstep commit").observe(
                    lat, tenant=name)
            m.counter("events_ingested_total",
                      "events handed to step()").inc(batch.shape[0],
                                                     tenant=name)
            m.counter("supersteps_total",
                      "step() calls served").inc(1, tenant=name)
            m.histogram("step_seconds",
                        "superstep wall time").observe(rec.step_seconds,
                                                       tenant=name)
            m.gauge("queue_depth").set(t.queued, tenant=name)
            m.gauge("stream_backlog").set(t.stream_backlog, tenant=name)
            m.gauge("pressure").set(t.pressure, tenant=name)
            m.gauge("cut_ratio").set(rec.cut_ratio, tenant=name)
            m.gauge("partitions").set(t.system.config.partition.k, tenant=name)
            self._autoscale(t, rec)
            out[name] = rec
        # host-memory high-water mark, refreshed every scheduling round so a
        # scrape of a long-running server shows whether memory stays bounded
        from repro.obs.profiling import peak_rss_bytes
        self.metrics.gauge("peak_rss_bytes",
                           "process peak RSS").set(peak_rss_bytes())
        pol = self.checkpoint_policy
        if pol.directory and pol.every and self.tick_count % pol.every == 0:
            self.save_checkpoint()
        return out

    def run(self, ticks: int) -> int:
        """Drive ``ticks`` scheduling rounds; returns supersteps executed."""
        steps = 0
        for _ in range(ticks):
            steps += sum(1 for r in self.tick().values() if r is not None)
        return steps

    def drain(self, max_ticks: int = 1000) -> int:
        """Tick until every tenant's queue AND stream backlog are empty."""
        for i in range(max_ticks):
            if all(not t.chunks and t.stream_backlog == 0
                   for t in self.tenants.values()):
                return i
            self.tick()
        raise RuntimeError(f"server did not drain in {max_ticks} ticks")

    # -- autoscale ----------------------------------------------------------
    def _occupancy_frac(self, t: Tenant) -> float:
        occ = np.asarray(t.system.tracker.occupancy, np.float64)
        cap = np.asarray(t.system.state.capacity, np.float64)
        return float(np.max(occ / np.maximum(cap, 1.0)))

    def _autoscale(self, t: Tenant, rec: SuperstepRecord) -> None:
        pol = t.autoscale
        if not pol.enabled:
            return
        a = pol.ewma
        t.lat_ewma = (rec.step_seconds if t.lat_ewma is None
                      else (1 - a) * t.lat_ewma + a * rec.step_seconds)
        if t.cooldown_left > 0:
            t.cooldown_left -= 1
            return
        k = t.system.config.partition.k
        occ = self._occupancy_frac(t)
        if (occ >= pol.occupancy_high or t.lat_ewma >= pol.latency_high) \
                and k < pol.max_k:
            direction = "up"
        elif (occ <= pol.occupancy_low and t.lat_ewma <= pol.latency_low
                and t.queued == 0 and k > pol.min_k):
            direction = "down"
        else:
            return
        new_k = k + 1 if direction == "up" else k - 1
        t.system.rescale(new_k, adapt_iters=pol.adapt_iters)
        t.cooldown_left = pol.cooldown
        t.rescales += 1
        self.metrics.counter("rescales_total",
                             "autoscale rescale() calls").inc(
            1, tenant=t.name, direction=direction)
        self.metrics.gauge("partitions").set(new_k, tenant=t.name)

    # -- observability ------------------------------------------------------
    def scrape(self) -> str:
        """Prometheus text exposition body (the /metrics endpoint)."""
        return self.metrics.to_prometheus()

    def stats(self) -> Dict[str, Any]:
        """Point-in-time per-tenant summary (exact quantiles from the raw
        latency reservoir; the histogram feeds the scrape instead)."""
        tenants = {}
        for name, t in self.tenants.items():
            lats = np.asarray(t.latencies, np.float64)
            tenants[name] = {
                "supersteps": t.system._superstep,
                "k": t.system.config.partition.k,
                "cut_ratio": t.system.cut_ratio,
                "queued": t.queued,
                "stream_backlog": t.stream_backlog,
                "pressure": t.pressure,
                "admitted": t.admitted,
                "rejected": t.rejected,
                "shed": t.shed,
                "rescales": t.rescales,
                "ingest_p50_s": float(np.percentile(lats, 50)) if lats.size else None,
                "ingest_p99_s": float(np.percentile(lats, 99)) if lats.size else None,
            }
        return {"tick": self.tick_count, "tenants": tenants}

    # -- crash recovery -----------------------------------------------------
    def save_checkpoint(self, directory: Optional[str] = None) -> str:
        """Checkpoint every tenant + its queue, then commit the manifest
        LAST (atomic rename) — a crash mid-checkpoint leaves the previous
        manifest pointing at the previous complete checkpoint."""
        d = directory or self.checkpoint_policy.directory
        if not d:
            raise ValueError("no checkpoint directory configured; set "
                             "CheckpointPolicy(directory=...) or pass one")
        os.makedirs(os.path.join(d, "queues"), exist_ok=True)
        now = self.clock()
        manifest: Dict[str, Any] = {
            "version": SERVER_CKPT_VERSION,
            "tick": self.tick_count,
            "admission": dataclasses.asdict(self.admission),
            "autoscale": dataclasses.asdict(self.autoscale),
            "checkpoint_every": self.checkpoint_policy.every,
            "tenants": [],
        }
        for name, t in self.tenants.items():
            step = t.system.save(os.path.join(d, "tenants", name))
            rows = [c.events[c.taken:] for c in t.chunks]
            ages = [now - c.arrival for c in t.chunks]
            qpath = os.path.join(d, "queues", f"{name}.npz")
            tmp = qpath + ".tmp.npz"
            np.savez(tmp,
                     events=(np.concatenate(rows, axis=0) if rows
                             else np.empty((0, 3), np.int64)),
                     sizes=np.asarray([r.shape[0] for r in rows], np.int64),
                     ages=np.asarray(ages, np.float64))
            os.replace(tmp, qpath)
            manifest["tenants"].append({
                "name": name, "step": step,
                "admission": dataclasses.asdict(t.admission),
                "autoscale": dataclasses.asdict(t.autoscale),
                "counters": {"admitted": t.admitted, "rejected": t.rejected,
                             "shed": t.shed, "rescales": t.rescales},
                "lat_ewma": t.lat_ewma,
                "cooldown_left": t.cooldown_left,
            })
        tmp = os.path.join(d, MANIFEST_NAME + ".tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
        os.replace(tmp, os.path.join(d, MANIFEST_NAME))
        return d

    @classmethod
    def recover(cls, directory: str, *,
                metrics: Optional[MetricsRegistry] = None,
                clock: Callable[[], float] = time.perf_counter,
                ) -> "GraphServer":
        """Rebuild a server from its last committed checkpoint: every tenant
        session resumes bit-exactly (graph, partition state, tracker, window,
        backlog, telemetry — PR 5's atomic restore), queued-but-unserved
        events re-enter the admission queues in order, and the tick counter
        (hence the checkpoint cadence and autoscale cooldowns) continues
        where it left off.  The recovery report lands in
        ``server.last_recovery``."""
        t0 = time.perf_counter()
        path = os.path.join(directory, MANIFEST_NAME)
        with open(path) as f:
            manifest = json.load(f)
        if manifest.get("version") != SERVER_CKPT_VERSION:
            raise ValueError(f"{path}: unsupported server checkpoint version "
                             f"{manifest.get('version')!r}")
        server = cls(
            admission=AdmissionPolicy(**manifest["admission"]),
            autoscale=AutoscalePolicy(**manifest["autoscale"]),
            checkpoint=CheckpointPolicy(directory=directory,
                                        every=manifest["checkpoint_every"]),
            metrics=metrics, clock=clock)
        server.tick_count = manifest["tick"]
        now = clock()
        report: Dict[str, Any] = {"tick": manifest["tick"], "tenants": {}}
        for entry in manifest["tenants"]:
            name = entry["name"]
            system = DynamicGraphSystem.restore(
                os.path.join(directory, "tenants", name), step=entry["step"])
            t = server.add_tenant(
                name, system=system,
                admission=AdmissionPolicy(**entry["admission"]),
                autoscale=AutoscalePolicy(**entry["autoscale"]))
            for key, val in entry["counters"].items():
                setattr(t, key, val)
            t.lat_ewma = entry["lat_ewma"]
            t.cooldown_left = entry["cooldown_left"]
            q = np.load(os.path.join(directory, "queues", f"{name}.npz"))
            off = 0
            for size, age in zip(q["sizes"], q["ages"]):
                t.push(q["events"][off:off + int(size)], now - float(age))
                off += int(size)
            report["tenants"][name] = {"superstep": system._superstep,
                                       "queued": t.queued}
        report["seconds"] = time.perf_counter() - t0
        server.last_recovery = report
        return server
