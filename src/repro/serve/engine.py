"""Batched serving engine: continuous-batching decode over a KV cache.

A scaled-down vLLM-style loop: requests enter a queue, join the running
batch at free slots, decode one token per engine step for every active slot,
and leave on EOS/max-len. Slot state (cache rows) is reused in place; the
decode step itself is the jit'd ``serve_step`` the dry-run lowers.

The engine feeds the observability layer's ``MetricsRegistry``
(DESIGN.md §11): request/token/completion counters, queue-depth and
active-slot gauges, and a step-latency histogram — ``engine.metrics``
exports as JSONL or Prometheus text (the scrape-endpoint body).  Pass an
existing registry to share one across engines; the default builds its own.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import TransformerConfig, decode_step, init_cache, prefill
from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (P,) int32
    max_new_tokens: int = 32
    eos_id: int = 2


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]


class ServeEngine:
    """Fixed-slot continuous batching (B slots, shared position clock)."""

    def __init__(self, params: Any, cfg: TransformerConfig, batch_slots: int,
                 max_seq: int, greedy: bool = True,
                 metrics: Optional[MetricsRegistry] = None):
        self.params = params
        self.cfg = cfg
        self.b = batch_slots
        self.max_seq = max_seq
        self.cache = init_cache(cfg, batch_slots, max_seq)
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self.active = np.zeros(batch_slots, bool)
        self.pos = np.zeros(batch_slots, np.int64)
        self.budget = np.zeros(batch_slots, np.int64)
        self.uid = np.full(batch_slots, -1, np.int64)
        self.outputs: Dict[int, List[int]] = {}
        self.queue: Deque[Request] = deque()
        self.greedy = greedy
        self._step = jax.jit(
            lambda p, t, c, i: decode_step(p, t, c, i, cfg))
        self.clock = 0                         # global position index
        self.metrics = metrics if metrics is not None \
            else MetricsRegistry(namespace="serve")

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self.metrics.counter("requests_total",
                             "requests submitted to the engine").inc()
        self.metrics.gauge("queue_depth").set(len(self.queue))

    def _admit(self) -> None:
        for slot in range(self.b):
            if self.active[slot] or not self.queue:
                continue
            req = self.queue.popleft()
            # prefill by stepping the prompt tokens through the decoder
            toks = req.prompt.astype(np.int32)
            for t in toks:
                tok = self.tokens.at[slot, 0].set(int(t))
                logits, self.cache = self._step(self.params, tok, self.cache,
                                                jnp.int32(self.clock))
                self.tokens = tok
                self.clock += 1
            self.active[slot] = True
            self.uid[slot] = req.uid
            self.budget[slot] = req.max_new_tokens
            self.outputs[req.uid] = []

    def step(self) -> List[Completion]:
        """One engine iteration: admit, decode one token for all active slots."""
        self._admit()
        self.metrics.gauge("queue_depth").set(len(self.queue))
        self.metrics.gauge("active_slots").set(int(self.active.sum()))
        if not self.active.any():
            return []
        t0 = time.perf_counter()
        logits, self.cache = self._step(self.params, self.tokens, self.cache,
                                        jnp.int32(self.clock))
        jax.block_until_ready(logits)          # latency, not dispatch time
        self.metrics.histogram("step_seconds",
                               "decode-step latency").observe(
            time.perf_counter() - t0)
        self.clock += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1)).astype(np.int32)
        done: List[Completion] = []
        new_tokens = np.asarray(self.tokens).copy()
        for slot in range(self.b):
            if not self.active[slot]:
                continue
            tok = int(nxt[slot])
            self.outputs[self.uid[slot]].append(tok)
            self.budget[slot] -= 1
            new_tokens[slot, 0] = tok
            self.metrics.counter("tokens_decoded_total",
                                 "tokens decoded across all slots").inc()
            if self.budget[slot] <= 0 or self.clock >= self.max_seq - 1:
                done.append(Completion(int(self.uid[slot]),
                                       self.outputs.pop(int(self.uid[slot]))))
                self.active[slot] = False
                self.metrics.counter("completions_total",
                                     "requests completed").inc()
        self.tokens = jnp.asarray(new_tokens)
        return done

    def run_until_drained(self, max_steps: int = 10_000) -> List[Completion]:
        out: List[Completion] = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.queue and not self.active.any():
                break
        return out
