"""Batched serving engine: continuous-batching decode over a KV cache.

A scaled-down vLLM-style loop: requests enter a queue, join the running
batch at free slots, decode one token per engine step for every active slot,
and leave on EOS/max-len. Slot state (cache rows) is reused in place; the
decode step itself is the jit'd ``serve_step`` the dry-run lowers.

Each slot carries its own position cursor (``pos``): concurrently active
slots sit at different sequence depths, so the decode step takes a (B,)
per-slot write index — one request joining late must not shift another's
cache positions. Admission prefills the whole prompt through the
``prefill()`` cache path in ONE device call per request (prompt lengths are
padded to power-of-two buckets so admission compiles O(log max_seq) times,
not once per distinct prompt length).

The engine feeds the observability layer's ``MetricsRegistry``
(DESIGN.md §11): request/token/completion counters, queue-depth and
active-slot gauges, and a step-latency histogram — ``engine.metrics``
exports as JSONL or Prometheus text (the scrape-endpoint body).  Pass an
existing registry to share one across engines; the default builds its own.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import TransformerConfig, decode_step, init_cache, prefill
from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (P,) int32
    max_new_tokens: int = 32
    eos_id: int = 2


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]


def _bucket(n: int) -> int:
    """Smallest power of two ≥ n (prefill compile-shape bucketing)."""
    return 1 << max(n - 1, 0).bit_length()


class ServeEngine:
    """Fixed-slot continuous batching (B slots, per-slot position cursors)."""

    def __init__(self, params: Any, cfg: TransformerConfig, batch_slots: int,
                 max_seq: int, greedy: bool = True,
                 metrics: Optional[MetricsRegistry] = None):
        self.params = params
        self.cfg = cfg
        self.b = batch_slots
        self.max_seq = max_seq
        self.cache = init_cache(cfg, batch_slots, max_seq)
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self.active = np.zeros(batch_slots, bool)
        self.pos = np.zeros(batch_slots, np.int32)   # next cache write index
        self.budget = np.zeros(batch_slots, np.int64)
        self.uid = np.full(batch_slots, -1, np.int64)
        self.outputs: Dict[int, List[int]] = {}
        self.queue: Deque[Request] = deque()
        self.greedy = greedy
        self._step = jax.jit(
            lambda p, t, c, i: decode_step(p, t, c, i, cfg))
        self._prefill = jax.jit(self._prefill_slot)
        self.metrics = metrics if metrics is not None \
            else MetricsRegistry(namespace="serve")

    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.max_seq:
            raise ValueError(f"prompt of {len(req.prompt)} tokens cannot fit "
                             f"max_seq={self.max_seq}")
        self.queue.append(req)
        self.metrics.counter("requests_total",
                             "requests submitted to the engine").inc()
        self.metrics.gauge("queue_depth").set(len(self.queue))

    def _prefill_slot(self, params: Any, toks: jax.Array, cache: Any,
                      slot: jax.Array) -> Any:
        """Write one slot's prompt KV rows [0, L) with a single prefill call
        (the slot's cache rows are sliced out, filled, and scattered back)."""
        sub = tuple(jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1)
                    for c in cache)
        _, sub = prefill(params, toks, self.cfg, cache=sub,
                         cache_index=jnp.int32(0))
        return tuple(jax.lax.dynamic_update_slice_in_dim(c, s, slot, axis=1)
                     for c, s in zip(cache, sub))

    def _admit(self) -> None:
        for slot in range(self.b):
            if self.active[slot] or not self.queue:
                continue
            req = self.queue.popleft()
            toks = np.asarray(req.prompt).astype(np.int32).reshape(-1)
            n_pre = toks.shape[0] - 1        # the last prompt token feeds the
            if n_pre > 0:                    # first decode step, as before
                padded = np.zeros((1, _bucket(n_pre)), np.int32)
                padded[0, :n_pre] = toks[:-1]
                # padding rows beyond n_pre hold garbage KV, but every row r
                # is rewritten by the decode step that reaches position r
                # before any query can attend to it (write precedes attend)
                self.cache = self._prefill(self.params, jnp.asarray(padded),
                                           self.cache, jnp.int32(slot))
                self.metrics.counter(
                    "tokens_prefilled_total",
                    "prompt tokens prefilled at admission").inc(n_pre)
            self.tokens = self.tokens.at[slot, 0].set(int(toks[-1]))
            self.pos[slot] = n_pre           # the pending decode writes here
            self.active[slot] = True
            self.uid[slot] = req.uid
            self.budget[slot] = req.max_new_tokens
            self.outputs[req.uid] = []

    def step(self) -> List[Completion]:
        """One engine iteration: admit, decode one token for all active slots."""
        self._admit()
        self.metrics.gauge("queue_depth").set(len(self.queue))
        self.metrics.gauge("active_slots").set(int(self.active.sum()))
        if not self.active.any():
            return []
        t0 = time.perf_counter()
        logits, self.cache = self._step(self.params, self.tokens, self.cache,
                                        jnp.asarray(self.pos))
        jax.block_until_ready(logits)          # latency, not dispatch time
        self.metrics.histogram("step_seconds",
                               "decode-step latency").observe(
            time.perf_counter() - t0)
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1)).astype(np.int32)
        done: List[Completion] = []
        new_tokens = np.asarray(self.tokens).copy()
        for slot in range(self.b):
            if not self.active[slot]:
                continue                       # inactive slots rewrite their
            tok = int(nxt[slot])               # own row in place (pos frozen)
            self.outputs[self.uid[slot]].append(tok)
            self.budget[slot] -= 1
            self.pos[slot] += 1
            new_tokens[slot, 0] = tok
            self.metrics.counter("tokens_decoded_total",
                                 "tokens decoded across all slots").inc()
            if self.budget[slot] <= 0 or self.pos[slot] >= self.max_seq - 1:
                done.append(Completion(int(self.uid[slot]),
                                       self.outputs.pop(int(self.uid[slot]))))
                self.active[slot] = False
                self.metrics.counter("completions_total",
                                     "requests completed").inc()
        self.tokens = jnp.asarray(new_tokens)
        return done

    def run_until_drained(self, max_steps: int = 10_000) -> List[Completion]:
        out: List[Completion] = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.queue and not self.active.any():
                break
        return out
