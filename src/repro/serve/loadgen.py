"""Open-loop bursty load generation for the graph session server.

An *open-loop* generator decides arrival times independently of how fast
the server drains them — the defining property of real user traffic (and
the reason closed-loop benchmarks underreport tail latency: a closed loop
slows its offered load down exactly when the server is struggling).  Here
the offered load is a ``TrafficShape``: a base Poisson process with
periodic burst windows at a higher rate, the near-real-time survey's
"bursty arrival" regime (PAPERS.md, arxiv 1410.1903).

Two layers:

* ``arrival_offsets`` — (n,) seconds-from-start for n events under a shape
  (deterministic per seed; inter-arrival gaps are exponential at the
  instantaneous rate, so burst windows compress gaps by rate ratio).
* ``OpenLoopLoad`` — binds a (t, u, v) event stream to those offsets and
  serves ``take_due(elapsed)`` batches: everything whose arrival time has
  passed, regardless of server state.  Event *payload* timestamps stay the
  stream's own logical time (windowing semantics are the tenant's); arrival
  time only decides *when* the front door sees them.

For deterministic tests/drills, ``tick_schedule`` precomputes the chunk
sequence per integer tick so replays (e.g. after crash recovery) are exact.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TrafficShape:
    """Offered-load description: base Poisson + periodic bursts.

    ``rate`` is the base mean arrival rate (events/second of wall time).
    Every ``burst_every`` seconds a burst window of ``burst_len`` seconds
    opens during which the instantaneous rate is ``burst_rate``.  With
    ``burst_rate == 0`` (or ``burst_every == 0``) the process is plain
    Poisson at ``rate``.
    """

    rate: float
    burst_rate: float = 0.0
    burst_every: float = 0.0
    burst_len: float = 0.0

    def instantaneous_rate(self, t: float) -> float:
        if self.burst_rate > 0 and self.burst_every > 0:
            if (t % self.burst_every) < self.burst_len:
                return self.burst_rate
        return self.rate


def arrival_offsets(n: int, shape: TrafficShape, seed: int = 0) -> np.ndarray:
    """(n,) sorted arrival offsets (seconds from start) under ``shape``.

    Sequential thinning-free construction: each gap is Exp(1) scaled by the
    instantaneous rate at the current time.  Exact for piecewise-constant
    rates at this granularity and deterministic per seed.
    """
    if shape.rate <= 0:
        raise ValueError(f"base rate must be positive, got {shape.rate}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0, size=n)
    out = np.empty(n, np.float64)
    t = 0.0
    for i in range(n):
        t += gaps[i] / shape.instantaneous_rate(t)
        out[i] = t
    return out


class OpenLoopLoad:
    """One tenant's offered load: a (t, u, v) stream + arrival offsets.

    ``take_due(elapsed)`` returns every not-yet-delivered event whose
    arrival offset ≤ elapsed, as one (m, 3) int64 batch in stream order —
    the front door submits it whole, so a server that fell behind sees the
    backlog as one oversized arrival (which is exactly what backpressure
    policies must handle).
    """

    def __init__(self, times: np.ndarray, src: np.ndarray, dst: np.ndarray,
                 shape: TrafficShape, seed: int = 0):
        self.events = np.stack([np.asarray(times, np.int64),
                                np.asarray(src, np.int64),
                                np.asarray(dst, np.int64)], axis=1)
        self.offsets = arrival_offsets(self.events.shape[0], shape, seed)
        self._cursor = 0

    @property
    def remaining(self) -> int:
        return self.events.shape[0] - self._cursor

    @property
    def duration(self) -> float:
        """Seconds from start until the last arrival."""
        return float(self.offsets[-1]) if self.offsets.size else 0.0

    def take_due(self, elapsed: float) -> np.ndarray:
        hi = int(np.searchsorted(self.offsets, elapsed, side="right"))
        batch = self.events[self._cursor:hi]
        self._cursor = hi
        return batch

    def reset(self) -> None:
        self._cursor = 0


def tick_schedule(times: np.ndarray, src: np.ndarray, dst: np.ndarray,
                  shape: TrafficShape, *, ticks: int, seed: int = 0,
                  ) -> List[Optional[np.ndarray]]:
    """Deterministic per-tick chunks: the open-loop arrivals quantised onto
    ``ticks`` equal wall-time slots.  Pure function of its arguments, so a
    crash-recovery replay regenerates the exact submission sequence
    (``serve.drill`` relies on this).  Entry i is the (m, 3) batch submitted
    at tick i, or None when no events arrive in that slot.
    """
    load = OpenLoopLoad(times, src, dst, shape, seed)
    span = load.duration
    out: List[Optional[np.ndarray]] = []
    for i in range(ticks):
        elapsed = span * (i + 1) / ticks
        batch = load.take_due(elapsed)
        out.append(batch if batch.size else None)
    return out


def synthetic_stream(n_nodes: int, n_events: int, *, seed: int = 0,
                     zipf_a: float = 1.6, span: int = 1000,
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """A deterministic skewed edge stream (power-law-ish endpoints over
    logical time [0, span)) — the tenant workload for serving tests and
    drills when a full scenario would be overkill."""
    rng = np.random.default_rng(seed)
    u = np.minimum(rng.zipf(zipf_a, n_events) - 1, n_nodes - 1)
    v = rng.integers(0, n_nodes, n_events)
    v = np.where(v == u, (v + 1) % n_nodes, v)
    t = np.sort(rng.integers(0, span, n_events))
    return t.astype(np.int64), u.astype(np.int64), v.astype(np.int64)
