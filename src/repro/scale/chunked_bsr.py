"""Chunked BSR construction: bit-identical to ``graph_to_bsr`` with
bounded peak host memory (DESIGN.md §14).

The monolithic packer materialises the full symmetrised COO (2·|E| int64
triples) plus a same-length ``np.unique`` workspace before a single
scatter-add — ~100 bytes/edge of transient peak, which at 10M vertices ×
degree 16 is >10 GB of scratch for a packing whose *output* may be far
smaller.  This builder replaces the one-shot pass with a two-pass
count-then-fill over edge chunks:

  pass 1 (count) — stream chunks, fold each chunk's unique tile keys into
      one sorted key set (``np.union1d``); peak state = key set + 1 chunk.
  pass 2 (fill)  — allocate the packed arrays once (guarded by
      ``memory_budget``), re-stream the same chunks, and scatter each
      chunk into its tiles via ``searchsorted`` into the global key set.

Bit-identity with ``graph_to_bsr`` is a contract, not an accident, and the
two ingredients are pinned by ``tests/test_scale.py``:

* the global tile index of every entry is identical — ``searchsorted``
  into the sorted key set equals ``np.unique(..., return_inverse=True)``
  over all entries at once;
* the float accumulation order is identical — chunks are iterated
  **direction-major** (every s→d chunk, then every d→s chunk), which is
  exactly the order ``np.concatenate([s, d])`` feeds ``np.add.at``.

Overflow policy: every quantity headed for an int32 container goes through
``check_int32_index`` and fails loudly (the same guard the monolithic
packer uses).  Memory policy: ``memory_budget`` bounds the bytes this call
may allocate for the packed blocks + key set; exceeding it raises
``MemoryBudgetError`` *before* the allocation, never after the host OOMs.
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.graph.bsr import BSRMatrix, check_int32_index
from repro.graph.structure import Graph


class MemoryBudgetError(MemoryError):
    """The packed BSR would exceed the caller's ``memory_budget``."""


def iter_edge_chunks(graph: Graph, chunk_edges: int
                     ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Live edges of ``graph`` in edge-slot order, ``chunk_edges`` at a
    time, as (src, dst) int64 arrays."""
    em = np.asarray(graph.edge_mask)
    idx = np.flatnonzero(em)
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    for lo in range(0, idx.size, chunk_edges):
        sel = idx[lo:lo + chunk_edges]
        yield src[sel].astype(np.int64), dst[sel].astype(np.int64)
    if idx.size == 0:
        yield (np.empty((0,), np.int64),) * 2


def _direction_major(graph: Graph, chunk_edges: int
                     ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    # the monolithic packer processes np.concatenate([s, d]) → all forward
    # entries, then all reversed ones; replaying chunks in the same global
    # order keeps the scatter-add float accumulation bit-identical
    for s, d in iter_edge_chunks(graph, chunk_edges):
        yield s, d
    for s, d in iter_edge_chunks(graph, chunk_edges):
        yield d, s


def graph_to_bsr_chunked(graph: Graph, blk: int = 128,
                         normalize: Optional[str] = None,
                         nnzb_cap: Optional[int] = None, dtype=np.float32,
                         chunk_edges: int = 1 << 20,
                         memory_budget: Optional[int] = None) -> BSRMatrix:
    """Two-pass chunked equivalent of ``graph_to_bsr`` — same signature
    plus the chunk size and an optional byte budget for the packed output.
    """
    if normalize not in (None, "sym", "row"):
        raise ValueError(normalize)
    n_cap = graph.n_cap
    n_pad = -(-n_cap // blk) * blk
    n_blocks = n_pad // blk
    check_int32_index(n_blocks, "n_blocks (tile rows)")

    # ---- pass 0: degrees (only when normalising) -------------------------
    deg = None
    if normalize is not None:
        deg = np.zeros((n_pad,), np.float64)
        for rows, _ in _direction_major(graph, chunk_edges):
            deg += np.bincount(rows, minlength=n_pad)
        deg = np.maximum(deg, 1.0)

    # ---- pass 1: count — fold chunk tile keys into one sorted set --------
    uniq = np.empty((0,), np.int64)
    for rows, cols in _direction_major(graph, chunk_edges):
        key = (rows // blk) * np.int64(n_blocks) + (cols // blk)
        uniq = np.union1d(uniq, key)
    nnzb = check_int32_index(uniq.shape[0], "nnzb (nonzero tile count)")
    cap = int(nnzb_cap if nnzb_cap is not None else max(nnzb, 1))
    if cap < nnzb:
        raise ValueError(f"nnzb_cap {cap} < required {nnzb}")

    # ---- budget gate: refuse *before* allocating the packed arrays -------
    itemsize = np.dtype(dtype).itemsize
    blocks_bytes = cap * blk * blk * itemsize
    planned = blocks_bytes + uniq.nbytes + cap * 4 + (n_blocks + 1) * 4
    if memory_budget is not None and planned > memory_budget:
        raise MemoryBudgetError(
            f"chunked BSR needs ~{planned / 2**20:.0f} MiB "
            f"({cap} tiles of {blk}x{blk} {np.dtype(dtype).name}) but "
            f"memory_budget is {memory_budget / 2**20:.0f} MiB; raise the "
            f"budget, raise blk, or relocate the graph first so tiles "
            f"concentrate")

    # ---- pass 2: fill — identical layout math to the monolithic packer ---
    blocks = np.zeros((cap, blk, blk), dtype=dtype)
    block_cols = np.full((cap,), -1, np.int32)
    block_cols[:nnzb] = (uniq % n_blocks).astype(np.int64)
    row_counts = np.zeros(n_blocks, dtype=np.int64)
    np.add.at(row_counts, (uniq // n_blocks).astype(np.int64), 1)
    row_ptr = np.zeros(n_blocks + 1, dtype=np.int32)
    np.cumsum(row_counts, out=row_ptr[1:])
    flat_blocks = blocks.reshape(-1)
    for rows, cols in _direction_major(graph, chunk_edges):
        key = (rows // blk) * np.int64(n_blocks) + (cols // blk)
        tile_of = np.searchsorted(uniq, key)
        vals = np.ones(rows.shape[0], dtype=np.float64)
        if normalize == "sym":
            vals /= np.sqrt(deg[rows] * deg[cols])
        elif normalize == "row":
            vals /= deg[rows]
        flat = tile_of * (blk * blk) + (rows % blk) * blk + (cols % blk)
        np.add.at(flat_blocks, flat, vals)
    return BSRMatrix(blocks=jnp.asarray(blocks),
                     block_cols=jnp.asarray(block_cols),
                     row_ptr=jnp.asarray(row_ptr),
                     nnzb=jnp.asarray(nnzb, jnp.int32))
