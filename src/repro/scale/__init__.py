"""Scale tier: streaming generators + chunked BSR construction so the
system reaches 1M–10M vertices with bounded host memory (DESIGN.md §14).

Entry points:

* ``make_edge_stream(name, n, ...)`` — registered streaming generators
  ("rmat"/"kronecker", "chung_lu") yielding deterministic edge chunks.
* ``stream_to_graph(stream)``        — chunk-wise dedup into a padded
  ``Graph``, bit-compatible with ``from_edges``.
* ``graph_to_bsr_chunked(graph)``    — two-pass count-then-fill BSR
  packing, bit-identical to ``graph_to_bsr``, with a ``memory_budget``.
* ``session_graph(section, seed)``   — the ``SystemConfig.graph`` wiring:
  a generator-named section builds its own starting graph.
"""
from __future__ import annotations

from typing import Optional

from repro.scale.chunked_bsr import (MemoryBudgetError, graph_to_bsr_chunked,
                                     iter_edge_chunks)
from repro.scale.generators import (ChungLuStream, EdgeChunkStream,
                                    RmatStream, SCALE_GENERATORS, chunk_rng,
                                    make_edge_stream, stream_events,
                                    stream_to_graph)

__all__ = [
    "ChungLuStream", "EdgeChunkStream", "MemoryBudgetError", "RmatStream",
    "SCALE_GENERATORS", "chunk_rng", "graph_to_bsr_chunked",
    "iter_edge_chunks", "make_edge_stream", "session_graph", "stream_events",
    "stream_to_graph",
]


def session_graph(section, seed: int = 0):
    """Build the starting ``Graph`` a ``SystemConfig.graph`` section with a
    ``generator`` name describes (``DynamicGraphSystem`` calls this when no
    explicit graph is passed).

    Capacities: ``n_cap`` defaults to the generator's ``n``; ``e_cap``
    defaults to 25% head-room over the generated live edges so a stream
    can still grow the graph.  Explicit caps win (and are validated).
    """
    stream = make_edge_stream(section.generator, section.n,
                              avg_degree=section.avg_degree,
                              chunk_edges=section.chunk_edges, seed=seed)
    n_cap: Optional[int] = section.n_cap if section.n_cap > 0 else None
    if section.e_cap > 0:
        return stream_to_graph(stream, n_cap=n_cap, e_cap=section.e_cap)
    graph = stream_to_graph(stream, n_cap=n_cap)     # e_cap = exact live
    import numpy as np
    import jax.numpy as jnp
    from repro.graph.structure import Graph
    pad = int(graph.e_cap * 0.25) + 16               # stream head-room
    fill = jnp.asarray(np.full((pad,), -1, np.int32))
    false = jnp.asarray(np.zeros((pad,), bool))
    return Graph(src=jnp.concatenate([graph.src, fill]),
                 dst=jnp.concatenate([graph.dst, fill]),
                 node_mask=graph.node_mask,
                 edge_mask=jnp.concatenate([graph.edge_mask, false]))
