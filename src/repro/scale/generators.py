"""Streaming graph generators for the million-vertex scale tier
(DESIGN.md §14).

The smoke-scale generators (``graph.generators``, ``scenarios/``) build the
whole edge list in one array — fine at 100k edges, hostile at 100M.  This
module generates edges as **chunks**: each generator is an indexable stream
of ``(src, dst)`` int64 arrays where chunk ``i`` is a pure function of
``(seed, i)`` via ``np.random.SeedSequence(entropy=(seed, TAG, i))``.  That
buys three properties the scale tier needs:

* bounded memory — nothing ever materialises the full edge list; peak host
  state is one chunk plus whatever the consumer accumulates;
* deterministic replay — any chunk can be regenerated independently (same
  seed ⇒ bit-identical stream), so a consumer can re-stream for a second
  pass instead of caching;
* no per-event Python state — every chunk is a single vectorized draw
  (ROADMAP: "the ingest path must never materialize O(|V|²) or per-event
  Python state").

Two families, both power-law by construction:

* ``RmatStream``   — recursive-matrix / stochastic-Kronecker sampling
  (Chakrabarti et al.; the graph500 generator family): each edge picks one
  of four quadrants per bit level, vectorized as ``levels`` independent
  Bernoulli draws over the whole chunk.
* ``ChungLuStream`` — Chung-Lu with Pareto weights: endpoints are drawn
  from the weight distribution via one ``searchsorted`` per chunk, giving
  an expected-degree power law with an exact O(n) setup.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, Optional, Tuple, Type

import numpy as np

# SeedSequence entropy tags: keep the per-chunk streams and the one-off
# weight draw in provably disjoint entropy pools
_TAG_CHUNK = 0x5CA1E
_TAG_WEIGHTS = 0x5CA1F


def chunk_rng(seed: int, chunk_idx: int) -> np.random.Generator:
    """The per-chunk RNG: a pure function of (seed, chunk index)."""
    ss = np.random.SeedSequence(entropy=(int(seed), _TAG_CHUNK, int(chunk_idx)))
    return np.random.default_rng(ss)


@dataclasses.dataclass(frozen=True)
class EdgeChunkStream:
    """Base class: a deterministic, indexable stream of edge chunks.

    ``chunk(i)`` returns ``(src, dst)`` int64 arrays (self-loops already
    dropped, so chunk sizes vary slightly below ``chunk_edges``).  Iterating
    yields every chunk in order; iterating twice replays the same stream.
    """

    n: int                     # vertex-id space [0, n)
    num_edges: int             # nominal emitted edges across the stream
    chunk_edges: int = 1 << 18 # emitted edges per chunk (pre self-loop drop)
    seed: int = 0

    def __post_init__(self):
        if self.n < 2:
            raise ValueError(f"need n >= 2 vertices, got {self.n}")
        if self.num_edges < 1:
            raise ValueError(f"need num_edges >= 1, got {self.num_edges}")
        if self.chunk_edges < 1:
            raise ValueError(f"need chunk_edges >= 1, got {self.chunk_edges}")

    @property
    def num_chunks(self) -> int:
        return -(-self.num_edges // self.chunk_edges)

    def _chunk_size(self, i: int) -> int:
        if not 0 <= i < self.num_chunks:
            raise IndexError(f"chunk {i} out of range [0, {self.num_chunks})")
        return min(self.chunk_edges, self.num_edges - i * self.chunk_edges)

    def chunk(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        for i in range(self.num_chunks):
            yield self.chunk(i)


@dataclasses.dataclass(frozen=True)
class RmatStream(EdgeChunkStream):
    """RMAT / stochastic-Kronecker edges (a=0.57 b=0.19 c=0.19 d=0.05 ≈
    the graph500 parameterisation).  Each edge descends ``ceil(log2 n)``
    quadrant levels; the descent is vectorized as one uniform draw per
    level over the whole chunk.  Ids land in [0, 2^levels) and are folded
    into [0, n) by modulo — the standard dense-id fold; the distribution
    tail is unaffected.
    """

    a: float = 0.57
    b: float = 0.19
    c: float = 0.19
    d: float = 0.05

    def __post_init__(self):
        super().__post_init__()
        total = self.a + self.b + self.c + self.d
        if not math.isclose(total, 1.0, rel_tol=1e-9):
            raise ValueError(f"RMAT quadrant probs must sum to 1, got {total}")

    @property
    def levels(self) -> int:
        return max(1, int(math.ceil(math.log2(self.n))))

    def chunk(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        size = self._chunk_size(i)
        rng = chunk_rng(self.seed, i)
        u = rng.random((self.levels, size))
        # quadrant layout per level:  (row, col) bit =
        #   (0,0) w.p. a | (0,1) w.p. b | (1,0) w.p. c | (1,1) w.p. d
        row_bit = u >= (self.a + self.b)
        col_bit = np.where(row_bit, u >= (self.a + self.b + self.c),
                           u >= self.a)
        weights = (np.int64(1) << np.arange(self.levels, dtype=np.int64))
        src = (row_bit.astype(np.int64) * weights[:, None]).sum(axis=0)
        dst = (col_bit.astype(np.int64) * weights[:, None]).sum(axis=0)
        src %= self.n
        dst %= self.n
        keep = src != dst
        return src[keep], dst[keep]


@dataclasses.dataclass(frozen=True)
class ChungLuStream(EdgeChunkStream):
    """Chung-Lu power-law edges: vertex weights ``w_v ~ Pareto(gamma-1)``,
    endpoints drawn proportionally to weight.  The weight vector is the
    only O(n) state and is drawn once from its own entropy pool; per-chunk
    sampling is two uniform draws + two ``searchsorted`` calls.
    """

    gamma: float = 2.5         # degree-distribution exponent p(d) ~ d^-gamma

    def __post_init__(self):
        super().__post_init__()
        if self.gamma <= 1.0:
            raise ValueError(f"need gamma > 1 for a normalisable power law, "
                             f"got {self.gamma}")
        ss = np.random.SeedSequence(entropy=(int(self.seed), _TAG_WEIGHTS))
        rng = np.random.default_rng(ss)
        w = rng.pareto(self.gamma - 1.0, size=self.n) + 1.0
        cdf = np.cumsum(w)
        cdf /= cdf[-1]
        object.__setattr__(self, "_cdf", cdf)

    def chunk(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        size = self._chunk_size(i)
        rng = chunk_rng(self.seed, i)
        src = np.searchsorted(self._cdf, rng.random(size)).astype(np.int64)
        dst = np.searchsorted(self._cdf, rng.random(size)).astype(np.int64)
        keep = src != dst
        return src[keep], dst[keep]


# registry: config-facing names → stream classes ("kronecker" is the RMAT
# synonym — RMAT *is* a stochastic Kronecker generator)
SCALE_GENERATORS: Dict[str, Type[EdgeChunkStream]] = {
    "rmat": RmatStream,
    "kronecker": RmatStream,
    "chung_lu": ChungLuStream,
    "chunglu": ChungLuStream,
}


def make_edge_stream(name: str, n: int, *, avg_degree: float = 8.0,
                     chunk_edges: int = 1 << 18, seed: int = 0,
                     **params) -> EdgeChunkStream:
    """Build a registered generator sized for ``avg_degree`` (emitted edges
    = n·avg_degree/2; dedup in the graph builder trims this slightly)."""
    cls = SCALE_GENERATORS.get(name)
    if cls is None:
        raise ValueError(f"unknown scale generator {name!r}; "
                         f"valid: {sorted(SCALE_GENERATORS)}")
    num_edges = max(1, int(round(n * avg_degree / 2.0)))
    return cls(n=n, num_edges=num_edges, chunk_edges=chunk_edges, seed=seed,
               **params)


def stream_to_graph(stream: EdgeChunkStream,
                    n_cap: Optional[int] = None,
                    e_cap: Optional[int] = None) -> "Graph":
    """Accumulate a chunk stream into a padded ``Graph``, dedup'd chunk by
    chunk.

    Bit-compatible with ``from_edges`` over the concatenated stream: both
    dedup through the same sorted ``lo·n + hi`` int64 key set, so the edge
    order in the packed arrays is identical.  Peak host state is the sorted
    key set (8 bytes per unique edge) plus one chunk — never the emitted
    multi-edge list.
    """
    from repro.graph.structure import Graph  # local import: keep the
    import jax.numpy as jnp                  # generators importable alone

    n = stream.n
    keys = np.empty((0,), np.int64)
    for src, dst in stream:
        lo = np.minimum(src, dst)
        hi = np.maximum(src, dst)
        keys = np.union1d(keys, lo * np.int64(n) + hi)
    lo = (keys // n).astype(np.int32)
    hi = (keys % n).astype(np.int32)
    e = lo.shape[0]
    n_cap = int(n_cap if n_cap is not None else n)
    e_cap = int(e_cap if e_cap is not None else e)
    if n_cap < n or e_cap < e:
        raise ValueError(f"capacity too small: n_cap={n_cap}<{n} "
                         f"or e_cap={e_cap}<{e}")
    s = np.full((e_cap,), -1, np.int32)
    d = np.full((e_cap,), -1, np.int32)
    s[:e], d[:e] = lo, hi
    nm = np.zeros((n_cap,), bool)
    nm[:n] = True
    em = np.zeros((e_cap,), bool)
    em[:e] = True
    return Graph(src=jnp.asarray(s), dst=jnp.asarray(d),
                 node_mask=jnp.asarray(nm), edge_mask=jnp.asarray(em))


def stream_events(stream: EdgeChunkStream, t0: int = 0,
                  span_per_chunk: int = 1) -> Iterator[np.ndarray]:
    """Adapt a chunk stream into ``(t, u, v)`` event batches for
    ``DynamicGraphSystem.step`` — chunk ``i`` gets timestamps in
    ``[t0 + i·span, t0 + (i+1)·span)``, evenly spread, so windowed ingest
    sees a moving clock without any per-event Python state."""
    for i, (src, dst) in enumerate(stream):
        m = src.shape[0]
        lo = t0 + i * span_per_chunk
        t = lo + (np.arange(m, dtype=np.int64) * span_per_chunk) // max(m, 1)
        yield np.stack([t, src, dst], axis=1)
