"""Elastic scaling: adapt the partitioning when workers join/leave.

The paper recovers failures by snapshot-restore (§4.3, Fig. 8 "sudden drop
... triggering of xDGP recovery mechanism"). We go further: on losing a
worker the partition count shrinks k → k', orphaned vertices are re-homed by
hash, and the SAME adaptive migration heuristic re-converges the placement —
partitioning quality recovers automatically instead of staying degraded.
On scale-UP, existing labels are kept; new partitions start empty and fill
only as the heuristic's quotas route movers there.

This module is the mechanism layer. The session-level operation is
``repro.api.DynamicGraphSystem.rescale`` (DESIGN.md §10), which re-homes
through :func:`rescale_assignment`, re-provisions capacity/telemetry for
the new k and re-adapts on the session's own execution backend;
``elastic_rescale`` below remains the standalone (graph, assignment)
entry point for benchmarks and ad-hoc use.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph, cut_ratio
from repro.core.partition_state import PartitionState, default_capacity, make_state
from repro.core.repartitioner import History, adapt_rounds


def rescale_assignment(assignment: jax.Array, old_k: int, new_k: int,
                       lost: Optional[Tuple[int, ...]] = None) -> jax.Array:
    """Map an assignment onto a new partition count.

    Scale-down: partitions in ``lost`` (default: the trailing ones) are
    re-homed by hashing the vertex id into the surviving set; the surviving
    partitions are renumbered densely.
    Scale-up: existing labels are kept (new partitions start empty).
    """
    a = assignment.astype(jnp.int32)
    n = a.shape[0]
    if new_k >= old_k:
        return a
    lost = tuple(lost) if lost is not None else tuple(range(new_k, old_k))
    keep = [p for p in range(old_k) if p not in lost]
    remap = np.full(old_k, -1, np.int32)
    for new_id, old_id in enumerate(keep):
        remap[old_id] = new_id
    remap_j = jnp.asarray(remap)
    ids = jnp.arange(n, dtype=jnp.uint32)
    mixed = ids * jnp.uint32(2654435761)
    rehash = (mixed % jnp.uint32(new_k)).astype(jnp.int32)
    mapped = remap_j[jnp.clip(a, 0, old_k - 1)]
    return jnp.where(mapped >= 0, mapped, rehash)


def elastic_rescale(graph: Graph, assignment: jax.Array, old_k: int,
                    new_k: int, adapt_iters: int = 60,
                    lost: Optional[Tuple[int, ...]] = None,
                    seed: int = 0) -> Tuple[jax.Array, History, dict]:
    """Full elastic event: re-home, then re-adapt. Returns (assignment,
    history, report) with before/after cut ratios."""
    a0 = rescale_assignment(assignment, old_k, new_k, lost)
    cut_before = float(cut_ratio(graph, a0))
    state = make_state(graph, a0, new_k, seed=seed)
    state, hist = adapt_rounds(graph, state, adapt_iters)
    cut_after = float(cut_ratio(graph, state.assignment))
    report = {"old_k": old_k, "new_k": new_k,
              "cut_after_rehash": cut_before, "cut_after_adapt": cut_after,
              "migrations": hist.total_migrations}
    return state.assignment, hist, report
