"""Sharding rules: logical-axis → mesh-axis mapping (MaxText-style).

The production mesh is ("data", "model") per pod, optionally with a leading
"pod" axis. Strategy (DESIGN.md §6):

  * batch-like dims          → ("pod", "data")
  * TP dims (heads, d_ff,
    vocab, experts)          → "model"
  * FSDP dim (the largest
    remaining param dim)     → "data"   (ZeRO: optimizer state inherits)
  * KV-cache sequence        → "model"  (flash-decoding style)
  * GNN node/edge dims       → flattened ("data", "model") device axis
  * embedding-table vocab    → "model"

Rules are expressed as predicates over param-tree paths so they apply to any
of the ten architectures without per-model tables.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _divisible(dim: int, mesh: Mesh, axes) -> bool:
    size = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple) else (axes,))]))
    return dim % size == 0


def lm_param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """2-D sharding for transformer params: TP over "model", FSDP over "data".

    path is the '/'-joined param tree path (e.g. "layers/attn/wq").
    """
    fsdp = "data"
    nd = len(shape)
    if nd == 0:
        return P()
    # stacked-layer leading dim (scan) is never sharded
    lead = 1 if path.startswith(("layers/", "dense_layers/")) else 0

    def spec_for(dims):
        full = [None] * nd
        for i, a in dims.items():
            full[i] = a
        return P(*full)

    name = path.split("/")[-1]
    d = {}
    if name in ("wq", "wk", "wv", "w_gate", "w_up"):            # (d_model, out)
        if nd - lead == 2:
            if _divisible(shape[-1], mesh, "model"):
                d[nd - 1] = "model"
            if _divisible(shape[-2], mesh, fsdp):
                d[nd - 2] = fsdp
        elif nd - lead == 3:                                     # experts (E,d,f)
            if _divisible(shape[lead], mesh, "model"):
                d[lead] = "model"
            if _divisible(shape[-1], mesh, fsdp):
                d[nd - 1] = fsdp
    elif name in ("wo", "w_down"):                               # (in, d_model)
        if nd - lead == 2:
            if _divisible(shape[-2], mesh, "model"):
                d[nd - 2] = "model"
            if _divisible(shape[-1], mesh, fsdp):
                d[nd - 1] = fsdp
        elif nd - lead == 3:
            if _divisible(shape[lead], mesh, "model"):
                d[lead] = "model"
            if _divisible(shape[-2], mesh, fsdp):
                d[nd - 2] = fsdp
    elif name in ("table", "w") and nd - lead == 2:              # embed / lm_head
        big = nd - 2 if shape[nd - 2] >= shape[nd - 1] else nd - 1
        small = nd - 1 if big == nd - 2 else nd - 2
        if _divisible(shape[big], mesh, "model"):
            d[big] = "model"
        if _divisible(shape[small], mesh, fsdp):
            d[small] = fsdp
    elif name in ("w_dkv", "w_uk", "w_uv", "router"):
        if _divisible(shape[-1], mesh, "model"):
            d[nd - 1] = "model"
        if _divisible(shape[-2], mesh, fsdp):
            d[nd - 2] = fsdp
    else:                                                        # norms, scalars
        return P()
    return spec_for(d)


def lm_param_shardings(abstract_params: Any, mesh: Mesh) -> Any:
    """NamedSharding pytree matching an abstract param pytree."""
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(abstract_params)[0]
    treedef = jax.tree.structure(abstract_params)

    def path_str(kp) -> str:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
        return "/".join(parts)

    specs = [NamedSharding(mesh, lm_param_spec(path_str(kp), leaf.shape, mesh))
             for kp, leaf in paths_and_leaves]
    return jax.tree.unflatten(treedef, specs)


def batch_spec(mesh: Mesh, extra: int = 1) -> P:
    """(B, ...) batch sharding over ("pod","data")."""
    return P(data_axes(mesh), *([None] * extra))


def token_spec(mesh: Mesh) -> P:
    return P(data_axes(mesh), None)


def cache_spec(mesh: Mesh, mla: bool = False) -> P:
    """KV cache (L, B, S, ...): B over data axes, S over model."""
    if mla:
        return P(None, data_axes(mesh), "model", None)
    return P(None, data_axes(mesh), "model", None, None)


def node_spec(mesh: Mesh, extra: int = 0) -> P:
    """GNN node/edge arrays: leading dim over every mesh axis (flattened)."""
    return P(tuple(mesh.axis_names), *([None] * extra))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def like_tree(tree: Any, sharding: NamedSharding) -> Any:
    return jax.tree.map(lambda _: sharding, tree)


# ---------------------------------------------------------------------------
# Activation sharding constraints (MaxText-style logical rules).
#
# GSPMD alone drops batch sharding on activations once FSDP-sharded weights
# enter the picture (it prefers resharding activations over all-gathering
# weights). Models call ``constrain(x, <logical axes>)`` at block boundaries;
# when no activation mesh is installed (unit tests, single-device) it is a
# no-op, so model code stays mesh-agnostic.
# ---------------------------------------------------------------------------

_ACTIVATION_MESH: Any = None

LOGICAL = {
    "batch": None,      # resolved to ("pod","data") / ("data",)
    "seq": None,
    "heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "d_model": None,
    "none": None,
}


def set_activation_mesh(mesh: Optional[Mesh]) -> None:
    global _ACTIVATION_MESH
    _ACTIVATION_MESH = mesh


def get_activation_mesh() -> Optional[Mesh]:
    return _ACTIVATION_MESH


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Pin activation sharding by logical axis names (no-op without a mesh)."""
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return x
    spec = []
    for i, name in enumerate(logical_axes):
        if name == "batch":
            axes = data_axes(mesh)
            spec.append(axes if x.shape[i] % int(
                np.prod([mesh.shape[a] for a in axes])) == 0 else None)
        elif name in ("heads", "d_ff", "vocab", "experts", "seq_sp"):
            # seq_sp = Megatron-style sequence parallelism: the residual
            # stream is sharded over "model" between blocks; GSPMD inserts
            # the all-gather (pre-attention/MLP) + reduce-scatter (post).
            spec.append("model" if x.shape[i] % mesh.shape["model"] == 0 else None)
        elif name == "flat":
            # GNN node/edge/triplet arrays: shard over every mesh axis
            axes = tuple(mesh.axis_names)
            spec.append(axes if x.shape[i] % int(
                np.prod([mesh.shape[a] for a in axes])) == 0 else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
