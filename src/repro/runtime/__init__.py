from repro.runtime.elastic import elastic_rescale, rescale_assignment
from repro.runtime import sharding

__all__ = ["elastic_rescale", "rescale_assignment", "sharding"]
