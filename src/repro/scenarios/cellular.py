"""Mobile/cellular connectivity graph with user-movement churn (paper use
case 2, §5.3 — the mobile operator's CDR stream).

Users live in the cells of a tower grid (``generators.cell_grid``) and call
each other; calls are strongly local (same cell or an adjacent cell), which
gives the graph its community structure. Users random-walk across
neighbouring towers over time, so community membership drifts continuously —
exactly the slow topology churn the adaptive repartitioner is built for.
The sliding window expires users who stop calling.

Nodes are users; the tower topology only shapes who calls whom and where
users can roam. The analysis program is min-label propagation (WCC), the
closest shipped analogue of the operator's community/clique analysis.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.graph import generators
from repro.graph.structure import to_csr
from repro.scenarios.base import Scenario, empty_graph

SIZES = {
    "smoke": dict(rows=4, cols=4, n_users=600, n_events=9_000, supersteps=18,
                  batch_span=80, k=4, a_cap=2048, d_cap=1024, e_cap=8_000,
                  adapt_iters=6),
    "small": dict(rows=8, cols=8, n_users=4_000, n_events=60_000,
                  supersteps=32, batch_span=100, k=8, a_cap=8192, d_cap=4096,
                  e_cap=40_000, adapt_iters=6),
    "full": dict(rows=14, cols=14, n_users=24_000, n_events=400_000,
                 supersteps=48, batch_span=150, k=16, a_cap=16384, d_cap=8192,
                 e_cap=200_000, adapt_iters=8),
}


def movement_stream(n_users: int, rows: int, cols: int, n_events: int,
                    t_end: int, seed: int = 0, move_prob: float = 0.04,
                    local_p: float = 0.7, nbr_p: float = 0.22,
                    ticks: int = 64,
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Call stream (t, caller, callee) over a roaming user population."""
    rng = np.random.default_rng(seed)
    n_towers = rows * cols
    towers = generators.cell_grid(rows, cols)
    indptr, indices = to_csr(towers)
    deg = np.diff(indptr).astype(np.int64)

    user_tower = rng.integers(0, n_towers, n_users)
    per = n_events // ticks
    dt = max(1, t_end // ticks)
    times_l, src_l, dst_l = [], [], []
    for tick in range(ticks):
        t0 = tick * dt
        # movement: a fraction of users hops to a random neighbouring tower
        movers = np.flatnonzero(rng.random(n_users) < move_prob)
        if movers.size:
            ut = user_tower[movers]
            off = rng.integers(0, np.maximum(deg[ut], 1))
            user_tower[movers] = indices[indptr[ut] + np.minimum(off, deg[ut] - 1)]
        # bucket users by tower for O(1) "random user in cell T" sampling
        order = np.argsort(user_tower, kind="stable")
        sorted_t = user_tower[order]
        start = np.searchsorted(sorted_t, np.arange(n_towers))
        count = (np.searchsorted(sorted_t, np.arange(n_towers), side="right")
                 - start)
        # calls this tick
        u = (rng.zipf(1.6, per) - 1) % n_users          # heavy callers
        r = rng.random(per)
        ut_u = user_tower[u]
        noff = rng.integers(0, np.maximum(deg[ut_u], 1))
        nbr_t = indices[indptr[ut_u] + np.minimum(noff, deg[ut_u] - 1)]
        tw = np.where(r < local_p, ut_u,
                      np.where(r < local_p + nbr_p, nbr_t,
                               rng.integers(0, n_towers, per)))
        c = count[tw]
        pick = start[tw] + rng.integers(0, np.maximum(c, 1))
        v = order[np.minimum(pick, n_users - 1)]
        v = np.where(c > 0, v, rng.integers(0, n_users, per))
        times_l.append(np.sort(rng.integers(t0, t0 + dt, per)))
        src_l.append(u)
        dst_l.append(v)
    times = np.concatenate(times_l)
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    keep = src != dst
    return times[keep], src[keep], dst[keep]


def build(scale: str = "small", seed: int = 0) -> Scenario:
    p = SIZES[scale]
    t_end = p["supersteps"] * p["batch_span"]
    window = 4 * p["batch_span"]
    times, src, dst = movement_stream(
        p["n_users"], p["rows"], p["cols"], p["n_events"], t_end, seed=seed,
        ticks=2 * p["supersteps"])
    return Scenario(
        name="cellular",
        program="wcc",
        graph=empty_graph(p["n_users"], p["e_cap"]),
        times=times, src=src, dst=dst,
        batch_span=p["batch_span"], window=window, k=p["k"],
        a_cap=p["a_cap"], d_cap=p["d_cap"], adapt_iters=p["adapt_iters"],
        payload_scale=32.0,        # CDR records / clique lists are heavy
        seed=seed,
        notes=f"{p['rows']}x{p['cols']} tower grid, {p['n_users']} roaming "
              "users, cell-local call pattern")
