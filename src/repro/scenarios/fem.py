"""Adaptively refined FEM mesh (paper use case 3, §5.3 — "heart cell" model).

The paper's biomedical workload simulates electrical wave propagation over a
3-D FEM mesh whose resolution is adaptively refined where the wave front is.
This driver reproduces that shape of dynamism on ``fem_cube`` meshes:

* the base cubic mesh is permanently live — every simulation sweep touches
  every cell, modelled as a rotating re-emission of the base mesh edges
  (dedupe folds the repeats into window refreshes, so the base mesh never
  duplicates and never expires);
* a refinement wave (a slab of cells around the moving wave front) spawns
  one child vertex per cell, wired to its parent cell and to the children of
  lattice-neighbour cells — a finer mesh layer riding on the coarse one;
* when the wave moves on, the slab's children stop being re-emitted and the
  sliding window coarsens them away.

The wave is therefore a moving load/locality hotspot: the adaptive
partitioner must keep each refined region co-located while it exists.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.graph import generators
from repro.scenarios.base import Scenario

SIZES = {
    "smoke": dict(side=7, supersteps=16, batch_span=60, k=4,
                  a_cap=4096, d_cap=2048, adapt_iters=6),
    "small": dict(side=11, supersteps=30, batch_span=80, k=8,
                  a_cap=8192, d_cap=4096, adapt_iters=6),
    "full": dict(side=16, supersteps=48, batch_span=100, k=12,
                 a_cap=20000, d_cap=8192, adapt_iters=8),
}


def refinement_stream(side: int, supersteps: int, batch_span: int, window: int,
                      seed: int = 0, slab_half: float = 1.5,
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Refinement-wave event stream over a ``side**3`` cubic mesh.

    Child of cell c has id ``side**3 + c`` (one live refinement level), so
    n_cap must be ``2 * side**3``.
    """
    rng = np.random.default_rng(seed)
    n_base = side ** 3
    base = generators.fem_cube(side)
    em = np.asarray(base.edge_mask)
    base_src = np.asarray(base.src)[em].astype(np.int64)
    base_dst = np.asarray(base.dst)[em].astype(np.int64)
    e_base = base_src.shape[0]

    ids = np.arange(n_base, dtype=np.int64)
    x = ids % side
    y = (ids // side) % side
    z = ids // (side * side)

    # every base edge is re-emitted once per refresh cycle, spread uniformly
    # across the cycle's supersteps, so no base vertex idles past the window
    refresh_steps = max(1, int(0.45 * window / batch_span))
    kslice = -(-e_base // refresh_steps)
    perm = rng.permutation(e_base)

    times_l, src_l, dst_l = [], [], []

    def emit(t0: int, s: np.ndarray, d: np.ndarray) -> None:
        times_l.append(rng.integers(t0, t0 + batch_span, s.shape[0]))
        src_l.append(s)
        dst_l.append(d)

    for step in range(supersteps):
        t0 = step * batch_span
        idx = perm[(np.arange(kslice) + step * kslice) % e_base]
        emit(t0, base_src[idx], base_dst[idx])

        # refinement slab around the moving wave front (sweeps the z axis)
        zc = (step / max(supersteps - 1, 1)) * (side - 1)
        in_slab = np.abs(z - zc) <= slab_half
        cells = ids[in_slab]
        emit(t0, n_base + cells, cells)                  # child ↔ parent
        for off, bounded in ((1, x + 1 < side),
                             (side, y + 1 < side),
                             (side * side, z + 1 < side)):
            m = in_slab & bounded
            nb = ids[m] + off
            m2 = in_slab[nb]
            emit(t0, n_base + ids[m][m2], n_base + nb[m2])  # child ↔ child

    times = np.concatenate(times_l)
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    order = np.argsort(times, kind="stable")
    return times[order], src[order], dst[order]


def build(scale: str = "small", seed: int = 0) -> Scenario:
    p = SIZES[scale]
    side = p["side"]
    n_base = side ** 3
    e_base = 3 * side * side * (side - 1)
    # 6 spans: wide enough that the keep-alive rotation spreads the base
    # mesh over refresh_steps=2 supersteps instead of re-emitting it whole
    window = 6 * p["batch_span"]
    times, src, dst = refinement_stream(side, p["supersteps"], p["batch_span"],
                                        window, seed=seed)
    graph = generators.fem_cube(side, n_cap=2 * n_base,
                                e_cap=int(2.5 * e_base) + 2000)
    return Scenario(
        name="fem",
        program="pagerank",        # diffusion-style propagation proxy
        graph=graph,
        times=times, src=src, dst=dst,
        batch_span=p["batch_span"], window=window, k=p["k"],
        a_cap=p["a_cap"], d_cap=p["d_cap"], adapt_iters=p["adapt_iters"],
        payload_scale=100.0,       # paper: ~100 state variables per cell
        seed=seed,
        notes="refinement wave sweeping a fem_cube mesh; children expire "
              "behind the wave")
