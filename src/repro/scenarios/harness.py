"""Compute-coupled scenario evaluation: adaptive vs. static-hash partitioning.

Thin wrapper over the ``repro.api`` front door: a ``Scenario`` is a valid
``stream`` for ``DynamicGraphSystem``, and the adaptive-vs-baseline dual run
(identical streams, execution-cost scoring, BSR snapshot) is
``DynamicGraphSystem.compare`` — the strategy swap ``xdgp`` ↔ ``static`` in
one ``SystemConfig`` field is the whole comparison:

  cost(step) = c_cpu · local_bytes + c_net · remote_bytes
               + c_mig · migrations · unit_bytes

c_net/c_cpu = 25 models the paper's §5.3 observation that cross-partition
messages dominate iteration time (>80%); the migration term charges the
adaptive run for its own overhead so the comparison is end to end, like the
paper's ">50% execution time reduction" claim. A BSR snapshot of the final
graph (vertices relabelled by partition) adds the TPU-locality view: fewer
nonzero tiles ⇒ proportionally less SpMM compute/DMA (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.api import DynamicGraphSystem, bsr_snapshot, partition_relabelled
from repro.core.vertex_program import CostModel
from repro.scenarios.base import Scenario

__all__ = ["CostModel", "bsr_snapshot", "compare_scenario",
           "partition_relabelled", "run_scenario"]


def _system(scn: Scenario, *, strategy: str, seed: Optional[int] = None,
            backend: str = "auto", cluster: str = "local",
            ) -> DynamicGraphSystem:
    return DynamicGraphSystem(scn.graph,
                              scn.system_config(strategy=strategy, seed=seed,
                                                backend=backend,
                                                cluster=cluster))


def run_scenario(scn: Scenario, *, adaptive: bool,
                 max_supersteps: Optional[int] = None, bsr_blk: int = 32,
                 cost: Optional[CostModel] = None, seed: Optional[int] = None,
                 backend: str = "auto", cluster: str = "local") -> Dict:
    """Drive the scenario through the system; return the measured run row."""
    system = _system(scn, strategy="xdgp" if adaptive else "static",
                     seed=seed, backend=backend, cluster=cluster)
    system.run(scn, max_supersteps=max_supersteps)
    return system.score(cost=cost, bsr_blk=bsr_blk)


def compare_scenario(scn: Scenario, *, strategy: str = "xdgp",
                     baseline: str = "static",
                     max_supersteps: Optional[int] = None,
                     bsr_blk: int = 32, cost: Optional[CostModel] = None,
                     seed: Optional[int] = None, backend: str = "auto",
                     cluster: str = "local") -> Dict:
    """``strategy`` vs. ``baseline`` on the identical stream (with the
    defaults: the paper's adaptive-vs-static-hash comparison; the strategy
    arena sweeps ``strategy`` over every canonical registry name).

    ``seed`` varies the system's own randomness (placement tie noise,
    migration damping) independently of the stream, which stays pinned to
    the scenario's seed. ``backend`` selects the migration-scoring path
    (DESIGN.md §9), ``cluster`` the execution backend (DESIGN.md §10) —
    bit-identical results whichever way."""
    system = _system(scn, strategy=strategy, seed=seed, backend=backend,
                     cluster=cluster)
    return system.compare(scn, baseline=baseline,
                          max_supersteps=max_supersteps, bsr_blk=bsr_blk,
                          cost=cost)
