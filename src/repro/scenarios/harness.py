"""Compute-coupled scenario evaluation: adaptive vs. static-hash partitioning.

Runs a ``Scenario`` end to end through the ``StreamEngine`` with its vertex
program executing every superstep, twice — once with online placement +
interleaved xDGP adaptation, once with static hash partitioning and zero
adaptation — and compares the per-superstep execution-cost proxy:

  cost(step) = c_cpu · local_bytes + c_net · remote_bytes
               + c_mig · migrations · unit_bytes

c_net/c_cpu = 25 models the paper's §5.3 observation that cross-partition
messages dominate iteration time (>80%); the migration term charges the
adaptive run for its own overhead so the comparison is end to end, like the
paper's ">50% execution time reduction" claim. A BSR snapshot of the final
graph (vertices relabelled by partition, ``graph_to_bsr`` +
``bsr_density_stats``) adds the TPU-locality view: fewer nonzero tiles ⇒
proportionally less SpMM compute/DMA (DESIGN.md §2).
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.core.vertex_program import CostModel, make_program
from repro.graph.bsr import bsr_density_stats, graph_to_bsr
from repro.graph.structure import Graph, from_edges
from repro.scenarios.base import Scenario
from repro.stream.engine import StreamEngine


def partition_relabelled(graph: Graph, assignment) -> Optional[Graph]:
    """Relabel live vertices grouped by partition (the relocation step that
    turns partition quality into BSR tile locality)."""
    nm = np.asarray(graph.node_mask)
    em = np.asarray(graph.edge_mask)
    lab = np.asarray(assignment)
    live = np.flatnonzero(nm)
    if live.size == 0 or not em.any():
        return None
    order = live[np.argsort(lab[live], kind="stable")]
    new_id = np.full(graph.n_cap, -1, np.int64)
    new_id[order] = np.arange(live.size)
    s = new_id[np.asarray(graph.src)[em]]
    d = new_id[np.asarray(graph.dst)[em]]
    return from_edges(s, d, live.size)


def bsr_snapshot(graph: Graph, assignment, blk: int = 32) -> Dict:
    """Tile stats of the partition-relabelled adjacency (kernel-cost proxy)."""
    relab = partition_relabelled(graph, assignment)
    if relab is None:      # no live vertices/edges: same shape as the
        return {"nnzb": 0, "diag_frac": 1.0, "mean_band": 0.0,  # empty branch
                "tiles_per_row": 0.0}                 # of bsr_density_stats
    return bsr_density_stats(graph_to_bsr(relab, blk=blk))


def run_scenario(scn: Scenario, *, adaptive: bool,
                 max_supersteps: Optional[int] = None, bsr_blk: int = 32,
                 cost: Optional[CostModel] = None, seed: Optional[int] = None,
                 ) -> Dict:
    """Drive the scenario through the engine; return the measured run row."""
    cost = cost or CostModel()
    prog = make_program(scn.program)
    cfg = scn.stream_config(adaptive=adaptive, seed=seed)
    eng = StreamEngine(scn.graph, cfg, program=prog)
    t0 = time.perf_counter()
    recs = eng.run_stream(np.asarray(scn.times), np.asarray(scn.src),
                          np.asarray(scn.dst), scn.batch_span,
                          max_supersteps=max_supersteps)
    wall = time.perf_counter() - t0
    drifts = [r.drift for r in recs if r.drift is not None]
    if any(d != 0.0 for d in drifts):     # survives python -O, unlike assert
        raise RuntimeError(f"quality tracker drifted: {drifts}")

    unit = prog.state_dim * 4 * scn.payload_scale
    local = sum(r.local_bytes for r in recs) * scn.payload_scale
    remote = sum(r.remote_bytes for r in recs) * scn.payload_scale
    migrations = sum(r.migrations for r in recs)
    per_step = [cost.superstep_cost(r.local_bytes * scn.payload_scale,
                                    r.remote_bytes * scn.payload_scale,
                                    r.migrations, unit) for r in recs]
    total = float(np.sum(per_step))
    return {
        "mode": "adaptive" if adaptive else "static_hash",
        "supersteps": len(recs),
        "events": int(sum(r.events for r in recs)),
        "cut_final": float(recs[-1].cut_ratio),
        "cut_mean": float(np.mean([r.cut_ratio for r in recs])),
        "imbalance_final": float(recs[-1].imbalance),
        "migrations_total": int(migrations),
        "placed_total": int(sum(r.new_placed for r in recs)),
        "local_bytes": float(local),
        "remote_bytes": float(remote),
        "exec_cost_total": total,
        "exec_cost_per_superstep": total / max(len(recs), 1),
        "adaptation_cost": float(cost.c_mig * migrations * unit),
        "compute_seconds": float(sum(r.compute_seconds for r in recs)),
        "wall_seconds": float(wall),
        "bsr": bsr_snapshot(eng.graph, eng.state.assignment, blk=bsr_blk),
        "cut_trajectory": [round(float(r.cut_ratio), 4) for r in recs],
    }


def compare_scenario(scn: Scenario, *, max_supersteps: Optional[int] = None,
                     bsr_blk: int = 32, cost: Optional[CostModel] = None,
                     seed: Optional[int] = None) -> Dict:
    """Adaptive vs. static-hash on the identical stream (paper's comparison).

    ``seed`` varies the engine's own randomness (placement tie noise,
    migration damping) independently of the stream, which stays pinned to
    the scenario's seed."""
    adaptive = run_scenario(scn, adaptive=True, max_supersteps=max_supersteps,
                            bsr_blk=bsr_blk, cost=cost, seed=seed)
    static = run_scenario(scn, adaptive=False, max_supersteps=max_supersteps,
                          bsr_blk=bsr_blk, cost=cost, seed=seed)
    s_cost = max(static["exec_cost_total"], 1e-12)
    reduction = 1.0 - adaptive["exec_cost_total"] / s_cost
    s_tiles = max(static["bsr"]["nnzb"], 1)
    return {
        "scenario": scn.name,
        "program": scn.program,
        "k": scn.k,
        "events": scn.n_events,
        "notes": scn.notes,
        "adaptive": adaptive,
        "static": static,
        "exec_cost_reduction_pct":
            round(100 * reduction, 1),
        "remote_reduction_pct":
            round(100 * (1 - adaptive["remote_bytes"]
                         / max(static["remote_bytes"], 1e-12)), 1),
        "cut_improvement":
            round(1 - adaptive["cut_final"] / max(static["cut_final"], 1e-12), 3),
        "bsr_tile_reduction_pct":
            round(100 * (1 - adaptive["bsr"]["nnzb"] / s_tiles), 1),
        "meets_50pct_claim": bool(reduction > 0.5),
    }
