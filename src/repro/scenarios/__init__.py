"""Paper-scenario workload suite (§5.3): three real-world dynamic workloads
driven end to end through ``repro.api.DynamicGraphSystem`` with compute
interleaved — Twitter mentions + TunkRank, an adaptively refined FEM mesh,
and a mobile/cellular call graph with user-movement churn. A ``Scenario``
is itself a valid ``stream`` for ``DynamicGraphSystem.run``/``compare``."""
from repro.scenarios.base import Scenario, empty_graph
from repro.scenarios import adversarial, cellular, fem, twitter
from repro.scenarios.harness import (CostModel, bsr_snapshot, compare_scenario,
                                     partition_relabelled, run_scenario)

SCENARIOS = {
    "twitter": twitter.build,
    "fem": fem.build,
    "cellular": cellular.build,
}

# the paper scenarios plus the arena-only adversarial churn stream; the
# strategy arena iterates this, while SCENARIOS stays the paper's §5.3 set
ARENA_SCENARIOS = {
    **SCENARIOS,
    "adversarial": adversarial.build,
}

__all__ = [
    "Scenario", "empty_graph", "SCENARIOS", "ARENA_SCENARIOS",
    "CostModel", "bsr_snapshot", "compare_scenario", "partition_relabelled",
    "run_scenario",
    "twitter", "fem", "cellular", "adversarial",
]
