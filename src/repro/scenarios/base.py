"""Scenario contract for the paper's real-world dynamic workloads (§5.3).

A ``Scenario`` bundles everything needed to drive one workload end to end
through the ``StreamEngine``: an initial padded graph, a ``(t, src, dst)``
event stream, the windowing/batching parameters, and the vertex program the
paper runs on that workload. The harness (``repro.scenarios.harness``) runs
the same scenario under adaptive and static-hash partitioning and compares
the per-superstep execution-cost proxy.

Every driver is deterministic under its seed, so the scenario regression
tests and the e2e benchmark replay identical streams.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph
from repro.stream.engine import StreamConfig


def empty_graph(n_cap: int, e_cap: int) -> Graph:
    """All-padding graph: the stream grows it from nothing."""
    return Graph(src=jnp.full((e_cap,), -1, jnp.int32),
                 dst=jnp.full((e_cap,), -1, jnp.int32),
                 node_mask=jnp.zeros((n_cap,), bool),
                 edge_mask=jnp.zeros((e_cap,), bool))


@dataclasses.dataclass
class Scenario:
    """One reproducible dynamic workload, ready for ``StreamEngine.run_stream``."""

    name: str
    program: str              # key into core.vertex_program.PROGRAMS
    graph: Graph              # initial padded graph (empty for pure streams)
    times: np.ndarray         # (m,) event timestamps, sorted
    src: np.ndarray           # (m,) event endpoints
    dst: np.ndarray
    batch_span: int           # stream time per engine superstep
    window: int               # sliding-window length (liveness horizon)
    k: int = 8                # partitions
    a_cap: int = 8192
    d_cap: int = 4096
    adapt_iters: int = 6      # migration rounds per superstep (adaptive mode)
    payload_scale: float = 1.0  # bytes-per-message multiplier (FEM: 100 state
                                # variables/cell; CDR: clique lists — §5.3)
    seed: int = 0
    notes: str = ""

    @property
    def n_events(self) -> int:
        return int(np.asarray(self.times).shape[0])

    @property
    def supersteps(self) -> int:
        t = np.asarray(self.times)
        if t.size == 0:
            return 0
        span = int(t.max()) - int(t.min())
        return span // self.batch_span + 1

    def stream_config(self, *, adaptive: bool, seed: Optional[int] = None,
                      recompute_every: int = 8) -> StreamConfig:
        """Engine config for this scenario.

        adaptive=True  → online placement of arrivals + interleaved xDGP
                         migration rounds (the system under test).
        adaptive=False → static hash partitioning: arrivals inherit the
                         padded-slot hash, zero adaptation (the baseline the
                         paper compares against).
        """
        return StreamConfig(
            k=self.k, window=self.window,
            a_cap=self.a_cap, d_cap=self.d_cap,
            adapt_iters=self.adapt_iters if adaptive else 0,
            placement="online" if adaptive else "hash",
            dedupe=True, recompute_every=recompute_every,
            seed=self.seed if seed is None else seed)
