"""Scenario contract for the paper's real-world dynamic workloads (§5.3).

A ``Scenario`` bundles everything needed to drive one workload end to end
through ``repro.api.DynamicGraphSystem``: an initial padded graph, a
``(t, src, dst)`` event stream, the windowing/batching parameters, and the
vertex program the paper runs on that workload. Because it exposes
``times``/``src``/``dst``/``batch_span``, a scenario is itself a valid
``stream`` argument for ``DynamicGraphSystem.run``/``compare``;
``system_config()`` produces the matching ``SystemConfig`` with the system
under test (``xdgp``) as the strategy — the harness compares it against
``static`` by swapping that one field.

Every driver is deterministic under its seed, so the scenario regression
tests and the e2e benchmark replay identical streams.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.api import (ClusterSection, ComputeSection, GraphSection,
                       PartitionSection, StreamSection, SystemConfig,
                       TelemetrySection, empty_graph)
from repro.graph.structure import Graph
from repro.stream.engine import StreamConfig

__all__ = ["Scenario", "empty_graph"]


@dataclasses.dataclass
class Scenario:
    """One reproducible dynamic workload, ready for ``DynamicGraphSystem.run``."""

    name: str
    program: str              # key into core.vertex_program.PROGRAMS
    graph: Graph              # initial padded graph (empty for pure streams)
    times: np.ndarray         # (m,) event timestamps, sorted
    src: np.ndarray           # (m,) event endpoints
    dst: np.ndarray
    batch_span: int           # stream time per engine superstep
    window: int               # sliding-window length (liveness horizon)
    k: int = 8                # partitions
    a_cap: int = 8192
    d_cap: int = 4096
    adapt_iters: int = 6      # migration rounds per superstep (adaptive mode)
    payload_scale: float = 1.0  # bytes-per-message multiplier (FEM: 100 state
                                # variables/cell; CDR: clique lists — §5.3)
    seed: int = 0
    notes: str = ""

    @property
    def n_events(self) -> int:
        return int(np.asarray(self.times).shape[0])

    @property
    def supersteps(self) -> int:
        t = np.asarray(self.times)
        if t.size == 0:
            return 0
        span = int(t.max()) - int(t.min())
        return span // self.batch_span + 1

    def system_config(self, *, strategy: str = "xdgp",
                      seed: Optional[int] = None,
                      recompute_every: int = 8,
                      backend: str = "auto",
                      cluster: str = "local") -> SystemConfig:
        """The session config for this scenario.

        ``strategy="xdgp"`` is the system under test (online placement of
        arrivals + interleaved migration); swapping the field to
        ``"static"`` yields the paper's static-hash baseline — no other
        change anywhere. ``backend`` selects the migration-scoring
        implementation (``"ref"``/``"pallas"``/``"auto"``, DESIGN.md §9);
        ``cluster`` selects the execution backend (``"local"``/``"sharded"``,
        DESIGN.md §10) — all combinations produce bit-identical runs.
        """
        return SystemConfig(
            graph=GraphSection(n_cap=self.graph.n_cap, e_cap=self.graph.e_cap),
            stream=StreamSection(window=self.window,
                                 batch_span=self.batch_span,
                                 a_cap=self.a_cap, d_cap=self.d_cap,
                                 dedupe=True),
            partition=PartitionSection(strategy=strategy, k=self.k,
                                       adapt_iters=self.adapt_iters),
            compute=ComputeSection(program=self.program,
                                   payload_scale=self.payload_scale,
                                   backend=backend),
            cluster=ClusterSection(backend=cluster),
            telemetry=TelemetrySection(recompute_every=recompute_every),
            seed=self.seed if seed is None else seed)

    def stream_config(self, *, adaptive: bool, seed: Optional[int] = None,
                      recompute_every: int = 8) -> StreamConfig:
        """Seed-era flat config (kept for the ``StreamEngine`` shim path)."""
        return StreamConfig(
            k=self.k, window=self.window,
            a_cap=self.a_cap, d_cap=self.d_cap,
            adapt_iters=self.adapt_iters if adaptive else 0,
            placement="online" if adaptive else "hash",
            dedupe=True, recompute_every=recompute_every,
            seed=self.seed if seed is None else seed)
