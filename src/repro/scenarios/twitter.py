"""Twitter-style mention stream + TunkRank (paper use case 1, §5.3).

The paper analyses a London-tweets mention graph with TunkRank while the
graph keeps changing under it. This driver synthesises that workload:

* users join over time (the active set grows linearly with stream time);
* authors are celebrity-skewed (zipf activity);
* mention targets mix a social circle (nearby ids — community structure),
  preferential attachment with recency (a bounded pool of recent mention
  targets — the hubs), and uniform exploration;
* the sliding window expires users who stop being mentioned, so the graph
  both grows and churns.

Repeated mentions of the same pair inside the window are frequent and real;
the engine's dedupe mode folds them into window refreshes instead of
duplicate edges.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.scenarios.base import Scenario, empty_graph

SIZES = {
    "smoke": dict(n_users=600, n_events=9_000, window=240, batch_span=80,
                  k=4, a_cap=2048, d_cap=1024, e_cap=8_000, t_end_windows=6,
                  adapt_iters=6),
    "small": dict(n_users=4_000, n_events=60_000, window=400, batch_span=100,
                  k=8, a_cap=8192, d_cap=4096, e_cap=40_000, t_end_windows=8,
                  adapt_iters=6),
    "full": dict(n_users=20_000, n_events=400_000, window=600, batch_span=150,
                 k=16, a_cap=16384, d_cap=8192, e_cap=200_000, t_end_windows=10,
                 adapt_iters=8),
}


def mention_stream(n_users: int, n_events: int, t_end: int, seed: int = 0,
                   circle_p: float = 0.5, pool_p: float = 0.35,
                   circle_width: int = 40, pool_cap: int = 20_000,
                   chunk: int = 8192,
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Preferential-attachment mention stream: (t, author, mentioned)."""
    rng = np.random.default_rng(seed)
    times = np.sort(rng.integers(0, t_end, n_events))
    n0 = max(circle_width + 2, n_users // 20)
    # active-user count at each event time (linear join process)
    act = np.minimum(n0 + ((n_users - n0) * times) // max(t_end, 1), n_users)
    act = np.maximum(act, 2)
    src = np.empty(n_events, np.int64)
    dst = np.empty(n_events, np.int64)
    pool = np.arange(n0, dtype=np.int64)      # recent mention targets
    for i0 in range(0, n_events, chunk):
        sl = slice(i0, min(i0 + chunk, n_events))
        a = act[sl]
        m = a.shape[0]
        u = (rng.zipf(1.5, m) - 1) % a        # celebrity-skewed authors
        r = rng.random(m)
        circle = (u + rng.integers(1, circle_width, m)) % a
        pref = pool[rng.integers(0, pool.shape[0], m)] % a
        explore = rng.integers(0, a)
        v = np.where(r < circle_p, circle,
                     np.where(r < circle_p + pool_p, pref, explore))
        src[sl] = u
        dst[sl] = v
        pool = np.concatenate([pool, v])[-pool_cap:]
    keep = src != dst
    return times[keep], src[keep], dst[keep]


def build(scale: str = "small", seed: int = 0) -> Scenario:
    p = SIZES[scale]
    t_end = p["window"] * p["t_end_windows"]
    times, src, dst = mention_stream(p["n_users"], p["n_events"], t_end,
                                     seed=seed)
    return Scenario(
        name="twitter",
        program="tunkrank",
        graph=empty_graph(p["n_users"], p["e_cap"]),
        times=times, src=src, dst=dst,
        batch_span=p["batch_span"], window=p["window"], k=p["k"],
        a_cap=p["a_cap"], d_cap=p["d_cap"], adapt_iters=p["adapt_iters"],
        payload_scale=1.0, seed=seed,
        notes="preferential-attachment mention stream, TunkRank influence")
