"""Adversarial churn stream for the strategy arena (not a paper scenario).

The three §5.3 workloads drift slowly — communities move a few members per
superstep, so any migrating strategy eventually catches up. This driver is
built to be hostile to *converged* partitions: vertices belong to
contiguous-id communities whose boundaries **rotate** through the id space
every tick (each tick re-assigns a ``stride``-sized slice of every
community to its neighbour), so the optimal partition is a moving target
and yesterday's perfect cut decays continuously. A strategy only keeps the
cut low by migrating forever — exactly the regime where migration volume,
damping and capacity discipline separate the rivals.

Edges are intra-community with high probability, with a uniform random
long-range remainder. Heavy-tailed caller activity plus the sliding window
add arrival/expiry churn on top of the community rotation.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.scenarios.base import Scenario, empty_graph

SIZES = {
    "smoke": dict(n=512, csize=64, n_events=8_000, supersteps=16,
                  batch_span=64, k=4, a_cap=2048, d_cap=1024, e_cap=8_000,
                  adapt_iters=6),
    "small": dict(n=3_000, csize=250, n_events=50_000, supersteps=32,
                  batch_span=100, k=8, a_cap=8192, d_cap=4096, e_cap=40_000,
                  adapt_iters=6),
    "full": dict(n=20_000, csize=1_250, n_events=300_000, supersteps=48,
                 batch_span=150, k=16, a_cap=16384, d_cap=8192,
                 e_cap=160_000, adapt_iters=8),
}


def churn_stream(n: int, csize: int, n_events: int, t_end: int,
                 seed: int = 0, intra_p: float = 0.85,
                 rotate_frac: float = 0.25, ticks: int = 64,
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Event stream (t, u, v) over rotating contiguous-id communities.

    At tick ``i`` vertex ``v`` belongs to community
    ``((v + i·stride) mod n) // csize`` with ``stride = rotate_frac·csize``
    — every tick, a quarter (by default) of each community's membership
    hands over to the neighbouring community.
    """
    rng = np.random.default_rng(seed)
    stride = max(1, int(round(rotate_frac * csize)))
    per = max(1, n_events // ticks)
    dt = max(1, t_end // ticks)
    times_l, src_l, dst_l = [], [], []
    for tick in range(ticks):
        t0 = tick * dt
        shift = (tick * stride) % n
        u = (rng.zipf(1.5, per) - 1) % n                 # heavy-tailed talkers
        comm_u = ((u + shift) % n) // csize
        # intra-community partner: uniform member of u's current community
        off = rng.integers(0, csize, per)
        partner = (comm_u * csize + off - shift) % n
        v = np.where(rng.random(per) < intra_p, partner,
                     rng.integers(0, n, per))
        times_l.append(np.sort(rng.integers(t0, t0 + dt, per)))
        src_l.append(u)
        dst_l.append(v)
    times = np.concatenate(times_l)
    src = np.concatenate(src_l).astype(np.int64)
    dst = np.concatenate(dst_l).astype(np.int64)
    keep = src != dst
    return times[keep], src[keep], dst[keep]


def build(scale: str = "small", seed: int = 0) -> Scenario:
    p = SIZES[scale]
    t_end = p["supersteps"] * p["batch_span"]
    window = 4 * p["batch_span"]
    times, src, dst = churn_stream(
        p["n"], p["csize"], p["n_events"], t_end, seed=seed,
        ticks=2 * p["supersteps"])
    return Scenario(
        name="adversarial",
        program="wcc",
        graph=empty_graph(p["n"], p["e_cap"]),
        times=times, src=src, dst=dst,
        batch_span=p["batch_span"], window=window, k=p["k"],
        a_cap=p["a_cap"], d_cap=p["d_cap"], adapt_iters=p["adapt_iters"],
        payload_scale=8.0,
        seed=seed,
        notes=f"{p['n']} vertices in {p['n'] // p['csize']} rotating "
              f"communities (25% membership churn per tick)")
