"""dimenet [gnn]: 6 blocks d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6 (arXiv:2003.03123).

Non-molecular shapes get synthesised 3-D positions and a per-edge triplet
budget of 20 (DESIGN.md §4) — DimeNet's triplet count is Σdeg², intractable
verbatim on ogb_products.
"""
from repro.configs.base import GNN_SHAPES
from repro.models.dimenet import DimeNetConfig

ARCH_ID = "dimenet"
FAMILY = "gnn"
SHAPES = {k: v for k, v in GNN_SHAPES.items()}
SKIPS = {}
TRIPLETS_PER_EDGE = 20            # static triplet budget per directed edge


def config(d_in: int = 100, n_out: int = 47, readout: str = "none") -> DimeNetConfig:
    return DimeNetConfig(n_blocks=6, d_hidden=128, n_bilinear=8,
                         n_spherical=7, n_radial=6, d_in=d_in, n_out=n_out,
                         readout=readout)


def smoke() -> DimeNetConfig:
    return DimeNetConfig(n_blocks=2, d_hidden=16, n_bilinear=4,
                         n_spherical=3, n_radial=3, d_in=8, n_out=1)
