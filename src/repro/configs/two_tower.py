"""two-tower-retrieval [recsys]: embed_dim=256, tower MLP 1024-512-256,
dot interaction, sampled softmax (RecSys'19 YouTube retrieval)."""
from repro.configs.base import RECSYS_SHAPES
from repro.models.recsys import FeatureSpec, TwoTowerConfig

ARCH_ID = "two-tower-retrieval"
FAMILY = "recsys"
SHAPES = {k: v for k, v in RECSYS_SHAPES.items()}
SKIPS = {}


def config() -> TwoTowerConfig:
    return TwoTowerConfig(
        embed_dim=256, tower_mlp=(1024, 512, 256),
        user_features=(
            FeatureSpec("user_id", 10_000_000, 128),
            FeatureSpec("user_geo", 100_000, 32),
            FeatureSpec("user_hist", 2_000_000, 64, n_hot=16),
            FeatureSpec("user_device", 64, 16),
        ),
        item_features=(
            FeatureSpec("item_id", 2_000_000, 128),
            FeatureSpec("item_topic", 50_000, 64),
            FeatureSpec("item_creator", 500_000, 48),
        ),
        n_dense_user=8, n_dense_item=4)


def smoke() -> TwoTowerConfig:
    return TwoTowerConfig(
        embed_dim=32, tower_mlp=(64, 32),
        user_features=(FeatureSpec("user_id", 1000, 16),
                       FeatureSpec("user_geo", 50, 8),
                       FeatureSpec("user_hist", 500, 16, n_hot=4),
                       FeatureSpec("user_device", 8, 4)),
        item_features=(FeatureSpec("item_id", 800, 16),
                       FeatureSpec("item_topic", 40, 8),
                       FeatureSpec("item_creator", 60, 8)),
        n_dense_user=4, n_dense_item=2)
