"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

GPT-BigCode-style code model (arXiv:2405.04324): MQA + GELU MLP (the 34B
parameter count matches the non-gated 4×d FFN), untied LM head.
long_500k SKIPPED: pure full attention (DESIGN.md §4).
"""
import jax.numpy as jnp

from repro.configs.base import LM_SHAPES
from repro.models import TransformerConfig

ARCH_ID = "granite-34b"
FAMILY = "lm"
SHAPES = {k: v for k, v in LM_SHAPES.items()}
SKIPS = {"long_500k": "pure full-attention arch (no sub-quadratic path)"}


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
        head_dim=128, d_ff=24576, vocab=49152, mlp_kind="gelu",
        tie_embeddings=False, param_dtype=jnp.bfloat16, remat=True,
        q_chunk=2048, loss_chunk=512)


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=128, n_heads=4,
        n_kv_heads=1, head_dim=32, d_ff=512, vocab=512, mlp_kind="gelu",
        tie_embeddings=False)
