"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA, tied embeddings (arXiv:2412.08905).
long_500k SKIPPED: pure full attention.
"""
import jax.numpy as jnp

from repro.configs.base import LM_SHAPES
from repro.models import TransformerConfig

ARCH_ID = "phi4-mini-3.8b"
FAMILY = "lm"
SHAPES = {k: v for k, v in LM_SHAPES.items()}
SKIPS = {"long_500k": "pure full-attention arch (no sub-quadratic path)"}


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        head_dim=128, d_ff=8192, vocab=200064, mlp_kind="swiglu",
        tie_embeddings=True, param_dtype=jnp.bfloat16, remat=True,
        q_chunk=2048, loss_chunk=512)


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=96, n_heads=6,
        n_kv_heads=2, head_dim=16, d_ff=256, vocab=512, mlp_kind="swiglu")
