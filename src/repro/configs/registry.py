"""Architecture registry: ``--arch <id>`` resolution for launchers."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import (arctic_480b, deepseek_v2_lite, dimenet_cfg,
                           gatedgcn_cfg, gemma2_9b, gin_tu, granite_34b,
                           phi4_mini, pna_cfg, two_tower)
from repro.configs.base import Cell

MODULES = {
    m.ARCH_ID: m
    for m in (granite_34b, gemma2_9b, phi4_mini, arctic_480b,
              deepseek_v2_lite, pna_cfg, dimenet_cfg, gatedgcn_cfg, gin_tu,
              two_tower)
}

ARCH_IDS = list(MODULES)


def get(arch_id: str):
    if arch_id not in MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return MODULES[arch_id]


def all_cells(include_skipped: bool = True) -> List[Cell]:
    """The 40 (arch × shape) dry-run cells, with skip annotations."""
    cells: List[Cell] = []
    for arch_id, mod in MODULES.items():
        for shape_name, shape in mod.SHAPES.items():
            skip = mod.SKIPS.get(shape_name)
            if skip and not include_skipped:
                continue
            cells.append(Cell(arch_id=arch_id, shape_name=shape_name,
                              family=mod.FAMILY, shape=shape, skip=skip))
    return cells
