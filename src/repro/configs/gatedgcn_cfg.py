"""gatedgcn [gnn]: 16L d_hidden=70 gated aggregation (arXiv:2003.00982)."""
from repro.configs.base import GNN_SHAPES
from repro.models.gnn import GatedGCNConfig

ARCH_ID = "gatedgcn"
FAMILY = "gnn"
SHAPES = {k: v for k, v in GNN_SHAPES.items()}
SKIPS = {}


def config(d_in: int = 100, n_out: int = 47, readout: str = "none") -> GatedGCNConfig:
    return GatedGCNConfig(n_layers=16, d_hidden=70, d_in=d_in, n_out=n_out,
                          readout=readout)


def smoke() -> GatedGCNConfig:
    return GatedGCNConfig(n_layers=3, d_hidden=16, d_in=8, n_out=4)
