"""pna [gnn]: 4L d_hidden=75, aggregators mean-max-min-std,
scalers id-amp-atten (arXiv:2004.05718)."""
from repro.configs.base import GNN_SHAPES
from repro.models.gnn import PNAConfig

ARCH_ID = "pna"
FAMILY = "gnn"
SHAPES = {k: v for k, v in GNN_SHAPES.items()}
SKIPS = {}


def config(d_in: int = 100, n_out: int = 47, readout: str = "none",
           avg_log_deg: float = 3.0) -> PNAConfig:
    return PNAConfig(n_layers=4, d_hidden=75, d_in=d_in, n_out=n_out,
                     readout=readout, avg_log_deg=avg_log_deg)


def smoke() -> PNAConfig:
    return PNAConfig(n_layers=2, d_hidden=16, d_in=8, n_out=4)
