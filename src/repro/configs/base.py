"""Config registry scaffolding for the assigned architectures.

Each ``configs/<arch>.py`` module exposes:
  * ``ARCH_ID``      — public id (e.g. "granite-34b")
  * ``FAMILY``       — "lm" | "gnn" | "recsys"
  * ``config()``     — the exact assigned full-scale config
  * ``smoke()``      — reduced same-family config for CPU smoke tests
  * ``SHAPES``       — {shape_name: dict} input-shape cells for the dry-run

Shape-cell conventions (DESIGN.md §4):
  lm:     train_4k → train_step, prefill_32k → prefill, decode_32k/long_500k
          → serve_step. long_500k only for hybrid/sub-quadratic attention.
  gnn:    full_graph_sm / ogb_products → full-batch train_step,
          minibatch_lg → sampled-block train_step, molecule → batched graphs.
  recsys: train_batch → train_step, serve_* / retrieval_cand → serve fns.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

LM_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

GNN_SHAPES: Dict[str, Dict[str, Any]] = {
    "full_graph_sm": dict(kind="full", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7),
    "minibatch_lg": dict(kind="minibatch", n_nodes=232_965,
                         n_edges=114_615_892, batch_nodes=1024,
                         fanout=(15, 10), d_feat=602, n_classes=41),
    "ogb_products": dict(kind="full", n_nodes=2_449_029, n_edges=61_859_140,
                         d_feat=100, n_classes=47),
    "molecule": dict(kind="graphs", n_nodes=30, n_edges=64, batch=128,
                     d_feat=16, n_out=1),
}

RECSYS_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(kind="train", batch=65_536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


@dataclasses.dataclass(frozen=True)
class Cell:
    """One (architecture × input-shape) dry-run cell."""
    arch_id: str
    shape_name: str
    family: str
    shape: Dict[str, Any]
    skip: Optional[str] = None      # reason, if inapplicable
