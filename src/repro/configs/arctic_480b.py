"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual (hf:Snowflake/snowflake-arctic-base).

Dense-MoE hybrid: every layer sums a dense SwiGLU FFN (d_ff 4864) with a
128-expert top-2 MoE whose experts share that hidden size. ~479B total
params, ~17B active/token. long_500k SKIPPED: pure full attention.
"""
import jax.numpy as jnp

from repro.configs.base import LM_SHAPES
from repro.models import MoEConfig, TransformerConfig

ARCH_ID = "arctic-480b"
FAMILY = "lm"
SHAPES = {k: v for k, v in LM_SHAPES.items()}
SKIPS = {"long_500k": "pure full-attention arch (no sub-quadratic path)"}


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
        head_dim=128, d_ff=4864, vocab=32000, mlp_kind="swiglu",
        moe=MoEConfig(n_experts=128, top_k=2, d_ff=4864,
                      capacity_factor=1.25, dispatch="sharded"),
        moe_dense_residual=True, tie_embeddings=False,
        param_dtype=jnp.bfloat16, remat=True, q_chunk=2048, loss_chunk=512)


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, mlp_kind="swiglu",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=128, dispatch="sorted"),
        moe_dense_residual=True, tie_embeddings=False)
