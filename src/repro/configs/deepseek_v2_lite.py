"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408 vocab=102400,
MLA kv_lora=512, MoE 64 routed experts top-6 + 2 shared, first layer dense
(arXiv:2405.04434).

Note: the assignment bracket mentions "160 routed" which is the full V2;
v2-lite (16B) has 64 routed experts — we follow the assigned primary config
"MoE 64e top-6". Dense layer-0 uses the published d_ff 10944.
long_500k SKIPPED: full attention (MLA compresses KV storage, not the
attention pattern).
"""
import jax.numpy as jnp

from repro.configs.base import LM_SHAPES
from repro.models import MLAConfig, MoEConfig, TransformerConfig

ARCH_ID = "deepseek-v2-lite-16b"
FAMILY = "lm"
SHAPES = {k: v for k, v in LM_SHAPES.items()}
SKIPS = {"long_500k": "pure full-attention arch (no sub-quadratic path)"}


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        head_dim=128, d_ff=1408, vocab=102400, mlp_kind="swiglu",
        mla=MLAConfig(n_heads=16, kv_lora=512, rope_dim=64, nope_dim=128,
                      v_dim=128),
        moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared=2,
                      capacity_factor=1.25, dispatch="sharded"),
        moe_first_dense=1, first_dense_dff=10944,
        tie_embeddings=False, param_dtype=jnp.bfloat16, remat=True,
        q_chunk=2048, loss_chunk=512)


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=64, vocab=256, mlp_kind="swiglu",
        mla=MLAConfig(n_heads=4, kv_lora=32, rope_dim=8, nope_dim=16, v_dim=16),
        moe=MoEConfig(n_experts=8, top_k=3, d_ff=64, n_shared=2,
                      dispatch="sorted"),
        moe_first_dense=1, first_dense_dff=128, tie_embeddings=False)
