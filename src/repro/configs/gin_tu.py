"""gin-tu [gnn]: 5L d_hidden=64 sum aggregation, learnable eps
(arXiv:1810.00826)."""
from repro.configs.base import GNN_SHAPES
from repro.models.gnn import GINConfig

ARCH_ID = "gin-tu"
FAMILY = "gnn"
SHAPES = {k: v for k, v in GNN_SHAPES.items()}
SKIPS = {}


def config(d_in: int = 100, n_out: int = 47, readout: str = "none") -> GINConfig:
    return GINConfig(n_layers=5, d_hidden=64, d_in=d_in, n_out=n_out,
                     readout=readout)


def smoke() -> GINConfig:
    return GINConfig(n_layers=2, d_hidden=16, d_in=8, n_out=4)
