"""Assigned-architecture configs (10 archs) + registry."""
