"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.

Local(4096)+global alternating attention, attn softcap 50 / final softcap 30,
sandwich RMSNorms, GeGLU, sqrt(d)-scaled tied embeddings (arXiv:2408.00118).
long_500k RUNS: hybrid local/global layers give the sub-quadratic path.
"""
import jax.numpy as jnp

from repro.configs.base import LM_SHAPES
from repro.models import TransformerConfig

ARCH_ID = "gemma2-9b"
FAMILY = "lm"
SHAPES = {k: v for k, v in LM_SHAPES.items()}
SKIPS = {}


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
        head_dim=256, d_ff=14336, vocab=256000, mlp_kind="geglu",
        attn_softcap=50.0, final_softcap=30.0, local_window=4096,
        layer_pattern="local_global", post_norm=True, embed_scale=True,
        tie_embeddings=True, param_dtype=jnp.bfloat16, remat=True,
        q_chunk=2048, loss_chunk=512)


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=4, d_model=96, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512, mlp_kind="geglu",
        attn_softcap=50.0, final_softcap=30.0, local_window=8,
        layer_pattern="local_global", post_norm=True, embed_scale=True)
