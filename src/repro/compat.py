"""Version-compat shims over moving JAX APIs.

The repo targets the newest public API surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``) but must run on whatever JAX the
container bakes in. Everything that touches these APIs goes through here so
a version bump is a one-file change.

* ``make_mesh(shape, axes)`` — ``jax.sharding.AxisType`` appeared after
  0.4.x; older JAX builds the same (fully ``Auto``) mesh without the kwarg.
* ``shard_map(...)`` — ``jax.shard_map`` graduated from
  ``jax.experimental.shard_map``; the experimental one additionally needs
  ``check_rep=False`` for programs that thread PRNG keys through collectives.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _NEEDS_CHECK_REP = False
else:  # pre-graduation JAX
    from jax.experimental.shard_map import shard_map as _shard_map
    _NEEDS_CHECK_REP = True


def make_mesh(shape: Tuple[int, ...], axes: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    if _AXIS_TYPE is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(_AXIS_TYPE.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def shard_map(f, *, mesh, in_specs, out_specs):
    """Uniform shard_map entry point across JAX versions."""
    if _NEEDS_CHECK_REP:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` fallback: psum of a unit is folded statically."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
