"""Version- and platform-compat shims over moving JAX APIs.

The repo targets the newest public API surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``) but must run on whatever JAX the
container bakes in. Everything that touches these APIs goes through here so
a version bump is a one-file change.

* ``make_mesh(shape, axes)`` — ``jax.sharding.AxisType`` appeared after
  0.4.x; older JAX builds the same (fully ``Auto``) mesh without the kwarg.
* ``shard_map(...)`` — ``jax.shard_map`` graduated from
  ``jax.experimental.shard_map``; the experimental one additionally needs
  ``check_rep=False`` for programs that thread PRNG keys through collectives.
* ``resolve_backend`` / ``pallas_executor`` — the one place that decides how
  the fused migration kernels execute on this host (DESIGN.md §9): native
  Mosaic on TPU, the bit-exact pure-jax oracle on CPU, or the Pallas
  interpreter when CI forces it.
"""
from __future__ import annotations

import os
from typing import Sequence, Tuple

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _NEEDS_CHECK_REP = False
else:  # pre-graduation JAX
    from jax.experimental.shard_map import shard_map as _shard_map
    _NEEDS_CHECK_REP = True


def make_mesh(shape: Tuple[int, ...], axes: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    if _AXIS_TYPE is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(_AXIS_TYPE.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def shard_map(f, *, mesh, in_specs, out_specs):
    """Uniform shard_map entry point across JAX versions."""
    if _NEEDS_CHECK_REP:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` fallback: psum of a unit is folded statically."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# ---------------------------------------------------------------------------
# Compute-backend selection for the fused migration kernels (DESIGN.md §9)
# ---------------------------------------------------------------------------

_BACKENDS = ("ref", "pallas")
_EXECUTORS = ("native", "interpret", "jax")


def resolve_backend(backend: str = "auto") -> str:
    """Resolve ``SystemConfig.compute.backend`` to ``"ref"`` or ``"pallas"``.

    ``"auto"`` (the default, overridable via ``REPRO_COMPUTE_BACKEND``)
    selects the fused ``"pallas"`` path: it has an executor on every
    platform (see :func:`pallas_executor`) and is bit-identical to the
    reference path, so there is never a correctness reason to avoid it.
    ``"ref"`` keeps the unfused op-by-op scoring pipeline — the oracle the
    parity suite and the kernel benchmark compare against.
    """
    if backend == "auto":
        backend = os.environ.get("REPRO_COMPUTE_BACKEND", "pallas")
        if backend == "auto":                # env var may restate the default
            backend = "pallas"
    if backend not in _BACKENDS:
        raise ValueError(f"unknown compute backend {backend!r}; "
                         f"valid: {('auto',) + _BACKENDS}")
    return backend


def pallas_executor() -> str:
    """How the fused kernels execute on this host.

    * ``"native"``    — Mosaic-compiled Pallas kernels over BSR tiles
                        (TPU; the MXU path DESIGN.md §9 describes).
    * ``"interpret"`` — the same Pallas kernels under ``interpret=True``
                        (bit-faithful to the kernel body; used by the CPU
                        parity CI via ``REPRO_PALLAS_EXECUTOR=interpret``).
    * ``"jax"``       — the fused pure-jax oracle from ``kernels/ref.py``
                        (CPU default: interpreting per-tile Python inside a
                        streaming loop is a debugger, not a runtime).

    All three produce bit-identical partition assignments; the parity suite
    (``tests/test_migration_kernels.py``) holds that as a property.
    """
    executor = os.environ.get("REPRO_PALLAS_EXECUTOR")
    if executor is not None:
        if executor not in _EXECUTORS:
            raise ValueError(f"unknown REPRO_PALLAS_EXECUTOR {executor!r}; "
                             f"valid: {_EXECUTORS}")
        return executor
    return "native" if jax.default_backend() == "tpu" else "jax"
