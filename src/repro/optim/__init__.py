from repro.optim.optimizer import (AdamWConfig, AdamWState, QTensor,
                                   apply_updates, global_norm, init_state,
                                   warmup_cosine)

__all__ = ["AdamWConfig", "AdamWState", "QTensor", "apply_updates",
           "global_norm", "init_state", "warmup_cosine"]
