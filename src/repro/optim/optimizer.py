"""Optimizers: AdamW with optional block-wise int8 moment quantization.

The int8 path is the distributed-optimization "gradient-state compression"
trick that makes arctic-480b trainable on a 256-chip v5e pod: moments are
stored as int8 with per-block fp32 scales (block = trailing 128 elements),
cutting optimizer state from 8 to ~2.06 bytes/param. Dequantize → update →
requantize happens inside the jit'd train step, so the HBM-resident state
is the quantized form.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False   # int8 block-quantized m/v


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QTensor:
    """Block-quantized tensor (int8 payload + per-block f32 scale/zero).

    Blocks run along the LAST axis only — quantization is layout-preserving:
    ``q`` has the parameter's shape (last dim padded to a BLOCK multiple) so
    it inherits the parameter's PartitionSpec verbatim, and ``scale``/``zero``
    keep the leading dims. (A flattened (n_blocks, BLOCK) layout forces GSPMD
    into full-tensor all-gathers at every reshape — 625 GB/op on arctic-480b;
    see EXPERIMENTS.md §Perf.)

    mode "lin": symmetric absmax — for the signed first moment m.
    mode "log": min/max in log-space — for the non-negative second moment v,
    whose within-block dynamic range spans many orders of magnitude (linear
    absmax quantizes small entries to 0 and 1/sqrt(v) explodes).
    """
    q: jax.Array          # (..., D) int8 — same shape as the parameter
    scale: jax.Array      # (...,) f32 — one scale per last-axis row
    zero: jax.Array       # same as scale
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True),
                                               default=())
    mode: str = dataclasses.field(metadata=dict(static=True), default="lin")


_LOG_FLOOR = 1e-24


def _quantize(x: jax.Array, mode: str = "lin") -> QTensor:
    """Row-wise (per last-axis vector) int8 quantization.

    Row granularity (vs 128-blocks) is chosen for sharding locality: q keeps
    the parameter's exact shape so it inherits the PartitionSpec verbatim and
    no reshape/reshard ever touches it (a flattened block layout costs
    625 GB/op in all-gathers on arctic-480b — EXPERIMENTS.md §Perf). Accuracy
    is recovered by the non-linear (log-space) code for v; training parity
    with fp32 moments is validated in tests/test_optimizer.py.
    """
    shape = x.shape
    if x.ndim == 0:
        x = x[None]
    if mode == "lin":
        scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
        zero = jnp.zeros_like(scale)
    else:  # log
        e = jnp.log(jnp.maximum(x, 0.0) + _LOG_FLOOR)
        lo = jnp.min(e, axis=-1)
        hi = jnp.max(e, axis=-1)
        scale = jnp.maximum(hi - lo, 1e-6) / 254.0
        q = (jnp.clip(jnp.round((e - lo[..., None]) / scale[..., None]), 0, 254)
             .astype(jnp.int16) - 127).astype(jnp.int8)
        zero = lo
    return QTensor(q=q.reshape(shape) if shape else q[0],
                   scale=scale, zero=zero, shape=shape, mode=mode)


def _dequantize(t: QTensor) -> jax.Array:
    q = t.q if t.q.ndim else t.q[None]
    if t.mode == "lin":
        full = q.astype(jnp.float32) * t.scale[..., None]
    else:
        e = (q.astype(jnp.float32) + 127.0) * t.scale[..., None] + t.zero[..., None]
        full = jnp.maximum(jnp.exp(e) - _LOG_FLOOR, 0.0)
    return full.reshape(t.q.shape)


class AdamWState(NamedTuple):
    step: jax.Array
    m: Params           # f32 pytree or QTensor pytree
    v: Params


def init_state(params: Params, cfg: AdamWConfig) -> AdamWState:
    # quantize matrix-shaped leaves only; vectors/scalars (norms, biases)
    # stay fp32 — negligible memory, avoids degenerate row quantization
    def zeros_m(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quantize(z, "lin") if (cfg.quantize_moments and p.ndim >= 2) else z

    def zeros_v(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quantize(z, "log") if (cfg.quantize_moments and p.ndim >= 2) else z

    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros_m, params),
                      v=jax.tree.map(zeros_v, params))


def global_norm(grads: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def apply_updates(params: Params, grads: Params, state: AdamWState,
                  cfg: AdamWConfig, lr_scale: jax.Array = 1.0
                  ) -> Tuple[Params, AdamWState]:
    """One AdamW step with global-norm clipping."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd_core(p, g, m, v):
        quantized = isinstance(m, QTensor)
        g = g.astype(jnp.float32) * clip
        m_f = _dequantize(m) if quantized else m
        v_f = _dequantize(v) if quantized else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        update = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        if quantized:
            return new_p, _quantize(m_f, "lin"), _quantize(v_f, "log")
        return new_p, m_f, v_f

    # Chunked update for very large (layer-stacked) leaves: scanning over the
    # leading axis keeps the f32 dequantized-moment working set to one slice
    # (35× smaller on arctic's expert stack — EXPERIMENTS.md §Perf).
    CHUNK_THRESHOLD = 1 << 26

    def upd(p, g, m, v):
        big = p.ndim >= 3 and p.size >= CHUNK_THRESHOLD and p.shape[0] <= 256
        if not big:
            return upd_core(p, g, m, v)

        def body(_, slices):
            pi, gi, mi, vi = slices
            return None, upd_core(pi, gi, mi, vi)

        _, (new_p, new_m, new_v) = jax.lax.scan(body, None, (p, g, m, v))
        # scan stacks per-slice QTensors; restore full-shape static metadata
        if isinstance(new_m, QTensor):
            new_m = dataclasses.replace(new_m, shape=tuple(p.shape))
            new_v = dataclasses.replace(new_v, shape=tuple(p.shape))
        return new_p, new_m, new_v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def warmup_cosine(step: jax.Array, warmup: int, total: int,
                  floor: float = 0.1) -> jax.Array:
    """LR multiplier: linear warmup then cosine decay to ``floor``."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
