"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) kernels execute with interpret=True — bit-faithful
to the kernel body; on TPU they compile natively. The wrappers keep the
pure-jnp contracts of ref.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.bsr import BSRMatrix
from repro.kernels import ref
from repro.kernels.bsr_spmm import bsr_spmm, max_tiles_per_row
from repro.kernels.embedding_bag import embedding_bag_sum
from repro.kernels.flash_attention import flash_attention as _fa


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: int = 0, softcap: Optional[float] = None,
              bq: int = 128, bk: int = 128) -> jax.Array:
    """Flash attention (B,H,Sq,D)×(B,KV,Sk,D) → (B,H,Sq,D)."""
    return _fa(q, k, v, causal=causal, window=window, softcap=softcap,
               bq=bq, bk=bk, interpret=_interpret())


def bsr_matmul(bsr: BSRMatrix, x: jax.Array,
               max_per_row: Optional[int] = None) -> jax.Array:
    """A_bsr @ X for a packed BSRMatrix (graph adjacency)."""
    if max_per_row is None:
        max_per_row = max_tiles_per_row(np.asarray(bsr.row_ptr))
    return bsr_spmm(bsr.blocks, bsr.block_cols, bsr.row_ptr, x,
                    max_per_row=max_per_row, interpret=_interpret())


def partition_counts(bsr: BSRMatrix, assignment: jax.Array, k: int,
                     max_per_row: Optional[int] = None) -> jax.Array:
    """xDGP migration scorer on TPU: counts = A @ one_hot(labels).

    Returns (n_cap_padded, k) neighbour counts — the kernel-served version
    of core.migration.neighbour_partition_counts. The migration hot path
    itself dispatches through the *fused* scorer (histogram + greedy
    selection + damping in one pass) in ``kernels/migration_kernels.py``;
    this wrapper stays as the standalone SpMM formulation.
    """
    n = bsr.n_blocks * bsr.blk
    lab = jnp.clip(assignment, 0, k - 1)[:n]
    onehot = jax.nn.one_hot(lab, k, dtype=bsr.blocks.dtype)
    return bsr_matmul(bsr, onehot, max_per_row)


def embedding_bag(table: jax.Array, indices: jax.Array,
                  combine: str = "mean") -> jax.Array:
    """Pallas EmbeddingBag matching models.recsys.embedding_bag."""
    out = embedding_bag_sum(table, indices, interpret=_interpret())
    if combine == "mean":
        valid = (indices >= 0).sum(axis=1, keepdims=True)
        out = out / jnp.maximum(valid, 1).astype(out.dtype)
    return out
