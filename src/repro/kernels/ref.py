"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def ref_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: Optional[float] = None) -> jax.Array:
    """q: (B,H,Sq,D), k/v: (B,KV,Sk,D), GQA by head folding. window 0 = full."""
    b, h, sq, d = q.shape
    kv = k.shape[1]
    rep = h // kv
    qg = q.reshape(b, kv, rep, sq, d)
    scores = jnp.einsum("bkrqd,bksd->bkrqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    sk = k.shape[2]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrqs,bksd->bkrqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)


def ref_bsr_spmm(blocks: jax.Array, block_cols: jax.Array, row_ptr: jax.Array,
                 x: jax.Array) -> jax.Array:
    """BSR (nnzb, blk, blk) × dense X (n_blocks*blk, d) → (n_blocks*blk, d).

    Padding tiles have block_cols == -1 and are skipped.
    """
    nnzb, blk, _ = blocks.shape
    n_blocks = row_ptr.shape[0] - 1
    d = x.shape[1]
    xb = x.reshape(n_blocks, blk, d)
    # per-tile row id
    rows = jnp.searchsorted(row_ptr, jnp.arange(nnzb), side="right") - 1
    valid = block_cols >= 0
    cols_safe = jnp.clip(block_cols, 0, n_blocks - 1)
    prods = jnp.einsum("nij,njd->nid", blocks.astype(jnp.float32),
                       xb[cols_safe].astype(jnp.float32))
    prods = jnp.where(valid[:, None, None], prods, 0.0)
    out = jax.ops.segment_sum(prods, jnp.clip(rows, 0, n_blocks - 1),
                              num_segments=n_blocks)
    return out.reshape(n_blocks * blk, d).astype(x.dtype)


def ref_bsr_label_histogram(blocks: jax.Array, block_cols: jax.Array,
                            row_ptr: jax.Array, labels: jax.Array,
                            k: int) -> jax.Array:
    """Oracle for the fused migration-scoring kernel's histogram stage.

    counts[v, j] = Σ_u A[v, u] · [labels[u] == j] over the BSR tiles —
    ``A @ one_hot(labels)`` with the one-hot built inside the contraction,
    exactly as the Pallas kernel does. Padding tiles (``block_cols == -1``)
    contribute nothing. Returns float32 ``(n_blocks*blk, k)``; entries are
    exact integers for unweighted adjacencies.
    """
    nnzb, blk, _ = blocks.shape
    n_blocks = row_ptr.shape[0] - 1
    onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32)   # out-of-range → 0
    onehot = onehot.reshape(n_blocks, blk, k)
    rows = jnp.searchsorted(row_ptr, jnp.arange(nnzb), side="right") - 1
    valid = block_cols >= 0
    cols_safe = jnp.clip(block_cols, 0, n_blocks - 1)
    prods = jnp.einsum("nij,njd->nid", blocks.astype(jnp.float32),
                       onehot[cols_safe])
    prods = jnp.where(valid[:, None, None], prods, 0.0)
    out = jax.ops.segment_sum(prods, jnp.clip(rows, 0, n_blocks - 1),
                              num_segments=n_blocks)
    return out.reshape(n_blocks * blk, k)


def ref_score_select(counts: jax.Array, assignment: jax.Array,
                     node_mask: jax.Array, noise: jax.Array,
                     gate: jax.Array, *, tie_break: str = "random"
                     ) -> tuple:
    """Oracle for the kernel's fused decide+damp epilogue (paper §3.2/§3.4).

    Given per-vertex neighbour-label ``counts`` (exact integers, any float
    or int dtype), the current ``assignment``, liveness ``node_mask``,
    pre-drawn tie-break ``noise`` (same shape as counts) and Bernoulli
    damping ``gate``, returns ``(target, willing, gain)``:

      target  — desired partition per vertex (the greedy rule)
      willing — wants to move AND survived damping
      gain    — best_count − current_count (≥ 0; diagnostic)

    ``tie_break="random"``: argmax of ``counts + noise`` (a < 1 gap means
    only ties shuffle). ``tie_break="stay"``: prefer the current partition
    whenever it is among the argmax set; noise is ignored.
    """
    k = counts.shape[1]
    c = counts.astype(jnp.float32)
    cur = jnp.clip(assignment, 0, k - 1)
    cur_count = jnp.take_along_axis(c, cur[:, None], axis=1)[:, 0]
    best_count = jnp.max(c, axis=1)
    isolated = (best_count == 0) | ~node_mask
    if tie_break == "stay":
        stay = (cur_count >= best_count) | isolated
        target = jnp.where(stay, cur, jnp.argmax(c, axis=1).astype(jnp.int32))
    elif tie_break == "random":
        score = c + noise
        target = jnp.argmax(score, axis=1).astype(jnp.int32)
        target = jnp.where(isolated, cur, target)
    else:
        raise ValueError(f"unknown tie_break {tie_break!r}")
    willing = (target != assignment) & node_mask & gate
    gain = (best_count - cur_count).astype(jnp.float32)
    return target, willing, gain


def ref_embedding_bag(table: jax.Array, indices: jax.Array,
                      combine: str = "sum") -> jax.Array:
    """(V,D) table, (B,n_hot) indices (−1 pad) → (B,D)."""
    b, h = indices.shape
    valid = indices >= 0
    safe = jnp.clip(indices, 0, table.shape[0] - 1)
    rows = jnp.take(table, safe.reshape(-1), axis=0).reshape(b, h, -1)
    rows = jnp.where(valid[..., None], rows.astype(jnp.float32), 0.0)
    out = rows.sum(axis=1)
    if combine == "mean":
        out = out / jnp.maximum(valid.sum(1, keepdims=True), 1)
    return out.astype(table.dtype)
