"""The kernel layer: Pallas TPU kernels for the repo's compute hot spots.

Each kernel ships with a pure-jax oracle in ``ref.py`` (its correctness
contract) and, where it sits on the xDGP hot path, a CPU executor so the
fused algorithm runs everywhere (DESIGN.md §9):

  bsr_spmm.py           BSR SpMM over 128×128 MXU tiles (GNN aggregation,
                        ``counts = A @ one_hot(labels)``) — DESIGN.md §2.
  migration_kernels.py  the fused xDGP superstep scorer: neighbour-label
                        histogram + gain scoring + greedy selection in one
                        pass over BSR tiles, with ELL/flat pure-jax
                        executors and ``MigrationPlan`` packing — §9.
  flash_attention.py    blocked flash attention (causal/windowed/softcap).
  embedding_bag.py      EmbeddingBag gather-sum for the recsys tower.
  ops.py                public jit'd wrappers (interpret=True on CPU).
  ref.py                pure-jnp oracles for every kernel above.

Parity rule: a kernel and its oracle must agree bit-for-bit on integer
data and to float tolerance otherwise; ``tests/test_kernels.py`` and
``tests/test_migration_kernels.py`` hold the contracts.
"""
