"""Block-sparse-row SpMM Pallas kernel (the TPU-native sparse adjacency op).

out[rowblock] = Σ_j A_tile[row_ptr[i]+j] @ X[block_cols[row_ptr[i]+j]]

128×128 dense tiles stream through the MXU; tile indices are scalar-
prefetched so the X block index map can chase the column pointer
(pltpu.PrefetchScalarGridSpec — the TPU gather idiom). Used for:

  * GNN sum-aggregation (GIN, GCN-normalised variants)
  * the xDGP migration scorer: counts = A @ one_hot(labels)  (DESIGN.md §2)

After xDGP repartitioning + relocation, tiles concentrate near the diagonal;
``max_tiles_per_row`` (the grid's inner extent) shrinks, cutting both DMA
and MXU work — partition quality becomes kernel speedup.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAS_TPU = True
except Exception:                                        # pragma: no cover
    pltpu = None
    _HAS_TPU = False


def _kernel(row_ptr_ref, cols_ref, a_ref, x_ref, o_ref, *, max_per_row: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    start = row_ptr_ref[i]
    end = row_ptr_ref[i + 1]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(start + j < end)
    def _accum():
        a = a_ref[0]                                     # (blk, blk)
        x = x_ref[0]                                     # (blk, d)
        o_ref[0] += jax.lax.dot(a, x, preferred_element_type=jnp.float32
                                ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("max_per_row", "interpret"))
def bsr_spmm(blocks: jax.Array, block_cols: jax.Array, row_ptr: jax.Array,
             x: jax.Array, *, max_per_row: int, interpret: bool = False
             ) -> jax.Array:
    """blocks (nnzb,blk,blk) · x (n_blocks*blk, d) → (n_blocks*blk, d).

    max_per_row: static upper bound on tiles per row-block (host-computed:
    ``int(np.diff(row_ptr).max())``).
    """
    nnzb, blk, _ = blocks.shape
    n_blocks = row_ptr.shape[0] - 1
    d = x.shape[1]
    xb = x.reshape(n_blocks, blk, d)

    def a_index(i, j, row_ptr_s, cols_s):
        idx = jnp.clip(row_ptr_s[i] + j, 0, nnzb - 1)
        return (idx, 0, 0)

    def x_index(i, j, row_ptr_s, cols_s):
        idx = jnp.clip(row_ptr_s[i] + j, 0, nnzb - 1)
        col = jnp.clip(cols_s[idx], 0, n_blocks - 1)
        return (col, 0, 0)

    def o_index(i, j, row_ptr_s, cols_s):
        return (i, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_blocks, max_per_row),
        in_specs=[
            pl.BlockSpec((1, blk, blk), a_index),
            pl.BlockSpec((1, blk, d), x_index),
        ],
        out_specs=pl.BlockSpec((1, blk, d), o_index),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, max_per_row=max_per_row),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks, blk, d), x.dtype),
        interpret=interpret,
    )(row_ptr, block_cols, blocks, xb)
    return out.reshape(n_blocks * blk, d)


def max_tiles_per_row(row_ptr: np.ndarray) -> int:
    return int(max(1, np.diff(np.asarray(row_ptr)).max()))
