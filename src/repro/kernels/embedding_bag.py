"""EmbeddingBag Pallas kernel: scalar-prefetched row gather + bag reduce.

The recsys hot path (multi-hot categorical → pooled embedding). Each grid
step (b, j) DMAs exactly one table row into VMEM — the row index comes from
the prefetched indices array via the BlockSpec index map, so padding (-1)
rows are clamped and masked with @pl.when. Sum combine in-kernel; mean
divides outside (ops.py) where the valid count is cheap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:                                        # pragma: no cover
    pltpu = None


def _kernel(idx_ref, table_ref, o_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(idx_ref[b, j] >= 0)
    def _accum():
        o_ref[0] += table_ref[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_sum(table: jax.Array, indices: jax.Array, *,
                      interpret: bool = False) -> jax.Array:
    """table (V,D) f32/bf16, indices (B,n_hot) int32 (−1 pad) → (B,D) sum."""
    v, d = table.shape
    b, h = indices.shape

    def t_index(bi, j, idx_s):
        return (jnp.clip(idx_s[bi, j], 0, v - 1), 0)

    def o_index(bi, j, idx_s):
        return (bi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h),
        in_specs=[pl.BlockSpec((1, d), t_index)],
        out_specs=pl.BlockSpec((1, d), o_index),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(indices, table).astype(table.dtype)
