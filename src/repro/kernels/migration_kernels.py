"""Fused Pallas kernels for the xDGP superstep hot path (DESIGN.md §9).

The per-iteration cost of the paper's adaptive loop is scoring: for every
vertex, a histogram of its neighbours' partition labels (paper §3.2) —
``counts = A @ one_hot(labels)`` on the BSR-tiled adjacency — followed by
the greedy target selection and Bernoulli damping. This module fuses those
three stages into one kernel pass over the BSR tiles:

  * **histogram** — 128×128 (or smaller) adjacency tiles stream through the
    MXU; the one-hot of the column block's labels is built *inside* the
    kernel, so the (n, k) one-hot never materialises in HBM.
  * **score**     — the epilogue (last tile of each row block) computes the
    capacity-relevant gain ``best − current`` and the greedy target with
    either tie-break rule, reading the accumulated counts from VMEM.
  * **select**    — the Bernoulli(s) damping gate and liveness mask are
    applied in the same epilogue, emitting the per-vertex ``willing`` flag
    that feeds the quota stage.

The quota stage itself (paper §3.3) stays outside the kernel by design: it
is the paper's O(k) *global* coordination step (a k-vector of free
capacities), not a per-vertex sparse reduction.

Execution is selected by ``repro.compat.pallas_executor()``:

  * ``"native"``    — Mosaic-compiled on TPU.
  * ``"interpret"`` — the same kernel body under ``interpret=True``
    (bit-faithful; the CPU parity CI forces this).
  * ``"jax"``       — the fused pure-jax oracle (``kernels/ref.py`` +
    the ELL/flat histogram below); the CPU default.

All executors produce bit-identical results to the unfused reference path
in ``core/migration.py`` — partition counts are exact integers in float32,
the RNG draws are shared, and argmax tie handling matches ``jnp.argmax``
(first index). ``tests/test_migration_kernels.py`` holds this parity as a
property over random BSR graphs, padded/empty tiles and full partitions.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro import compat
from repro.graph.bsr import graph_to_bsr
from repro.graph.structure import Graph
from repro.kernels import ref
from repro.kernels.bsr_spmm import max_tiles_per_row

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PALLAS_TPU = True
except Exception:                                        # pragma: no cover
    pltpu = None
    _HAS_PALLAS_TPU = False


# ---------------------------------------------------------------------------
# Plan: the host-packed view of the graph the kernels run over
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """Pre-packed adjacency for the fused scorer (host-built, reused across
    iterations on a fixed graph — converge/adapt amortise one pack over the
    whole superstep).

    kind:
      "flat" — no packing; the scorer scatters into flattened
               ``dst*k + label`` bins straight from the padded COO graph.
               The only kind that needs no host work, so it is what the
               streaming path uses (the graph changes every superstep).
      "ell"  — padded neighbour lists ``(n_cap, deg_cap)``; turns the
               histogram into dense gather+compare (the CPU winner on
               low-skew graphs like the paper's FEM meshes).
      "bsr"  — the BSR tiles from ``graph_to_bsr``; what the Pallas kernel
               streams through the MXU (``native``/``interpret``).
    """

    kind: str
    nbrs: Optional[jax.Array] = None          # ("ell") (n_cap, deg_cap) int32
    blocks: Optional[jax.Array] = None        # ("bsr") (nnzb_cap, blk, blk)
    block_cols: Optional[jax.Array] = None    # ("bsr") (nnzb_cap,)
    row_ptr: Optional[jax.Array] = None       # ("bsr") (n_blocks + 1,)
    max_per_row: int = 1                      # ("bsr") static inner grid extent


jax.tree_util.register_dataclass(
    MigrationPlan,
    data_fields=("nbrs", "blocks", "block_cols", "row_ptr"),
    meta_fields=("kind", "max_per_row"))

FLAT_PLAN = MigrationPlan(kind="flat")


def build_plan(graph: Graph, *, executor: Optional[str] = None,
               blk: int = 64, ell_max_overhead: float = 4.0) -> MigrationPlan:
    """Pack ``graph`` for the fused scorer (host-side numpy).

    ``executor`` (default: :func:`repro.compat.pallas_executor`) picks the
    representation: BSR tiles for the Pallas executors, ELL neighbour lists
    for the pure-jax oracle — unless the degree skew would pad ELL beyond
    ``ell_max_overhead``× the edge count, in which case the plan degrades
    to "flat" (no packing, still fused).
    """
    executor = compat.pallas_executor() if executor is None else executor
    if executor in ("native", "interpret"):
        bsr = graph_to_bsr(graph, blk=blk)
        return MigrationPlan(
            kind="bsr", blocks=bsr.blocks, block_cols=bsr.block_cols,
            row_ptr=bsr.row_ptr,
            max_per_row=max_tiles_per_row(np.asarray(bsr.row_ptr)))
    em = np.asarray(graph.edge_mask)
    s = np.asarray(graph.src)[em].astype(np.int64)
    d = np.asarray(graph.dst)[em].astype(np.int64)
    src2 = np.concatenate([s, d])
    dst2 = np.concatenate([d, s])
    n_cap = graph.n_cap
    deg = np.bincount(dst2, minlength=n_cap)
    deg_cap = int(max(deg.max() if deg.size else 0, 1))
    if n_cap * deg_cap > ell_max_overhead * max(src2.shape[0], 1):
        return FLAT_PLAN                      # high skew: padding would blow up
    order = np.argsort(dst2, kind="stable")
    starts = np.zeros(n_cap + 1, dtype=np.int64)
    np.cumsum(deg, out=starts[1:])
    slot = np.arange(src2.shape[0]) - starts[dst2[order]]
    nbrs = np.full((n_cap, deg_cap), -1, dtype=np.int32)
    nbrs[dst2[order], slot] = src2[order].astype(np.int32)
    return MigrationPlan(kind="ell", nbrs=jnp.asarray(nbrs))


# ---------------------------------------------------------------------------
# Pure-jax fused histograms (the "jax" executor)
# ---------------------------------------------------------------------------

def _counts_flat(graph: Graph, assignment: jax.Array, k: int) -> jax.Array:
    """Histogram by scattering 1s into flattened ``dst*k + label`` bins —
    the (2E, k) one-hot of the reference path never materialises."""
    n_cap = graph.n_cap
    src2, dst2, mask2 = graph.symmetrized()
    lab = assignment[jnp.clip(src2, 0, n_cap - 1)]
    ok = mask2 & (lab >= 0) & (lab < k)       # one_hot drops out-of-range too
    idx = jnp.where(ok, dst2 * k + lab, n_cap * k)
    c = jax.ops.segment_sum(jnp.ones_like(idx), idx,
                            num_segments=n_cap * k + 1)[: n_cap * k]
    return c.reshape(n_cap, k)


def _counts_ell(nbrs: jax.Array, assignment: jax.Array, k: int) -> jax.Array:
    """Histogram over padded neighbour lists: gather + compare, no scatter."""
    n_cap = nbrs.shape[0]
    valid = nbrs >= 0
    lab = assignment[jnp.clip(nbrs, 0, n_cap - 1)]       # (n_cap, deg_cap)
    onehot = (lab[..., None] == jnp.arange(k, dtype=lab.dtype)) \
        & valid[..., None]
    return jnp.sum(onehot.astype(jnp.int32), axis=1)


# ---------------------------------------------------------------------------
# The fused Pallas kernel ("native"/"interpret" executors)
# ---------------------------------------------------------------------------

def _fused_kernel(row_ptr_ref, cols_ref, a_ref, lab_ref, cur_ref, mask_ref,
                  noise_ref, gate_ref, counts_ref, target_ref, willing_ref,
                  gain_ref, *, k: int, max_per_row: int, tie_break: str):
    i = pl.program_id(0)
    j = pl.program_id(1)
    start = row_ptr_ref[i]
    end = row_ptr_ref[i + 1]

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    @pl.when(start + j < end)
    def _accum():
        a = a_ref[0]                                      # (blk, blk)
        lab = lab_ref[0]                                  # (blk,) column labels
        blk = a.shape[0]
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (blk, k), 1)
        onehot = (lab[:, None] == iota_k).astype(jnp.float32)
        counts_ref[0] += jax.lax.dot(a, onehot,
                                     preferred_element_type=jnp.float32)

    @pl.when(j == max_per_row - 1)
    def _select():
        c = counts_ref[0]                                 # (blk, k) exact ints
        cur = cur_ref[0]
        mask = mask_ref[0] != 0
        iota_k = jax.lax.broadcasted_iota(jnp.int32, c.shape, 1)
        cur_cl = jnp.clip(cur, 0, k - 1)
        cur_count = jnp.sum(jnp.where(iota_k == cur_cl[:, None], c, 0.0),
                            axis=1)
        best = jnp.max(c, axis=1)
        isolated = (best == 0.0) | ~mask
        if tie_break == "stay":
            first = jnp.min(jnp.where(c == best[:, None], iota_k, k),
                            axis=1).astype(jnp.int32)
            stay = (cur_count >= best) | isolated
            tgt = jnp.where(stay, cur_cl, first)
        else:
            score = c + noise_ref[0]
            smax = jnp.max(score, axis=1)
            first = jnp.min(jnp.where(score == smax[:, None], iota_k, k),
                            axis=1).astype(jnp.int32)
            tgt = jnp.where(isolated, cur_cl, first)
        willing = (tgt != cur) & mask & (gate_ref[0] != 0)
        target_ref[0] = tgt
        willing_ref[0] = willing.astype(jnp.int32)
        gain_ref[0] = best - cur_count


@functools.partial(jax.jit, static_argnames=("k", "max_per_row", "tie_break",
                                             "interpret"))
def pallas_score_select(blocks: jax.Array, block_cols: jax.Array,
                        row_ptr: jax.Array, assignment: jax.Array,
                        node_mask: jax.Array, noise: jax.Array,
                        gate: jax.Array, *, k: int, max_per_row: int,
                        tie_break: str = "random", interpret: bool = False
                        ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused histogram+score+select over BSR tiles.

    All per-vertex inputs are padded to ``n_pad = n_blocks * blk`` rows
    (``assignment``/``node_mask``/``gate`` with dead slots, ``noise`` with
    zeros). Returns ``(counts f32, target i32, willing i32, gain f32)`` at
    ``n_pad`` rows; callers slice back to ``n_cap``. Padding tiles
    (``block_cols == -1``) are never visited: ``row_ptr`` only addresses
    the packed prefix, and the ``start + j < end`` guard masks the rest.
    """
    if pltpu is None:                                     # pragma: no cover
        raise RuntimeError("pallas TPU frontend unavailable; use the 'jax' "
                           "executor (repro.compat.pallas_executor)")
    nnzb, blk, _ = blocks.shape
    n_blocks = row_ptr.shape[0] - 1
    lab_b = assignment.reshape(n_blocks, blk)
    cur_b = lab_b
    mask_b = node_mask.astype(jnp.int32).reshape(n_blocks, blk)
    noise_b = noise.reshape(n_blocks, blk, k)
    gate_b = gate.astype(jnp.int32).reshape(n_blocks, blk)

    def a_index(i, j, row_ptr_s, cols_s):
        return (jnp.clip(row_ptr_s[i] + j, 0, nnzb - 1), 0, 0)

    def col_index(i, j, row_ptr_s, cols_s):
        idx = jnp.clip(row_ptr_s[i] + j, 0, nnzb - 1)
        return (jnp.clip(cols_s[idx], 0, n_blocks - 1), 0)

    def row_index(i, j, row_ptr_s, cols_s):
        return (i, 0)

    def row_index3(i, j, row_ptr_s, cols_s):
        return (i, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_blocks, max_per_row),
        in_specs=[
            pl.BlockSpec((1, blk, blk), a_index),
            pl.BlockSpec((1, blk), col_index),
            pl.BlockSpec((1, blk), row_index),
            pl.BlockSpec((1, blk), row_index),
            pl.BlockSpec((1, blk, k), row_index3),
            pl.BlockSpec((1, blk), row_index),
        ],
        out_specs=[
            pl.BlockSpec((1, blk, k), row_index3),
            pl.BlockSpec((1, blk), row_index),
            pl.BlockSpec((1, blk), row_index),
            pl.BlockSpec((1, blk), row_index),
        ],
    )
    counts, target, willing, gain = pl.pallas_call(
        functools.partial(_fused_kernel, k=k, max_per_row=max_per_row,
                          tie_break=tie_break),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, blk, k), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, blk), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, blk), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, blk), jnp.float32),
        ],
        interpret=interpret,
    )(row_ptr, block_cols, blocks, lab_b, cur_b, mask_b, noise_b, gate_b)
    n_pad = n_blocks * blk
    return (counts.reshape(n_pad, k), target.reshape(n_pad),
            willing.reshape(n_pad), gain.reshape(n_pad))


# ---------------------------------------------------------------------------
# Dispatch: one fused score/select entry point for every executor
# ---------------------------------------------------------------------------

def _pad_rows(x: jax.Array, n_pad: int, fill) -> jax.Array:
    pad = n_pad - x.shape[0]
    if pad == 0:
        return x
    widths = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def score_select(graph: Graph, plan: Optional[MigrationPlan],
                 assignment: jax.Array, node_mask: jax.Array,
                 noise: jax.Array, gate: jax.Array, k: int, *,
                 tie_break: str = "random", executor: Optional[str] = None,
                 ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused scoring for one migration iteration: neighbour-label histogram,
    greedy target selection, damping — one pass, executor-dispatched.

    Returns ``(counts i32, target i32, willing bool, gain f32)`` at
    ``n_cap`` rows, bit-identical across executors and to the unfused
    reference path (``core.migration.neighbour_partition_counts`` +
    ``greedy_targets`` + the Bernoulli gate).
    """
    executor = compat.pallas_executor() if executor is None else executor
    plan = FLAT_PLAN if plan is None else plan
    n_cap = graph.n_cap
    if plan.kind == "bsr" and executor in ("native", "interpret"):
        n_pad = (plan.row_ptr.shape[0] - 1) * plan.blocks.shape[1]
        counts, target, willing, gain = pallas_score_select(
            plan.blocks, plan.block_cols, plan.row_ptr,
            _pad_rows(assignment, n_pad, -1),
            _pad_rows(node_mask, n_pad, False),
            _pad_rows(noise, n_pad, 0.0),
            _pad_rows(gate, n_pad, False),
            k=k, max_per_row=plan.max_per_row, tie_break=tie_break,
            interpret=executor == "interpret")
        return (counts[:n_cap].astype(jnp.int32), target[:n_cap],
                willing[:n_cap].astype(bool), gain[:n_cap])
    if plan.kind == "ell":
        counts = _counts_ell(plan.nbrs, assignment, k)
    elif plan.kind == "bsr":          # BSR plan but jax executor: use oracle
        counts = ref.ref_bsr_label_histogram(
            plan.blocks, plan.block_cols, plan.row_ptr,
            _pad_rows(assignment, (plan.row_ptr.shape[0] - 1)
                      * plan.blocks.shape[1], -1),
            k)[:n_cap].astype(jnp.int32)
    else:
        counts = _counts_flat(graph, assignment, k)
    target, willing, gain = ref.ref_score_select(
        counts, assignment, node_mask, noise, gate, tie_break=tie_break)
    return counts, target, willing, gain


def label_histogram(graph: Graph, plan: Optional[MigrationPlan],
                    assignment: jax.Array, k: int, *,
                    executor: Optional[str] = None) -> jax.Array:
    """Per-vertex neighbour-label histogram alone (diagnostics/tests):
    ``counts[v, j]`` = number of v's live neighbours with label j."""
    n_cap = graph.n_cap
    counts, _, _, _ = score_select(
        graph, plan, assignment, jnp.ones((n_cap,), bool),
        jnp.zeros((n_cap, k), jnp.float32), jnp.zeros((n_cap,), bool), k,
        tie_break="stay", executor=executor)
    return counts
