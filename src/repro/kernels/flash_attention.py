"""Blocked flash attention for TPU (pl.pallas_call + BlockSpec VMEM tiling).

Supports causal masking, sliding window (gemma-2 local layers), logit
soft-capping and GQA (kv-head folding via the index map — no KV repeat in
HBM). Online-softmax accumulation in f32 VMEM scratch; MXU-aligned block
shapes (q-block × head_dim and q-block × k-block matmuls).

Target: TPU v5e. Validated on CPU with interpret=True against
ref.ref_flash_attention (tests/test_kernels_attention.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scratch, l_scratch, acc_scratch,
               *, scale: float, causal: bool, window: int,
               softcap: Optional[float], bq: int, bk: int, n_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scratch[...]                             # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scratch[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scratch[...] = m_new
    l_scratch[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scratch[...], 1e-20)
        o_ref[0, 0] = (acc_scratch[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: Optional[float] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B,H,Sq,D); k/v: (B,KV,Sk,D) with H % KV == 0. Returns (B,H,Sq,D)."""
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    assert h % kv == 0, (h, kv)
    rep = h // kv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    n_q, n_k = sq // bq, sk // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               window=window, softcap=softcap, bq=bq, bk=bk,
                               n_k=n_k)
    grid = (b, h, n_q, n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:                                    # pragma: no cover
        return pl.MemorySpace.ANY(shape, dtype)
