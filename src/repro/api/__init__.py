"""repro.api — the one front door to the xDGP runtime.

Four pieces (DESIGN.md §8, §10):

  * ``PartitionStrategy`` — pluggable partitioning policy (init / place /
    adapt hooks) with a registry: ``static``, ``hash``, ``random``, ``dgr``,
    ``mnn``, ``fennel``, ``xdgp``, plus the rival migrators ``spinner``,
    ``sdp``, ``restream`` (+ seed-era aliases;
    ``canonical_strategy_names()`` lists each exactly once).
  * ``ExecutionBackend`` — pluggable execution layer (``local`` |
    ``sharded``) deciding *where* the adaptation runs: on-host, or
    partition-per-device SPMD with bit-identical assignments.
  * ``SystemConfig`` — layered graph/stream/partition/compute/cluster/
    telemetry sections, ``to_dict``/``from_dict`` round-trip.
  * ``DynamicGraphSystem`` — the session: ``step``/``run`` (streaming),
    ``converge``/``adapt`` (batch), ``snapshot``/``score``/``compare``
    (measurement), ``distribute``/``gather``/``rescale``/``save``/
    ``restore`` (cluster lifecycle).

``__all__`` is the frozen public surface, pinned by the API snapshot test —
extend it deliberately, never accidentally.
"""
from repro.api.backend import (ExecutionBackend, LocalBackend, ShardedBackend,
                               execution_backend_names,
                               register_execution_backend,
                               resolve_execution_backend)
from repro.api.config import (ClusterSection, ComputeSection, GraphSection,
                              PartitionSection, StreamSection, SystemConfig,
                              TelemetrySection)
from repro.api.strategy import (Block, Dgr, Hash, Mnn, Modulo, OnlineFennel,
                                PartitionStrategy, Random, Restream, Sdp,
                                Spinner, Static, StrategyContext,
                                XdgpAdaptive, canonical_strategy_names,
                                register_strategy, resolve_strategy,
                                strategy_names)
from repro.api.system import (DynamicGraphSystem, SuperstepRecord,
                              bsr_snapshot, empty_graph, partition_relabelled)
from repro.core.repartitioner import History
from repro.core.vertex_program import CostModel

__all__ = [
    # config
    "SystemConfig", "GraphSection", "StreamSection", "PartitionSection",
    "ComputeSection", "ClusterSection", "TelemetrySection",
    # strategy protocol + registry
    "PartitionStrategy", "StrategyContext",
    "register_strategy", "resolve_strategy", "strategy_names",
    "canonical_strategy_names",
    # shipped strategies
    "Static", "Hash", "Random", "Modulo", "Block", "Dgr", "Mnn",
    "OnlineFennel", "XdgpAdaptive", "Spinner", "Sdp", "Restream",
    # execution backends
    "ExecutionBackend", "LocalBackend", "ShardedBackend",
    "register_execution_backend", "resolve_execution_backend",
    "execution_backend_names",
    # session + measurement
    "DynamicGraphSystem", "SuperstepRecord", "History", "CostModel",
    "empty_graph", "bsr_snapshot", "partition_relabelled",
]
