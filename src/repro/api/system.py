"""DynamicGraphSystem: the one front door to the xDGP runtime.

One session object owns the paper's full loop — ingest → place → adapt →
compute → measure — with the partitioning policy abstracted behind a
``PartitionStrategy`` (paper §4: one system; §3: the policy inside it):

    events ──► WindowIngestor (vectorized batch + expiry, backpressure)
                   │ GraphDelta
                   ▼
               apply_delta (static-shape scatter, jit)
                   │
                   ▼
               strategy.place (where do arrivals go?)
                   │
                   ▼
               strategy.adapt (interleaved migration rounds)
                   │
                   ▼
               VertexProgram superstep (optional, message traffic charged)
                   │
                   ▼
               QualityTracker (incremental cut / occupancy, drift-checked)

The session replaces the former ``StreamEngine`` (streaming),
``AdaptivePartitioner`` drivers (batch convergence) and the scenario
harness's hand-wired dual run (comparison):

  step(events, now)   one superstep → SuperstepRecord telemetry
  run(stream)         windowed replay of a whole (t, u, v) stream
  converge()          batch mode: adapt the current graph to quiescence
  adapt(iters)        batch mode: a fixed number of adaptation rounds
  inject(delta)       apply a pre-built GraphDelta (bursts, benchmarks)
  snapshot()          partition-quality + BSR-tiling view of *now*
  score()             cost-model scoring of the telemetry (paper §5.3)
  compare(stream)     dual run vs. a baseline strategy on the same stream

plus the cluster lifecycle (DESIGN.md §10):

  distribute()        execute on the "sharded" backend (partition-per-device)
  gather()            return to on-host execution
  rescale(new_k)      elastic k-change: re-home orphans, re-adapt, report
  save(path)          checkpoint the whole session (atomic, resumable)
  restore(path)       class method: resume a saved session mid-run

Swapping ``config.partition.strategy`` between ``"xdgp"`` and ``"static"``
reproduces the paper's adaptive-vs-static-hash comparison with no other
code changes; ``config.compute.backend`` independently selects the
migration-scoring implementation (fused kernels vs the unfused reference —
bit-identical results, DESIGN.md §9); ``config.cluster.backend`` selects
the execution layer (on-host vs shard_map SPMD — bit-identical again,
DESIGN.md §10).

Example — batch-adapt a static mesh to quiescence (doctested in CI):

    >>> from repro.api import DynamicGraphSystem, PartitionSection, SystemConfig
    >>> from repro.graph.generators import fem_grid2d
    >>> g = fem_grid2d(8)                                  # 64-vertex mesh
    >>> cfg = SystemConfig(partition=PartitionSection(strategy="xdgp", k=4))
    >>> system = DynamicGraphSystem(g, cfg)
    >>> before = system.cut_ratio                          # hash partitioning
    >>> hist = system.converge(record_history=False)
    >>> system.cut_ratio < before                          # paper §3: improved
    True
    >>> snap = system.snapshot()
    >>> snap["nodes"], snap["k"]
    (64, 4)

    Sessions checkpoint and resume as one operation (DESIGN.md §10):

    >>> import tempfile
    >>> with tempfile.TemporaryDirectory() as ckpt:
    ...     _ = system.save(ckpt)
    ...     resumed = DynamicGraphSystem.restore(ckpt)
    >>> resumed.cut_ratio == system.cut_ratio
    True
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.backend import resolve_execution_backend
from repro.api.config import SystemConfig
from repro.api.strategy import StrategyContext, resolve_strategy
from repro.checkpoint import Checkpointer
from repro.core.partition_state import PartitionState, default_capacity, make_state
from repro.core.repartitioner import History
from repro.core.vertex_program import (CostModel, VertexProgram, make_program,
                                       message_volume)
from repro.core.vertex_program import superstep as program_superstep
from repro.api.telemetry import SuperstepRecord
from repro.obs.metrics import MetricsRegistry, record_superstep
from repro.obs.trace import NULL_TRACER, Tracer
from repro.graph.bsr import bsr_density_stats, graph_to_bsr
from repro.graph.structure import Graph, GraphDelta, apply_delta, from_edges
from repro.graph.structure import cut_ratio as graph_cut_ratio
from repro.stream.ingest import (EdgeStreamBuffer, WindowIngestor,
                                 stream_batches)
from repro.stream.metrics import (QualityTracker, cut_ratio_of, delta_update,
                                  drift_check, imbalance_of, init_tracker,
                                  move_update)

StreamLike = Union[Tuple[np.ndarray, np.ndarray, np.ndarray], Any]


def empty_graph(n_cap: int, e_cap: int) -> Graph:
    """All-padding graph: a stream grows it from nothing."""
    return Graph(src=jnp.full((e_cap,), -1, jnp.int32),
                 dst=jnp.full((e_cap,), -1, jnp.int32),
                 node_mask=jnp.zeros((n_cap,), bool),
                 edge_mask=jnp.zeros((e_cap,), bool))


# ---------------------------------------------------------------------------
# Partition-quality snapshots (BSR tiling view)
# ---------------------------------------------------------------------------

def partition_relabelled(graph: Graph, assignment) -> Optional[Graph]:
    """Relabel live vertices grouped by partition (the relocation step that
    turns partition quality into BSR tile locality)."""
    nm = np.asarray(graph.node_mask)
    em = np.asarray(graph.edge_mask)
    lab = np.asarray(assignment)
    live = np.flatnonzero(nm)
    if live.size == 0 or not em.any():
        return None
    order = live[np.argsort(lab[live], kind="stable")]
    new_id = np.full(graph.n_cap, -1, np.int64)
    new_id[order] = np.arange(live.size)
    s = new_id[np.asarray(graph.src)[em]]
    d = new_id[np.asarray(graph.dst)[em]]
    return from_edges(s, d, live.size)


def bsr_snapshot(graph: Graph, assignment, blk: int = 32) -> Dict:
    """Tile stats of the partition-relabelled adjacency (kernel-cost proxy)."""
    relab = partition_relabelled(graph, assignment)
    if relab is None:      # no live vertices/edges: same shape as the
        return {"nnzb": 0, "diag_frac": 1.0, "mean_band": 0.0,  # empty branch
                "tiles_per_row": 0.0}                 # of bsr_density_stats
    return bsr_density_stats(graph_to_bsr(relab, blk=blk))


def _stream_arrays(stream: StreamLike) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Accept a (times, src, dst) tuple or any object with those attributes
    (a ``Scenario`` drops straight in)."""
    if isinstance(stream, (tuple, list)) and len(stream) == 3:
        t, u, v = stream
    else:
        t, u, v = stream.times, stream.src, stream.dst
    return np.asarray(t), np.asarray(u), np.asarray(v)


class DynamicGraphSystem:
    """One dynamic-graph processing session (graph + strategy + telemetry)."""

    def __init__(self, graph: Optional[Graph] = None,
                 config: Optional[SystemConfig] = None, *,
                 assignment: Optional[jax.Array] = None,
                 strategy: Any = None,
                 program: Optional[VertexProgram] = None):
        """Args:
          graph:      initial padded graph; None builds an empty one from
                      ``config.graph`` (n_cap/e_cap must be set).
          config:     the layered ``SystemConfig`` (defaults throughout).
          assignment: explicit initial labels; None asks the strategy.
          strategy:   overrides ``config.partition.strategy`` with a name,
                      class or instance (for variants a string can't express,
                      e.g. ``XdgpAdaptive(placement="inherit")``).
          program:    overrides ``config.compute.program`` with a constructed
                      ``VertexProgram``.
        """
        self.config = cfg = config if config is not None else SystemConfig()
        if graph is None:
            if cfg.graph.generator is not None:
                # scale tier (DESIGN.md §14): build the starting graph from
                # a streaming generator, chunked, seeded from the session
                from repro.scale import session_graph
                graph = session_graph(cfg.graph, seed=cfg.seed)
            elif cfg.graph.n_cap <= 0 or cfg.graph.e_cap <= 0:
                raise ValueError("pass an initial graph, set config.graph "
                                 "n_cap/e_cap so the session can build an "
                                 "empty one, or name a config.graph "
                                 "generator to synthesise one")
            else:
                graph = empty_graph(cfg.graph.n_cap, cfg.graph.e_cap)
        p = cfg.partition
        self.strategy = resolve_strategy(strategy if strategy is not None
                                         else p.strategy)
        self.backend = resolve_execution_backend(cfg.cluster.backend,
                                                 cluster=cfg.cluster)
        # observability (DESIGN.md §11): disabled sessions hold the shared
        # NULL_TRACER, whose hooks are constant-time no-ops — the superstep
        # pays no clock reads, fences or allocation unless telemetry.trace
        # turned tracing on
        if cfg.telemetry.trace:
            self.tracer: Any = Tracer(meta={
                "label": f"{self.strategy.name}/{cfg.cluster.backend}",
                "strategy": self.strategy.name,
                "backend": cfg.cluster.backend, "k": cfg.partition.k})
        else:
            self.tracer = NULL_TRACER
        self.backend.tracer = self.tracer
        self.backend.comm_probe = cfg.telemetry.trace_comm_probe
        self.metrics: Optional[MetricsRegistry] = (
            MetricsRegistry() if cfg.telemetry.metrics else None)
        # remembered so compare() can replay identical fresh sessions
        self._initial_graph = graph
        self._initial_assignment = assignment
        self._program_arg = program
        # a constructor-override strategy/program cannot be rebuilt from the
        # config alone — save() records the fact so restore() can insist on
        # being handed the same objects back
        self._strategy_override = strategy is not None
        self._program_override = program is not None

        self.graph = graph
        if assignment is None:
            assignment = self.strategy.init(graph, p.k)
        # capacity is provisioned for the slot space, not the current live
        # set: a stream can legally grow the graph to n_cap vertices.
        capacity = default_capacity(graph.n_cap, p.k, p.slack)
        self.state: PartitionState = make_state(
            graph, assignment, p.k, slack=p.slack, seed=cfg.seed,
            capacity=capacity)
        self.ingestor = WindowIngestor(
            n_cap=graph.n_cap, window=cfg.stream.window,
            a_cap=cfg.stream.a_cap, d_cap=cfg.stream.d_cap,
            dedupe=cfg.stream.dedupe,
            carry_backlog=cfg.stream.carry_backlog)
        if cfg.stream.dedupe:
            em = np.asarray(graph.edge_mask)
            if em.any():
                self.ingestor.seed_live_edges(np.asarray(graph.src)[em],
                                              np.asarray(graph.dst)[em])
        self.tracker: QualityTracker = init_tracker(graph, self.state.assignment,
                                                    p.k)
        self.telemetry: List[SuperstepRecord] = []
        self._superstep = 0
        self._now = 0
        self._run_seconds = 0.0
        self._place_key = jax.random.PRNGKey(cfg.seed ^ 0x5EED)

        # optional interleaved vertex program (think-like-a-vertex compute)
        if program is None and cfg.compute.program is not None:
            program = make_program(cfg.compute.program)
        self.program = program
        self.program_state: Optional[jax.Array] = None
        if program is not None:
            self.program_state = program.init(graph)

            def _prog_step(before_mask, g, st, step):
                # vertices born this superstep enter with their init state
                born = g.node_mask & ~before_mask
                st = jnp.where(born[:, None], program.init(g), st)
                return program_superstep(program, g, st, step)

            self._prog_step = jax.jit(_prog_step)
            self._msg_volume = jax.jit(
                lambda g, lab: message_volume(g, lab, program.state_dim))

    # -- context assembly ---------------------------------------------------
    @property
    def labels(self) -> jax.Array:
        """Current per-slot partition assignment."""
        return self.state.assignment

    @property
    def cut_ratio(self) -> float:
        """Current cut ratio (incrementally tracked — O(1) read)."""
        return float(cut_ratio_of(self.tracker))

    @property
    def imbalance(self) -> float:
        """Current max/mean occupancy (incrementally tracked — O(1) read)."""
        return float(imbalance_of(self.tracker))

    @property
    def backlog(self) -> Tuple[int, int]:
        """Deferred ingest work: (queued adds, queued dels) still sitting in
        the stream buffer past a_cap/d_cap — the capacity-backpressure signal
        the serving layer folds into per-tenant pressure (DESIGN.md §12)."""
        return self.ingestor.buffer.backlog

    @property
    def pressure(self) -> float:
        """Stream-buffer backlog relative to one superstep's drain capacity
        (≥ 1.0 means ingest is deferring work)."""
        return self.ingestor.buffer.pressure

    def _ctx(self, **runtime: Any) -> StrategyContext:
        p = self.config.partition
        return StrategyContext(
            k=p.k, s=p.s, adapt_iters=p.adapt_iters, tie_break=p.tie_break,
            placement_passes=p.placement_passes, patience=p.patience,
            max_iters=p.max_iters, rel_tol=p.rel_tol,
            backend=self.config.compute.backend, **runtime)

    def _place(self, delta: GraphDelta, before: Graph, after: Graph,
               ) -> Tuple[jax.Array, int]:
        """Route a delta's arrivals through the strategy's place hook."""
        labels_before = self.state.assignment
        self._place_key, sub = jax.random.split(self._place_key)
        ctx = self._ctx(node_mask=before.node_mask, assignment=labels_before,
                        occupancy=self.tracker.occupancy,
                        capacity=self.state.capacity, rng=sub)
        labels = self.strategy.place(delta, ctx)
        if ctx.placed is not None:
            placed = ctx.placed
        else:
            placed = int(jnp.sum(~before.node_mask & after.node_mask))
        return labels, placed

    # -- one superstep ------------------------------------------------------
    def step(self, events: np.ndarray, now: Optional[int] = None) -> SuperstepRecord:
        """Ingest one event batch, place arrivals, adapt, compute, measure."""
        cfg = self.config
        if now is None:
            ev = np.asarray(events)
            now = int(ev[:, 0].max()) if ev.size else self._now
        t_start = time.perf_counter()
        tr = self.tracer
        sp_step = tr.span("superstep", superstep=self._superstep + 1)
        sp_step.__enter__()

        # 1. INGEST: vectorized batch → one padded GraphDelta
        with tr.span("ingest"):
            delta, istats = self.ingestor.ingest(events, now)
        t_ingest = time.perf_counter() - t_start

        # 2. APPLY + PLACE: grow/shrink the graph, route arrivals through the
        # strategy. A provably empty delta skips the device pipeline entirely
        # (quiet stream gaps would otherwise pay full-graph scatters for
        # no-ops).
        before = self.graph
        labels_before = self.state.assignment
        if istats.adds_out == 0 and istats.dels_out == 0:
            after = before
            labels_placed = labels_before
            new_placed = 0
        else:
            with tr.span("place", adds=istats.adds_out,
                         dels=istats.dels_out) as sp:
                after = apply_delta(before, delta)
                labels_placed, new_placed = self._place(delta, before, after)

                # 3. MEASURE the ingest: incremental cut/occupancy from
                # diffs only
                self.tracker, _ = delta_update(self.tracker, before, after,
                                               labels_before, labels_placed)
                sp.fence(labels_placed, self.tracker.cut)

        # 4. ADAPT: the strategy's interleaved rounds on the new graph,
        # executed wherever the session's backend runs (local / sharded)
        state = dataclasses.replace(self.state, assignment=labels_placed)
        with tr.span("migrate") as sp:
            state = self.backend.adapt(self.strategy, after, state,
                                       self._ctx())
            self.tracker, moved = move_update(self.tracker, after,
                                              labels_placed, state.assignment)
            sp.fence(state.assignment, self.tracker.cut)
        comm = self.backend.pop_superstep_comm()

        self.graph = after
        self.state = state
        self._superstep += 1
        self._now = int(now)

        # dedupe mode models the live edge set exactly, which makes e_cap
        # exhaustion detectable: apply_delta drops additions silently once
        # free slots run out, and the mirror would drift forever after
        if cfg.stream.dedupe and \
                self.ingestor.live_edge_count != int(self.tracker.edges):
            raise RuntimeError(
                f"edge capacity exhausted at superstep {self._superstep}: "
                f"graph holds {int(self.tracker.edges)} live edges but "
                f"{self.ingestor.live_edge_count} were released "
                f"(e_cap={after.e_cap}); increase e_cap or lower a_cap")

        # 5. COMPUTE: one BSP superstep of the vertex program on the adapted
        # graph; its message traffic under the current assignment is the
        # paper's execution-time driver (§5.3: remote messages dominate).
        local_bytes = remote_bytes = 0
        compute_seconds = 0.0
        if self.program is not None:
            with tr.span("compute"):
                t_c = time.perf_counter()
                self.program_state = self._prog_step(
                    before.node_mask, after, self.program_state,
                    jnp.asarray(self._superstep, jnp.int32))
                self.program_state.block_until_ready()
                compute_seconds = time.perf_counter() - t_c
                lb, rb = self._msg_volume(after, state.assignment)
                local_bytes, remote_bytes = int(lb), int(rb)

        # 6. DRIFT CHECK: periodic full recompute validates the tracker
        drift = None
        every = cfg.telemetry.recompute_every
        with tr.span("commit"):
            if every and self._superstep % every == 0:
                self.tracker, drift = drift_check(self.tracker, after,
                                                  state.assignment)

        record = SuperstepRecord(
            superstep=self._superstep, now=int(now),
            events=int(np.asarray(events).shape[0]) if np.asarray(events).size else 0,
            adds=istats.adds_out, dels=istats.dels_out,
            backlog_adds=istats.adds_backlog, backlog_dels=istats.dels_backlog,
            invalid_events=istats.invalid, stale_dropped=istats.stale_dropped,
            new_placed=new_placed, migrations=int(moved),
            cut_edges=int(self.tracker.cut), live_edges=int(self.tracker.edges),
            cut_ratio=float(cut_ratio_of(self.tracker)),
            imbalance=float(imbalance_of(self.tracker)),
            ingest_seconds=t_ingest,
            step_seconds=time.perf_counter() - t_start,
            drift=drift,
            dup_dropped=istats.dup_dropped,
            local_bytes=local_bytes, remote_bytes=remote_bytes,
            compute_seconds=compute_seconds,
            halo_bytes=comm["halo_bytes"],
            halo_live_bytes=comm.get("halo_live_bytes", 0),
            collective_bytes=comm["collective_bytes"],
        )
        self.telemetry.append(record)
        sp_step.set(migrations=int(moved), cut_ratio=record.cut_ratio)
        sp_step.__exit__(None, None, None)
        tr.counter("migrations", record.migrations)
        if self.metrics is not None:
            record_superstep(self.metrics, record,
                             backend=self.backend.name)
        return record

    # -- windowed replay of a whole stream ----------------------------------
    def run(self, stream: StreamLike, *, batch_span: Optional[int] = None,
            max_supersteps: Optional[int] = None) -> List[SuperstepRecord]:
        """Replay a (t, u, v) stream window-by-window through the session.

        ``stream`` is a 3-tuple of arrays or any object with ``times`` /
        ``src`` / ``dst`` attributes (a ``Scenario`` drops straight in, its
        ``batch_span`` honoured unless overridden).
        """
        times, src, dst = _stream_arrays(stream)
        if batch_span is None:
            batch_span = getattr(stream, "batch_span", None)
        if batch_span is None:
            batch_span = self.config.stream.batch_span
        t0 = time.perf_counter()
        out: List[SuperstepRecord] = []
        for now, events in stream_batches(times, src, dst, batch_span):
            out.append(self.step(events, now))
            if max_supersteps is not None and len(out) >= max_supersteps:
                break
        self._run_seconds += time.perf_counter() - t0
        return out

    def drain(self, now: Optional[int] = None, max_supersteps: int = 64,
              ) -> List[SuperstepRecord]:
        """Flush capacity-deferred changes with empty-input supersteps."""
        now = self._now if now is None else now
        out: List[SuperstepRecord] = []
        empty = np.empty((0, 3), np.int64)
        while len(self.ingestor.buffer) and len(out) < max_supersteps:
            out.append(self.step(empty, now))
        return out

    # -- batch adaptation (the former AdaptivePartitioner drivers) -----------
    def converge(self, *, record_history: bool = True) -> History:
        """Adapt the current graph to quiescence (paper's convergence rule)."""
        old = self.state.assignment
        state, hist = self.backend.converge(
            self.strategy, self.graph, self.state,
            self._ctx(record_history=record_history))
        self.tracker, _ = move_update(self.tracker, self.graph, old,
                                      state.assignment)
        self.state = state
        self.backend.pop_superstep_comm()   # batch comm lands in the totals
        return hist

    def adapt(self, iters: int, *, record_history: bool = True) -> History:
        """A fixed number of adaptation rounds on the current graph."""
        old = self.state.assignment
        state, hist = self.backend.adapt_rounds(
            self.strategy, self.graph, self.state, iters,
            self._ctx(record_history=record_history))
        self.tracker, _ = move_update(self.tracker, self.graph, old,
                                      state.assignment)
        self.state = state
        self.backend.pop_superstep_comm()   # batch comm lands in the totals
        return hist

    def inject(self, delta: GraphDelta) -> int:
        """Apply a pre-built ``GraphDelta`` (growth burst, benchmark event)
        through the place/measure path, bypassing the event-stream ingestor.
        Returns the number of vertices placed. Not compatible with
        ``stream.dedupe`` sessions (the live-edge mirror only sees the
        ingest path)."""
        if self.config.stream.dedupe:
            raise RuntimeError("inject() bypasses the ingest path and would "
                               "desync the dedupe live-edge mirror; ingest "
                               "events via step() instead")
        before = self.graph
        after = apply_delta(before, delta)
        labels_before = self.state.assignment
        labels, placed = self._place(delta, before, after)
        self.tracker, _ = delta_update(self.tracker, before, after,
                                       labels_before, labels)
        self.graph = after
        self.state = dataclasses.replace(self.state, assignment=labels)
        return placed

    # -- cluster lifecycle (DESIGN.md §10) -----------------------------------
    def _swap_backend(self, backend_name: str, **cluster_changes: Any) -> None:
        """Atomically move to another backend: resolve and validate the
        candidate first, commit config + backend only if that succeeds."""
        cfg = self.config.with_cluster(backend=backend_name,
                                       **cluster_changes)
        if self.backend.name == backend_name:
            # same backend class: keep the instance (and its cumulative
            # comm totals), just refresh its knobs and drop stale caches
            self.backend.cluster = cfg.cluster
            self.backend.invalidate()
        else:
            self.backend = resolve_execution_backend(backend_name,
                                                     cluster=cfg.cluster)
            self.backend.tracer = self.tracer
            self.backend.comm_probe = cfg.telemetry.trace_comm_probe
        self.config = cfg

    def distribute(self, *, devices: Optional[int] = None,
                   ) -> "DynamicGraphSystem":
        """Move the session onto the sharded backend (partition-per-device
        SPMD via the cluster engine). Validates device availability eagerly
        so a missing ``XLA_FLAGS`` fails here — with the session left
        untouched on its current backend — not at the next superstep.
        The adaptation trajectory is unchanged — the sharded engine is
        decision-identical to the local one (DESIGN.md §10)."""
        changes = {} if devices is None else {"devices": int(devices)}
        cfg = self.config.with_cluster(backend="sharded", **changes)
        candidate = resolve_execution_backend("sharded", cluster=cfg.cluster)
        candidate.required_devices(self.config.partition.k)   # may raise
        if self.backend.name == "sharded":
            # already sharded: keep the instance (cumulative comm totals),
            # refresh its knobs and drop caches built for the old config
            self.backend.cluster = cfg.cluster
            self.backend.invalidate()
        else:
            self.backend = candidate          # the validated instance
            self.backend.tracer = self.tracer
            self.backend.comm_probe = cfg.telemetry.trace_comm_probe
        self.config = cfg
        return self

    def gather(self) -> "DynamicGraphSystem":
        """Return the session to on-host execution. The session's canonical
        arrays never left slot order, so this is a pure backend swap."""
        self._swap_backend("local")
        return self

    def rescale(self, new_k: int, *, lost: Optional[Tuple[int, ...]] = None,
                adapt_iters: int = 60) -> Dict:
        """Elastic k-change: workers joined (``new_k > k``) or died.

        Orphaned vertices are re-homed by hash (``runtime.elastic``), the
        session re-provisions capacity for the new partition count, and the
        strategy re-adapts on the session's own backend — the paper's §4.3
        recovery story promoted to one session operation. Returns the
        ``elastic_rescale`` report (cut before/after, migrations)."""
        from repro.runtime.elastic import rescale_assignment

        old_k = self.config.partition.k
        # validate the post-rescale cluster BEFORE mutating anything: a
        # sharded session needs one device per new partition, and failing
        # mid-rescale would leave the session half-rewritten and unusable
        cl = self.config.cluster
        if cl.devices not in (0, int(new_k)):
            cl = dataclasses.replace(cl, devices=0)
        probe = resolve_execution_backend(cl.backend, cluster=cl)
        if hasattr(probe, "required_devices"):
            probe.required_devices(int(new_k))                # may raise
        a0 = rescale_assignment(self.labels, old_k, int(new_k), lost)
        cut_rehash = float(graph_cut_ratio(self.graph, a0))
        p = dataclasses.replace(self.config.partition, k=int(new_k))
        self.config = dataclasses.replace(self.config, partition=p)
        if self.config.cluster.devices not in (0, int(new_k)):
            # a pinned device count cannot survive a k-change (k == P)
            self.config = self.config.with_cluster(devices=0)
        capacity = default_capacity(self.graph.n_cap, int(new_k), p.slack)
        self.state = make_state(self.graph, a0, int(new_k), slack=p.slack,
                                seed=self.config.seed, capacity=capacity)
        self.tracker = init_tracker(self.graph, self.state.assignment,
                                    int(new_k))
        # a k-change is a mesh change: drop the backend's bucketing/mesh
        # caches but keep the instance (cumulative comm totals survive)
        self.backend.cluster = self.config.cluster
        self.backend.invalidate()
        hist = self.adapt(adapt_iters)
        return {"old_k": old_k, "new_k": int(new_k),
                "cut_after_rehash": cut_rehash,
                "cut_after_adapt": self.cut_ratio,
                "migrations": hist.total_migrations}

    # -- checkpoint / restore -------------------------------------------------
    _CKPT_VERSION = 1

    def _session_arrays(self) -> Dict[str, Any]:
        """The array pytree the checkpointer persists (fixed key structure —
        the treedef must match between save and the restore template)."""
        ing = self.ingestor
        add_src, add_dst, add_t, dels = ing.buffer.peek_all()
        prog = (self.program_state if self.program_state is not None
                else jnp.zeros((0,), jnp.float32))
        return {
            "graph": {"src": self.graph.src, "dst": self.graph.dst,
                      "node_mask": self.graph.node_mask,
                      "edge_mask": self.graph.edge_mask},
            "state": {"assignment": self.state.assignment,
                      "pending": self.state.pending,
                      "capacity": self.state.capacity,
                      "rng": self.state.rng,
                      "iteration": self.state.iteration,
                      "last_moves": self.state.last_moves},
            "tracker": {"cut": self.tracker.cut, "edges": self.tracker.edges,
                        "occupancy": self.tracker.occupancy},
            "window": {"last_seen": ing.tracker.last_seen,
                       "live_lo": ing._live_lo, "live_hi": ing._live_hi,
                       "backlog_add_src": add_src, "backlog_add_dst": add_dst,
                       "backlog_add_t": add_t, "backlog_dels": dels},
            "place_key": self._place_key,
            "program_state": prog,
        }

    def save(self, path: str, *, step: Optional[int] = None) -> int:
        """Checkpoint the whole session — graph, partition state, tracker,
        window/backlog state, telemetry and config — atomically under
        ``path``. Returns the step id (defaults to the superstep counter).
        A sharded session checkpoints its canonical slot-order state, so it
        can be restored on any host and re-``distribute()``-d there."""
        step = self._superstep if step is None else int(step)
        extra = {
            "version": self._CKPT_VERSION,
            "config": self.config.to_dict(),
            "strategy": self.strategy.name,
            "strategy_override": self._strategy_override,
            "program_override": self._program_override,
            "has_program": self.program is not None,
            "superstep": self._superstep,
            "now": self._now,
            "run_seconds": self._run_seconds,
            "telemetry": [dataclasses.asdict(r) for r in self.telemetry],
        }
        ckpt = Checkpointer(path, use_async=False)
        ckpt.save(step, self._session_arrays(), extra=extra)
        return step

    @classmethod
    def restore(cls, path: str, *, step: Optional[int] = None,
                strategy: Any = None,
                program: Optional[VertexProgram] = None,
                ) -> "DynamicGraphSystem":
        """Resume a session saved with :meth:`save` — mid-run: partition
        state (including deferred moves and the RNG), incremental tracker,
        window liveness, ingest backlog and telemetry all pick up exactly
        where the checkpoint left them.

        A session built with constructor overrides (``strategy=`` /
        ``program=`` instances the config cannot express) must be handed
        the same overrides here — a checkpoint records only their names,
        and resuming with a different policy would silently diverge from
        the saved trajectory, so restore refuses instead."""
        ckpt = Checkpointer(path, use_async=False)
        extra = ckpt.read_extra(step)
        if extra is None or extra.get("version") != cls._CKPT_VERSION:
            raise ValueError(f"{path} is not a session checkpoint "
                             f"(missing/incompatible extra.json)")
        cfg = SystemConfig.from_dict(extra["config"])
        dummy = jnp.zeros((0,), jnp.float32)
        template = {
            "graph": {k: dummy for k in ("src", "dst", "node_mask",
                                         "edge_mask")},
            "state": {k: dummy for k in ("assignment", "pending", "capacity",
                                         "rng", "iteration", "last_moves")},
            "tracker": {k: dummy for k in ("cut", "edges", "occupancy")},
            "window": {k: dummy for k in ("last_seen", "live_lo", "live_hi",
                                          "backlog_add_src",
                                          "backlog_add_dst", "backlog_add_t",
                                          "backlog_dels")},
            "place_key": dummy,
            "program_state": dummy,
        }
        payload, _ = ckpt.restore(template, step)
        g = payload["graph"]
        graph = Graph(src=jnp.asarray(g["src"]), dst=jnp.asarray(g["dst"]),
                      node_mask=jnp.asarray(g["node_mask"]),
                      edge_mask=jnp.asarray(g["edge_mask"]))
        if extra.get("strategy_override") and strategy is None:
            raise ValueError(
                f"checkpoint was saved from a session built with an "
                f"explicit strategy override ({extra['strategy']!r}); the "
                f"config alone cannot rebuild it — pass the same strategy "
                f"via restore(..., strategy=...)")
        if extra.get("program_override") and program is None:
            raise ValueError(
                "checkpoint was saved from a session built with an explicit "
                "program override; the config alone cannot rebuild it — "
                "pass the same program via restore(..., program=...)")
        st = payload["state"]
        system = cls(graph, cfg, assignment=jnp.asarray(st["assignment"]),
                     strategy=strategy, program=program)
        if system.strategy.name != extra["strategy"]:
            raise ValueError(
                f"checkpoint was saved with strategy "
                f"{extra['strategy']!r} but the restored session resolves "
                f"to {system.strategy.name!r}; pass the original strategy "
                f"instance via restore(..., strategy=...)")
        if extra.get("has_program") and system.program is None:
            raise ValueError(
                "checkpoint carries a vertex-program state but the restored "
                "session has no program (it was passed as a constructor "
                "override); pass it via restore(..., program=...)")
        system.state = PartitionState(
            assignment=jnp.asarray(st["assignment"], jnp.int32),
            pending=jnp.asarray(st["pending"], jnp.int32),
            capacity=jnp.asarray(st["capacity"], jnp.int32),
            rng=jnp.asarray(st["rng"]),
            iteration=jnp.asarray(st["iteration"], jnp.int32),
            last_moves=jnp.asarray(st["last_moves"], jnp.int32))
        tr = payload["tracker"]
        system.tracker = QualityTracker(
            cut=jnp.asarray(tr["cut"], jnp.int32),
            edges=jnp.asarray(tr["edges"], jnp.int32),
            occupancy=jnp.asarray(tr["occupancy"], jnp.int32))
        w = payload["window"]
        ing = system.ingestor
        # host-side window state must be writable numpy, not device views
        ing.tracker.last_seen = np.array(w["last_seen"], np.int64)
        ing._live_lo = np.array(w["live_lo"], np.int64)
        ing._live_hi = np.array(w["live_hi"], np.int64)
        ing.buffer = EdgeStreamBuffer(ing.a_cap, ing.d_cap)
        if np.asarray(w["backlog_add_src"]).size:
            ing.buffer.push_edges(np.asarray(w["backlog_add_src"]),
                                  np.asarray(w["backlog_add_dst"]),
                                  np.asarray(w["backlog_add_t"]))
        if np.asarray(w["backlog_dels"]).size:
            ing.buffer.push_node_removals(np.asarray(w["backlog_dels"]))
        system._place_key = jnp.asarray(payload["place_key"])
        prog = np.asarray(payload["program_state"])
        if system.program is not None and prog.size:
            system.program_state = jnp.asarray(prog)
        system._superstep = int(extra["superstep"])
        system._now = int(extra["now"])
        system._run_seconds = float(extra["run_seconds"])
        system.telemetry = [SuperstepRecord(**r) for r in extra["telemetry"]]
        return system

    # -- measurement --------------------------------------------------------
    def snapshot(self, *, bsr_blk: Optional[int] = None) -> Dict:
        """Partition-quality + BSR-tiling view of the session right now."""
        blk = bsr_blk if bsr_blk is not None else self.config.telemetry.bsr_blk
        return {
            "strategy": self.strategy.name,
            "backend": self.backend.name,
            "cluster": self.backend.device_stats(),
            "k": self.config.partition.k,
            "supersteps": self._superstep,
            "now": self._now,
            "nodes": int(jnp.sum(self.graph.node_mask)),
            "edges": int(self.tracker.edges),
            "cut_edges": int(self.tracker.cut),
            "cut_ratio": float(cut_ratio_of(self.tracker)),
            "imbalance": float(imbalance_of(self.tracker)),
            "occupancy": np.asarray(self.tracker.occupancy).tolist(),
            "capacity": np.asarray(self.state.capacity).tolist(),
            "bsr": bsr_snapshot(self.graph, self.state.assignment, blk=blk),
        }

    def cost_model(self) -> CostModel:
        c = self.config.compute
        return CostModel(c_cpu=c.c_cpu, c_net=c.c_net, c_mig=c.c_mig)

    def score(self, *, cost: Optional[CostModel] = None,
              bsr_blk: Optional[int] = None) -> Dict:
        """Cost-model scoring of the session's telemetry (paper §5.3):

          cost(step) = c_cpu · local_bytes + c_net · remote_bytes
                       + c_mig · migrations · unit_bytes

        so the strategy is charged for its own migration overhead, like the
        paper's end-to-end ">50% execution time reduction" claim."""
        recs = self.telemetry
        if not recs:
            raise RuntimeError("score() needs telemetry; run() or step() first")
        drifts = [r.drift for r in recs if r.drift is not None]
        if any(d != 0.0 for d in drifts):     # survives python -O, unlike assert
            raise RuntimeError(f"quality tracker drifted: {drifts}")
        cost = cost if cost is not None else self.cost_model()
        scale = self.config.compute.payload_scale
        state_dim = self.program.state_dim if self.program is not None else 0
        unit = state_dim * 4 * scale
        local = sum(r.local_bytes for r in recs) * scale
        remote = sum(r.remote_bytes for r in recs) * scale
        migrations = sum(r.migrations for r in recs)
        per_step = [cost.superstep_cost(r.local_bytes * scale,
                                        r.remote_bytes * scale,
                                        r.migrations, unit) for r in recs]
        total = float(np.sum(per_step))
        blk = bsr_blk if bsr_blk is not None else self.config.telemetry.bsr_blk
        return {
            "mode": self.strategy.name,
            "backend": self.backend.name,
            "supersteps": len(recs),
            "events": int(sum(r.events for r in recs)),
            "halo_bytes": int(sum(r.halo_bytes for r in recs)),
            "halo_live_bytes": int(sum(r.halo_live_bytes for r in recs)),
            "collective_bytes": int(sum(r.collective_bytes for r in recs)),
            "cut_final": float(recs[-1].cut_ratio),
            "cut_mean": float(np.mean([r.cut_ratio for r in recs])),
            "imbalance_final": float(recs[-1].imbalance),
            "migrations_total": int(migrations),
            "placed_total": int(sum(r.new_placed for r in recs)),
            "local_bytes": float(local),
            "remote_bytes": float(remote),
            "exec_cost_total": total,
            "exec_cost_per_superstep": total / max(len(recs), 1),
            "adaptation_cost": float(cost.c_mig * migrations * unit),
            "compute_seconds": float(sum(r.compute_seconds for r in recs)),
            "wall_seconds": float(self._run_seconds),
            "bsr": bsr_snapshot(self.graph, self.state.assignment, blk=blk),
            "cut_trajectory": [round(float(r.cut_ratio), 4) for r in recs],
        }

    # -- dual-run comparison (the former scenario harness) --------------------
    def fresh(self, *, strategy: Any = None, seed: Optional[int] = None,
              ) -> "DynamicGraphSystem":
        """A new session over the same initial graph/config — optionally with
        a different strategy or seed. The initial graph is immutable, so
        replays are exact."""
        cfg = self.config if seed is None else self.config.with_seed(seed)
        strat = self.strategy if strategy is None else resolve_strategy(strategy)
        if strategy is not None:
            cfg = cfg.with_strategy(strat.name)
        return DynamicGraphSystem(self._initial_graph, cfg,
                                  assignment=self._initial_assignment,
                                  strategy=strat,
                                  program=self._program_arg)

    def compare(self, stream: StreamLike, *, baseline: Any = "static",
                max_supersteps: Optional[int] = None,
                bsr_blk: Optional[int] = None,
                cost: Optional[CostModel] = None,
                seed: Optional[int] = None) -> Dict:
        """Run the same stream under this session's strategy and under
        ``baseline``, from identical fresh sessions, and compare the
        execution-cost proxy (the paper's adaptive-vs-static comparison).

        ``seed`` varies the sessions' own randomness (placement tie noise,
        migration damping) independently of the stream. Keys follow the
        historical harness layout: the candidate row is ``"adaptive"``, the
        baseline row ``"static"``, whatever the strategies actually are.
        """
        if self.program is None:
            # without a vertex program every superstep records zero message
            # bytes, both totals are 0 and the "reduction" would read 100%
            raise RuntimeError(
                "compare() needs a vertex program to measure execution cost; "
                "set config.compute.program (e.g. 'pagerank') or pass "
                "program= to the session")
        rows: Dict[str, Dict] = {}
        for key, strat in (("adaptive", None), ("static", baseline)):
            system = self.fresh(strategy=strat, seed=seed)
            system.run(stream, max_supersteps=max_supersteps)
            rows[key] = system.score(cost=cost, bsr_blk=bsr_blk)
        adaptive, static = rows["adaptive"], rows["static"]
        s_cost = max(static["exec_cost_total"], 1e-12)
        reduction = 1.0 - adaptive["exec_cost_total"] / s_cost
        s_tiles = max(static["bsr"]["nnzb"], 1)
        times, _, _ = _stream_arrays(stream)
        return {
            "scenario": getattr(stream, "name", None),
            "program": getattr(stream, "program",
                               self.config.compute.program),
            "k": self.config.partition.k,
            "events": int(getattr(stream, "n_events", times.shape[0])),
            "notes": getattr(stream, "notes", ""),
            "adaptive": adaptive,
            "static": static,
            "exec_cost_reduction_pct":
                round(100 * reduction, 1),
            "remote_reduction_pct":
                round(100 * (1 - adaptive["remote_bytes"]
                             / max(static["remote_bytes"], 1e-12)), 1),
            "cut_improvement":
                round(1 - adaptive["cut_final"]
                      / max(static["cut_final"], 1e-12), 3),
            "bsr_tile_reduction_pct":
                round(100 * (1 - adaptive["bsr"]["nnzb"] / s_tiles), 1),
            "meets_50pct_claim": bool(reduction > 0.5),
        }
