"""Layered system configuration — the one knob surface for the front door.

``SystemConfig`` replaces the overlapping ``AdaptiveConfig`` /
``StreamConfig`` knob sets with five orthogonal sections:

  graph      — slot capacities when the session builds its own empty graph
  stream     — ingestion: window, batching, backpressure caps, dedupe
  partition  — the strategy name plus every partitioning knob it may read
  compute    — interleaved vertex program + the §5.3 execution-cost model
  cluster    — execution backend (local | sharded), mesh axis/devices, halo
               padding policy (DESIGN.md §10)
  telemetry  — drift-check cadence and snapshot tiling

Every field is a JSON-compatible scalar, so ``to_dict``/``from_dict``
round-trip losslessly — configs can live in result files, CI matrices and
experiment sweeps. ``from_dict`` rejects unknown keys with the valid set in
the message (the same fail-loudly contract as the strategy registry).

Example — build a config, round-trip it through plain JSON data, and swap
the strategy for the baseline comparison (doctested in CI):

    >>> from repro.api import ClusterSection, PartitionSection, SystemConfig
    >>> cfg = SystemConfig(partition=PartitionSection(strategy="xdgp", k=4))
    >>> cfg.partition.k
    4
    >>> SystemConfig.from_dict(cfg.to_dict()) == cfg
    True
    >>> cfg.with_strategy("static").partition.strategy
    'static'
    >>> cfg.compute.backend           # migration scoring path (DESIGN.md §9)
    'auto'
    >>> cfg.cluster.backend           # execution backend (DESIGN.md §10)
    'local'
    >>> ClusterSection(backend="sharded").devices   # 0 = partition-per-device
    0
    >>> try:
    ...     SystemConfig.from_dict({"partitoin": {}})
    ... except ValueError as e:
    ...     "unknown SystemConfig sections" in str(e)
    True
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional


@dataclasses.dataclass(frozen=True)
class GraphSection:
    """How the session gets its graph when none is supplied.

    Two modes: bare capacities (``n_cap``/``e_cap``) build an empty graph a
    stream grows from nothing (the original behaviour), while a
    ``generator`` name builds a starting graph through the scale tier's
    streaming generators (``repro.scale``, DESIGN.md §14) — chunked, with
    deterministic per-chunk seeding from the session seed.
    """

    n_cap: int = 0                 # vertex slots (0 = a graph must be passed,
                                   # or = generator's n when one is named)
    e_cap: int = 0                 # edge slots (generator mode: 0 = generated
                                   # edges + 25% streaming head-room)
    generator: Optional[str] = None  # scale-tier generator name
                                   # ("rmat" | "kronecker" | "chung_lu")
    n: int = 0                     # generator vertex count
    avg_degree: float = 8.0        # generator target average degree
    chunk_edges: int = 262144      # edges per generated/packed chunk

    def __post_init__(self):
        if self.generator is not None and self.n < 2:
            raise ValueError(f"graph.generator={self.generator!r} needs "
                             f"graph.n >= 2 vertices, got {self.n}")


@dataclasses.dataclass(frozen=True)
class StreamSection:
    """Ingestion-side knobs (the former ``StreamConfig`` surface)."""

    window: int = 300              # sliding-window length (event time units)
    batch_span: int = 100          # stream time per superstep (run() default)
    a_cap: int = 8192              # max edge additions per superstep
    d_cap: int = 4096              # max node expiries per superstep
    dedupe: bool = False           # drop additions whose edge is already live
    carry_backlog: bool = True     # False = seed semantics (overflow dropped)


@dataclasses.dataclass(frozen=True)
class PartitionSection:
    """Partitioning strategy + every knob a strategy may read from its ctx."""

    strategy: str = "xdgp"         # registry name (see repro.api.strategy)
    k: int = 8                     # partitions
    s: float = 0.5                 # migration damping (paper §3.4)
    adapt_iters: int = 5           # migration rounds interleaved per superstep
    tie_break: str = "random"      # "stay" = paper's literal rule
    slack: float = 0.2             # capacity head-room over n_cap/k
    placement_passes: int = 2      # online-placement refinement passes
    patience: int = 30             # converge(): quiet/plateau window
    max_iters: int = 500           # converge(): hard iteration cap
    rel_tol: float = 1e-3          # converge(): cut plateau tolerance


@dataclasses.dataclass(frozen=True)
class ComputeSection:
    """Interleaved vertex program + §5.3 execution-cost model constants."""

    program: Optional[str] = None  # key into core.vertex_program.PROGRAMS
    payload_scale: float = 1.0     # bytes-per-message multiplier (FEM/CDR §5.3)
    c_cpu: float = 1.0             # cost per local message byte
    c_net: float = 25.0            # cost per remote message byte (§5.3: 25×)
    c_mig: float = 50.0            # cost per migrated vertex, in message units
    backend: str = "auto"          # migration scoring: "ref" | "pallas" |
                                   # "auto" (DESIGN.md §9; compat resolves)


@dataclasses.dataclass(frozen=True)
class ClusterSection:
    """Execution-layer knobs: where does the session's adaptation run?

    ``backend="local"`` executes on-host (the default); ``"sharded"``
    executes partition-per-device SPMD through the cluster engine in
    ``core.distributed`` — same assignments bit for bit, plus per-device
    halo/collective byte telemetry (DESIGN.md §10).
    """

    backend: str = "local"         # execution backend registry name
    axis: str = "nodes"            # mesh axis name the node dimension shards on
    devices: int = 0               # device-count override (0 = k, one
                                   # partition per device)
    halo_pad: float = 0.0          # halo padding policy: fractional head-room
                                   # over the largest boundary segment
    block_pad: float = 0.25        # node-block growth policy: head-room added
                                   # when the largest partition outgrows the
                                   # current block (0 = exact fit every rebuild)
    edge_pad: float = 0.25         # edge-bucket growth policy: head-room added
                                   # when the largest per-device edge bucket
                                   # outgrows the current padded size
    # block_pad/edge_pad (with the halo's halo_pad) keep consecutive
    # streaming rebuilds shape-stable so the compiled cluster step is
    # reused instead of re-jitted per superstep (DESIGN.md §10)

    def __post_init__(self):
        # fail at the knob, not with a broadcast error deep in the bucketing
        if self.halo_pad < 0:
            raise ValueError(f"cluster.halo_pad must be >= 0 (head-room over "
                             f"the largest boundary), got {self.halo_pad}")
        if self.block_pad < 0:
            raise ValueError(f"cluster.block_pad must be >= 0 (head-room over "
                             f"the largest partition), got {self.block_pad}")
        if self.edge_pad < 0:
            raise ValueError(f"cluster.edge_pad must be >= 0 (head-room over "
                             f"the largest edge bucket), got {self.edge_pad}")
        if self.devices < 0:
            raise ValueError(f"cluster.devices must be >= 0 (0 = one device "
                             f"per partition), got {self.devices}")


@dataclasses.dataclass(frozen=True)
class TelemetrySection:
    """Measurement-side knobs."""

    recompute_every: int = 10      # supersteps between full drift checks (0 = off)
    bsr_blk: int = 32              # tile size for snapshot() BSR stats
    trace: bool = False            # span tracing (repro.obs.trace; <3% overhead
                                   # budget, DESIGN.md §11)
    trace_comm_probe: bool = False # also time halo/collective mirrors per graph
                                   # rebuild (sharded only; adds probe dispatches)
    metrics: bool = False          # fold SuperstepRecords into a MetricsRegistry


_SECTIONS = {
    "graph": GraphSection,
    "stream": StreamSection,
    "partition": PartitionSection,
    "compute": ComputeSection,
    "cluster": ClusterSection,
    "telemetry": TelemetrySection,
}


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """The complete configuration of one ``DynamicGraphSystem`` session."""

    graph: GraphSection = dataclasses.field(default_factory=GraphSection)
    stream: StreamSection = dataclasses.field(default_factory=StreamSection)
    partition: PartitionSection = dataclasses.field(default_factory=PartitionSection)
    compute: ComputeSection = dataclasses.field(default_factory=ComputeSection)
    cluster: ClusterSection = dataclasses.field(default_factory=ClusterSection)
    telemetry: TelemetrySection = dataclasses.field(default_factory=TelemetrySection)
    seed: int = 0                  # session randomness (placement ties, damping)

    # -- serialisation ------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {name: dataclasses.asdict(getattr(self, name))
                             for name in _SECTIONS}
        d["seed"] = self.seed
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SystemConfig":
        valid_top = set(_SECTIONS) | {"seed"}
        unknown = sorted(set(d) - valid_top)
        if unknown:
            raise ValueError(f"unknown SystemConfig sections {unknown}; "
                             f"valid: {sorted(valid_top)}")
        kwargs: Dict[str, Any] = {}
        for name, sec_cls in _SECTIONS.items():
            if name in d:
                sec = d[name]
                fields = {f.name for f in dataclasses.fields(sec_cls)}
                bad = sorted(set(sec) - fields)
                if bad:
                    raise ValueError(f"unknown keys {bad} in '{name}' section; "
                                     f"valid: {sorted(fields)}")
                kwargs[name] = sec_cls(**sec)
        if "seed" in d:
            kwargs["seed"] = int(d["seed"])
        return cls(**kwargs)

    # -- convenience --------------------------------------------------------
    def with_strategy(self, strategy: str) -> "SystemConfig":
        """Same config, different partitioning strategy — the one-field swap
        that turns the system under test into its baseline (and back)."""
        return dataclasses.replace(
            self, partition=dataclasses.replace(self.partition, strategy=strategy))

    def with_seed(self, seed: int) -> "SystemConfig":
        return dataclasses.replace(self, seed=int(seed))

    def with_cluster(self, **changes: Any) -> "SystemConfig":
        """Same config, different execution-layer knobs — the one-section
        swap that moves a session between local and sharded execution."""
        return dataclasses.replace(
            self, cluster=dataclasses.replace(self.cluster, **changes))
