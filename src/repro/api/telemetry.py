"""Per-superstep telemetry — the record every front door emits.

Lives in its own leaf module (no repro imports) so both the session
(``repro.api.system``) and the deprecated ``StreamEngine`` shim
(``repro.stream.engine``) can share the one dataclass without an import
cycle between the api and stream packages.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class SuperstepRecord:
    """Telemetry for one system superstep."""

    superstep: int
    now: int                   # stream time at the end of the batch
    events: int                # events offered this superstep
    adds: int                  # edge additions released into the graph
    dels: int                  # node expiries released
    backlog_adds: int          # additions held back by a_cap backpressure
    backlog_dels: int
    invalid_events: int        # events rejected at ingest (ids out of range)
    stale_dropped: int         # backlogged changes invalidated by window movement
    new_placed: int            # vertices placed online this superstep
    migrations: int            # vertices moved by the adaptation rounds
    cut_edges: int
    live_edges: int
    cut_ratio: float
    imbalance: float
    ingest_seconds: float      # delta construction (the streaming front end)
    step_seconds: float        # full superstep wall clock
    drift: Optional[float]     # set on drift-check supersteps (must be 0.0)
    dup_dropped: int = 0       # additions dropped as already-live (dedupe mode)
    local_bytes: int = 0       # program message traffic staying intra-partition
    remote_bytes: int = 0      # program message traffic crossing partitions
    compute_seconds: float = 0.0  # vertex-program superstep wall clock
    halo_bytes: int = 0        # sharded backend: halo bytes received this
                               # superstep, summed over devices (0 on local)
    halo_live_bytes: int = 0   # live (unpadded) fraction of the halo — the
                               # cut frontier the heuristic shrinks; the
                               # padded halo_bytes is shape-stable by design
    collective_bytes: int = 0  # sharded backend: capacity-psum + rank-gather
                               # bytes, summed over devices (0 on local)

    @property
    def events_per_second(self) -> float:
        return self.events / max(self.ingest_seconds, 1e-12)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["events_per_second"] = self.events_per_second
        return d
