"""Pluggable partitioning strategies behind one protocol (the Spinner/SDP
shape: partitioning as a swappable policy inside a stable processing API).

A ``PartitionStrategy`` answers the three questions the runtime asks:

  init(graph, k)           -> labels   initial assignment of every vertex slot
  place(delta, ctx)        -> labels   where do *arriving* vertices go?
  adapt(graph, state, ctx) -> state    interleaved repartitioning per superstep

plus two batch-mode extensions used by ``DynamicGraphSystem.converge()`` /
``.adapt()``: ``converge(graph, state, ctx)`` and
``adapt_rounds(graph, state, iters, ctx)``, both returning
``(state, History)``.

Contract for ``place``: it may only relabel vertices that were dead before
the delta (``ctx.node_mask``) — surviving vertices keep their labels, which
is what keeps the incremental ``QualityTracker`` exact (see
``repro.stream.metrics``). Strategies that know exactly how many vertices
they placed report it via ``ctx.placed``; otherwise the system derives the
count from the liveness diff.

Strategies register under a name (plus seed-era aliases) in a module-level
registry; ``resolve_strategy`` turns a name / class / instance into an
instance and raises a ``ValueError`` listing every registered name on a
typo. ``repro.core.initial.initial_partition`` dispatches through the same
registry, so "adaptive vs. static-hash" is two strategy values — never two
code paths. ``canonical_strategy_names()`` lists each strategy exactly once
(primary names, no aliases) — the form every "run all strategies" loop
(arena benchmark, conformance suite) must use, or aliases run duplicates.

Example — resolve strategies from the registry and plug in a custom one
(doctested in CI):

    >>> from repro.api import (register_strategy, resolve_strategy,
    ...                        strategy_names, canonical_strategy_names)
    >>> {"static", "hash", "fennel", "xdgp"} <= set(strategy_names())
    True
    >>> {"spinner", "sdp", "restream"} <= set(canonical_strategy_names())
    True
    >>> "hsh" in strategy_names(), "hsh" in canonical_strategy_names()
    (True, False)
    >>> resolve_strategy("xdgp").name          # name, class or instance
    'xdgp'
    >>> from repro.api.strategy import StrategyBase
    >>> @register_strategy("doctest-noop")
    ... class Noop(StrategyBase):
    ...     name = "doctest-noop"
    >>> resolve_strategy("doctest-noop").name
    'doctest-noop'
    >>> try:
    ...     resolve_strategy("typo")
    ... except ValueError as e:
    ...     "registered strategies" in str(e)
    True
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, runtime_checkable

import jax

from repro.compat import resolve_backend
from repro.core.initial import (block_partition, deterministic_greedy,
                                hash_partition, min_neighbours,
                                modulo_partition, random_partition)
from repro.core.partition_state import PartitionState, imbalance
from repro.core.repartitioner import (History, adapt_jit, adapt_rounds,
                                      run_to_convergence)
from repro.core.restream import restream_state
from repro.core.sdp import sdp_adapt_jit, sdp_refine_step
from repro.core.spinner import spinner_adapt_jit, spinner_step
from repro.graph.structure import Graph, GraphDelta, cut_ratio
from repro.stream.placement import place_delta


@dataclasses.dataclass
class StrategyContext:
    """Everything a strategy may read during one runtime call.

    The partitioning knobs mirror ``SystemConfig.partition``; the array
    fields are filled by the system per call. ``placed`` is the one
    out-parameter: a placement strategy sets it to the exact number of
    vertices it placed.
    """

    k: int = 8
    s: float = 0.5
    adapt_iters: int = 5
    tie_break: str = "random"
    placement_passes: int = 2
    patience: int = 30
    max_iters: int = 500
    rel_tol: float = 1e-3
    record_history: bool = True
    backend: str = "auto"          # migration scoring backend (DESIGN.md §9)
    # runtime arrays (filled by the system per call)
    node_mask: Optional[jax.Array] = None    # liveness *before* the delta
    assignment: Optional[jax.Array] = None   # current labels
    occupancy: Optional[jax.Array] = None    # (k,) live vertices per partition
    capacity: Optional[jax.Array] = None     # (k,) hard capacities
    rng: Optional[jax.Array] = None          # fresh subkey for this call
    # out-parameter
    placed: Optional[int] = None


@runtime_checkable
class PartitionStrategy(Protocol):
    """Structural protocol — anything with these hooks plugs into the system."""

    name: str

    def init(self, graph: Graph, k: int) -> jax.Array: ...

    def place(self, delta: GraphDelta, ctx: StrategyContext) -> jax.Array: ...

    def adapt(self, graph: Graph, state: PartitionState,
              ctx: StrategyContext) -> PartitionState: ...


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., "StrategyBase"]] = {}
_CANONICAL: list = []          # primary names only, registration order


def register_strategy(name: str, *aliases: str
                      ) -> Callable[[Callable[..., Any]], Callable[..., Any]]:
    """Class decorator: register a strategy factory under ``name`` (+aliases)."""

    def deco(factory: Callable[..., Any]) -> Callable[..., Any]:
        for key in (name, *aliases):
            if key in _REGISTRY:
                raise ValueError(f"strategy name {key!r} already registered")
            _REGISTRY[key] = factory
        _CANONICAL.append(name)
        return factory

    return deco


def strategy_names() -> Tuple[str, ...]:
    """Every registered name, aliases included, sorted."""
    return tuple(sorted(_REGISTRY))


def canonical_strategy_names() -> Tuple[str, ...]:
    """Each registered strategy exactly once — primary names, no aliases,
    sorted. "Run every strategy" loops (the arena, the conformance suite)
    iterate this; ``strategy_names()`` would silently run ``hash`` again as
    ``hsh``, ``xdgp`` again as ``adaptive``, and so on."""
    return tuple(sorted(_CANONICAL))


def resolve_strategy(spec: Any, **kwargs: Any) -> "StrategyBase":
    """Turn a registry name, strategy class, or instance into an instance.

    Unknown names raise a ``ValueError`` that lists the registered names —
    a typo should cost seconds, not a debugging session.
    """
    if isinstance(spec, str):
        try:
            factory = _REGISTRY[spec]
        except KeyError:
            raise ValueError(
                f"unknown partition strategy {spec!r}; registered strategies: "
                f"{', '.join(strategy_names())}") from None
        return factory(**kwargs)
    if isinstance(spec, type):
        return spec(**kwargs)
    if kwargs:
        raise TypeError(f"cannot apply kwargs {sorted(kwargs)} to an already-"
                        f"constructed strategy instance {spec!r}")
    return spec


# ---------------------------------------------------------------------------
# Concrete strategies
# ---------------------------------------------------------------------------

class StrategyBase:
    """Default behaviour: hash init, arrivals inherit their padded-slot
    label, and no adaptation. Subclasses override the hooks they care about.

    ``adapts`` declares that the strategy's adaptation hooks do real
    migration work (the session uses it for telemetry and drift triggers).
    ``cluster_native`` additionally declares that those hooks implement the
    xDGP deferred-commit step — the one the sharded backend's cluster
    engine reproduces — so the backend may replace them with its SPMD
    migrator. Rival migrators (spinner/sdp/restream) set ``adapts=True``
    but stay ``cluster_native=False``: under a sharded session they run
    their own local hooks on the gathered arrays.
    """

    name = "base"
    adapts = False                 # True → adapt/converge run migrations
    cluster_native = False         # True → sharded backend may take over adapt

    def init(self, graph: Graph, k: int) -> jax.Array:
        return hash_partition(graph, k)

    def place(self, delta: GraphDelta, ctx: StrategyContext) -> jax.Array:
        return ctx.assignment

    def adapt(self, graph: Graph, state: PartitionState,
              ctx: StrategyContext) -> PartitionState:
        return state

    def converge(self, graph: Graph, state: PartitionState,
                 ctx: StrategyContext) -> Tuple[PartitionState, History]:
        return state, History.empty()

    def adapt_rounds(self, graph: Graph, state: PartitionState, iters: int,
                     ctx: StrategyContext) -> Tuple[PartitionState, History]:
        return state, History.empty()

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


@register_strategy("static")
class Static(StrategyBase):
    """The no-op baseline: hash init, inherited placement, zero adaptation.
    Swapping ``xdgp`` for ``static`` in ``SystemConfig.partition.strategy``
    is the paper's adaptive-vs-static-hash comparison."""

    name = "static"


@register_strategy("hash", "hsh")
class Hash(StrategyBase):
    """HSH: H(v) mod k (paper §5.2.1) — the de-facto standard; scatters."""

    name = "hash"


@register_strategy("random", "rnd")
class Random(StrategyBase):
    """RND: balanced pseudorandom assignment."""

    name = "random"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def init(self, graph: Graph, k: int) -> jax.Array:
        return random_partition(graph, k, seed=self.seed)


@register_strategy("modulo", "mod")
class Modulo(StrategyBase):
    """v mod k without mixing — keeps sequential locality; for ablations."""

    name = "modulo"

    def init(self, graph: Graph, k: int) -> jax.Array:
        return modulo_partition(graph, k)


@register_strategy("block", "blk")
class Block(StrategyBase):
    """Contiguous id blocks (what a range-sharded store would do)."""

    name = "block"

    def init(self, graph: Graph, k: int) -> jax.Array:
        return block_partition(graph, k)


@register_strategy("dgr")
class Dgr(StrategyBase):
    """DGR: Stanton & Kliot linear deterministic greedy (streaming init)."""

    name = "dgr"

    def __init__(self, slack: float = 0.1):
        self.slack = slack

    def init(self, graph: Graph, k: int) -> jax.Array:
        return deterministic_greedy(graph, k, slack=self.slack)


@register_strategy("mnn")
class Mnn(StrategyBase):
    """MNN: minimum number of neighbours (Prabhakaran et al., streaming init)."""

    name = "mnn"

    def __init__(self, slack: float = 0.1):
        self.slack = slack

    def init(self, graph: Graph, k: int) -> jax.Array:
        return min_neighbours(graph, k, slack=self.slack)


@register_strategy("fennel", "online")
class OnlineFennel(StrategyBase):
    """Online Fennel/DGR placement of arriving vertices, no adaptation.

    score(v, j) = |N(v) ∩ P_j| · (1 − occ_j / C_j), computed from the
    delta's own edges only — one fused jit program (see
    ``repro.stream.placement``).
    """

    name = "fennel"

    def __init__(self, passes: Optional[int] = None):
        self.passes = passes            # None = take ctx.placement_passes

    def place(self, delta: GraphDelta, ctx: StrategyContext) -> jax.Array:
        passes = self.passes if self.passes is not None else ctx.placement_passes
        labels, stats = place_delta(
            delta, ctx.node_mask, ctx.assignment, ctx.occupancy,
            ctx.capacity, ctx.rng, k=ctx.k, passes=passes)
        ctx.placed = int(stats.placed)
        return labels


@register_strategy("xdgp", "adaptive")
class XdgpAdaptive(OnlineFennel):
    """The full xDGP policy: online placement of arrivals + interleaved
    greedy vertex migration (paper §3), run to convergence on demand.

    ``placement="inherit"`` keeps arrivals on their padded-slot hash label
    (the seed behaviour) while still adapting — useful for ablating what
    online placement itself buys.
    """

    name = "xdgp"
    adapts = True
    cluster_native = True

    def __init__(self, placement: str = "online", passes: Optional[int] = None):
        if placement not in ("online", "inherit"):
            raise ValueError(f"placement must be 'online' or 'inherit', "
                             f"got {placement!r}")
        super().__init__(passes=passes)
        self.placement = placement
        self._adapt_cache: Dict[Tuple[float, int, str], Callable] = {}

    def place(self, delta: GraphDelta, ctx: StrategyContext) -> jax.Array:
        if self.placement == "inherit":
            return ctx.assignment
        return super().place(delta, ctx)

    def _plan(self, graph: Graph, backend: str):
        """Pre-pack the adjacency for the fused scorer (batch modes only).

        Streaming ``adapt`` passes ``plan=None`` — the packing-free flat
        plan — because the graph changes every superstep and a host-side
        repack per superstep would cost more than it saves. The batch
        drivers (``converge``/``adapt_rounds``) run many iterations over a
        fixed graph, so one pack amortises across all of them.
        """
        if backend != "pallas":
            return None
        from repro.kernels.migration_kernels import build_plan
        return build_plan(graph)

    def adapt(self, graph: Graph, state: PartitionState,
              ctx: StrategyContext) -> PartitionState:
        backend = resolve_backend(ctx.backend)
        key = (ctx.s, ctx.adapt_iters, ctx.tie_break, backend)
        fn = self._adapt_cache.get(key)
        if fn is None:
            s, iters, tie_break, bk = key
            fn = jax.jit(lambda g, st: adapt_jit(g, st, s=s, iters=iters,
                                                 tie_break=tie_break,
                                                 backend=bk))
            self._adapt_cache[key] = fn
        return fn(graph, state)

    def converge(self, graph: Graph, state: PartitionState,
                 ctx: StrategyContext) -> Tuple[PartitionState, History]:
        backend = resolve_backend(ctx.backend)
        return run_to_convergence(
            graph, state, s=ctx.s, patience=ctx.patience,
            max_iters=ctx.max_iters, tie_break=ctx.tie_break,
            rel_tol=ctx.rel_tol, record_history=ctx.record_history,
            backend=backend, plan=self._plan(graph, backend))

    def adapt_rounds(self, graph: Graph, state: PartitionState, iters: int,
                     ctx: StrategyContext) -> Tuple[PartitionState, History]:
        backend = resolve_backend(ctx.backend)
        return adapt_rounds(graph, state, iters, s=ctx.s,
                            tie_break=ctx.tie_break,
                            record_history=ctx.record_history,
                            backend=backend, plan=self._plan(graph, backend))


def _maybe_plan(graph: Graph, backend: str):
    """Pre-pack the adjacency for the fused scorer when the pallas backend
    is selected (batch drivers only — see ``XdgpAdaptive._plan``)."""
    if backend != "pallas":
        return None
    from repro.kernels.migration_kernels import build_plan
    return build_plan(graph)


@register_strategy("spinner", "lpa")
class Spinner(StrategyBase):
    """Spinner-style balanced label propagation (arXiv 1404.3861).

    Iterative LPA with an additive free-capacity bonus, Bernoulli(s)
    damping and deterministic free-capacity admission — see
    ``repro.core.spinner``. Spinner is a *batch* repartitioner: arrivals
    inherit their slot label (the paper restreams periodically rather than
    placing online), and every adaptation hook runs balanced-LPA sweeps.
    Shares the fused BSR histogram kernels with xDGP when the pallas
    scoring backend is selected.
    """

    name = "spinner"
    adapts = True

    def __init__(self, balance_weight: float = 0.5):
        self.balance_weight = balance_weight
        self._adapt_cache: Dict[Tuple[float, float, int, str], Callable] = {}

    def _step_fn(self, graph: Graph, ctx: StrategyContext, backend: str):
        plan = _maybe_plan(graph, backend)
        return lambda st: spinner_step(st, graph, plan,
                                       balance_weight=self.balance_weight,
                                       s=ctx.s, backend=backend)

    def adapt(self, graph: Graph, state: PartitionState,
              ctx: StrategyContext) -> PartitionState:
        backend = resolve_backend(ctx.backend)
        key = (self.balance_weight, ctx.s, ctx.adapt_iters, backend)
        fn = self._adapt_cache.get(key)
        if fn is None:
            w, s, iters, bk = key
            fn = jax.jit(lambda g, st: spinner_adapt_jit(
                g, st, iters=iters, balance_weight=w, s=s, backend=bk))
            self._adapt_cache[key] = fn
        return fn(graph, state)

    def converge(self, graph: Graph, state: PartitionState,
                 ctx: StrategyContext) -> Tuple[PartitionState, History]:
        backend = resolve_backend(ctx.backend)
        return run_to_convergence(
            graph, state, patience=ctx.patience, max_iters=ctx.max_iters,
            tie_break=ctx.tie_break, rel_tol=ctx.rel_tol,
            record_history=ctx.record_history,
            step_fn=self._step_fn(graph, ctx, backend))

    def adapt_rounds(self, graph: Graph, state: PartitionState, iters: int,
                     ctx: StrategyContext) -> Tuple[PartitionState, History]:
        backend = resolve_backend(ctx.backend)
        return adapt_rounds(graph, state, iters,
                            record_history=ctx.record_history,
                            step_fn=self._step_fn(graph, ctx, backend))


@register_strategy("sdp")
class Sdp(OnlineFennel):
    """SDP-style scalable real-time dynamic placement (arXiv 2110.15669).

    Online Fennel placement of arrivals (inherited) plus a boundary-only
    strict-improvement refinement sweep per adaptation call — see
    ``repro.core.sdp``. Cheap by construction: only cut-boundary vertices
    reconsider, and only on a strict greedy·balance gain.
    """

    name = "sdp"
    adapts = True

    def __init__(self, passes: Optional[int] = None):
        super().__init__(passes=passes)
        self._adapt_cache: Dict[Tuple[float, int, str], Callable] = {}

    def _step_fn(self, graph: Graph, ctx: StrategyContext, backend: str):
        plan = _maybe_plan(graph, backend)
        return lambda st: sdp_refine_step(st, graph, plan, s=ctx.s,
                                          backend=backend)

    def adapt(self, graph: Graph, state: PartitionState,
              ctx: StrategyContext) -> PartitionState:
        backend = resolve_backend(ctx.backend)
        key = (ctx.s, ctx.adapt_iters, backend)
        fn = self._adapt_cache.get(key)
        if fn is None:
            s, iters, bk = key
            fn = jax.jit(lambda g, st: sdp_adapt_jit(g, st, iters=iters,
                                                     s=s, backend=bk))
            self._adapt_cache[key] = fn
        return fn(graph, state)

    def converge(self, graph: Graph, state: PartitionState,
                 ctx: StrategyContext) -> Tuple[PartitionState, History]:
        backend = resolve_backend(ctx.backend)
        return run_to_convergence(
            graph, state, patience=ctx.patience, max_iters=ctx.max_iters,
            tie_break=ctx.tie_break, rel_tol=ctx.rel_tol,
            record_history=ctx.record_history,
            step_fn=self._step_fn(graph, ctx, backend))

    def adapt_rounds(self, graph: Graph, state: PartitionState, iters: int,
                     ctx: StrategyContext) -> Tuple[PartitionState, History]:
        backend = resolve_backend(ctx.backend)
        return adapt_rounds(graph, state, iters,
                            record_history=ctx.record_history,
                            step_fn=self._step_fn(graph, ctx, backend))


@register_strategy("restream", "lemerrer")
class Restream(OnlineFennel):
    """Le Merrer-style restreaming repartitioning (arXiv 1310.8211),
    layered on the online Fennel placement path.

    Arrivals are placed online (inherited); each adaptation call replays
    one sequential restreaming pass over the whole live graph with the
    same greedy·balance rule, seeded by the current assignment — see
    ``repro.core.restream``. ``period`` runs the (host-side, O(V+E)) pass
    every Nth ``adapt`` call on this instance; the default restreams every
    superstep. ``converge`` repeats passes until one moves nothing (a pass
    fixpoint is stable, so further passes are provably no-ops).
    """

    name = "restream"
    adapts = True

    def __init__(self, passes: Optional[int] = None, period: int = 1):
        if period < 1:
            raise ValueError(f"period must be >= 1, got {period}")
        super().__init__(passes=passes)
        self.period = period
        self._calls = 0

    def adapt(self, graph: Graph, state: PartitionState,
              ctx: StrategyContext) -> PartitionState:
        self._calls += 1
        if (self._calls - 1) % self.period:
            return state
        state, _ = restream_state(state, graph)
        return state

    def _record(self, hist: History, graph: Graph, state: PartitionState,
                moved: int, record: bool) -> None:
        if record:
            hist.cut_ratio.append(float(cut_ratio(graph, state.assignment)))
            hist.migrations.append(moved)
            hist.willing.append(moved)
            hist.imbalance.append(float(imbalance(state, graph.node_mask)))

    def converge(self, graph: Graph, state: PartitionState,
                 ctx: StrategyContext) -> Tuple[PartitionState, History]:
        hist = History.empty()
        for _ in range(ctx.max_iters):
            state, stats = restream_state(state, graph)
            moved = int(stats.committed)
            self._record(hist, graph, state, moved, ctx.record_history)
            if moved == 0:
                break
        return state, hist

    def adapt_rounds(self, graph: Graph, state: PartitionState, iters: int,
                     ctx: StrategyContext) -> Tuple[PartitionState, History]:
        hist = History.empty()
        for _ in range(iters):
            state, stats = restream_state(state, graph)
            self._record(hist, graph, state, int(stats.committed),
                         ctx.record_history)
        return state, hist
