"""Pluggable execution backends: where does a session's adaptation run?

The ``PartitionStrategy`` decides *what* the heuristic does; the
``ExecutionBackend`` decides *where* it executes (DESIGN.md §10):

  local    — on-host, delegating straight to the strategy hooks (the
             single-process path every session used before this layer).
  sharded  — partition-per-device SPMD through the cluster engine in
             ``core.distributed``: labels travel by boundary-segment halo
             exchange, capacity by an O(k) psum, and quota ranking by a
             globally-ordered gather — with assignments bit-identical to
             the local path (pinned by the cluster parity suite), plus
             per-device halo/collective byte counters so "cut == comm
             volume" is measurable from the session.

Backends register under a name, exactly like strategies; ``SystemConfig``
selects one via ``cluster.backend`` and ``DynamicGraphSystem.distribute()``
/ ``.gather()`` move a live session between them.

Example — resolve backends from the registry (doctested in CI):

    >>> from repro.api import (ClusterSection, execution_backend_names,
    ...                        resolve_execution_backend)
    >>> execution_backend_names()
    ('local', 'sharded')
    >>> resolve_execution_backend("local").name
    'local'
    >>> cl = ClusterSection(backend="sharded", devices=4)
    >>> resolve_execution_backend("sharded", cluster=cl).cluster.devices
    4
    >>> try:
    ...     resolve_execution_backend("shardedd")
    ... except ValueError as e:
    ...     "execution backends" in str(e)
    True
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import ClusterSection
from repro.api.strategy import StrategyContext
from repro.core.distributed import (BlockLayout, DistGraph,
                                    build_cluster_graph, comm_model,
                                    layout_device_arrays, make_cluster_step)
from repro.core.migration import MigrationStats, flush_pending
from repro.core.partition_state import PartitionState
from repro.core.repartitioner import History
from repro.core.repartitioner import adapt_rounds as _adapt_rounds
from repro.core.repartitioner import run_to_convergence as _run_to_convergence
from repro.graph.structure import Graph
from repro.obs.trace import NULL_TRACER


@runtime_checkable
class ExecutionBackend(Protocol):
    """Structural protocol — anything with these hooks executes a session.

    The three execution hooks mirror the strategy surface the session
    drives (interleaved ``adapt`` per superstep, batch ``converge`` /
    ``adapt_rounds``); the two telemetry hooks feed the session's comm
    counters. A backend receives the *strategy* so non-migrating policies
    can stay on their (free) local hooks.
    """

    name: str

    def adapt(self, strategy: Any, graph: Graph, state: PartitionState,
              ctx: StrategyContext) -> PartitionState: ...

    def converge(self, strategy: Any, graph: Graph, state: PartitionState,
                 ctx: StrategyContext) -> Tuple[PartitionState, History]: ...

    def adapt_rounds(self, strategy: Any, graph: Graph, state: PartitionState,
                     iters: int, ctx: StrategyContext,
                     ) -> Tuple[PartitionState, History]: ...

    def pop_superstep_comm(self) -> Dict[str, int]: ...

    def device_stats(self) -> Optional[Dict[str, Any]]: ...


# ---------------------------------------------------------------------------
# Registry (same contract as the strategy registry: fail loudly on typos)
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., Any]] = {}


def register_execution_backend(name: str, *aliases: str
                               ) -> Callable[[Callable[..., Any]],
                                             Callable[..., Any]]:
    """Class decorator: register a backend factory under ``name`` (+aliases)."""

    def deco(factory: Callable[..., Any]) -> Callable[..., Any]:
        for key in (name, *aliases):
            if key in _REGISTRY:
                raise ValueError(f"execution backend {key!r} already registered")
            _REGISTRY[key] = factory
        return factory

    return deco


def execution_backend_names() -> Tuple[str, ...]:
    """Every registered backend name, sorted."""
    return tuple(sorted(_REGISTRY))


def resolve_execution_backend(spec: Any,
                              cluster: Optional[ClusterSection] = None) -> Any:
    """Turn a registry name, backend class, or instance into an instance."""
    if isinstance(spec, str):
        try:
            factory = _REGISTRY[spec]
        except KeyError:
            raise ValueError(
                f"unknown execution backend {spec!r}; registered execution "
                f"backends: {', '.join(execution_backend_names())}") from None
        return factory(cluster=cluster)
    if isinstance(spec, type):
        return spec(cluster=cluster)
    return spec


_ZERO_COMM = {"halo_bytes": 0, "halo_live_bytes": 0, "collective_bytes": 0}


def _graph_fingerprint(graph: Graph) -> Tuple[int, ...]:
    """Cheap content fingerprint of a ``Graph``'s live topology.

    Object identity is not enough to decide whether the device bucketing is
    stale: a caller can mutate a numpy-backed ``Graph`` in place, and the
    streaming path hands over a *new* object every superstep even when the
    delta was empty. An order-sensitive polynomial hash over the live edge
    endpoints and live node ids (int64, wraparound) catches both — O(E)
    numpy, far below the bucketing cost it gates.
    """
    nm = np.asarray(graph.node_mask)
    em = np.asarray(graph.edge_mask)
    s = np.asarray(graph.src)[em].astype(np.int64)
    d = np.asarray(graph.dst)[em].astype(np.int64)
    ei = np.flatnonzero(em).astype(np.int64)
    ni = np.flatnonzero(nm).astype(np.int64)
    with np.errstate(over="ignore"):
        h_edges = int(((s * 0x9E3779B1 + d * 0x85EBCA77)
                       * (ei + 0xC2B2AE3D)).sum()) & (2 ** 63 - 1)
        h_nodes = int((ni * 0x27D4EB2F + 1).sum()) & (2 ** 63 - 1)
    return (nm.shape[0], int(nm.sum()), int(em.sum()), h_edges, h_nodes)


@register_execution_backend("local")
class LocalBackend:
    """On-host execution: straight delegation to the strategy hooks."""

    name = "local"
    # the session re-points these at its own tracer/config (DESIGN.md §11);
    # a directly-constructed backend stays on the no-op defaults
    tracer: Any = NULL_TRACER
    comm_probe = False

    def __init__(self, cluster: Optional[ClusterSection] = None):
        self.cluster = cluster if cluster is not None else ClusterSection()

    def adapt(self, strategy, graph, state, ctx):
        with self.tracer.span("kernel/score_select",
                              iters=ctx.adapt_iters) as sp:
            state = strategy.adapt(graph, state, ctx)
            sp.fence(state.assignment)
        return state

    def converge(self, strategy, graph, state, ctx):
        return strategy.converge(graph, state, ctx)

    def adapt_rounds(self, strategy, graph, state, iters, ctx):
        return strategy.adapt_rounds(graph, state, iters, ctx)

    def pop_superstep_comm(self) -> Dict[str, int]:
        return dict(_ZERO_COMM)

    def device_stats(self) -> Optional[Dict[str, Any]]:
        return None

    def invalidate(self) -> None:
        pass

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


@register_execution_backend("sharded")
class ShardedBackend:
    """Partition-per-device SPMD execution over the cluster engine.

    The session keeps its canonical arrays in slot order; this backend
    buckets the graph into device blocks (``build_cluster_graph``, rebuilt
    only when the graph's content fingerprint changes, with padded bucket
    shapes that survive streaming growth), runs the parity migrator under
    ``shard_map``, and maps assignments back. Compiled steps take the
    bucketing as jit *arguments* and are cached per shape signature, so a
    shape-stable rebuild costs zero recompiles — the ``cluster/recompile``
    span fires only on genuine shape growth. Only strategies flagged
    ``cluster_native`` (the xDGP migrator — the deferred-commit step the
    cluster engine implements) route through it; everything else —
    non-adapting baselines *and* rival migrators (spinner/sdp/restream)
    with different step semantics — falls through to its local hooks.

    Decision parity with the local path is exact — same RNG draws, same
    quota order — so ``distribute()``/``gather()`` can move a session
    mid-run without perturbing its trajectory.
    """

    name = "sharded"
    tracer: Any = NULL_TRACER
    comm_probe = False                # timed comm mirrors (telemetry knob)

    def __init__(self, cluster: Optional[ClusterSection] = None):
        self.cluster = (cluster if cluster is not None
                        else ClusterSection(backend="sharded"))
        self._mesh: Optional[jax.sharding.Mesh] = None
        self._mesh_devices = 0
        self._graph_ref: Optional[Graph] = None
        self._graph_fp: Optional[Tuple[int, ...]] = None
        self._dg: Optional[DistGraph] = None
        self._layout: Optional[BlockLayout] = None
        self._comm: Optional[Dict[str, Any]] = None
        self._mig_args: Optional[Tuple[Any, ...]] = None
        # compiled cluster steps keyed by shape signature
        # (P, n_blk, B, E, n_cap, k, tie_break): a streaming rebuild whose
        # padded bucket shapes hold dispatches into the cached executable
        self._migrators: Dict[Tuple[Any, ...], Any] = {}
        self._probed = False
        self._superstep_comm = dict(_ZERO_COMM)
        self._total_comm = dict(_ZERO_COMM)
        self._total_iterations = 0

    # -- mesh / bucketing lifecycle ----------------------------------------
    def required_devices(self, k: int) -> int:
        """Device count this backend will run ``k`` partitions on."""
        P = self.cluster.devices or k
        if P != k:
            raise ValueError(
                f"sharded backend is partition-per-device: cluster.devices "
                f"({P}) must equal partition.k ({k}) or be 0")
        avail = len(jax.devices())
        if P > avail:
            raise RuntimeError(
                f"sharded backend needs {P} devices but only {avail} are "
                f"visible; on CPU hosts launch with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={P}")
        return P

    def invalidate(self) -> None:
        """Drop bucketing/mesh caches (k-change, restore); totals survive."""
        self._mesh = None
        self._mesh_devices = 0
        self._graph_ref = None
        self._graph_fp = None
        self._dg = self._layout = self._comm = None
        self._mig_args = None
        self._migrators.clear()
        self._probed = False

    def _ensure(self, graph: Graph, state: PartitionState,
                ctx: StrategyContext) -> None:
        P = self.required_devices(ctx.k)
        if self._mesh is None or self._mesh_devices != P:
            devs = np.asarray(jax.devices()[:P])
            self._mesh = jax.sharding.Mesh(devs, (self.cluster.axis,))
            self._mesh_devices = P
            # block shapes and compiled executables are mesh-bound
            self._graph_ref = None
            self._graph_fp = None
            self._dg = self._layout = self._comm = None
            self._mig_args = None
            self._migrators.clear()
        fp = _graph_fingerprint(graph)
        if self._dg is not None and fp == self._graph_fp:
            # same live topology (identical object, an in-place no-op, or a
            # quiet streaming superstep): the bucketing is still valid
            self._graph_ref = graph
            return
        # host-side bucketing (runs on every topology change); previous
        # shapes are passed as floors so a rebuild keeps them unless the
        # graph genuinely outgrew a bucket — the compiled step stays hot
        with self.tracer.span("cluster/bucket", devices=P) as sp:
            if self._dg is None:
                floors = {}
            else:
                floors = {"min_block": self._dg.block_size,
                          "min_edges": int(self._dg.src_owner.shape[1]),
                          "min_halo": self._dg.halo_size}
            dg, self._layout = build_cluster_graph(
                graph, np.asarray(state.assignment), P,
                halo_pad=self.cluster.halo_pad,
                block_pad=self.cluster.block_pad,
                edge_pad=self.cluster.edge_pad, **floors)
            self._comm = comm_model(dg, ctx.k)
            # pin device placement once per rebuild: every dispatch then
            # sees identically-sharded avals (stable jit cache key)
            shard = jax.sharding.NamedSharding(
                self._mesh, jax.sharding.PartitionSpec(self.cluster.axis))
            repl = jax.sharding.NamedSharding(
                self._mesh, jax.sharding.PartitionSpec())
            self._dg = jax.device_put(dg, shard)
            blk_live, orig, ng_safe, slot_live = layout_device_arrays(
                self._layout)
            self._mig_args = (self._dg,
                              jax.device_put(blk_live, shard),
                              jax.device_put(orig, shard),
                              jax.device_put(ng_safe, repl),
                              jax.device_put(slot_live, repl))
            sp.set(halo_slots=self._dg.halo_size,
                   block=self._dg.block_size)
        self._graph_ref = graph
        self._graph_fp = fp

    def _charge(self, iters: int = 1) -> None:
        c = self._comm
        P = c["devices"]
        halo = iters * P * c["halo_bytes_per_device"]
        live = iters * P * c["halo_live_bytes_per_device"]
        coll = iters * P * c["collective_bytes_per_device"]
        for acc in (self._superstep_comm, self._total_comm):
            acc["halo_bytes"] += halo
            acc["halo_live_bytes"] += live
            acc["collective_bytes"] += coll
        self._total_iterations += iters

    def _sig(self, ctx: StrategyContext) -> Tuple[Any, ...]:
        """Shape signature a compiled cluster step is keyed by."""
        dg = self._dg
        return (dg.num_devices, dg.block_size, dg.halo_size,
                int(dg.src_owner.shape[1]), self._layout.n_cap,
                ctx.k, ctx.tie_break)

    def _migrator(self, ctx: StrategyContext,
                  state: Optional[PartitionState] = None):
        """The compiled step for the current shapes — built (and, given a
        state, compile-warmed) at most once per shape signature. The
        ``cluster/recompile`` span fires only here: on first use and on
        genuine shape growth past the padded buckets, never on a
        shape-stable streaming rebuild."""
        key = self._sig(ctx)
        mig = self._migrators.get(key)
        if mig is None:
            with self.tracer.span("cluster/recompile", devices=key[0],
                                  block=key[1], halo_slots=key[2],
                                  edge_bucket=key[3], n_cap=key[4]) as sp:
                mig = make_cluster_step(self._mesh, k=ctx.k,
                                        n_cap=self._layout.n_cap,
                                        tie_break=ctx.tie_break,
                                        axis=self.cluster.axis)
                if state is not None:
                    # warm the executable inside the span (pure: the result
                    # is discarded, no comm is charged) so the span, not the
                    # first dispatch, carries the compile cost
                    out = mig(state.assignment, state.pending, state.rng,
                              state.capacity, ctx.s, *self._mig_args)
                    sp.fence(out[0])
            self._migrators[key] = mig
        return mig

    def _step_fn(self, graph: Graph, ctx: StrategyContext,
                 unshard_each: bool = False,
                 state: Optional[PartitionState] = None):
        """state -> (state, MigrationStats) over the cluster engine, in the
        session's canonical slot order (plugs into the shared drivers).
        The migrator handles the slot↔block permutation on device, so one
        iteration is one jit dispatch — no host round-trips.

        ``unshard_each`` places every returned state back on the default
        device: the batch drivers interleave the step with single-device
        jits (cut history, flush) that must not see this mesh's sharding.
        The streaming ``adapt`` loop keeps the state mesh-resident instead
        and unshards once at the end."""
        mig = self._migrator(ctx, state)
        mig_args = self._mig_args
        s = ctx.s

        def step(state: PartitionState):
            a, p, rng, (committed, willing, admitted) = mig(
                state.assignment, state.pending, state.rng, state.capacity,
                s, *mig_args)
            self._charge(1)
            new_state = PartitionState(
                assignment=a, pending=p, capacity=state.capacity, rng=rng,
                iteration=state.iteration + 1, last_moves=committed)
            if unshard_each:
                new_state = self._unshard(new_state)
            return new_state, MigrationStats(committed=committed,
                                             willing=willing,
                                             admitted=admitted)

        return step

    @staticmethod
    def _unshard(state: PartitionState) -> PartitionState:
        """Place the final state back on the default device: the session's
        own jits (tracker updates, vertex program) must not inherit this
        mesh's sharding — it may be gone after a gather()/rescale()."""
        return jax.device_put(state, jax.devices()[0])

    # -- comm probe (DESIGN.md §11) ----------------------------------------
    def _probe_comm(self, state, ctx) -> None:
        """Attribute one migrator iteration to named comm phases.

        The halo exchange and the packed-key quota collective live *inside*
        one jit'd shard_map program, so they cannot be host-timed in situ.
        Instead, tiny jits mirroring exactly those collectives (same shapes,
        same mesh) are timed with fences — min of 3 reps after a compile
        warmup — alongside one full migrator iteration (pure function,
        results discarded: the session trajectory is untouched).  The
        decomposition enters the trace as synthetic spans:

          comm/halo_exchange     boundary-segment all_gather
          comm/quota_collective  packed-key all_gather + global sort
          kernel/score           residual (scoring + decide + damp + commit)

        Probes run ONCE per session (first adapt after enabling): the
        streaming path rebuilds the bucketing every superstep, and
        re-compiling the probe jits each time would dominate the very wall
        time the trace is meant to attribute.  The probe's own cost
        (compiles + reps) is visible as an ``obs/comm_probe`` span.
        """
        mesh, dg, axis = self._mesh, self._dg, self.cluster.axis
        from repro.compat import shard_map
        spec_n = jax.sharding.PartitionSpec(axis)
        dg_specs = DistGraph(*([spec_n] * 8))
        rep = jax.sharding.PartitionSpec()
        P, n_blk = dg.num_devices, dg.block_size

        @jax.jit
        def halo_probe(flat):
            f = shard_map(
                lambda lf, dgl: jax.lax.all_gather(
                    jnp.where(dgl.boundary_ok[0], lf[dgl.boundary[0]], 0),
                    axis, tiled=True),
                mesh=mesh, in_specs=(spec_n, dg_specs), out_specs=rep)
            return f(flat, dg)

        @jax.jit
        def quota_probe(keys):
            f = shard_map(
                lambda kb: jnp.sort(jax.lax.all_gather(kb, axis,
                                                       tiled=True)),
                mesh=mesh, in_specs=(spec_n,), out_specs=rep)
            return f(keys)

        @jax.jit
        def null_probe(x):
            # dispatch floor: a do-nothing shard_map of the same shape —
            # subtracted so the probes report collective cost, not the
            # per-dispatch overhead every tiny jit pays
            f = shard_map(lambda xb: xb + 1, mesh=mesh, in_specs=(spec_n,),
                          out_specs=spec_n)
            return f(x)

        def best_of(fn, *a, reps: int = 3) -> float:
            jax.block_until_ready(fn(*a))           # compile warmup
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*a))
                best = min(best, time.perf_counter() - t0)
            return best

        iters_before = self._total_iterations
        with self.tracer.span("obs/comm_probe", devices=P):
            flat = jnp.zeros((P * n_blk,), jnp.int32)
            t_null = best_of(null_probe, flat)
            raw_halo = best_of(halo_probe, flat)
            raw_quota = best_of(quota_probe, flat)
            t_halo = max(raw_halo - t_null, 0.0)
            t_quota = max(raw_quota - t_null, 0.0)
            mig_step = self._step_fn(self._graph_ref, ctx, state=state)

            def full_iter():
                s2, _ = mig_step(state)             # pure: result discarded
                return s2.assignment

            t_full = best_of(full_iter)
        # the probe's _charge() calls are rolled back exactly (counted, not
        # hard-coded to best_of's rep count) — the probe must not inflate
        # the session's comm telemetry
        self._charge(-(self._total_iterations - iters_before))
        residual = max(t_full - t_null - t_halo - t_quota, 0.0)
        tr = self.tracer
        tr.add_span("comm/halo_exchange", t_halo, probed=True,
                    halo_slots=dg.halo_size, raw_s=raw_halo,
                    dispatch_floor_s=t_null)
        tr.add_span("comm/quota_collective", t_quota, probed=True,
                    raw_s=raw_quota, dispatch_floor_s=t_null)
        tr.add_span("kernel/score", residual, probed=True,
                    full_iter_s=t_full)

    # -- execution hooks ----------------------------------------------------
    def adapt(self, strategy, graph, state, ctx):
        if not getattr(strategy, "cluster_native", False):
            return strategy.adapt(graph, state, ctx)
        self._ensure(graph, state, ctx)
        first = self._sig(ctx) not in self._migrators
        step = self._step_fn(graph, ctx, state=state)
        tr = self.tracer
        if tr.enabled and self.comm_probe and not self._probed:
            self._probed = True
            self._probe_comm(state, ctx)
        with tr.span("cluster/dispatch", iters=ctx.adapt_iters,
                     compiled=first) as sp:
            for _ in range(ctx.adapt_iters):
                state, _ = step(state)
            sp.fence(state.assignment)
        with tr.span("cluster/host_sync") as sp:
            state = self._unshard(state)
            sp.fence(state.assignment)
        with tr.span("cluster/flush") as sp:
            state = flush_pending(state, graph)
            sp.fence(state.assignment)
        return state

    def converge(self, strategy, graph, state, ctx):
        if not getattr(strategy, "cluster_native", False):
            return strategy.converge(graph, state, ctx)
        self._ensure(graph, state, ctx)
        state, hist = _run_to_convergence(
            graph, state, s=ctx.s, patience=ctx.patience,
            max_iters=ctx.max_iters, tie_break=ctx.tie_break,
            rel_tol=ctx.rel_tol, record_history=ctx.record_history,
            step_fn=self._step_fn(graph, ctx, unshard_each=True,
                                  state=state))
        return state, hist

    def adapt_rounds(self, strategy, graph, state, iters, ctx):
        if not getattr(strategy, "cluster_native", False):
            return strategy.adapt_rounds(graph, state, iters, ctx)
        self._ensure(graph, state, ctx)
        state, hist = _adapt_rounds(
            graph, state, iters, record_history=ctx.record_history,
            step_fn=self._step_fn(graph, ctx, unshard_each=True,
                                  state=state))
        return state, hist

    # -- telemetry ----------------------------------------------------------
    def pop_superstep_comm(self) -> Dict[str, int]:
        out, self._superstep_comm = self._superstep_comm, dict(_ZERO_COMM)
        return out

    def device_stats(self) -> Optional[Dict[str, Any]]:
        """Per-device view of the comm bill (None before the first run)."""
        if self._comm is None:
            return None
        c = self._comm
        return {
            "devices": c["devices"],
            "halo_slots": c["halo_slots"],
            "boundary_live_per_device": c["boundary_live_per_device"],
            "halo_bytes_per_iter_per_device": c["halo_bytes_per_device"],
            "halo_live_bytes_per_iter_per_device":
                c["halo_live_bytes_per_device"],
            "collective_bytes_per_iter_per_device":
                c["collective_bytes_per_device"],
            "halo_bytes_total": self._total_comm["halo_bytes"],
            "halo_live_bytes_total": self._total_comm["halo_live_bytes"],
            "collective_bytes_total": self._total_comm["collective_bytes"],
            "iterations_total": self._total_iterations,
            "compiled_steps": len(self._migrators),
        }

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r} {self.cluster!r}>"
