"""Pluggable execution backends: where does a session's adaptation run?

The ``PartitionStrategy`` decides *what* the heuristic does; the
``ExecutionBackend`` decides *where* it executes (DESIGN.md §10):

  local    — on-host, delegating straight to the strategy hooks (the
             single-process path every session used before this layer).
  sharded  — partition-per-device SPMD through the cluster engine in
             ``core.distributed``: labels travel by boundary-segment halo
             exchange, capacity by an O(k) psum, and quota ranking by a
             globally-ordered gather — with assignments bit-identical to
             the local path (pinned by the cluster parity suite), plus
             per-device halo/collective byte counters so "cut == comm
             volume" is measurable from the session.

Backends register under a name, exactly like strategies; ``SystemConfig``
selects one via ``cluster.backend`` and ``DynamicGraphSystem.distribute()``
/ ``.gather()`` move a live session between them.

Example — resolve backends from the registry (doctested in CI):

    >>> from repro.api import (ClusterSection, execution_backend_names,
    ...                        resolve_execution_backend)
    >>> execution_backend_names()
    ('local', 'sharded')
    >>> resolve_execution_backend("local").name
    'local'
    >>> cl = ClusterSection(backend="sharded", devices=4)
    >>> resolve_execution_backend("sharded", cluster=cl).cluster.devices
    4
    >>> try:
    ...     resolve_execution_backend("shardedd")
    ... except ValueError as e:
    ...     "execution backends" in str(e)
    True
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Protocol, Tuple, runtime_checkable

import jax
import numpy as np

from repro.api.config import ClusterSection
from repro.api.strategy import StrategyContext
from repro.core.distributed import (BlockLayout, DistGraph,
                                    build_cluster_graph, comm_model,
                                    make_cluster_migrator)
from repro.core.migration import MigrationStats, flush_pending
from repro.core.partition_state import PartitionState
from repro.core.repartitioner import History
from repro.core.repartitioner import adapt_rounds as _adapt_rounds
from repro.core.repartitioner import run_to_convergence as _run_to_convergence
from repro.graph.structure import Graph


@runtime_checkable
class ExecutionBackend(Protocol):
    """Structural protocol — anything with these hooks executes a session.

    The three execution hooks mirror the strategy surface the session
    drives (interleaved ``adapt`` per superstep, batch ``converge`` /
    ``adapt_rounds``); the two telemetry hooks feed the session's comm
    counters. A backend receives the *strategy* so non-migrating policies
    can stay on their (free) local hooks.
    """

    name: str

    def adapt(self, strategy: Any, graph: Graph, state: PartitionState,
              ctx: StrategyContext) -> PartitionState: ...

    def converge(self, strategy: Any, graph: Graph, state: PartitionState,
                 ctx: StrategyContext) -> Tuple[PartitionState, History]: ...

    def adapt_rounds(self, strategy: Any, graph: Graph, state: PartitionState,
                     iters: int, ctx: StrategyContext,
                     ) -> Tuple[PartitionState, History]: ...

    def pop_superstep_comm(self) -> Dict[str, int]: ...

    def device_stats(self) -> Optional[Dict[str, Any]]: ...


# ---------------------------------------------------------------------------
# Registry (same contract as the strategy registry: fail loudly on typos)
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., Any]] = {}


def register_execution_backend(name: str, *aliases: str
                               ) -> Callable[[Callable[..., Any]],
                                             Callable[..., Any]]:
    """Class decorator: register a backend factory under ``name`` (+aliases)."""

    def deco(factory: Callable[..., Any]) -> Callable[..., Any]:
        for key in (name, *aliases):
            if key in _REGISTRY:
                raise ValueError(f"execution backend {key!r} already registered")
            _REGISTRY[key] = factory
        return factory

    return deco


def execution_backend_names() -> Tuple[str, ...]:
    """Every registered backend name, sorted."""
    return tuple(sorted(_REGISTRY))


def resolve_execution_backend(spec: Any,
                              cluster: Optional[ClusterSection] = None) -> Any:
    """Turn a registry name, backend class, or instance into an instance."""
    if isinstance(spec, str):
        try:
            factory = _REGISTRY[spec]
        except KeyError:
            raise ValueError(
                f"unknown execution backend {spec!r}; registered execution "
                f"backends: {', '.join(execution_backend_names())}") from None
        return factory(cluster=cluster)
    if isinstance(spec, type):
        return spec(cluster=cluster)
    return spec


_ZERO_COMM = {"halo_bytes": 0, "collective_bytes": 0}


@register_execution_backend("local")
class LocalBackend:
    """On-host execution: straight delegation to the strategy hooks."""

    name = "local"

    def __init__(self, cluster: Optional[ClusterSection] = None):
        self.cluster = cluster if cluster is not None else ClusterSection()

    def adapt(self, strategy, graph, state, ctx):
        return strategy.adapt(graph, state, ctx)

    def converge(self, strategy, graph, state, ctx):
        return strategy.converge(graph, state, ctx)

    def adapt_rounds(self, strategy, graph, state, iters, ctx):
        return strategy.adapt_rounds(graph, state, iters, ctx)

    def pop_superstep_comm(self) -> Dict[str, int]:
        return dict(_ZERO_COMM)

    def device_stats(self) -> Optional[Dict[str, Any]]:
        return None

    def invalidate(self) -> None:
        pass

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


@register_execution_backend("sharded")
class ShardedBackend:
    """Partition-per-device SPMD execution over the cluster engine.

    The session keeps its canonical arrays in slot order; this backend
    buckets the graph into device blocks (``build_cluster_graph``, rebuilt
    whenever the graph object changes — once per streaming superstep, once
    per batch call), runs the parity migrator under ``shard_map``, and maps
    assignments back. Strategies with ``adapts=False`` fall through to
    their local hooks (there is nothing to distribute).

    Decision parity with the local path is exact — same RNG draws, same
    quota order — so ``distribute()``/``gather()`` can move a session
    mid-run without perturbing its trajectory.
    """

    name = "sharded"

    def __init__(self, cluster: Optional[ClusterSection] = None):
        self.cluster = (cluster if cluster is not None
                        else ClusterSection(backend="sharded"))
        self._mesh: Optional[jax.sharding.Mesh] = None
        self._mesh_devices = 0
        self._graph_ref: Optional[Graph] = None
        self._dg: Optional[DistGraph] = None
        self._layout: Optional[BlockLayout] = None
        self._comm: Optional[Dict[str, Any]] = None
        self._migrators: Dict[Tuple[float, str], Any] = {}
        self._superstep_comm = dict(_ZERO_COMM)
        self._total_comm = dict(_ZERO_COMM)
        self._total_iterations = 0

    # -- mesh / bucketing lifecycle ----------------------------------------
    def required_devices(self, k: int) -> int:
        """Device count this backend will run ``k`` partitions on."""
        P = self.cluster.devices or k
        if P != k:
            raise ValueError(
                f"sharded backend is partition-per-device: cluster.devices "
                f"({P}) must equal partition.k ({k}) or be 0")
        avail = len(jax.devices())
        if P > avail:
            raise RuntimeError(
                f"sharded backend needs {P} devices but only {avail} are "
                f"visible; on CPU hosts launch with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={P}")
        return P

    def invalidate(self) -> None:
        """Drop bucketing/mesh caches (k-change, restore); totals survive."""
        self._mesh = None
        self._mesh_devices = 0
        self._graph_ref = None
        self._dg = self._layout = self._comm = None
        self._migrators.clear()

    def _ensure(self, graph: Graph, state: PartitionState,
                ctx: StrategyContext) -> None:
        P = self.required_devices(ctx.k)
        if self._mesh is None or self._mesh_devices != P:
            devs = np.asarray(jax.devices()[:P])
            self._mesh = jax.sharding.Mesh(devs, (self.cluster.axis,))
            self._mesh_devices = P
            self._graph_ref = None            # block size may change with P
        if self._graph_ref is not graph:
            self._dg, self._layout = build_cluster_graph(
                graph, np.asarray(state.assignment), P,
                halo_pad=self.cluster.halo_pad)
            self._comm = comm_model(self._dg, ctx.k)
            self._migrators.clear()
            self._graph_ref = graph

    def _charge(self, iters: int = 1) -> None:
        c = self._comm
        P = c["devices"]
        halo = iters * P * c["halo_bytes_per_device"]
        coll = iters * P * c["collective_bytes_per_device"]
        for acc in (self._superstep_comm, self._total_comm):
            acc["halo_bytes"] += halo
            acc["collective_bytes"] += coll
        self._total_iterations += iters

    def _step_fn(self, graph: Graph, ctx: StrategyContext,
                 unshard_each: bool = False):
        """state -> (state, MigrationStats) over the cluster engine, in the
        session's canonical slot order (plugs into the shared drivers).
        The migrator handles the slot↔block permutation on device, so one
        iteration is one jit dispatch — no host round-trips.

        ``unshard_each`` places every returned state back on the default
        device: the batch drivers interleave the step with single-device
        jits (cut history, flush) that must not see this mesh's sharding.
        The streaming ``adapt`` loop keeps the state mesh-resident instead
        and unshards once at the end."""
        key = (ctx.s, ctx.tie_break)
        mig = self._migrators.get(key)
        if mig is None:
            mig = make_cluster_migrator(self._mesh, self._dg, self._layout,
                                        ctx.k, s=ctx.s,
                                        tie_break=ctx.tie_break,
                                        axis=self.cluster.axis)
            self._migrators[key] = mig

        def step(state: PartitionState):
            a, p, rng, (committed, willing, admitted) = mig(
                state.assignment, state.pending, state.rng, state.capacity)
            self._charge(1)
            new_state = PartitionState(
                assignment=a, pending=p, capacity=state.capacity, rng=rng,
                iteration=state.iteration + 1, last_moves=committed)
            if unshard_each:
                new_state = self._unshard(new_state)
            return new_state, MigrationStats(committed=committed,
                                             willing=willing,
                                             admitted=admitted)

        return step

    @staticmethod
    def _unshard(state: PartitionState) -> PartitionState:
        """Place the final state back on the default device: the session's
        own jits (tracker updates, vertex program) must not inherit this
        mesh's sharding — it may be gone after a gather()/rescale()."""
        return jax.device_put(state, jax.devices()[0])

    # -- execution hooks ----------------------------------------------------
    def adapt(self, strategy, graph, state, ctx):
        if not getattr(strategy, "adapts", False):
            return strategy.adapt(graph, state, ctx)
        self._ensure(graph, state, ctx)
        step = self._step_fn(graph, ctx)
        for _ in range(ctx.adapt_iters):
            state, _ = step(state)
        return flush_pending(self._unshard(state), graph)

    def converge(self, strategy, graph, state, ctx):
        if not getattr(strategy, "adapts", False):
            return strategy.converge(graph, state, ctx)
        self._ensure(graph, state, ctx)
        state, hist = _run_to_convergence(
            graph, state, s=ctx.s, patience=ctx.patience,
            max_iters=ctx.max_iters, tie_break=ctx.tie_break,
            rel_tol=ctx.rel_tol, record_history=ctx.record_history,
            step_fn=self._step_fn(graph, ctx, unshard_each=True))
        return state, hist

    def adapt_rounds(self, strategy, graph, state, iters, ctx):
        if not getattr(strategy, "adapts", False):
            return strategy.adapt_rounds(graph, state, iters, ctx)
        self._ensure(graph, state, ctx)
        state, hist = _adapt_rounds(graph, state, iters,
                                    record_history=ctx.record_history,
                                    step_fn=self._step_fn(graph, ctx,
                                                          unshard_each=True))
        return state, hist

    # -- telemetry ----------------------------------------------------------
    def pop_superstep_comm(self) -> Dict[str, int]:
        out, self._superstep_comm = self._superstep_comm, dict(_ZERO_COMM)
        return out

    def device_stats(self) -> Optional[Dict[str, Any]]:
        """Per-device view of the comm bill (None before the first run)."""
        if self._comm is None:
            return None
        c = self._comm
        return {
            "devices": c["devices"],
            "halo_slots": c["halo_slots"],
            "boundary_live_per_device": c["boundary_live_per_device"],
            "halo_bytes_per_iter_per_device": c["halo_bytes_per_device"],
            "halo_live_bytes_per_iter_per_device":
                c["halo_live_bytes_per_device"],
            "collective_bytes_per_iter_per_device":
                c["collective_bytes_per_device"],
            "halo_bytes_total": self._total_comm["halo_bytes"],
            "collective_bytes_total": self._total_comm["collective_bytes"],
            "iterations_total": self._total_iterations,
        }

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r} {self.cluster!r}>"
