"""Train-step factories per model family (loss → grad → AdamW update).

The returned ``train_step(state, batch) -> (state, metrics)`` is what the
dry-run lowers and the Trainer drives. ``TrainState`` is a plain pytree so it
checkpoints/shards transparently.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.optim import (AdamWConfig, AdamWState, apply_updates, global_norm,
                         init_state, warmup_cosine)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 10_000


def make_train_state(params: Any, tcfg: TrainConfig) -> TrainState:
    return TrainState(params=params, opt=init_state(params, tcfg.optimizer))


def make_train_step(loss_fn: Callable[[Any, Dict[str, jax.Array]], jax.Array],
                    tcfg: TrainConfig,
                    donate: bool = True) -> Callable:
    """loss_fn(params, batch) -> scalar; returns jit-able train_step."""

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        lr_scale = warmup_cosine(state.opt.step, tcfg.warmup_steps,
                                 tcfg.total_steps)
        new_params, new_opt = apply_updates(state.params, grads, state.opt,
                                            tcfg.optimizer, lr_scale)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": global_norm(grads),
                   "lr_scale": lr_scale}
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_eval_step(loss_fn: Callable) -> Callable:
    def eval_step(state: TrainState, batch) -> jax.Array:
        return loss_fn(state.params, batch)
    return eval_step
