from repro.train.train_step import (TrainConfig, TrainState, make_eval_step,
                                    make_train_state, make_train_step)
from repro.train.trainer import (FailureInjector, Trainer, TrainerConfig,
                                 WorkerFailure)

__all__ = ["TrainConfig", "TrainState", "make_eval_step", "make_train_state",
           "make_train_step", "FailureInjector", "Trainer", "TrainerConfig",
           "WorkerFailure"]
