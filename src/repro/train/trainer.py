"""Training driver with checkpoint/restart fault tolerance.

Production behaviours demonstrated at laptop scale:
  * periodic async checkpointing (atomic; partial writes never restored)
  * automatic restore-on-start (resume from the latest complete step)
  * injected-failure recovery: a ``FailureInjector`` raises ``WorkerFailure``
    mid-run; the Trainer restores the last checkpoint and replays the data
    stream deterministically (data batches are pure functions of step)
  * straggler mitigation hook: per-step wall-time EMA; steps slower than
    ``straggler_factor``× the EMA are counted and surfaced in metrics — on a
    real pod this signal feeds the elastic rescaler (runtime/elastic.py)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.train.train_step import TrainState


class WorkerFailure(RuntimeError):
    """Simulated node failure."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at the given steps (once each)."""

    fail_at: tuple = ()

    def __post_init__(self):
        self._fired = set()

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise WorkerFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    max_restarts: int = 5


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 batch_at: Callable[[int], Dict[str, jax.Array]],
                 injector: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.train_step = jax.jit(train_step)
        self.batch_at = batch_at
        self.injector = injector
        self.ckpt = Checkpointer(cfg.checkpoint_dir, keep_last=cfg.keep_last)
        self.metrics_log: List[Dict[str, float]] = []
        self.restarts = 0
        self.straggler_steps = 0

    def run(self, state: TrainState, start_step: int = 0) -> TrainState:
        # resume if checkpoints exist
        latest = self.ckpt.latest_step()
        if latest is not None and latest > start_step:
            state, start_step = self.ckpt.restore(state)
        elif latest is None:
            # checkpoint the initial state so failure-before-first-checkpoint
            # restores cleanly instead of restarting on in-memory state
            self.ckpt.save(start_step, state)
        step = start_step
        ema = None
        while step < self.cfg.total_steps:
            try:
                t0 = time.perf_counter()
                if self.injector is not None:
                    self.injector.check(step)
                batch = self.batch_at(step)
                state, metrics = self.train_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                ema = dt if ema is None else 0.9 * ema + 0.1 * dt
                if dt > self.cfg.straggler_factor * ema:
                    self.straggler_steps += 1
                step += 1
                if step % self.cfg.log_every == 0 or step == self.cfg.total_steps:
                    self.metrics_log.append(
                        {"step": step, "loss": float(metrics["loss"]),
                         "grad_norm": float(metrics["grad_norm"]),
                         "sec_per_step": dt})
                if step % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step, state)
            except WorkerFailure:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = start_step           # restart from scratch
                else:
                    state, step = self.ckpt.restore(state)
        self.ckpt.wait()
        self.ckpt.save(self.cfg.total_steps, state)
        self.ckpt.wait()
        return state
