"""Decoder-only transformer LM covering the five assigned architectures.

granite-34b   : 88L MQA(kv=1) + GELU MLP
gemma2-9b     : 42L GQA(kv=8) head_dim 256, alternating local(4096)/global
                attention, attn/final logit soft-caps, sandwich norms, GeGLU
phi4-mini     : 32L GQA(kv=8) RoPE SwiGLU, tied embeddings
arctic-480b   : 35L GQA(kv=8) + [dense SwiGLU ∥ 128-expert top-2 MoE]
deepseek-v2-lite : 27L MLA(kv_lora 512) + 64-expert top-6 MoE (2 shared,
                first layer dense)

Layers are lax.scan-stacked (HLO is O(1) in depth — essential for the
512-device dry-run) with optional remat. Params are nested dicts;
``jax.eval_shape(init_params, ...)`` gives the abstract pytree the dry-run
lowers against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.mla import MLAConfig, mla_attention, mla_init
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.runtime.sharding import constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    mlp_kind: str = "swiglu"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    local_window: int = 0               # >0 enables sliding-window layers
    layer_pattern: str = "global"       # "global" | "local_global"
    post_norm: bool = False             # gemma2 sandwich norms
    embed_scale: bool = False           # gemma2 multiplies embeddings by sqrt(d)
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    moe_dense_residual: bool = False    # arctic: dense FFN + MoE summed
    moe_first_dense: int = 0            # deepseek: first N layers use dense FFN
    first_dense_dff: int = 0            # ... with this hidden size
    mla: Optional[MLAConfig] = None
    param_dtype: Any = jnp.float32
    q_chunk: int = 1024
    remat: bool = False
    loss_chunk: int = 0           # >0: chunked cross-entropy over seq (big vocab)
    unroll_layers: bool = False   # inline the layer scan (cost-analysis calibration)

    @property
    def n_scanned(self) -> int:
        return self.n_layers - self.moe_first_dense

    def layer_windows(self) -> jnp.ndarray:
        """Per-layer sliding window (0 = global)."""
        w = []
        for i in range(self.n_layers):
            local = (self.layer_pattern == "local_global") and (i % 2 == 0)
            w.append(self.local_window if local else 0)
        return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key: jax.Array, cfg: TransformerConfig, dense_override: int = 0
                ) -> Params:
    ka, km, ke = jax.random.split(key, 3)
    dt = cfg.param_dtype
    p: Params = {"ln_attn": L.rmsnorm_init(cfg.d_model, dt),
                 "ln_mlp": L.rmsnorm_init(cfg.d_model, dt)}
    if cfg.post_norm:
        p["ln_attn_post"] = L.rmsnorm_init(cfg.d_model, dt)
        p["ln_mlp_post"] = L.rmsnorm_init(cfg.d_model, dt)
    if cfg.mla is not None:
        p["attn"] = mla_init(ka, cfg.d_model, cfg.mla, dt)
    else:
        p["attn"] = L.attention_init(ka, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim, dt)
    use_moe = cfg.moe is not None and dense_override == 0
    if use_moe:
        p["moe"] = moe_init(km, cfg.d_model, cfg.moe, dt)
        if cfg.moe_dense_residual:
            p["mlp"] = L.mlp_init(ke, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dt)
    else:
        dff = dense_override or cfg.d_ff
        p["mlp"] = L.mlp_init(ke, cfg.d_model, dff, cfg.mlp_kind, dt)
    return p


def init_params(key: jax.Array, cfg: TransformerConfig) -> Params:
    k_embed, k_layers, k_dense, k_head = jax.random.split(key, 4)
    params: Params = {
        "embed": L.embed_init(k_embed, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": jax.random.normal(k_head, (cfg.d_model, cfg.vocab),
                                   cfg.param_dtype) * (cfg.d_model ** -0.5)}
    # scanned homogeneous layers
    keys = jax.random.split(k_layers, cfg.n_scanned)
    params["layers"] = jax.vmap(lambda k: _layer_init(k, cfg))(keys)
    # unscanned leading dense layers (deepseek layer 0)
    if cfg.moe_first_dense:
        dkeys = jax.random.split(k_dense, cfg.moe_first_dense)
        params["dense_layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, dense_override=cfg.first_dense_dff))(dkeys)
    return params


def param_count(cfg: TransformerConfig) -> int:
    import math
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


def active_param_count(cfg: TransformerConfig) -> int:
    """Parameters touched per token (MoE counts top_k + shared experts only)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    expert_p = 3 * cfg.d_model * cfg.moe.d_ff
    inactive = cfg.n_scanned * (e - k) * expert_p
    return total - inactive


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block(cfg: TransformerConfig, p: Params, x: jax.Array, *,
           positions: jax.Array, window: jax.Array,
           cache: Optional[Tuple] = None, cache_index=None):
    """One transformer block. Returns (x, new_cache, aux_loss)."""
    x = constrain(x, "batch", "seq_sp", None)
    h = L.rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    if cfg.mla is not None:
        attn_out, new_cache = mla_attention(
            p["attn"], h, cfg.mla, positions=positions,
            rope_theta=cfg.rope_theta, cache=cache, cache_index=cache_index,
            q_chunk=cfg.q_chunk)
    else:
        attn_out, new_cache = L.attention(
            p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, positions=positions, window=window,
            attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            cache=cache, cache_index=cache_index, q_chunk=cfg.q_chunk)
    if cfg.post_norm:
        attn_out = L.rmsnorm(p["ln_attn_post"], attn_out, cfg.norm_eps)
    x = x + attn_out

    h = L.rmsnorm(p["ln_mlp"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        moe_out, aux = moe_apply(p["moe"], h, cfg.moe)
        if cfg.moe_dense_residual and "mlp" in p:
            moe_out = moe_out + L.mlp(p["mlp"], h, cfg.mlp_kind)
        ff_out = moe_out
    else:
        ff_out = L.mlp(p["mlp"], h, cfg.mlp_kind)
    if cfg.post_norm:
        ff_out = L.rmsnorm(p["ln_mlp_post"], ff_out, cfg.norm_eps)
    return constrain(x + ff_out, "batch", "seq", None), new_cache, aux


def forward_hidden(params: Params, tokens: jax.Array, cfg: TransformerConfig
                   ) -> Tuple[jax.Array, jax.Array]:
    """Backbone forward: tokens (B,S) -> (final hidden (B,S,d), aux_loss)."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg.embed_scale).astype(cfg.param_dtype)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    windows = cfg.layer_windows()

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.moe_first_dense:
        def dense_body(carry, layer_p):
            x, aux = carry
            x, _, a = _block(cfg, layer_p, x, positions=positions,
                             window=jnp.int32(0))
            return (x, aux + a), None
        (x, aux_total), _ = jax.lax.scan(
            dense_body, (x, aux_total), params["dense_layers"])

    def body(carry, xs):
        x, aux = carry
        layer_p, window = xs
        x, _, a = _block(cfg, layer_p, x, positions=positions, window=window)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    scan_windows = windows[cfg.moe_first_dense:]
    (x, aux_total), _ = jax.lax.scan(body_fn, (x, aux_total),
                                     (params["layers"], scan_windows),
                                     unroll=cfg.n_scanned if cfg.unroll_layers else 1)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux_total


def logits_from_hidden(params: Params, x: jax.Array, cfg: TransformerConfig
                       ) -> jax.Array:
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["w"],
                            preferred_element_type=jnp.float32)
    logits = constrain(logits, "batch", "seq", "vocab")
    return L.softcap(logits, cfg.final_softcap)


def forward(params: Params, tokens: jax.Array, cfg: TransformerConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """Training forward: tokens (B,S) -> (logits (B,S,V) f32, aux_loss)."""
    x, aux = forward_hidden(params, tokens, cfg)
    return logits_from_hidden(params, x, cfg), aux


def _ce(logits: jax.Array, labels: jax.Array, mask: jax.Array
        ) -> Tuple[jax.Array, jax.Array]:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum(), mask.sum()


def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: TransformerConfig,
            aux_weight: float = 0.01) -> jax.Array:
    """Next-token cross entropy (+ MoE aux).

    With cfg.loss_chunk > 0 the (B,S,V) logits tensor is never materialised:
    the unembed + CE run chunk-by-chunk over the sequence under lax.scan with
    rematerialisation — the standard big-vocab memory optimisation.
    """
    x, aux = forward_hidden(params, batch["tokens"], cfg)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    s = labels.shape[1]
    chunk = cfg.loss_chunk
    if chunk and s % chunk == 0 and s > chunk:
        n_chunks = s // chunk

        @jax.checkpoint
        def chunk_loss(xc, yc, mc):
            logits = logits_from_hidden(params, xc, cfg)
            return _ce(logits, yc, mc)

        def body(carry, i):
            tot, cnt = carry
            xc = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
            yc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
            mc = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
            t, c = chunk_loss(xc, yc, mc)
            return (tot + t, cnt + c), None

        (total, count), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            jnp.arange(n_chunks))
    else:
        logits = logits_from_hidden(params, x, cfg)
        total, count = _ce(logits, labels, mask)
    loss = total / jnp.maximum(count, 1.0)
    return loss + aux_weight * aux / max(cfg.n_layers, 1)


# ---------------------------------------------------------------------------
# serving (prefill + decode with KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, s_max: int,
               dtype=None) -> Tuple:
    dt = dtype or cfg.param_dtype
    n = cfg.n_layers
    if cfg.mla is not None:
        return (jnp.zeros((n, batch, s_max, cfg.mla.kv_lora), dt),
                jnp.zeros((n, batch, s_max, cfg.mla.rope_dim), dt))
    return (jnp.zeros((n, batch, s_max, cfg.n_kv_heads, cfg.head_dim), dt),
            jnp.zeros((n, batch, s_max, cfg.n_kv_heads, cfg.head_dim), dt))


def decode_step(params: Params, token: jax.Array, cache: Tuple,
                index: jax.Array, cfg: TransformerConfig
                ) -> Tuple[jax.Array, Tuple]:
    """One decode step. token (B,S) int32; index is the cache write position —
    a scalar (all rows at the same depth: whole-batch prefill, lockstep
    decode) or a (B,) vector of per-row positions (continuous batching:
    concurrently active slots sit at different sequence depths).

    Lowered as ``serve_step`` for the decode_32k / long_500k dry-run cells.
    """
    b, s = token.shape
    x = L.embed(params["embed"], token, cfg.embed_scale).astype(cfg.param_dtype)
    positions, _ = L.cache_positions(index, b, s)
    windows = cfg.layer_windows()

    layer_off = cfg.moe_first_dense
    c0, c1 = cache
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe_first_dense:
        def dense_body(carry, xs):
            x, aux = carry
            layer_p, lc0, lc1 = xs
            x, nc, a = _block(cfg, layer_p, x, positions=positions,
                              window=jnp.int32(0), cache=(lc0, lc1),
                              cache_index=index)
            return (x, aux + a), nc
        (x, aux), dense_cache = jax.lax.scan(
            dense_body, (x, aux),
            (params["dense_layers"], c0[:layer_off], c1[:layer_off]))

    def body(carry, xs):
        x, aux = carry
        layer_p, window, lc0, lc1 = xs
        x, nc, a = _block(cfg, layer_p, x, positions=positions, window=window,
                          cache=(lc0, lc1), cache_index=index)
        return (x, aux + a), nc

    (x, aux), scan_cache = jax.lax.scan(
        body, (x, aux),
        (params["layers"], windows[layer_off:], c0[layer_off:], c1[layer_off:]),
        unroll=cfg.n_scanned if cfg.unroll_layers else 1)

    if cfg.moe_first_dense:
        new_c0 = jnp.concatenate([dense_cache[0], scan_cache[0]], axis=0)
        new_c1 = jnp.concatenate([dense_cache[1], scan_cache[1]], axis=0)
    else:
        new_c0, new_c1 = scan_cache

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["w"],
                            preferred_element_type=jnp.float32)
    return L.softcap(logits, cfg.final_softcap), (new_c0, new_c1)


def prefill(params: Params, tokens: jax.Array, cfg: TransformerConfig,
            cache: Optional[Tuple] = None, cache_index=None):
    """Prefill forward returning last-position logits.

    Without a cache, the cache write is elided — the dry-run prefill cell
    measures the compute-dominant forward. With ``cache`` (and
    ``cache_index``: scalar or (B,) per-row write offsets), the whole prompt
    chunk runs through the decode path in ONE device call, writing its KV
    rows, and ``(last logits, cache)`` is returned — the admission path of a
    continuous-batching engine."""
    if cache is None:
        logits, _ = forward(params, tokens, cfg)
        return logits[:, -1, :]
    idx = jnp.int32(0) if cache_index is None else cache_index
    logits, cache = decode_step(params, tokens, cache, idx, cfg)
    return logits[:, -1, :], cache
