"""GNN architectures over padded COO graphs: PNA, GatedGCN, GIN.

Message passing is ``jax.ops.segment_sum``/``segment_max`` over an
edge-index → node scatter (JAX has no CSR SpMM; this IS the system per the
assignment). The same aggregation is served by the BSR-SpMM Pallas kernel on
TPU for the sum-aggregated archs (GIN/GCN-like), where the xDGP-partitioned
node ordering concentrates tiles near the diagonal.

All models share the ``GraphBatch`` input contract so the distributed
runtime, sampler and dry-run treat them uniformly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain

Params = Dict[str, Any]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Padded graph inputs (all static shapes).

    node_feat: (N, F)      edge endpoints: src/dst (E,) int32 (directed,
    message src→dst; callers pass both directions for undirected graphs)
    graph_ids: (N,) int32 — readout segment per node (0 for single graph)
    """

    node_feat: jax.Array
    src: jax.Array
    dst: jax.Array
    node_mask: jax.Array
    edge_mask: jax.Array
    graph_ids: jax.Array
    n_graphs: int = dataclasses.field(metadata=dict(static=True), default=1)
    edge_feat: Optional[jax.Array] = None
    positions: Optional[jax.Array] = None
    labels: Optional[jax.Array] = None
    label_mask: Optional[jax.Array] = None


def _seg(vals: jax.Array, seg: jax.Array, n: int, mask: jax.Array,
         mode: str = "sum") -> jax.Array:
    seg = jnp.where(mask, seg, n)
    if mode == "sum":
        vals = jnp.where(mask[:, None], vals, 0)
        return jax.ops.segment_sum(vals, seg, num_segments=n + 1)[:n]
    if mode == "max":
        vals = jnp.where(mask[:, None], vals, -jnp.inf)
        out = jax.ops.segment_max(vals, seg, num_segments=n + 1)[:n]
        return jnp.where(jnp.isfinite(out), out, 0)
    if mode == "min":
        vals = jnp.where(mask[:, None], vals, jnp.inf)
        out = jax.ops.segment_min(vals, seg, num_segments=n + 1)[:n]
        return jnp.where(jnp.isfinite(out), out, 0)
    raise ValueError(mode)


def _degrees(batch: GraphBatch) -> jax.Array:
    n = batch.node_mask.shape[0]
    ones = batch.edge_mask.astype(jnp.float32)
    seg = jnp.where(batch.edge_mask, batch.dst, n)
    return jax.ops.segment_sum(ones, seg, num_segments=n + 1)[:n]


def _linear_init(key, d_in, d_out, dtype=jnp.float32):
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) / math.sqrt(d_in),
            "b": jnp.zeros((d_out,), dtype)}


def _linear(p, x):
    return x @ p["w"] + p["b"]


def _mlp2_init(key, d_in, d_hidden, d_out, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"l1": _linear_init(k1, d_in, d_hidden, dtype),
            "l2": _linear_init(k2, d_hidden, d_out, dtype)}


def _mlp2(p, x):
    return _linear(p["l2"], jax.nn.relu(_linear(p["l1"], x)))


def _layernorm_init(d, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _layernorm(p, x, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


# ---------------------------------------------------------------------------
# PNA — principal neighbourhood aggregation (arXiv:2004.05718)
# n_layers=4 d_hidden=75, aggregators mean/max/min/std, scalers id/amp/atten
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 0                 # set per shape
    n_out: int = 1
    avg_log_deg: float = 2.0      # dataset statistic δ
    readout: str = "none"         # "none" (node-level) | "sum" (graph-level)
    remat: bool = False           # per-layer gradient checkpointing (full-scale)


def pna_init(key: jax.Array, cfg: PNAConfig) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 2)
    p: Params = {"encode": _linear_init(keys[0], cfg.d_in, cfg.d_hidden)}
    layers = []
    for i in range(cfg.n_layers):
        km, ku, kn = jax.random.split(keys[i + 1], 3)
        layers.append({
            "msg": _mlp2_init(km, 2 * cfg.d_hidden, cfg.d_hidden, cfg.d_hidden),
            "update": _mlp2_init(ku, 13 * cfg.d_hidden, cfg.d_hidden, cfg.d_hidden),
            "ln": _layernorm_init(cfg.d_hidden),
        })
    p["layers"] = layers
    p["decode"] = _mlp2_init(keys[-1], cfg.d_hidden, cfg.d_hidden, cfg.n_out)
    return p


def pna_forward(params: Params, batch: GraphBatch, cfg: PNAConfig) -> jax.Array:
    n = batch.node_mask.shape[0]
    h = jax.nn.relu(_linear(params["encode"], batch.node_feat))
    deg = _degrees(batch)
    dmax = jnp.maximum(deg, 1.0)
    log_deg = jnp.log(dmax + 1.0)
    amp = (log_deg / cfg.avg_log_deg)[:, None]
    att = (cfg.avg_log_deg / jnp.maximum(log_deg, 1e-6))[:, None]
    src_safe = jnp.clip(batch.src, 0, n - 1)
    dst = batch.dst

    def layer_fn(lp, h):
        m = constrain(_mlp2(lp["msg"], jnp.concatenate(
            [h[src_safe], h[jnp.clip(dst, 0, n - 1)]], axis=-1)), "flat", None)
        s = _seg(m, dst, n, batch.edge_mask, "sum")
        mean = s / dmax[:, None]
        mx = _seg(m, dst, n, batch.edge_mask, "max")
        mn = _seg(m, dst, n, batch.edge_mask, "min")
        sq = _seg(m * m, dst, n, batch.edge_mask, "sum") / dmax[:, None]
        std = jnp.sqrt(jnp.maximum(sq - mean ** 2, 0.0) + 1e-6)
        aggs = jnp.concatenate([mean, mx, mn, std], axis=-1)          # (N,4d)
        scaled = jnp.concatenate([aggs, aggs * amp, aggs * att], -1)  # (N,12d)
        h = h + _mlp2(lp["update"], jnp.concatenate([h, scaled], -1))
        h = _layernorm(lp["ln"], h)
        return jnp.where(batch.node_mask[:, None], h, 0)

    step = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    for lp in params["layers"]:
        h = step(lp, h)
    if cfg.readout == "sum":
        g = jax.ops.segment_sum(jnp.where(batch.node_mask[:, None], h, 0),
                                batch.graph_ids, num_segments=batch.n_graphs)
        return _mlp2(params["decode"], g)
    return _mlp2(params["decode"], h)


# ---------------------------------------------------------------------------
# GatedGCN (arXiv:1711.07553 / benchmarking-gnns config: 16L d=70)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_in: int = 0
    d_edge_in: int = 0
    n_out: int = 1
    readout: str = "none"
    remat: bool = False


def gatedgcn_init(key: jax.Array, cfg: GatedGCNConfig) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 3)
    d = cfg.d_hidden
    p: Params = {"encode": _linear_init(keys[0], cfg.d_in, d),
                 "encode_e": _linear_init(keys[1], max(cfg.d_edge_in, 1), d)}
    layers = []
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[i + 2], 5)
        layers.append({
            "A": _linear_init(ks[0], d, d), "B": _linear_init(ks[1], d, d),
            "C": _linear_init(ks[2], d, d), "U": _linear_init(ks[3], d, d),
            "V": _linear_init(ks[4], d, d),
            "ln_h": _layernorm_init(d), "ln_e": _layernorm_init(d),
        })
    p["layers"] = layers
    p["decode"] = _mlp2_init(keys[-1], d, d, cfg.n_out)
    return p


def gatedgcn_forward(params: Params, batch: GraphBatch, cfg: GatedGCNConfig
                     ) -> jax.Array:
    n = batch.node_mask.shape[0]
    h = jax.nn.relu(_linear(params["encode"], batch.node_feat))
    if batch.edge_feat is not None:
        e = jax.nn.relu(_linear(params["encode_e"], batch.edge_feat))
    else:
        e = jnp.zeros((batch.src.shape[0], cfg.d_hidden), h.dtype)
    src = jnp.clip(batch.src, 0, n - 1)
    dst = jnp.clip(batch.dst, 0, n - 1)

    def layer_fn(lp, h, e):
        e_new = constrain(
            _linear(lp["A"], h[dst]) + _linear(lp["B"], h[src])
            + _linear(lp["C"], e), "flat", None)
        eta = jax.nn.sigmoid(e_new)
        denom = _seg(eta, batch.dst, n, batch.edge_mask, "sum") + 1e-6
        msg = eta * _linear(lp["V"], h)[src]
        agg = _seg(msg, batch.dst, n, batch.edge_mask, "sum") / denom
        h_new = _linear(lp["U"], h) + agg
        h = h + jax.nn.relu(_layernorm(lp["ln_h"], h_new))
        e = e + jax.nn.relu(_layernorm(lp["ln_e"], e_new))
        return jnp.where(batch.node_mask[:, None], h, 0), e

    step = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    for lp in params["layers"]:
        h, e = step(lp, h, e)
    if cfg.readout == "sum":
        g = jax.ops.segment_sum(jnp.where(batch.node_mask[:, None], h, 0),
                                batch.graph_ids, num_segments=batch.n_graphs)
        return _mlp2(params["decode"], g)
    return _mlp2(params["decode"], h)


# ---------------------------------------------------------------------------
# GIN (arXiv:1810.00826, TU config: 5L d=64, sum agg, learnable eps)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 0
    n_out: int = 1
    readout: str = "sum"
    remat: bool = False


def gin_init(key: jax.Array, cfg: GINConfig) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 2)
    p: Params = {"encode": _linear_init(keys[0], cfg.d_in, cfg.d_hidden)}
    layers = []
    for i in range(cfg.n_layers):
        layers.append({
            "mlp": _mlp2_init(keys[i + 1], cfg.d_hidden, cfg.d_hidden, cfg.d_hidden),
            "eps": jnp.zeros((), jnp.float32),
            "ln": _layernorm_init(cfg.d_hidden),
        })
    p["layers"] = layers
    p["decode"] = _mlp2_init(keys[-1], cfg.d_hidden, cfg.d_hidden, cfg.n_out)
    return p


def gin_forward(params: Params, batch: GraphBatch, cfg: GINConfig) -> jax.Array:
    n = batch.node_mask.shape[0]
    h = _linear(params["encode"], batch.node_feat)
    src = jnp.clip(batch.src, 0, n - 1)

    def layer_fn(lp, h):
        agg = _seg(constrain(h[src], "flat", None), batch.dst, n,
                   batch.edge_mask, "sum")
        h = _mlp2(lp["mlp"], (1.0 + lp["eps"]) * h + agg)
        h = jax.nn.relu(_layernorm(lp["ln"], h))
        return jnp.where(batch.node_mask[:, None], h, 0)

    step = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    for lp in params["layers"]:
        h = step(lp, h)
    if cfg.readout == "sum":
        g = jax.ops.segment_sum(h, batch.graph_ids, num_segments=batch.n_graphs)
        return _mlp2(params["decode"], g)
    return _mlp2(params["decode"], h)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def node_classification_loss(logits: jax.Array, batch: GraphBatch) -> jax.Array:
    mask = batch.label_mask if batch.label_mask is not None else batch.node_mask
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    ll = jnp.take_along_axis(logp, batch.labels[:, None], -1)[:, 0]
    m = mask.astype(jnp.float32)
    return -(ll * m).sum() / jnp.maximum(m.sum(), 1.0)


def graph_regression_loss(preds: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((preds[:, 0].astype(jnp.float32) - labels.astype(jnp.float32)) ** 2)
