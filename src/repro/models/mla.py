"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed into a rank-``kv_lora`` latent (plus a shared RoPE key
head); the decode cache stores only (latent, k_rope) — the compression that
makes deepseek-v2-lite's 32k decode cache small. Up-projections reconstruct
per-head K_nope and V from the latent.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, cache_positions, cache_write
from repro.runtime.sharding import constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    n_heads: int = 16
    kv_lora: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


def mla_init(key: jax.Array, d_model: int, cfg: MLAConfig, dtype=jnp.float32) -> Params:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d_model)
    sl = 1.0 / math.sqrt(cfg.kv_lora)
    h = cfg.n_heads
    return {
        "wq": jax.random.normal(k1, (d_model, h * (cfg.nope_dim + cfg.rope_dim)), dtype) * s,
        "w_dkv": jax.random.normal(k2, (d_model, cfg.kv_lora + cfg.rope_dim), dtype) * s,
        "w_uk": jax.random.normal(k3, (cfg.kv_lora, h * cfg.nope_dim), dtype) * sl,
        "w_uv": jax.random.normal(k4, (cfg.kv_lora, h * cfg.v_dim), dtype) * sl,
        "wo": jax.random.normal(k5, (h * cfg.v_dim, d_model), dtype) * (1.0 / math.sqrt(h * cfg.v_dim)),
    }


def _mla_attend(q_nope, q_rope, k_nope, k_rope, v, q_pos, kv_pos, kv_mask):
    """q_nope (B,Sq,H,Dn)  q_rope (B,Sq,H,Dr)  k_rope shared (B,Sk,Dr).

    KV-sequence-sharded over "model" (see layers._attend)."""
    scale = 1.0 / math.sqrt(q_nope.shape[-1] + q_rope.shape[-1])
    k_nope = constrain(k_nope, "batch", "seq_sp", None, None)
    v = constrain(v, "batch", "seq_sp", None, None)
    k_rope = constrain(k_rope, "batch", "seq_sp", None)
    s_nope = jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
    scores = constrain((s_nope + s_rope) * scale,
                       "batch", None, None, "seq_sp")
    mask = kv_pos[:, None, :] <= q_pos[:, :, None]
    if kv_mask is not None:
        mask = mask & kv_mask[:, None, :]
    scores = jnp.where(mask[:, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    probs = constrain(probs, "batch", None, None, "seq_sp")
    return jnp.einsum("bhqs,bshd->bqhd", probs, v)


def mla_attention(params: Params, x: jax.Array, cfg: MLAConfig, *,
                  positions: jax.Array, rope_theta: float = 10000.0,
                  cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                  cache_index: Optional[jax.Array] = None,
                  q_chunk: int = 2048,
                  ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """MLA layer. cache = (latent (B,S,kv_lora), k_rope (B,S,rope_dim))."""
    b, s, _ = x.shape
    h = cfg.n_heads
    q = constrain((x @ params["wq"]).reshape(b, s, h, cfg.nope_dim + cfg.rope_dim),
                  "batch", "seq", "heads", None)
    q_nope, q_rope = q[..., : cfg.nope_dim], q[..., cfg.nope_dim:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    dkv = x @ params["w_dkv"]                              # (B,S,lora+rope)
    latent, k_rope = dkv[..., : cfg.kv_lora], dkv[..., cfg.kv_lora:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rope_theta)[:, :, 0, :]

    if cache is None:
        k_nope = constrain((latent @ params["w_uk"]).reshape(b, s, h, cfg.nope_dim),
                           "batch", "seq", "heads", None)
        v = constrain((latent @ params["w_uv"]).reshape(b, s, h, cfg.v_dim),
                      "batch", "seq", "heads", None)
        if s <= q_chunk:
            out = _mla_attend(q_nope, q_rope, k_nope, k_rope, v,
                              positions, positions, None)
        else:
            n_chunks = s // q_chunk
            assert n_chunks * q_chunk == s

            def chunk_fn(_, i):
                qn = jax.lax.dynamic_slice_in_dim(q_nope, i * q_chunk, q_chunk, 1)
                qr = jax.lax.dynamic_slice_in_dim(q_rope, i * q_chunk, q_chunk, 1)
                pc = jax.lax.dynamic_slice_in_dim(positions, i * q_chunk, q_chunk, 1)
                return None, _mla_attend(qn, qr, k_nope, k_rope, v, pc,
                                         positions, None)

            _, outs = jax.lax.scan(chunk_fn, None, jnp.arange(n_chunks))
            out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, cfg.v_dim)
        new_cache = None
    else:
        c_lat = cache_write(cache[0], latent, cache_index)
        c_rope = cache_write(cache[1], k_rope, cache_index)
        s_max = c_lat.shape[1]
        k_nope = (c_lat @ params["w_uk"]).reshape(b, s_max, h, cfg.nope_dim)
        v = (c_lat @ params["w_uv"]).reshape(b, s_max, h, cfg.v_dim)
        kv_pos = jnp.arange(s_max, dtype=jnp.int32)[None, :].repeat(b, 0)
        _, last = cache_positions(cache_index, b, s)
        kv_valid = kv_pos <= last[:, None]
        out = _mla_attend(q_nope, q_rope, k_nope, c_rope, v,
                          positions, kv_pos, kv_valid)
        new_cache = (c_lat, c_rope)

    return out.reshape(b, s, h * cfg.v_dim) @ params["wo"], new_cache
