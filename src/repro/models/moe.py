"""Mixture-of-Experts FFN with top-k routing (GShard / DeepSeekMoE family).

Two dispatch implementations with identical math:

* ``einsum``  — classic GShard one-hot dispatch (T,E,C). Exact reference,
                used for smoke tests, decode (tiny T) and small models.
* ``sorted``  — sort-based dispatch into an (E, C, d) buffer. O(T·k) index
                work + dense expert matmuls, no (T,E,C) tensor. This is the
                path production dry-runs lower; combined with expert sharding
                over the "model" mesh axis, GSPMD turns the scatter/gather
                into the expected all_to_all pattern.

Arctic's "dense residual" (parallel always-on FFN) and DeepSeek's shared
experts are expressed at the transformer layer level (models/transformer.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import axis_size, shard_map
from repro.runtime.sharding import constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    n_shared: int = 0              # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    dispatch: str = "sorted"       # "sorted" | "einsum" | "sharded"
    router_noise: float = 0.0


def moe_init(key: jax.Array, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e = cfg.n_experts
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(cfg.d_ff)
    p = {
        "router": jax.random.normal(kr, (d_model, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(kg, (e, d_model, cfg.d_ff), dtype) * s_in,
        "w_up": jax.random.normal(ku, (e, d_model, cfg.d_ff), dtype) * s_in,
        "w_down": jax.random.normal(kd, (e, cfg.d_ff, d_model), dtype) * s_out,
    }
    if cfg.n_shared:
        k1, k2, k3 = jax.random.split(ks, 3)
        dff_s = cfg.n_shared * cfg.d_ff
        p["shared"] = {
            "w_gate": jax.random.normal(k1, (d_model, dff_s), dtype) * s_in,
            "w_up": jax.random.normal(k2, (d_model, dff_s), dtype) * s_in,
            "w_down": jax.random.normal(k3, (dff_s, d_model), dtype) * s_out,
        }
    return p


def _router(params: Params, x: jax.Array, cfg: MoEConfig
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gates (T,k) f32, experts (T,k) int32, aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ params["router"])          # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)                                  # mean prob
    ce = jnp.mean(jax.nn.one_hot(experts[:, 0], e), axis=0)       # top-1 load
    aux = e * jnp.sum(me * ce)
    return gates, experts.astype(jnp.int32), aux


def _expert_ffn(w_gate, w_up, w_down, x):
    """Batched SwiGLU over experts: x (E,C,d) -> (E,C,d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", x, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_einsum(params: Params, x: jax.Array, cfg: MoEConfig
                ) -> Tuple[jax.Array, jax.Array]:
    t, d = x.shape
    e = cfg.n_experts
    cap = max(1, int(math.ceil(t * cfg.top_k * cfg.capacity_factor / e)))
    gates, experts, aux = _router(params, x, cfg)                 # (T,k)
    onehot_e = jax.nn.one_hot(experts, e, dtype=jnp.int32)        # (T,k,E)
    # position within expert = number of earlier (token, choice) hits
    flat = onehot_e.reshape(t * cfg.top_k, e)
    before = jnp.cumsum(flat, axis=0) - flat                      # exclusive count
    pos = jnp.sum(before.reshape(t, cfg.top_k, e) * onehot_e, axis=-1)  # (T,k)
    keep = pos < cap
    onehot_c = jax.nn.one_hot(jnp.clip(pos, 0, cap - 1), cap,
                              dtype=x.dtype) * keep[..., None].astype(x.dtype)
    disp = jnp.einsum("tke,tkc->tec", onehot_e.astype(x.dtype), onehot_c)
    xe = jnp.einsum("td,tec->ecd", x, disp)
    ye = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"], xe)
    comb = jnp.einsum("tke,tkc,tk->tec", onehot_e.astype(x.dtype), onehot_c,
                      gates.astype(x.dtype))
    y = jnp.einsum("ecd,tec->td", ye, comb)
    return y, aux


def _moe_sorted(params: Params, x: jax.Array, cfg: MoEConfig,
                capacity: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """Sort-based dispatch: scatter tokens into an (E, C, d) buffer."""
    t, d = x.shape
    e = cfg.n_experts
    cap = capacity or max(1, int(math.ceil(t * cfg.top_k * cfg.capacity_factor / e)))
    gates, experts, aux = _router(params, x, cfg)
    flat_e = experts.reshape(-1)                                   # (T*k,)
    flat_g = gates.reshape(-1)
    flat_t = jnp.arange(t).repeat(cfg.top_k)
    order = jnp.argsort(flat_e)                                    # group by expert
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position within expert group
    pos = jnp.arange(t * cfg.top_k) - jnp.searchsorted(se, se, side="left")
    ok = pos < cap
    buf_idx = se * cap + jnp.where(ok, pos, 0)
    buffer = jnp.zeros((e * cap, d), x.dtype)
    buffer = buffer.at[buf_idx].add(jnp.where(ok[:, None], x[st], 0))
    buffer = constrain(buffer.reshape(e, cap, d), "experts", None, None)
    ye = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"], buffer)
    ye = constrain(ye, "experts", None, None).reshape(e * cap, d)
    contrib = jnp.where(ok[:, None], ye[buf_idx] * sg[:, None].astype(x.dtype), 0)
    y = jnp.zeros((t, d), x.dtype).at[st].add(contrib)
    return y, aux


def _local_dispatch_ffn(w_gate, w_up, w_down, router, x_loc, cfg: MoEConfig,
                        model_axis: str, fsdp_axis: Optional[str],
                        all_axes: Optional[tuple] = None):
    """Per-device MoE body under shard_map (GShard expert parallelism).

    x_loc: (t_loc, d) local tokens. Experts are sharded over ``model_axis``
    (E_loc per device) with d_ff FSDP-sharded over ``fsdp_axis``. Dispatch:
    local top-k → local capacity buffers (E, C_loc, d) → all_to_all over the
    model axis → expert FFN → all_to_all back → weighted combine.
    Capacity is per-source-device (C_loc = t_loc·k·cf/E), the standard
    hierarchical GShard behaviour.
    """
    t_loc, d = x_loc.shape
    e = cfg.n_experts
    m = axis_size(model_axis)
    e_loc = e // m
    cap = max(1, int(math.ceil(t_loc * cfg.top_k * cfg.capacity_factor / e)))

    # router (replicated weights) ------------------------------------------
    logits = x_loc.astype(jnp.float32) @ router                  # (t,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    experts = experts.astype(jnp.int32)
    # aux loss from global statistics (psum over every mesh axis)
    me_loc = jnp.sum(probs, axis=0)
    ce_loc = jnp.sum(jax.nn.one_hot(experts[:, 0], e), axis=0)
    cnt = jnp.float32(t_loc)
    if all_axes is None:
        all_axes = (model_axis,) if fsdp_axis is None else (fsdp_axis, model_axis)
    me = jax.lax.psum(me_loc, all_axes)
    ce = jax.lax.psum(ce_loc, all_axes)
    n_tok = jax.lax.psum(cnt, all_axes)
    aux = e * jnp.sum((me / n_tok) * (ce / n_tok))

    # local dispatch into (E, cap, d) --------------------------------------
    onehot = jax.nn.one_hot(experts, e, dtype=jnp.int32)          # (t,k,E)
    flat = onehot.reshape(t_loc * cfg.top_k, e)
    before = jnp.cumsum(flat, axis=0) - flat
    pos = jnp.sum(before.reshape(t_loc, cfg.top_k, e) * onehot, -1)  # (t,k)
    keep = pos < cap
    flat_e = experts.reshape(-1)
    flat_t = jnp.arange(t_loc).repeat(cfg.top_k)
    flat_p = jnp.where(keep.reshape(-1), pos.reshape(-1), 0)
    ok = keep.reshape(-1)
    buf = jnp.zeros((e, cap, d), x_loc.dtype)
    buf = buf.at[flat_e, flat_p].add(
        jnp.where(ok[:, None], x_loc[flat_t], 0))

    # all_to_all: expert shards to their owners -----------------------------
    buf = buf.reshape(m, e_loc, cap, d)
    buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=0,
                             tiled=False)                         # (m, e_loc, cap, d)
    xe = buf.transpose(1, 0, 2, 3).reshape(e_loc, m * cap, d)

    # expert FFN (FSDP all-gather of the local expert weights) -------------
    if fsdp_axis is not None:
        w_gate = jax.lax.all_gather(w_gate, fsdp_axis, axis=2, tiled=True)
        w_up = jax.lax.all_gather(w_up, fsdp_axis, axis=2, tiled=True)
        w_down = jax.lax.all_gather(w_down, fsdp_axis, axis=1, tiled=True)
    ye = _expert_ffn(w_gate, w_up, w_down, xe)                    # (e_loc, m*cap, d)

    # return trip ------------------------------------------------------------
    ye = ye.reshape(e_loc, m, cap, d).transpose(1, 0, 2, 3)       # (m, e_loc, cap, d)
    ye = jax.lax.all_to_all(ye, model_axis, split_axis=0, concat_axis=0,
                            tiled=False)
    ye = ye.reshape(e, cap, d)

    # combine ---------------------------------------------------------------
    contrib = jnp.where(ok[:, None],
                        ye[flat_e, flat_p] *
                        gates.reshape(-1)[:, None].astype(x_loc.dtype), 0)
    y = jnp.zeros((t_loc, d), x_loc.dtype).at[flat_t].add(contrib)
    return y, aux


def _moe_shard_map(params: Params, x: jax.Array, cfg: MoEConfig,
                   mesh) -> Tuple[jax.Array, jax.Array]:
    """shard_map expert-parallel MoE. x: (B, S, d) with B|data-axes, S|model."""
    from jax.sharding import PartitionSpec as P
    from repro.runtime.sharding import data_axes

    dp = data_axes(mesh)
    fsdp = "data"
    b, s, d = x.shape

    all_axes = tuple(mesh.axis_names)

    def body(router, w_gate, w_up, w_down, x_blk):
        bb, ss, dd = x_blk.shape
        y, aux = _local_dispatch_ffn(w_gate, w_up, w_down, router,
                                     x_blk.reshape(bb * ss, dd), cfg,
                                     model_axis="model", fsdp_axis=fsdp,
                                     all_axes=all_axes)
        return y.reshape(bb, ss, dd), aux

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P("model", None, fsdp), P("model", None, fsdp),
                  P("model", fsdp, None), P(dp, "model", None)),
        out_specs=(P(dp, "model", None), P()),
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"], x)
    return y, aux


def moe_apply(params: Params, x: jax.Array, cfg: MoEConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (..., d) -> (moe_out, aux_loss). Shared experts included if any.

    Path selection: "sharded" uses the shard_map expert-parallel dispatch
    whenever an activation mesh is installed and shapes divide it (falling
    back to the local sorted dispatch otherwise — e.g. decode's single-token
    steps); "einsum" is the exact GShard reference.
    """
    from repro.runtime.sharding import data_axes, get_activation_mesh

    shape = x.shape
    if cfg.dispatch == "sharded" and x.ndim == 3:
        mesh = get_activation_mesh()
        if mesh is not None:
            b, s, _ = shape
            dp_size = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
            m_size = mesh.shape["model"]
            if (b % dp_size == 0 and s % m_size == 0
                    and cfg.n_experts % m_size == 0):
                y, aux = _moe_shard_map(params, x, cfg, mesh)
                if cfg.n_shared and "shared" in params:
                    sp = params["shared"]
                    flat = x.reshape(-1, shape[-1])
                    ys = (jax.nn.silu(flat @ sp["w_gate"]) *
                          (flat @ sp["w_up"])) @ sp["w_down"]
                    y = y + ys.reshape(shape)
                return y, aux
    flat = x.reshape(-1, shape[-1])
    if cfg.dispatch == "einsum":
        y, aux = _moe_einsum(params, flat, cfg)
    else:
        y, aux = _moe_sorted(params, flat, cfg)
    if cfg.n_shared and "shared" in params:
        sp = params["shared"]
        y = y + (jax.nn.silu(flat @ sp["w_gate"]) * (flat @ sp["w_up"])) @ sp["w_down"]
    return y.reshape(shape), aux
