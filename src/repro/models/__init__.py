"""Assigned-architecture model zoo (5 LM + 4 GNN + 1 recsys)."""
from repro.models.transformer import (TransformerConfig, init_params,
                                      forward, lm_loss, prefill, decode_step,
                                      init_cache, param_count,
                                      active_param_count)
from repro.models.moe import MoEConfig
from repro.models.mla import MLAConfig
from repro.models import gnn, dimenet, recsys

__all__ = ["TransformerConfig", "init_params", "forward", "lm_loss", "prefill",
           "decode_step", "init_cache", "param_count", "active_param_count",
           "MoEConfig", "MLAConfig", "gnn", "dimenet", "recsys"]
