"""Shared transformer building blocks (functional, explicit param pytrees).

All params are plain nested dicts of jnp arrays so they shard transparently
under pjit and can be abstract-initialised with ``jax.eval_shape`` for the
multi-pod dry-run (no host allocation of 480B-parameter models).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Norms / activations / embeddings
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(params: Params, tokens: jax.Array, scale_by_dim: bool = False) -> jax.Array:
    x = jnp.take(params["table"], tokens, axis=0)
    if scale_by_dim:
        x = x * jnp.asarray(math.sqrt(x.shape[-1]), x.dtype)
    return x


def unembed(params: Params, x: jax.Array) -> jax.Array:
    """Tied read-out: x @ table.T (f32 accumulation)."""
    return jnp.einsum("...d,vd->...v", x, params["table"],
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D) rotated pairwise; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                           # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., :, None, :]                        # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA/MQA, optional sliding window + softcap), q-chunked softmax
# ---------------------------------------------------------------------------

def attention_init(key: jax.Array, d_model: int, n_heads: int, n_kv: int,
                   head_dim: int, dtype=jnp.float32) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    so = 1.0 / math.sqrt(n_heads * head_dim)
    return {
        "wq": jax.random.normal(k1, (d_model, n_heads * head_dim), dtype) * s,
        "wk": jax.random.normal(k2, (d_model, n_kv * head_dim), dtype) * s,
        "wv": jax.random.normal(k3, (d_model, n_kv * head_dim), dtype) * s,
        "wo": jax.random.normal(k4, (n_heads * head_dim, d_model), dtype) * so,
    }


def _attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
            q_positions: jax.Array, kv_positions: jax.Array,
            window: Optional[jax.Array], attn_softcap: Optional[float],
            kv_mask: Optional[jax.Array]) -> jax.Array:
    """Masked softmax attention. q: (B,Sq,H,D), k/v: (B,Sk,KV,D).

    ``window`` may be a traced scalar (0 = full attention) so alternating
    local/global layers can share one scan body (gemma-2 pattern).

    Distribution: K/V (and hence scores) are sharded over the "model" mesh
    axis along the KV-sequence dim (flash-decoding style). Works for any
    head count (24 q-heads / 8 KV heads never divide a 16-way TP axis);
    softmax max/sum reduce and the PV contraction psum across ranks are
    inserted by GSPMD from the constraints.
    """
    b, sq, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    k = constrain(k, "batch", "seq_sp", None, None)
    v = constrain(v, "batch", "seq_sp", None, None)
    qg = q.reshape(b, sq, kv, rep, d)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = constrain(scores, "batch", None, None, None, "seq_sp")
    scores = scores / math.sqrt(d)
    if attn_softcap is not None:
        scores = attn_softcap * jnp.tanh(scores / attn_softcap)
    causal = kv_positions[:, None, :] <= q_positions[:, :, None]      # (B,Sq,Sk)
    mask = causal
    if window is not None:
        in_window = kv_positions[:, None, :] > (q_positions[:, :, None] - window)
        mask = mask & jnp.where(window > 0, in_window, True)
    if kv_mask is not None:
        mask = mask & kv_mask[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    probs = constrain(probs, "batch", None, None, None, "seq_sp")
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs, v)
    return out.reshape(b, sq, h, d)


def cache_write(buf: jax.Array, rows: jax.Array, index: jax.Array) -> jax.Array:
    """Write ``rows`` (B, S, ...) into a decode cache ``buf`` (B, S_max, ...)
    starting at ``index``.

    ``index`` is either a scalar — every batch row writes at the same offset
    (whole-batch prefill) — or a (B,) vector of per-row offsets, which is what
    continuous batching needs: concurrently active slots sit at different
    sequence depths, so each writes its own cache row.
    """
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            buf, rows.astype(buf.dtype), idx, axis=1)
    b, s = rows.shape[:2]
    rowi = jnp.arange(b, dtype=jnp.int32)[:, None]
    coli = idx[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    return buf.at[rowi, coli].set(rows.astype(buf.dtype))


def cache_positions(index: jax.Array, b: int, s: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """(q_positions (B,S), last written position (B,)) for a cached write of
    ``s`` tokens starting at ``index`` (scalar or (B,) per-row)."""
    idx = jnp.asarray(index, jnp.int32)
    start = jnp.broadcast_to(jnp.atleast_1d(idx), (b,))
    positions = start[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    return positions, start + jnp.int32(s - 1)


def attention(params: Params, x: jax.Array, *, n_heads: int, n_kv: int,
              head_dim: int, positions: jax.Array,
              window: Optional[jax.Array] = None,
              attn_softcap: Optional[float] = None,
              rope_theta: float = 10000.0,
              cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None,
              q_chunk: int = 2048,
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Full attention layer. Training when cache is None; decode otherwise.

    Decode: x is (B, 1, d); cache = (k, v) with shape (B, S_max, KV, D); the
    new KV row is written at ``cache_index`` and attention spans the cache.
    """
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    q = constrain(q.reshape(b, s, n_heads, head_dim),
                  "batch", "seq", "heads", None)
    k = constrain(k.reshape(b, s, n_kv, head_dim),
                  "batch", "seq", "heads", None)
    v = constrain(v.reshape(b, s, n_kv, head_dim),
                  "batch", "seq", "heads", None)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    if cache is None:
        # ---- training / prefill: q-chunked to bound the score matrix -----
        if s <= q_chunk:
            out = _attend(q, k, v, q_positions=positions, kv_positions=positions,
                          window=window, attn_softcap=attn_softcap, kv_mask=None)
        else:
            n_chunks = s // q_chunk
            assert n_chunks * q_chunk == s, "seq_len must divide q_chunk"

            def chunk_fn(carry, i):
                q_c = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
                p_c = jax.lax.dynamic_slice_in_dim(positions, i * q_chunk, q_chunk, axis=1)
                o = _attend(q_c, k, v, q_positions=p_c, kv_positions=positions,
                            window=window, attn_softcap=attn_softcap, kv_mask=None)
                return carry, o

            _, outs = jax.lax.scan(chunk_fn, None, jnp.arange(n_chunks))
            out = jnp.moveaxis(outs, 0, 1).reshape(b, s, n_heads, head_dim)
        new_cache = None
    else:
        ck = cache_write(cache[0], k, cache_index)
        cv = cache_write(cache[1], v, cache_index)
        s_max = ck.shape[1]
        kv_pos = jnp.arange(s_max, dtype=jnp.int32)[None, :].repeat(b, 0)
        _, last = cache_positions(cache_index, b, s)
        kv_valid = kv_pos <= last[:, None]
        out = _attend(q, ck, cv, q_positions=positions, kv_positions=kv_pos,
                      window=window, attn_softcap=attn_softcap, kv_mask=kv_valid)
        new_cache = (ck, cv)

    return out.reshape(b, s, n_heads * head_dim) @ params["wo"], new_cache


# ---------------------------------------------------------------------------
# MLPs (gelu / swiglu / geglu)
# ---------------------------------------------------------------------------

def mlp_init(key: jax.Array, d_model: int, d_ff: int, kind: str,
             dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    if kind == "gelu":
        return {"w_up": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
                "w_down": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out}
    return {"w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
            "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
            "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out}


def mlp(params: Params, x: jax.Array, kind: str) -> jax.Array:
    cst = lambda h: constrain(h, "batch", "seq", "d_ff")
    if kind == "gelu":
        return cst(jax.nn.gelu(x @ params["w_up"])) @ params["w_down"]
    if kind == "swiglu":
        h = cst(jax.nn.silu(x @ params["w_gate"])) * cst(x @ params["w_up"])
        return h @ params["w_down"]
    if kind == "geglu":
        h = cst(jax.nn.gelu(x @ params["w_gate"])) * cst(x @ params["w_up"])
        return h @ params["w_down"]
    raise ValueError(kind)
