"""DimeNet — directional message passing (arXiv:2003.03123).

Config (assigned): 6 interaction blocks, d_hidden 128, n_bilinear 8,
n_spherical 7, n_radial 6.

Messages live on *directed edges*; the triplet gather (k→j over edge j→i)
is the kernel regime that distinguishes DimeNet from SpMM GNNs. Triplet
index lists are **precomputed inputs** (standard for DimeNet impls) with a
static cap; the data pipeline builds them (graph/triplets via
``build_triplets``) and synthesises 3-D positions for non-molecular graphs
(DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.sharding import constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_in: int = 0
    n_out: int = 1
    cutoff: float = 5.0
    readout: str = "sum"
    remat: bool = False


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TripletBatch:
    """Precomputed directed-edge + triplet structure (static shapes).

    edge_src/edge_dst: (E,) directed edges j->i
    trip_in/trip_out:  (T,) indices into edges: message (k->j) feeds (j->i)
    """

    edge_src: jax.Array
    edge_dst: jax.Array
    edge_mask: jax.Array
    trip_in: jax.Array
    trip_out: jax.Array
    trip_mask: jax.Array


def build_triplets(src: np.ndarray, dst: np.ndarray, mask: np.ndarray,
                   t_cap: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side triplet enumeration: pairs of directed edges (k->j), (j->i), k != i."""
    e = src.shape[0]
    live = np.flatnonzero(mask)
    by_dst: Dict[int, list] = {}
    for idx in live:
        by_dst.setdefault(int(dst[idx]), []).append(idx)
    t_in = np.full(t_cap, 0, np.int32)
    t_out = np.full(t_cap, 0, np.int32)
    t_ok = np.zeros(t_cap, bool)
    t = 0
    for out_idx in live:                       # edge j -> i
        j = int(src[out_idx])
        i = int(dst[out_idx])
        for in_idx in by_dst.get(j, ()):       # edge k -> j
            if int(src[in_idx]) == i:
                continue
            if t >= t_cap:
                return t_in, t_out, t_ok
            t_in[t] = in_idx
            t_out[t] = out_idx
            t_ok[t] = True
            t += 1
    return t_in, t_out, t_ok


def _rbf(d: jax.Array, n_radial: int, cutoff: float) -> jax.Array:
    """Radial Bessel basis."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(d, 1e-6)[:, None]
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d


def _sbf(d: jax.Array, angle: jax.Array, n_spherical: int, n_radial: int,
         cutoff: float) -> jax.Array:
    """Simplified spherical basis: cos(l·θ) ⊗ Bessel_n(d) (l < n_spherical)."""
    ls = jnp.arange(n_spherical, dtype=jnp.float32)
    ang = jnp.cos(ls[None, :] * angle[:, None])                  # (T, S)
    rad = _rbf(d, n_radial, cutoff)                              # (T, R)
    return (ang[:, :, None] * rad[:, None, :]).reshape(d.shape[0], -1)


def _lin_init(key, din, dout):
    return {"w": jax.random.normal(key, (din, dout), jnp.float32) / math.sqrt(din),
            "b": jnp.zeros((dout,), jnp.float32)}


def _lin(p, x):
    return x @ p["w"] + p["b"]


def dimenet_init(key: jax.Array, cfg: DimeNetConfig) -> Params:
    keys = jax.random.split(key, cfg.n_blocks + 5)
    d = cfg.d_hidden
    sbf_dim = cfg.n_spherical * cfg.n_radial
    p: Params = {
        "embed_node": _lin_init(keys[0], cfg.d_in, d),
        "embed_rbf": _lin_init(keys[1], cfg.n_radial, d),
        "embed_msg": _lin_init(keys[2], 3 * d, d),
    }
    blocks = []
    for i in range(cfg.n_blocks):
        ks = jax.random.split(keys[i + 3], 6)
        blocks.append({
            "w_rbf": _lin_init(ks[0], cfg.n_radial, d),
            "w_sbf": _lin_init(ks[1], sbf_dim, cfg.n_bilinear),
            "bilinear": jax.random.normal(ks[2], (d, cfg.n_bilinear, d),
                                          jnp.float32) / math.sqrt(d),
            "w_src": _lin_init(ks[3], d, d),
            "w_msg": _lin_init(ks[4], d, d),
            "w_update": _lin_init(ks[5], d, d),
        })
    p["blocks"] = blocks
    p["out_edge"] = _lin_init(keys[-2], d, d)
    p["decode"] = _lin_init(keys[-1], d, cfg.n_out)
    return p


def dimenet_forward(params: Params, node_feat: jax.Array, positions: jax.Array,
                    trip: TripletBatch, node_mask: jax.Array,
                    graph_ids: jax.Array, n_graphs: int,
                    cfg: DimeNetConfig) -> jax.Array:
    n = node_feat.shape[0]
    src = jnp.clip(trip.edge_src, 0, n - 1)
    dst = jnp.clip(trip.edge_dst, 0, n - 1)
    vec = positions[dst] - positions[src]
    dist = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, -1), 1e-12))
    rbf = _rbf(dist, cfg.n_radial, cfg.cutoff)                  # (E,R)

    # triplet geometry: angle between edge (k->j) and (j->i)
    e_in = jnp.clip(trip.trip_in, 0, src.shape[0] - 1)
    e_out = jnp.clip(trip.trip_out, 0, src.shape[0] - 1)
    v1 = -vec[e_in]                                              # j->k
    v2 = vec[e_out]                                              # j->i
    cos = jnp.sum(v1 * v2, -1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-9)
    angle = jnp.arccos(jnp.clip(cos, -1 + 1e-7, 1 - 1e-7))
    sbf = _sbf(dist[e_out], angle, cfg.n_spherical, cfg.n_radial, cfg.cutoff)

    # embedding block: message per directed edge
    hx = constrain(jax.nn.silu(_lin(params["embed_node"], node_feat)), "flat", None)
    hr = constrain(jax.nn.silu(_lin(params["embed_rbf"], rbf)), "flat", None)
    m = constrain(jax.nn.silu(_lin(params["embed_msg"],
                  jnp.concatenate([hx[src], hx[dst], hr], -1))), "flat", None)

    out_nodes = jnp.zeros((n, cfg.d_hidden), jnp.float32)
    e_count = src.shape[0]

    def block_fn(blk, m, out_nodes):
        # directional message update via SBF-bilinear triplet aggregation
        m = constrain(m, "flat", None)
        m_in = constrain(jax.nn.silu(_lin(blk["w_msg"], m))[e_in], "flat", None)
        sb = constrain(_lin(blk["w_sbf"], sbf), "flat", None)     # (T,B)
        inter = jnp.einsum("td,dbe,tb->te", m_in, blk["bilinear"], sb)
        inter = constrain(jnp.where(trip.trip_mask[:, None], inter, 0),
                          "flat", None)
        agg = jax.ops.segment_sum(inter, jnp.where(trip.trip_mask, e_out, e_count),
                                  num_segments=e_count + 1)[:e_count]
        gate = jax.nn.silu(_lin(blk["w_rbf"], rbf))
        m = m + jax.nn.silu(_lin(blk["w_src"], m)) * gate + agg
        m = jnp.where(trip.edge_mask[:, None], m, 0)
        out_nodes = out_nodes + jax.ops.segment_sum(
            jax.nn.silu(_lin(params["out_edge"], m)),
            jnp.where(trip.edge_mask, dst, n), num_segments=n + 1)[:n]
        return m, out_nodes

    step = jax.checkpoint(block_fn) if cfg.remat else block_fn
    for blk in params["blocks"]:
        m, out_nodes = step(blk, m, out_nodes)

    out_nodes = jnp.where(node_mask[:, None], out_nodes, 0)
    if cfg.readout == "sum":
        g = jax.ops.segment_sum(out_nodes, graph_ids, num_segments=n_graphs)
        return _lin(params["decode"], g)
    return _lin(params["decode"], out_nodes)
