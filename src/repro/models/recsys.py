"""Two-tower retrieval model (YouTube RecSys'19 style).

embed_dim 256, tower MLPs 1024-512-256, dot-product interaction, sampled
softmax with logQ correction over in-batch negatives.

EmbeddingBag is built from ``jnp.take`` + ``jax.ops.segment_sum`` (JAX has no
native EmbeddingBag — this is part of the system, per the assignment); the
Pallas ``embedding_bag`` kernel serves the same contract on TPU.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class FeatureSpec:
    name: str
    vocab: int
    dim: int
    n_hot: int = 1                 # multi-hot bag size (fixed, padded)


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    user_features: Tuple[FeatureSpec, ...] = (
        FeatureSpec("user_id", 10_000_000, 128),
        FeatureSpec("user_geo", 100_000, 32),
        FeatureSpec("user_hist", 2_000_000, 64, n_hot=16),   # watched items bag
        FeatureSpec("user_device", 64, 16),
    )
    item_features: Tuple[FeatureSpec, ...] = (
        FeatureSpec("item_id", 2_000_000, 128),
        FeatureSpec("item_topic", 50_000, 64),
        FeatureSpec("item_creator", 500_000, 48),
    )
    n_dense_user: int = 8
    n_dense_item: int = 4
    temperature: float = 0.05


def embedding_bag(table: jax.Array, indices: jax.Array,
                  weights: Optional[jax.Array] = None,
                  combine: str = "mean") -> jax.Array:
    """(B, n_hot) indices → (B, dim). take + segment-reduce (mean over valid).

    indices < 0 are padding. This is the pure-jnp contract the Pallas kernel
    (kernels/embedding_bag.py) implements for TPU.
    """
    b, h = indices.shape
    valid = indices >= 0
    safe = jnp.clip(indices, 0, table.shape[0] - 1)
    rows = jnp.take(table, safe.reshape(-1), axis=0).reshape(b, h, -1)
    rows = jnp.where(valid[..., None], rows, 0)
    if weights is not None:
        rows = rows * weights[..., None]
    out = rows.sum(axis=1)
    if combine == "mean":
        out = out / jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
    return out


def _tower_init(key: jax.Array, feats: Tuple[FeatureSpec, ...], n_dense: int,
                mlp: Tuple[int, ...], out_dim: int) -> Params:
    keys = jax.random.split(key, len(feats) + len(mlp) + 1)
    p: Params = {"tables": {}}
    for i, f in enumerate(feats):
        p["tables"][f.name] = jax.random.normal(
            keys[i], (f.vocab, f.dim), jnp.float32) * (1.0 / math.sqrt(f.dim))
    d_in = sum(f.dim for f in feats) + n_dense
    dims = [d_in] + list(mlp)
    p["mlp"] = []
    for i in range(len(mlp)):
        k = keys[len(feats) + i]
        p["mlp"].append({
            "w": jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32)
                 / math.sqrt(dims[i]),
            "b": jnp.zeros((dims[i + 1],), jnp.float32)})
    return p


def init_params(key: jax.Array, cfg: TwoTowerConfig) -> Params:
    ku, ki = jax.random.split(key)
    return {
        "user": _tower_init(ku, cfg.user_features, cfg.n_dense_user,
                            cfg.tower_mlp, cfg.embed_dim),
        "item": _tower_init(ki, cfg.item_features, cfg.n_dense_item,
                            cfg.tower_mlp, cfg.embed_dim),
    }


def _tower(params: Params, feats: Tuple[FeatureSpec, ...],
           cat_inputs: Dict[str, jax.Array], dense: jax.Array) -> jax.Array:
    parts: List[jax.Array] = []
    for f in feats:
        idx = cat_inputs[f.name]
        if idx.ndim == 1:
            idx = idx[:, None]
        parts.append(embedding_bag(params["tables"][f.name], idx))
    x = jnp.concatenate(parts + [dense], axis=-1)
    for i, layer in enumerate(params["mlp"]):
        x = x @ layer["w"] + layer["b"]
        if i + 1 < len(params["mlp"]):
            x = jax.nn.relu(x)
    # L2-normalised embeddings (standard for dot-product retrieval)
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def user_embed(params: Params, batch: Dict[str, jax.Array],
               cfg: TwoTowerConfig) -> jax.Array:
    return _tower(params["user"], cfg.user_features, batch, batch["user_dense"])


def item_embed(params: Params, batch: Dict[str, jax.Array],
               cfg: TwoTowerConfig) -> jax.Array:
    return _tower(params["item"], cfg.item_features, batch, batch["item_dense"])


def sampled_softmax_loss(params: Params, batch: Dict[str, jax.Array],
                         cfg: TwoTowerConfig) -> jax.Array:
    """In-batch sampled softmax with logQ correction.

    batch carries user features, positive-item features and ``item_logq``
    (log sampling probability of each in-batch item).
    """
    u = user_embed(params, batch, cfg)                       # (B, D)
    v = item_embed(params, batch, cfg)                       # (B, D)
    logits = (u @ v.T) / cfg.temperature                     # (B, B)
    logq = batch.get("item_logq")
    if logq is not None:
        logits = logits - logq[None, :]
    labels = jnp.arange(u.shape[0])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))


def score_pairs(params: Params, batch: Dict[str, jax.Array],
                cfg: TwoTowerConfig) -> jax.Array:
    """Online/bulk scoring: one score per (user, item) row."""
    u = user_embed(params, batch, cfg)
    v = item_embed(params, batch, cfg)
    return jnp.sum(u * v, axis=-1)


def retrieval_scores(params: Params, batch: Dict[str, jax.Array],
                     cfg: TwoTowerConfig) -> jax.Array:
    """One query against N candidates: (1,D) x (N,D) -> (N,) + top-k."""
    u = user_embed(params, batch, cfg)                       # (1, D)
    v = item_embed(params, batch, cfg)                       # (N, D)
    return (v @ u[0]).astype(jnp.float32)


def retrieval_topk(params: Params, batch: Dict[str, jax.Array],
                   cfg: TwoTowerConfig, k: int = 100):
    return jax.lax.top_k(retrieval_scores(params, batch, cfg), k)
