"""Production mesh builders (function-scoped: importing never touches jax
device state — the dry-run sets XLA_FLAGS before any jax init)."""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires XLA_FLAGS device count)."""
    return make_mesh((n_data, n_model), ("data", "model"))
