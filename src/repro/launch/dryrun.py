import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and record memory/cost/collective analyses.

  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out results/]

Proves (assignment deliverable (e)): the distribution config is coherent —
.lower().compile() succeeds for the 16×16 (256-chip) single-pod mesh AND the
2×16×16 (512-chip) multi-pod mesh for every cell; memory_analysis shows it
fits; cost_analysis + HLO collective parsing feed §Roofline.
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(token_dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[token_dtype]


def parse_collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum per-op result bytes of every collective in the optimised HLO.

    Result-shape convention: for all-gather/all-to-all the result is the
    received buffer; for all-reduce it equals the operand; reduce-scatter's
    result understates by ~(n-1)/n — acceptable for a roofline term.
    """
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        for kind in _COLLECTIVES:
            if f" {kind}(" in s or f" {kind}-start(" in s:
                # result type sits between '=' and the op name
                rhs = s.split("=", 1)[1]
                head = rhs.split(f" {kind}", 1)[0]
                m = _SHAPE_RE.findall(head)
                if not m:
                    continue
                # tuples (e.g. -start ops) repeat in/out buffers: take the
                # largest component = the received buffer
                bytes_ = max(_shape_bytes(dt, dims) for dt, dims in m)
                per_kind[kind] += bytes_
                counts[kind] += 1
                break
    total = sum(per_kind.values())
    return {"total_bytes": total, "per_kind_bytes": per_kind,
            "op_counts": counts}


def run_cell(cell, mesh, mesh_name: str) -> Dict[str, Any]:
    t0 = time.time()
    rec: Dict[str, Any] = {
        "arch": cell.arch_id, "shape": cell.shape_name, "mesh": mesh_name,
        "family": cell.family,
    }
    if cell.skip:
        rec["status"] = "SKIP"
        rec["skip_reason"] = cell.skip
        return rec
    try:
        spec = build_cell(cell, mesh)
        with mesh:
            jitted = jax.jit(spec.fn,
                             in_shardings=spec.in_shardings,
                             out_shardings=spec.out_shardings,
                             donate_argnums=spec.donate_argnums)
            lowered = jitted.lower(*spec.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        rec["status"] = "OK"
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)
        rec["static_info"] = spec.static_info
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
                "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
            }
        except Exception as e:                                  # noqa: BLE001
            rec["memory"] = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            rec["cost"] = {k: float(v) for k, v in ca.items()
                           if isinstance(v, (int, float)) and (
                               k in ("flops", "bytes accessed")
                               or k.startswith("bytes accessed"))}
            rec["cost"]["flops"] = float(ca.get("flops", 0.0))
        except Exception as e:                                  # noqa: BLE001
            rec["cost"] = {"error": str(e)}
        try:
            hlo = compiled.as_text()
            rec["collectives"] = parse_collective_bytes(hlo)
            rec["hlo_bytes"] = len(hlo)
        except Exception as e:                                  # noqa: BLE001
            rec["collectives"] = {"error": str(e)}
    except Exception as e:                                      # noqa: BLE001
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results")
    ap.add_argument("--force", action="store_true",
                    help="recompute cached cells")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_256", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_512", make_production_mesh(multi_pod=True)))

    cells = registry.all_cells()
    if args.arch:
        cells = [c for c in cells if c.arch_id == args.arch]
    if args.shape:
        cells = [c for c in cells if c.shape_name == args.shape]

    for mesh_name, mesh in meshes:
        out_path = os.path.join(args.out, f"dryrun_{mesh_name}.json")
        results: Dict[str, Any] = {}
        if os.path.exists(out_path):
            with open(out_path) as f:
                results = json.load(f)   # --force recomputes selected cells
                                         # but never discards other entries
        for cell in cells:
            key = f"{cell.arch_id}:{cell.shape_name}"
            if key in results and results[key].get("status") == "OK" and not args.force:
                print(f"[{mesh_name}] {key}: cached OK", flush=True)
                continue
            print(f"[{mesh_name}] {key}: compiling ...", flush=True)
            rec = run_cell(cell, mesh, mesh_name)
            results[key] = rec
            with open(out_path, "w") as f:
                json.dump(results, f, indent=1)
            status = rec["status"]
            extra = ""
            if status == "OK":
                coll = rec.get("collectives", {}).get("total_bytes", 0)
                extra = (f" compile={rec['compile_s']}s"
                         f" flops={rec.get('cost', {}).get('flops', 0):.3g}"
                         f" coll={coll / 1e9:.2f}GB")
            elif status == "FAIL":
                extra = " " + rec.get("error", "")[:200]
            print(f"[{mesh_name}] {key}: {status}{extra}", flush=True)

    # summary
    for mesh_name, _ in meshes:
        out_path = os.path.join(args.out, f"dryrun_{mesh_name}.json")
        with open(out_path) as f:
            results = json.load(f)
        ok = sum(1 for r in results.values() if r["status"] == "OK")
        skip = sum(1 for r in results.values() if r["status"] == "SKIP")
        fail = sum(1 for r in results.values() if r["status"] == "FAIL")
        print(f"== {mesh_name}: {ok} OK / {skip} SKIP / {fail} FAIL "
              f"of {len(results)}")


if __name__ == "__main__":
    main()
