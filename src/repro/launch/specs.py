"""Per-cell lowering specs: (fn, abstract args, in/out shardings).

``build_cell(arch_id, shape_name, mesh)`` returns a ``LoweringSpec`` the
dry-run compiles. Inputs are ``jax.ShapeDtypeStruct`` stand-ins (weak-type
correct, no allocation); params come from ``jax.eval_shape`` over the real
initialisers, so the lowered program is byte-identical to what the real
launcher would compile on a pod.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import Cell
from repro.models import (TransformerConfig, decode_step, init_cache,
                          init_params, lm_loss, prefill)
from repro.models import transformer as tfm
from repro.models.dimenet import DimeNetConfig, TripletBatch, dimenet_init, dimenet_forward
from repro.models.gnn import (GatedGCNConfig, GINConfig, GraphBatch,
                              PNAConfig, gatedgcn_forward, gatedgcn_init,
                              gin_forward, gin_init, node_classification_loss,
                              graph_regression_loss, pna_forward, pna_init)
from repro.models import recsys as rs
from repro.optim import AdamWConfig
from repro.runtime import sharding as shr
from repro.train import TrainConfig, make_train_state, make_train_step

KEY = jax.random.PRNGKey(0)

# per-shape DimeNet triplet budget (per directed edge); see configs/dimenet_cfg
TRIPLET_BUDGET = {"full_graph_sm": 20, "minibatch_lg": 10, "ogb_products": 4,
                  "molecule": 20}


@dataclasses.dataclass
class LoweringSpec:
    name: str
    fn: Callable
    args: Tuple[Any, ...]            # ShapeDtypeStruct pytrees
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    static_info: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _sds(tree, shardings=None):
    """Attach shardings (NamedSharding pytree) to a ShapeDtypeStruct pytree."""
    return tree


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _maybe(axes, dim: int, mesh: Mesh):
    """Return axes if they divide dim, else None (replicate)."""
    size = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple) else (axes,))]))
    return axes if dim % size == 0 else None


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_state_shardings(abstract_state, mesh, quantized: bool):
    p_sh = shr.lm_param_shardings(abstract_state.params, mesh)

    def moment_sharding_tree(abstract_m):
        if not quantized:
            return p_sh
        from repro.optim.optimizer import QTensor

        def leaf_spec(qt, param_sh):
            if not isinstance(qt, QTensor):      # fp32 moment (vector/scalar)
                return param_sh
            # q is layout-preserving (same shape as the param): inherit the
            # param's spec verbatim. Row-wise scale/zero drop the last axis.
            pspec = list(param_sh.spec) + [None] * (qt.q.ndim - len(param_sh.spec))
            pspec = pspec[: qt.q.ndim]
            q_sh = NamedSharding(mesh, P(*pspec))
            s_sh = NamedSharding(mesh, P(*pspec[: qt.scale.ndim]))
            return QTensor(q=q_sh, scale=s_sh, zero=s_sh,
                           shape=qt.shape, mode=qt.mode)

        return jax.tree.map(leaf_spec, abstract_m, p_sh,
                            is_leaf=lambda x: isinstance(x, QTensor))

    from repro.train.train_step import TrainState
    from repro.optim.optimizer import AdamWState
    return TrainState(
        params=p_sh,
        opt=AdamWState(step=NamedSharding(mesh, P()),
                       m=moment_sharding_tree(abstract_state.opt.m),
                       v=moment_sharding_tree(abstract_state.opt.v)))


def lm_cell(arch_id: str, shape_name: str, shape: Dict, mesh: Mesh) -> LoweringSpec:
    mod = registry.get(arch_id)
    cfg: TransformerConfig = mod.config()
    dp = shr.data_axes(mesh)
    B, S = shape["global_batch"], shape["seq_len"]
    kind = shape["kind"]

    if kind == "train":
        quant = cfg.moe is not None and cfg.moe.n_experts >= 64
        tcfg = TrainConfig(optimizer=AdamWConfig(quantize_moments=quant),
                           warmup_steps=100, total_steps=10_000)
        abstract_state = jax.eval_shape(
            lambda k: make_train_state(init_params(k, cfg), tcfg), KEY)
        state_sh = _lm_state_shardings(abstract_state, mesh, quant)
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        batch_sh = {"tokens": _ns(mesh, dp, None), "labels": _ns(mesh, dp, None)}
        step = make_train_step(lambda p, b: lm_loss(p, b, cfg), tcfg)
        metrics_sh = {"loss": _ns(mesh), "grad_norm": _ns(mesh), "lr_scale": _ns(mesh)}
        return LoweringSpec(
            name=f"{arch_id}:{shape_name}", fn=step,
            args=(abstract_state, batch),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, metrics_sh),
            donate_argnums=(0,),
            static_info=dict(kind="train", tokens=B * S,
                             quantized_moments=quant))

    abstract_params = jax.eval_shape(lambda k: init_params(k, cfg), KEY)
    p_sh = shr.lm_param_shardings(abstract_params, mesh)

    if kind == "prefill":
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        fn = lambda p, t: prefill(p, t, cfg)
        return LoweringSpec(
            name=f"{arch_id}:{shape_name}", fn=fn,
            args=(abstract_params, tokens),
            in_shardings=(p_sh, _ns(mesh, dp, None)),
            out_shardings=_ns(mesh, dp, _maybe(("model",), cfg.vocab, mesh)),
            static_info=dict(kind="prefill", tokens=B * S))

    # decode: one token step against an S-long cache
    abstract_cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    b_ax = _maybe(dp, B, mesh)
    if b_ax is None:
        # batch=1 long-context: shard the sequence over every axis instead
        s_ax = _maybe(tuple(mesh.axis_names), S, mesh) or _maybe(("model",), S, mesh)
    else:
        s_ax = _maybe(("model",), S, mesh)
    if cfg.mla is not None:
        cache_sh = (_ns(mesh, None, b_ax, s_ax, None),
                    _ns(mesh, None, b_ax, s_ax, None))
    else:
        cache_sh = (_ns(mesh, None, b_ax, s_ax, None, None),
                    _ns(mesh, None, b_ax, s_ax, None, None))
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    fn = lambda p, t, c, i: decode_step(p, t, c, i, cfg)
    logits_sh = _ns(mesh, b_ax, None, _maybe(("model",), cfg.vocab, mesh)
                    if b_ax != ("model",) else None)
    return LoweringSpec(
        name=f"{arch_id}:{shape_name}", fn=fn,
        args=(abstract_params, token, abstract_cache, index),
        in_shardings=(p_sh, _ns(mesh, b_ax, None), cache_sh, _ns(mesh)),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(2,),
        static_info=dict(kind="decode", tokens=B, cache_len=S))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_model(arch_id: str, shape_name: str, shape: Dict):
    mod = registry.get(arch_id)
    kind = shape["kind"]
    readout = "sum" if kind == "graphs" else "none"
    n_out = shape.get("n_classes", shape.get("n_out", 1))
    d_in = shape["d_feat"]
    if arch_id == "pna":
        cfg = dataclasses.replace(mod.config(d_in=d_in, n_out=n_out,
                                             readout=readout), remat=True)
        return cfg, pna_init, pna_forward
    if arch_id == "gatedgcn":
        cfg = dataclasses.replace(mod.config(d_in=d_in, n_out=n_out,
                                             readout=readout), remat=True)
        return cfg, gatedgcn_init, gatedgcn_forward
    if arch_id == "gin-tu":
        cfg = dataclasses.replace(mod.config(d_in=d_in, n_out=n_out,
                                             readout=readout), remat=True)
        return cfg, gin_init, gin_forward
    if arch_id == "dimenet":
        cfg = dataclasses.replace(mod.config(d_in=d_in, n_out=n_out,
                                             readout=readout), remat=True)
        return cfg, dimenet_init, dimenet_forward
    raise KeyError(arch_id)


def _gnn_sizes(shape: Dict, mesh: Mesh) -> Tuple[int, int, int]:
    """(n_pad, e_pad_directed, n_graphs) — padded to divide the mesh."""
    total = int(np.prod(list(mesh.shape.values())))
    unit = max(total, 512)
    kind = shape["kind"]
    if kind == "full":
        n = _round_up(shape["n_nodes"], unit)
        e = _round_up(2 * shape["n_edges"], unit)
        return n, e, 1
    if kind == "minibatch":
        batch = shape["batch_nodes"]
        n, e = batch, 0
        cur = batch
        for f in shape["fanout"]:
            e += cur * f
            cur += cur * f
            n = cur
        return _round_up(n, unit), _round_up(e, unit), 1
    if kind == "graphs":
        b = shape["batch"]
        n = _round_up(b * shape["n_nodes"], unit)
        e = _round_up(b * 2 * shape["n_edges"], unit)
        return n, e, b
    raise ValueError(kind)


def gnn_cell(arch_id: str, shape_name: str, shape: Dict, mesh: Mesh) -> LoweringSpec:
    cfg, init_fn, fwd_fn = _gnn_model(arch_id, shape_name, shape)
    n_pad, e_pad, n_graphs = _gnn_sizes(shape, mesh)
    all_ax = tuple(mesh.axis_names)
    node_s = _ns(mesh, all_ax)
    node_s2 = _ns(mesh, all_ax, None)
    edge_s = _ns(mesh, all_ax)

    f32, i32, b8 = jnp.float32, jnp.int32, jnp.bool_
    batch = GraphBatch(
        node_feat=jax.ShapeDtypeStruct((n_pad, shape["d_feat"]), f32),
        src=jax.ShapeDtypeStruct((e_pad,), i32),
        dst=jax.ShapeDtypeStruct((e_pad,), i32),
        node_mask=jax.ShapeDtypeStruct((n_pad,), b8),
        edge_mask=jax.ShapeDtypeStruct((e_pad,), b8),
        graph_ids=jax.ShapeDtypeStruct((n_pad,), i32),
        n_graphs=n_graphs,
        labels=jax.ShapeDtypeStruct((n_pad,), i32),
    )
    batch_sh = GraphBatch(
        node_feat=node_s2, src=edge_s, dst=edge_s, node_mask=node_s,
        edge_mask=edge_s, graph_ids=node_s, n_graphs=n_graphs, labels=node_s)

    is_graph_task = shape["kind"] == "graphs"

    if arch_id == "dimenet":
        t_cap = _round_up(e_pad * TRIPLET_BUDGET[shape_name],
                          int(np.prod(list(mesh.shape.values()))))
        trip = TripletBatch(
            edge_src=jax.ShapeDtypeStruct((e_pad,), i32),
            edge_dst=jax.ShapeDtypeStruct((e_pad,), i32),
            edge_mask=jax.ShapeDtypeStruct((e_pad,), b8),
            trip_in=jax.ShapeDtypeStruct((t_cap,), i32),
            trip_out=jax.ShapeDtypeStruct((t_cap,), i32),
            trip_mask=jax.ShapeDtypeStruct((t_cap,), b8))
        trip_sh = TripletBatch(edge_src=edge_s, edge_dst=edge_s,
                               edge_mask=edge_s, trip_in=edge_s,
                               trip_out=edge_s, trip_mask=edge_s)
        positions = jax.ShapeDtypeStruct((n_pad, 3), f32)
        glabels = jax.ShapeDtypeStruct((n_graphs,), f32)

        def loss_fn(params, b, pos, tr, glab):
            out = dimenet_forward(params, b.node_feat, pos, tr, b.node_mask,
                                  b.graph_ids, n_graphs, cfg)
            if is_graph_task:
                return graph_regression_loss(out, glab)
            return node_classification_loss(out, b)

        tcfg = TrainConfig(optimizer=AdamWConfig())
        abstract_state = jax.eval_shape(
            lambda k: make_train_state(init_fn(k, cfg), tcfg), KEY)
        repl = shr.like_tree(abstract_state, _ns(mesh))

        def train_step(state, b, pos, tr, glab):
            from repro.train.train_step import TrainState
            from repro.optim import apply_updates, global_norm, warmup_cosine
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, b, pos, tr, glab))(state.params)
            lr = warmup_cosine(state.opt.step, 100, 10_000)
            new_p, new_opt = apply_updates(state.params, grads, state.opt,
                                           tcfg.optimizer, lr)
            return TrainState(new_p, new_opt), loss

        return LoweringSpec(
            name=f"{arch_id}:{shape_name}", fn=train_step,
            args=(abstract_state, batch, positions, trip, glabels),
            in_shardings=(repl, batch_sh, node_s2, trip_sh, _ns(mesh)),
            out_shardings=(repl, _ns(mesh)),
            donate_argnums=(0,),
            static_info=dict(kind="gnn_train", n=n_pad, e=e_pad, t=t_cap))

    def loss_fn(params, b, glab):
        out = fwd_fn(params, b, cfg)
        if is_graph_task:
            return graph_regression_loss(out, glab)
        return node_classification_loss(out, b)

    tcfg = TrainConfig(optimizer=AdamWConfig())
    abstract_state = jax.eval_shape(
        lambda k: make_train_state(init_fn(k, cfg), tcfg), KEY)
    repl = shr.like_tree(abstract_state, _ns(mesh))
    glabels = jax.ShapeDtypeStruct((n_graphs,), f32)

    def train_step(state, b, glab):
        from repro.train.train_step import TrainState
        from repro.optim import apply_updates, warmup_cosine
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, b, glab))(state.params)
        lr = warmup_cosine(state.opt.step, 100, 10_000)
        new_p, new_opt = apply_updates(state.params, grads, state.opt,
                                       tcfg.optimizer, lr)
        return TrainState(new_p, new_opt), loss

    return LoweringSpec(
        name=f"{arch_id}:{shape_name}", fn=train_step,
        args=(abstract_state, batch, glabels),
        in_shardings=(repl, batch_sh, _ns(mesh)),
        out_shardings=(repl, _ns(mesh)),
        donate_argnums=(0,),
        static_info=dict(kind="gnn_train", n=n_pad, e=e_pad))


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def _recsys_batch(cfg, B: int, mesh: Mesh, dp):
    i32, f32 = jnp.int32, jnp.float32
    batch, batch_sh = {}, {}
    bs = _ns(mesh, _maybe(dp, B, mesh))
    bs2 = _ns(mesh, _maybe(dp, B, mesh), None)
    for f in cfg.user_features:
        if f.n_hot == 1:
            batch[f.name] = jax.ShapeDtypeStruct((B,), i32)
            batch_sh[f.name] = bs
        else:
            batch[f.name] = jax.ShapeDtypeStruct((B, f.n_hot), i32)
            batch_sh[f.name] = bs2
    for f in cfg.item_features:
        batch[f.name] = jax.ShapeDtypeStruct((B,), i32)
        batch_sh[f.name] = bs
    batch["user_dense"] = jax.ShapeDtypeStruct((B, cfg.n_dense_user), f32)
    batch["item_dense"] = jax.ShapeDtypeStruct((B, cfg.n_dense_item), f32)
    batch_sh["user_dense"] = bs2
    batch_sh["item_dense"] = bs2
    return batch, batch_sh


def _recsys_param_shardings(abstract_params, mesh):
    def spec(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
        if "tables" in names and leaf.ndim == 2:
            ax = _maybe(("model",), leaf.shape[0], mesh)
            return NamedSharding(mesh, P(ax, None))
        return NamedSharding(mesh, P())
    flat = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = [spec(kp, leaf) for kp, leaf in flat[0]]
    return jax.tree.unflatten(jax.tree.structure(abstract_params), specs)


def recsys_cell(arch_id: str, shape_name: str, shape: Dict, mesh: Mesh
                ) -> LoweringSpec:
    mod = registry.get(arch_id)
    cfg = mod.config()
    dp = shr.data_axes(mesh)
    kind = shape["kind"]
    abstract_params = jax.eval_shape(lambda k: rs.init_params(k, cfg), KEY)
    p_sh = _recsys_param_shardings(abstract_params, mesh)

    if kind == "train":
        B = shape["batch"]
        batch, batch_sh = _recsys_batch(cfg, B, mesh, dp)
        batch["item_logq"] = jax.ShapeDtypeStruct((B,), jnp.float32)
        batch_sh["item_logq"] = _ns(mesh, _maybe(dp, B, mesh))
        tcfg = TrainConfig(optimizer=AdamWConfig())
        abstract_state = jax.eval_shape(
            lambda k: make_train_state(rs.init_params(k, cfg), tcfg), KEY)
        from repro.train.train_step import TrainState
        from repro.optim.optimizer import AdamWState
        state_sh = TrainState(params=p_sh,
                              opt=AdamWState(step=_ns(mesh), m=p_sh, v=p_sh))
        step = make_train_step(lambda p, b: rs.sampled_softmax_loss(p, b, cfg),
                               tcfg)
        metrics_sh = {"loss": _ns(mesh), "grad_norm": _ns(mesh),
                      "lr_scale": _ns(mesh)}
        return LoweringSpec(
            name=f"{arch_id}:{shape_name}", fn=step,
            args=(abstract_state, batch),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, metrics_sh),
            donate_argnums=(0,),
            static_info=dict(kind="train", batch=B))

    if kind == "serve":
        B = shape["batch"]
        batch, batch_sh = _recsys_batch(cfg, B, mesh, dp)
        fn = lambda p, b: rs.score_pairs(p, b, cfg)
        return LoweringSpec(
            name=f"{arch_id}:{shape_name}", fn=fn,
            args=(abstract_params, batch),
            in_shardings=(p_sh, batch_sh),
            out_shardings=_ns(mesh, _maybe(dp, B, mesh)),
            static_info=dict(kind="serve", batch=B))

    # retrieval: 1 user vs N candidates
    N = shape["n_candidates"]
    cand_ax = _maybe(("data",), N, mesh)
    i32, f32 = jnp.int32, jnp.float32
    batch = {}
    batch_sh = {}
    for f in cfg.user_features:
        shp = (1,) if f.n_hot == 1 else (1, f.n_hot)
        batch[f.name] = jax.ShapeDtypeStruct(shp, i32)
        batch_sh[f.name] = _ns(mesh, *([None] * len(shp)))
    for f in cfg.item_features:
        batch[f.name] = jax.ShapeDtypeStruct((N,), i32)
        batch_sh[f.name] = _ns(mesh, cand_ax)
    batch["user_dense"] = jax.ShapeDtypeStruct((1, cfg.n_dense_user), f32)
    batch["item_dense"] = jax.ShapeDtypeStruct((N, cfg.n_dense_item), f32)
    batch_sh["user_dense"] = _ns(mesh, None, None)
    batch_sh["item_dense"] = _ns(mesh, cand_ax, None)
    fn = lambda p, b: tuple(rs.retrieval_topk(p, b, cfg, k=100))
    return LoweringSpec(
        name=f"{arch_id}:{shape_name}", fn=fn,
        args=(abstract_params, batch),
        in_shardings=(p_sh, batch_sh),
        out_shardings=(_ns(mesh), _ns(mesh)),
        static_info=dict(kind="retrieval", candidates=N))


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def build_cell(cell: Cell, mesh: Mesh) -> LoweringSpec:
    shr.set_activation_mesh(mesh)      # activation constraints trace with mesh
    if cell.family == "lm":
        return lm_cell(cell.arch_id, cell.shape_name, cell.shape, mesh)
    if cell.family == "gnn":
        return gnn_cell(cell.arch_id, cell.shape_name, cell.shape, mesh)
    if cell.family == "recsys":
        return recsys_cell(cell.arch_id, cell.shape_name, cell.shape, mesh)
    raise ValueError(cell.family)
