"""Deterministic synthetic data pipelines for every model family.

Seeded, stateless-per-step generation (batch i is a pure function of
(seed, i)) so a restarted trainer resumes mid-stream with no data skew —
the data-side half of fault tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np
import jax.numpy as jnp


# ----------------------------------------------------------------- language
@dataclasses.dataclass
class TokenStream:
    """Zipf-distributed synthetic token stream with next-token labels."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        z = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        toks = (z % (self.vocab - 2)).astype(np.int32) + 1
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:]),
                "mask": jnp.ones((self.batch, self.seq_len), jnp.float32)}

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1


# ------------------------------------------------------------------- graphs
@dataclasses.dataclass
class NodeLabelTask:
    """Synthetic node labels correlated with graph structure (community-ish)."""

    n_classes: int
    seed: int = 0

    def labels_for(self, n_cap: int, assignment_like: Optional[np.ndarray] = None
                   ) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        if assignment_like is not None:
            base = assignment_like % self.n_classes
            flip = rng.random(n_cap) < 0.1
            noise = rng.integers(0, self.n_classes, n_cap)
            return np.where(flip, noise, base).astype(np.int32)
        return rng.integers(0, self.n_classes, n_cap).astype(np.int32)


def node_features(n_cap: int, d_feat: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(scale=1.0, size=(n_cap, d_feat)).astype(np.float32)


# ------------------------------------------------------------------- recsys
@dataclasses.dataclass
class RecsysStream:
    """Synthetic interaction batches for the two-tower model."""

    cfg: object                     # TwoTowerConfig
    batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        out: Dict[str, jnp.ndarray] = {}
        for f in self.cfg.user_features:
            shape = (self.batch,) if f.n_hot == 1 else (self.batch, f.n_hot)
            idx = rng.zipf(1.2, size=shape) % f.vocab
            if f.n_hot > 1:   # ragged bags: mask a random suffix
                keep = rng.integers(1, f.n_hot + 1, size=(self.batch, 1))
                idx = np.where(np.arange(f.n_hot)[None, :] < keep, idx, -1)
            out[f.name] = jnp.asarray(idx.astype(np.int32))
        for f in self.cfg.item_features:
            idx = rng.zipf(1.2, size=(self.batch,)) % f.vocab
            out[f.name] = jnp.asarray(idx.astype(np.int32))
        out["user_dense"] = jnp.asarray(
            rng.normal(size=(self.batch, self.cfg.n_dense_user)).astype(np.float32))
        out["item_dense"] = jnp.asarray(
            rng.normal(size=(self.batch, self.cfg.n_dense_item)).astype(np.float32))
        # logQ correction: zipf sampling probability of each in-batch item
        item_ids = np.asarray(out["item_id"])
        q = 1.0 / np.maximum(item_ids.astype(np.float64) + 1, 1) ** 1.2
        out["item_logq"] = jnp.asarray(np.log(q / q.sum()).astype(np.float32))
        return out
