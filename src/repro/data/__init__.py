from repro.data.pipeline import (NodeLabelTask, RecsysStream, TokenStream,
                                 node_features)

__all__ = ["NodeLabelTask", "RecsysStream", "TokenStream", "node_features"]
