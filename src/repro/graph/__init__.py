from repro.graph.structure import (Graph, GraphDelta, apply_delta, cut_edges,
                                   cut_ratio, from_edges, to_csr)
from repro.graph import generators

__all__ = ["Graph", "GraphDelta", "apply_delta", "cut_edges", "cut_ratio",
           "from_edges", "to_csr", "generators"]
