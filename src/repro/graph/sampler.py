"""Fanout neighbour sampler for minibatch GNN training (GraphSAGE-style).

``minibatch_lg`` (Reddit-scale: 233k nodes / 115M edges, batch 1024,
fanout 15-10) requires a real sampler: uniform without replacement per hop,
CSR-backed, padded to static shapes so the sampled block jits.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Tuple

import numpy as np


class SampledBlock(NamedTuple):
    """One sampled computation block, fixed shapes.

    node_ids:  (n_max,) global ids of all nodes in the block (seeds first),
               -1 padding
    node_mask: (n_max,) validity
    edge_src / edge_dst: (e_max,) indices *into node_ids* (message flows
               src -> dst), -1 padding
    edge_mask: (e_max,)
    seed_count: number of valid seeds (== batch unless graph exhausted)
    """

    node_ids: np.ndarray
    node_mask: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    seed_count: int


@dataclasses.dataclass
class NeighbourSampler:
    """Uniform fanout sampler over a CSR graph."""

    indptr: np.ndarray
    indices: np.ndarray
    fanouts: Tuple[int, ...]          # e.g. (15, 10)
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def block_caps(self, batch: int) -> Tuple[int, int]:
        """Static (n_max, e_max) for a given seed batch size."""
        n = batch
        e = 0
        for f in self.fanouts:
            e += n * f
            n += n * f
        return n, e

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        n_max, e_max = self.block_caps(len(seeds))
        node_ids = np.full(n_max, -1, np.int64)
        node_pos = {}                      # global id -> block slot
        for i, s in enumerate(seeds):
            node_ids[i] = s
            node_pos[int(s)] = i
        n_count = len(seeds)
        e_src = np.full(e_max, -1, np.int32)
        e_dst = np.full(e_max, -1, np.int32)
        e_count = 0
        frontier = list(range(len(seeds)))
        for f in self.fanouts:
            nxt: List[int] = []
            for slot in frontier:
                v = int(node_ids[slot])
                lo, hi = self.indptr[v], self.indptr[v + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = min(f, deg)
                picks = self._rng.choice(self.indices[lo:hi], size=take,
                                         replace=False)
                for w in picks:
                    w = int(w)
                    ws = node_pos.get(w)
                    if ws is None:
                        if n_count >= n_max:
                            continue
                        ws = n_count
                        node_ids[ws] = w
                        node_pos[w] = ws
                        n_count += 1
                        nxt.append(ws)
                    if e_count < e_max:
                        e_src[e_count] = ws          # message: neighbour -> seed side
                        e_dst[e_count] = slot
                        e_count += 1
            frontier = nxt
        return SampledBlock(
            node_ids=node_ids,
            node_mask=node_ids >= 0,
            edge_src=e_src,
            edge_dst=e_dst,
            edge_mask=e_src >= 0,
            seed_count=len(seeds),
        )

    def batches(self, num_nodes: int, batch: int, num_batches: int):
        for _ in range(num_batches):
            seeds = self._rng.choice(num_nodes, size=batch, replace=False)
            yield self.sample(seeds)
