"""Synthetic graph generators matching the paper's evaluation datasets (§5.1).

* ``fem_cube``      — 3-D regular cubic mesh ("heart cell" FEM, Ten Tusscher model graphs)
* ``power_law``     — Holme–Kim-style powerlaw-cluster graph (paper: networkX
                      ``powerlaw_cluster_graph`` with D = log|V|, p = 0.1)
* ``forest_fire``   — Leskovec forest-fire growth model, used by the paper to
                      inject dynamic changes ("burst of new vertices ... 1,2,5,10%")

All generators are host-side numpy (deterministic via seed) and return padded
``Graph`` objects ready for the jit'd adaptive loop.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.graph.structure import Graph, GraphDelta, from_edges, to_csr


def fem_cube(side: int, n_cap: Optional[int] = None, e_cap: Optional[int] = None) -> Graph:
    """Regular 3-D cubic lattice with 6-neighbourhood; |V| = side**3."""
    n = side ** 3
    ids = np.arange(n, dtype=np.int64)
    x = ids % side
    y = (ids // side) % side
    z = ids // (side * side)
    srcs: List[np.ndarray] = []
    dsts: List[np.ndarray] = []
    # +x, +y, +z neighbours (each undirected edge emitted once)
    m = x + 1 < side
    srcs.append(ids[m]); dsts.append(ids[m] + 1)
    m = y + 1 < side
    srcs.append(ids[m]); dsts.append(ids[m] + side)
    m = z + 1 < side
    srcs.append(ids[m]); dsts.append(ids[m] + side * side)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    return from_edges(src, dst, n, n_cap=n_cap, e_cap=e_cap)


def fem_grid2d(side: int, n_cap: Optional[int] = None, e_cap: Optional[int] = None) -> Graph:
    """2-D lattice (stand-in for 3elt/4elt style FEM meshes)."""
    n = side * side
    ids = np.arange(n, dtype=np.int64)
    x = ids % side
    y = ids // side
    srcs, dsts = [], []
    m = x + 1 < side
    srcs.append(ids[m]); dsts.append(ids[m] + 1)
    m = y + 1 < side
    srcs.append(ids[m]); dsts.append(ids[m] + side)
    return from_edges(np.concatenate(srcs), np.concatenate(dsts), n, n_cap=n_cap, e_cap=e_cap)


def cell_grid(rows: int, cols: int, diagonals: bool = True,
              n_cap: Optional[int] = None, e_cap: Optional[int] = None) -> Graph:
    """Cell-tower backbone: rows×cols grid of towers, edges between towers
    whose coverage areas overlap (4-neighbourhood, plus diagonals by default
    for the hexagonal-ish overlap real deployments have).

    Used by the mobile/cellular scenario (paper §5.3's operator use case):
    the tower adjacency defines which cells users can roam between and which
    cross-cell calls are "nearby".
    """
    n = rows * cols
    ids = np.arange(n, dtype=np.int64)
    x = ids % cols
    y = ids // cols
    srcs: List[np.ndarray] = []
    dsts: List[np.ndarray] = []
    m = x + 1 < cols
    srcs.append(ids[m]); dsts.append(ids[m] + 1)
    m = y + 1 < rows
    srcs.append(ids[m]); dsts.append(ids[m] + cols)
    if diagonals:
        m = (x + 1 < cols) & (y + 1 < rows)
        srcs.append(ids[m]); dsts.append(ids[m] + cols + 1)
        m = (x > 0) & (y + 1 < rows)
        srcs.append(ids[m]); dsts.append(ids[m] + cols - 1)
    return from_edges(np.concatenate(srcs), np.concatenate(dsts), n,
                      n_cap=n_cap, e_cap=e_cap)


def power_law(n: int, seed: int = 0, m: Optional[int] = None, p: float = 0.1,
              n_cap: Optional[int] = None, e_cap: Optional[int] = None) -> Graph:
    """Holme–Kim powerlaw-cluster graph (paper: D = log|V|, rewiring p = 0.1).

    Each new node attaches ``m`` edges by preferential attachment; with
    probability ``p`` the next edge is a triad-closing edge instead.
    """
    if m is None:
        m = max(1, int(round(np.log(max(n, 3)))) // 2)  # avg degree ≈ log|V|
    rng = np.random.default_rng(seed)
    # repeated-nodes list for preferential attachment
    targets = list(range(m))
    repeated: List[int] = []
    src_l: List[int] = []
    dst_l: List[int] = []
    for v in range(m, n):
        chosen = set()
        t = int(targets[rng.integers(len(targets))]) if targets else 0
        for _ in range(m):
            # triad closure with prob p: link to a neighbour of t
            if repeated and rng.random() < p and len(chosen) > 0:
                nbrs = [d for s, d in zip(src_l[-3 * m:], dst_l[-3 * m:]) if s == t]
                cand = int(nbrs[rng.integers(len(nbrs))]) if nbrs else int(repeated[rng.integers(len(repeated))])
            else:
                cand = int(repeated[rng.integers(len(repeated))]) if repeated else int(rng.integers(max(v, 1)))
            tries = 0
            while (cand in chosen or cand == v) and tries < 8:
                cand = int(rng.integers(v))
                tries += 1
            if cand != v and cand not in chosen:
                chosen.add(cand)
                src_l.append(v)
                dst_l.append(cand)
        repeated.extend(chosen)
        repeated.append(v)
        targets = repeated
    src = np.asarray(src_l, dtype=np.int64)
    dst = np.asarray(dst_l, dtype=np.int64)
    return from_edges(src, dst, n, n_cap=n_cap, e_cap=e_cap)


def chung_lu(n: int, avg_degree: float, seed: int = 0, gamma: float = 2.2,
             n_cap: Optional[int] = None, e_cap: Optional[int] = None) -> Graph:
    """Fast vectorised power-law graph (Chung–Lu model): edge (u,v) drawn
    with probability ∝ w_u·w_v, weights Pareto(γ). Millions of edges in
    seconds — used for partition-quality measurements at ogb_products scale.
    """
    rng = np.random.default_rng(seed)
    w = rng.pareto(gamma - 1.0, size=n) + 1.0
    p = w / w.sum()
    m = int(n * avg_degree / 2 * 1.15)           # oversample for dedup losses
    src = rng.choice(n, size=m, p=p)
    dst = rng.choice(n, size=m, p=p)
    return from_edges(src, dst, n, n_cap=n_cap, e_cap=e_cap)


def forest_fire_delta(graph: Graph, growth_frac: float, seed: int = 0,
                      fwd_prob: float = 0.35, a_cap: Optional[int] = None) -> GraphDelta:
    """Forest-fire growth (Leskovec et al.) sized to ``growth_frac`` of |V|.

    New vertices pick an ambassador, "burn" a geometric number of its
    neighbours, and link to burned vertices — producing the bursty,
    preferential-attachment-like growth the paper injects (Fig. 7, §5.3).
    Returns a GraphDelta; apply with ``structure.apply_delta``.
    """
    rng = np.random.default_rng(seed)
    n_now = int(np.asarray(graph.num_nodes))
    n_new = max(1, int(round(n_now * growth_frac)))
    indptr, indices = to_csr(graph)
    alive = np.flatnonzero(np.asarray(graph.node_mask))
    add_src: List[int] = []
    add_dst: List[int] = []
    next_id = int(alive.max()) + 1 if alive.size else 0
    for i in range(n_new):
        v = next_id + i
        if v >= graph.n_cap:
            break
        amb = int(alive[rng.integers(alive.size)])
        add_src.append(v); add_dst.append(amb)
        # burn outward
        frontier = [amb]
        burned = {amb}
        depth = 0
        while frontier and depth < 3:
            nxt: List[int] = []
            for u in frontier:
                nbrs = indices[indptr[u]:indptr[u + 1]]
                if nbrs.size == 0:
                    continue
                k = rng.geometric(1.0 - fwd_prob) - 1
                k = int(min(k, nbrs.size))
                if k <= 0:
                    continue
                picks = rng.choice(nbrs, size=k, replace=False)
                for w in picks:
                    w = int(w)
                    if w not in burned:
                        burned.add(w)
                        add_src.append(v); add_dst.append(w)
                        nxt.append(w)
            frontier = nxt
            depth += 1
    import jax.numpy as jnp
    a = len(add_src)
    cap = int(a_cap if a_cap is not None else a)
    cap = max(cap, a)
    s = np.full((cap,), -1, dtype=np.int32); s[:a] = add_src
    d = np.full((cap,), -1, dtype=np.int32); d[:a] = add_dst
    m = np.zeros((cap,), dtype=bool); m[:a] = True
    return GraphDelta(add_src=jnp.asarray(s), add_dst=jnp.asarray(d),
                      add_mask=jnp.asarray(m),
                      del_nodes=jnp.full((1,), -1, jnp.int32),
                      del_mask=jnp.zeros((1,), bool))


def sliding_window_stream(n_users: int, n_events: int, window: int, seed: int = 0
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """CDR-style call stream: (time, caller, callee) with power-law activity.

    Models the paper's mobile-operator use case (§5.3): a sliding window over
    the stream adds edges for new calls and expires inactive ones.
    """
    rng = np.random.default_rng(seed)
    # zipf-ish caller activity
    pop = rng.zipf(1.8, size=n_users).astype(np.float64)
    pop = pop / pop.sum()
    callers = rng.choice(n_users, size=n_events, p=pop)
    # callee: mixture of social circle (nearby id) and random
    circle = (callers + rng.integers(1, 50, size=n_events)) % n_users
    rnd = rng.integers(0, n_users, size=n_events)
    take_circle = rng.random(n_events) < 0.8
    callees = np.where(take_circle, circle, rnd)
    times = np.sort(rng.integers(0, window * 8, size=n_events))
    keep = callers != callees
    return times[keep], callers[keep].astype(np.int64), callees[keep].astype(np.int64)
